//! Fig 1(a)/(b) + Appendix Figs 5/6/7 reproduction: the two low-rankness
//! properties that motivate TeZO.
//!
//! Using the FO-gradient artifact (`fo_valgrad`) during a short fine-tune:
//!   (a) model dimension — top-k singular values of individual layer
//!       gradients (Fig 1a / Fig 5);
//!   (b) temporal dimension — pairwise cosine similarity of *normalized*
//!       gradients across steps (Fig 1b / Fig 6), plus the singular value
//!       mass of the stacked gradient matrix [g_0 ... g_T];
//!   (c) weight-rank vs gradient-rank correlation (Fig 7 — the Eq. 7
//!       justification).
//!
//! ```sh
//! cargo run --release --example rank_analysis [--config tiny] [--steps 40]
//! ```
//! Writes out/fig1a_spectra.csv, out/fig1b_cosine.csv, out/fig7_ranks.csv.

use anyhow::Result;

use tezo::clix::{self, ArgSpec};
use tezo::config::{Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::exec::to_vec_f32;
use tezo::runtime::{ArgValue, ParamStore, Runtime};
use tezo::tensor::{stats, svd, Matrix};

const SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("steps", "40", "fine-tune steps to observe"),
    ArgSpec::opt("track", "block0.attn.wo,block1.ffn.w2", "layers to analyze"),
    ArgSpec::opt("topk", "24", "singular values to record"),
    ArgSpec::opt("out", "out", "output directory"),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = clix::parse(&argv, SPECS)?;
    let config = args.get_str("config")?;
    let steps = args.get_usize("steps")?;
    let topk = args.get_usize("topk")?;
    let tracked = args.get_list("track")?;
    let out_dir = args.get_str("out")?.to_string();
    std::fs::create_dir_all(&out_dir)?;

    let rt = Runtime::open_config(config)?;
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 64);

    // we advance training with FO-Adam (the paper observes FO gradients),
    // capturing the gradient of the tracked layers each step
    let mut cfg = TrainConfig::with_preset(Method::FoAdam, config);
    cfg.steps = 1; // stepped manually below

    let tracked_idx: Vec<usize> = tracked.iter()
        .map(|n| params.index_of(n).expect("tracked layer"))
        .collect();
    let mut grad_history: Vec<Vec<Vec<f32>>> = vec![Vec::new(); tracked.len()];
    let mut spectra: Vec<Vec<Vec<f64>>> = vec![Vec::new(); tracked.len()];

    for step in 0..steps as u64 {
        let batch = builder.train_batch(0, step);
        // grads at current params
        let out = rt.call("fo_valgrad")?
            .bufs(params.bufs())?
            .arg(ArgValue::I32(&batch.tokens))?
            .arg(ArgValue::I32(&batch.targets))?
            .arg(ArgValue::F32(&batch.mask))?
            .run()?;
        for (t, &pi) in tracked_idx.iter().enumerate() {
            let g = to_vec_f32(&out[1 + pi])?;
            let e = &params.entries[pi];
            let gm = Matrix::from_vec(e.shape[0], e.shape[1], g.clone())?;
            spectra[t].push(svd::top_singular_values(&gm, topk, step)?);
            // normalized flat gradient for the temporal analysis
            let norm = gm.fro_norm() as f32;
            grad_history[t].push(g.iter().map(|x| x / norm.max(1e-12)).collect());
        }
        // one FO-Adam step to move along the fine-tuning trajectory
        let mut trainer = Trainer::new(&rt, cfg.clone(),
                                       DataSource::Task(builder.clone()));
        trainer.run(&mut params)?;
        if step % 10 == 0 {
            println!("observed step {step}");
        }
    }

    // ---- Fig 1a: per-step spectra ----------------------------------------
    let mut csv = String::from("layer,step");
    for k in 0..topk {
        csv.push_str(&format!(",sigma{k}"));
    }
    csv.push('\n');
    for (t, name) in tracked.iter().enumerate() {
        for (step, sv) in spectra[t].iter().enumerate() {
            csv.push_str(&format!("{name},{step}"));
            for k in 0..topk {
                csv.push_str(&format!(",{:.6e}", sv.get(k).copied().unwrap_or(0.0)));
            }
            csv.push('\n');
        }
    }
    std::fs::write(format!("{out_dir}/fig1a_spectra.csv"), csv)?;

    // effective rank summary (Fig 1a claim: gradients are low-rank)
    for (t, name) in tracked.iter().enumerate() {
        let sv = &spectra[t][spectra[t].len() / 2];
        let above = sv.iter().filter(|&&s| s > 0.02 * sv[0]).count();
        println!("{name}: {above}/{} singular values above 2% of sigma_max \
                  (paper Fig 5: ~20 of 100)", sv.len());
    }

    // ---- Fig 1b/6: pairwise cosine of normalized gradients ---------------
    let mut csv = String::from("layer,t1,t2,cosine\n");
    for (t, name) in tracked.iter().enumerate() {
        let h = &grad_history[t];
        let mut mean_offdiag = 0.0;
        let mut count = 0usize;
        for i in 0..h.len() {
            for j in 0..h.len() {
                let c = stats::cosine(&h[i], &h[j]);
                csv.push_str(&format!("{name},{i},{j},{c:.4}\n"));
                if i != j {
                    mean_offdiag += c;
                    count += 1;
                }
            }
        }
        println!("{name}: mean off-diagonal gradient cosine {:.3} \
                  (paper Fig 6: high similarity)", mean_offdiag / count as f64);
    }
    std::fs::write(format!("{out_dir}/fig1b_cosine.csv"), csv)?;

    // ---- Fig 7: weight rank vs gradient rank -----------------------------
    let mut csv = String::from("layer,weight_rank,grad_rank\n");
    println!("\nFig 7 — weight rank vs gradient rank (threshold 25%):");
    // one gradient evaluation on the final batch serves every matrix
    let batch = builder.train_batch(0, steps as u64);
    let out = rt.call("fo_valgrad")?
        .bufs(params.bufs())?
        .arg(ArgValue::I32(&batch.tokens))?
        .arg(ArgValue::I32(&batch.targets))?
        .arg(ArgValue::F32(&batch.mask))?
        .run()?;
    for p in rt.manifest.matrix_params() {
        let w = params.fetch_matrix(&p.name)?;
        let wr = svd::rank_at_threshold(&w, 0.25, 64, 1)?;
        let pi = params.index_of(&p.name)?;
        let g = to_vec_f32(&out[1 + pi])?;
        let gm = Matrix::from_vec(p.shape[0], p.shape[1], g)?;
        let gr = svd::rank_at_threshold(&gm, 0.25, 64, 2)?;
        csv.push_str(&format!("{},{wr},{gr}\n", p.name));
        println!("  {:24} weight r={wr:3}  grad r={gr:3}", p.name);
    }
    std::fs::write(format!("{out_dir}/fig7_ranks.csv"), csv)?;
    println!("\nwrote {out_dir}/fig1a_spectra.csv, fig1b_cosine.csv, fig7_ranks.csv");
    Ok(())
}
