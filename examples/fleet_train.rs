//! Data-parallel fine-tuning demo: the seed-synchronized fleet vs the
//! single-process trainer on one task, with the communication ledger that
//! is the whole point — per-step traffic is O(workers) scalars while a
//! gradient all-reduce would move the whole parameter set.
//!
//! ```sh
//! cargo run --release --example fleet_train -- --config tiny --workers 4
//! ```

use std::path::PathBuf;

use anyhow::Result;

use tezo::clix::{self, ArgSpec};
use tezo::config::{FleetConfig, Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::{task_job_factory, FleetTrainer};
use tezo::memmodel::comm;
use tezo::runtime::{Manifest, ParamStore, Runtime};

const SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config (artifacts/<config>)"),
    ArgSpec::opt("method", "tezo", "ZO optimizer"),
    ArgSpec::opt("task", "sst2", "synthetic task"),
    ArgSpec::opt("steps", "60", "training steps"),
    ArgSpec::opt("workers", "4", "fleet worker replicas"),
    ArgSpec::opt("seed", "0", "master seed"),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = clix::parse(&argv, SPECS)?;
    let config = args.get_str("config")?;
    let method = Method::parse(args.get_str("method")?)?;
    let workers = args.get_usize("workers")?;
    let task_name = args.get_str("task")?.to_string();
    let seed = args.get_u64("seed")?;

    let mut cfg = TrainConfig::with_preset(method, config);
    cfg.steps = args.get_usize("steps")?;
    cfg.seed = seed;
    let dir: PathBuf = tezo::artifacts_root().join(config);
    let n_params = Manifest::load(&dir)?.config.n_params as u64;

    // --- single-process reference ------------------------------------------
    let rt = Runtime::open(&dir)?;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let spec = tasks::spec_by_name(&task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name:?}"))?;
    let task = Task::new(spec, tok, rt.manifest.config.seq_len, seed);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let plain = Trainer::new(&rt, cfg.clone(), DataSource::Task(builder))
        .run(&mut params)?;
    drop(rt);
    println!("single process : loss {:.4} -> {:.4}  ({:.0} ms/step)",
             plain.metrics.initial_loss_avg(10),
             plain.metrics.final_loss_avg(10),
             plain.metrics.seconds_per_step() * 1e3);

    // --- the fleet ----------------------------------------------------------
    let factory = task_job_factory(task_name, seed, 16, 64, None);
    let mut ft = FleetTrainer::new(FleetConfig::new(workers), cfg.clone(),
                                   dir, factory);
    ft.on_step = Some(Box::new(|step, loss| {
        if step % 20 == 0 {
            println!("  fleet step {step:4}  global loss {loss:.4}");
        }
    }));
    let out = ft.run()?;

    println!("fleet W={workers}     : loss {:.4} -> {:.4}  ({:.0} ms/step)",
             out.metrics.initial_loss_avg(10),
             out.metrics.final_loss_avg(10),
             out.metrics.seconds_per_step() * 1e3);
    if let Some((step, acc)) = out.metrics.evals.last() {
        println!("eval @ step {step}: {:.1}%", acc * 100.0);
    }
    println!("straggler factor {:.3}; fast replicas idled {:.2}s",
             out.fleet.straggler_factor(), out.fleet.straggler_wait_secs());

    let scalar = out.fleet.comm.total_bytes();
    let allreduce = comm::gradient_allreduce_step_bytes(n_params, workers as u64)
        * cfg.steps as u64;
    println!("\n== communication ledger ({} steps, {} workers) ==",
             cfg.steps, workers);
    println!("  scalar sync (this run) : {scalar:>16} bytes");
    println!("  gradient all-reduce    : {allreduce:>16} bytes");
    if workers > 1 {
        println!("  reduction              : {:>15.1e}x",
                 allreduce as f64 / scalar.max(1) as f64);
    }
    Ok(())
}
