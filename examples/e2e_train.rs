//! End-to-end validation driver (DESIGN.md E2E): train an OPTLite LM on a
//! real (synthetic Markov) corpus for a few hundred steps with TeZO-Adam,
//! with MeZO as the reference curve, and report losses + step times +
//! held-out perplexity.
//!
//! This exercises every layer at once: Pallas-kernel HLO (tiny) or fused
//! jnp HLO (small/e2e) compiled by PJRT, the fused two-point step
//! functions, the factorized optimizer state, the seed schedule, the data
//! substrate, metrics, and the memory accounting.
//!
//! ```sh
//! cargo run --release --example e2e_train -- --config small --steps 300
//! ```
//! Writes out/e2e_<config>_<method>.csv; a recorded run lives in
//! EXPERIMENTS.md §E2E.

use anyhow::Result;

use tezo::clix::{self, ArgSpec};
use tezo::config::{Method, TrainConfig};
use tezo::coordinator::eval;
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{BatchBuilder, Corpus, Tokenizer};
use tezo::runtime::{ParamStore, Runtime};

const SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "small", "model config (small ~3.9M, e2e ~92M)"),
    ArgSpec::opt("steps", "300", "training steps"),
    ArgSpec::opt("methods", "tezo-adam,mezo", "methods to run"),
    ArgSpec::opt("seed", "0", "master seed"),
    ArgSpec::opt("eval-n", "16", "held-out sequences for perplexity"),
    ArgSpec::opt("out", "out", "output directory"),
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = clix::parse(&argv, SPECS)?;
    let config = args.get_str("config")?;
    let steps = args.get_usize("steps")?;
    let seed = args.get_u64("seed")?;
    let out_dir = args.get_str("out")?.to_string();
    std::fs::create_dir_all(&out_dir)?;

    let rt = Runtime::open_config(config)?;
    println!("e2e: {} ({:.1}M params), {} steps",
             rt.manifest.config.name,
             rt.manifest.config.n_params as f64 / 1e6, steps);

    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let corpus = Corpus::new(tok.clone(), rt.manifest.config.seq_len, seed ^ 0xC0);
    let batch = rt.manifest.config.batch;

    // held-out eval batches (disjoint index range)
    let eval_corpus = Corpus::new(tok, rt.manifest.config.seq_len, seed ^ 0xC0);
    let eval_batches: Vec<_> = (0..args.get_usize("eval-n")? / batch.max(1) + 1)
        .map(|i| BatchBuilder::corpus_batch(&eval_corpus, batch,
                                            0xEEEE_0000 + seed, 1_000_000 + i as u64))
        .collect();

    for mname in args.get_list("methods")? {
        let method = Method::parse(&mname)?;
        let mut cfg = TrainConfig::with_preset(method, config);
        cfg.steps = steps;
        cfg.seed = seed;
        let mut params = ParamStore::load(&rt.client, &rt.manifest)?;

        let ppl0 = eval::lm_loss(&rt, &params, &eval_batches)?;
        let mut trainer = Trainer::new(&rt, cfg,
            DataSource::Corpus { corpus: corpus.clone(), batch });
        trainer.on_step = Some(Box::new(|step, loss| {
            if step % 25 == 0 {
                println!("  [{mname}] step {step:5}  loss {loss:.4}");
            }
        }));
        let outcome = trainer.run(&mut params)?;
        let ppl1 = eval::lm_loss(&rt, &params, &eval_batches)?;

        println!("\n== {} on {} corpus ==", method.name(), config);
        println!("train loss  : {:.4} -> {:.4}",
                 outcome.metrics.initial_loss_avg(20),
                 outcome.metrics.final_loss_avg(20));
        println!("held-out    : loss {ppl0:.4} -> {ppl1:.4}  \
                  (ppl {:.1} -> {:.1})", ppl0.exp(), ppl1.exp());
        println!("wall        : {:.1}s  ({:.0} ms/step)",
                 outcome.metrics.wall_seconds,
                 outcome.metrics.seconds_per_step() * 1e3);
        for (name, secs, frac) in outcome.metrics.timers.breakdown() {
            println!("  {name:9} {secs:8.2}s  {:5.1}%", frac * 100.0);
        }
        println!("opt state   : {} bytes", outcome.state_bytes);
        println!("sampled     : {} matrix + {} vector elements",
                 outcome.counter.matrix_elements, outcome.counter.vector_elements);
        if outcome.skipped > 0 {
            println!("warning: {} skipped steps", outcome.skipped);
        }
        let path = format!("{out_dir}/e2e_{config}_{}.csv", method.name());
        outcome.metrics.write_loss_csv(std::path::Path::new(&path))?;
        println!("loss curve  -> {path}\n");
    }
    Ok(())
}
