//! Quickstart: fine-tune the tiny model on a synthetic SST-2 with
//! TeZO-Adam, entirely through the public API.
//!
//! ```sh
//! make artifacts          # once: python AOT -> artifacts/tiny
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use tezo::config::{Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::{ParamStore, Runtime};

fn main() -> Result<()> {
    // 1. open the AOT artifacts for a model config (python never runs here)
    let rt = Runtime::open_config("tiny")?;
    println!("model: {} ({} params)", rt.manifest.config.name, rt.manifest.config.n_params);

    // 2. load the initial parameters as device-resident buffers
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;

    // 3. build a few-shot task (k=16 per class, MeZO protocol)
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let label_tokens = task.label_tokens();
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let eval_batches = builder.eval_batches(128);

    // 4. configure TeZO-Adam with the Table-6 presets and train
    let mut cfg = TrainConfig::with_preset(Method::TezoAdam, "tiny");
    cfg.steps = 150;
    cfg.eval_every = 50;
    let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder))
        .with_eval(eval_batches, label_tokens);
    trainer.on_step = Some(Box::new(|step, loss| {
        if step % 25 == 0 {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }));
    let outcome = trainer.run(&mut params)?;

    // 5. inspect the results
    println!("\nloss {:.4} -> {:.4}",
             outcome.metrics.initial_loss_avg(20),
             outcome.metrics.final_loss_avg(20));
    for (step, acc) in &outcome.metrics.evals {
        println!("accuracy @ {step:4}: {:.1}%", acc * 100.0);
    }
    println!("optimizer state: {} bytes (TeZO-Adam keeps only factor panels + tau vectors)",
             outcome.state_bytes);
    Ok(())
}
