//! Fig 4 reproduction: training-loss curves of the ZO-SGD family
//! (MeZO / LOZO / SubZO / TeZO) and the ZO-Adam family (MeZO-Adam /
//! TeZO-Adam) on SST-2 and RTE.
//!
//! The paper's observation under test: the SGD-family curves are nearly
//! identical; the Adam-family curves drop faster and further.
//!
//! ```sh
//! cargo run --release --example compare_optimizers [--config tiny] [--steps 300]
//! ```
//! Writes out/fig4_<task>.csv with one smoothed-loss column per method.

use anyhow::Result;

use tezo::clix::{self, ArgSpec};
use tezo::config::{Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::{ParamStore, Runtime};

const SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("steps", "300", "steps per curve"),
    ArgSpec::opt("tasks", "sst2,rte", "tasks to run"),
    ArgSpec::opt("out", "out", "output directory"),
];

const METHODS: [Method; 6] = [
    Method::Mezo, Method::Lozo, Method::Subzo, Method::Tezo,
    Method::MezoAdam, Method::TezoAdam,
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = clix::parse(&argv, SPECS)?;
    let config = args.get_str("config")?;
    let steps = args.get_usize("steps")?;
    let rt = Runtime::open_config(config)?;

    for tname in args.get_list("tasks")? {
        println!("== {tname} ==");
        let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
        for m in METHODS {
            let mut cfg = TrainConfig::with_preset(m, config);
            cfg.steps = steps;
            let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
            let tok = Tokenizer::new(rt.manifest.config.vocab);
            let task = Task::new(tasks::spec_by_name(&tname).unwrap(), tok,
                                 rt.manifest.config.seq_len, 0);
            let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
            let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder));
            let outcome = trainer.run(&mut params)?;
            println!("  {:10} {:.4} -> {:.4}  ({:.0} ms/step)",
                     m.name(),
                     outcome.metrics.initial_loss_avg(20),
                     outcome.metrics.final_loss_avg(20),
                     outcome.metrics.seconds_per_step() * 1e3);
            curves.push((m.name().to_string(), outcome.metrics.smoothed_losses(0.05)));
        }
        // write CSV
        let mut csv = String::from("step");
        for (name, _) in &curves {
            csv.push(',');
            csv.push_str(name);
        }
        csv.push('\n');
        for t in 0..steps {
            csv.push_str(&format!("{t}"));
            for (_, c) in &curves {
                csv.push_str(&format!(",{:.6}", c.get(t).copied().unwrap_or(f64::NAN)));
            }
            csv.push('\n');
        }
        let dir = args.get_str("out")?;
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/fig4_{tname}.csv");
        std::fs::write(&path, csv)?;
        println!("  curves -> {path}");

        // the Fig-4 claims, checked numerically
        let finals: Vec<(String, f64)> = curves.iter()
            .map(|(n, c)| (n.clone(), *c.last().unwrap()))
            .collect();
        let sgd: Vec<f64> = finals.iter().take(4).map(|(_, l)| *l).collect();
        let adam: Vec<f64> = finals.iter().skip(4).map(|(_, l)| *l).collect();
        let sgd_mean = sgd.iter().sum::<f64>() / sgd.len() as f64;
        let adam_mean = adam.iter().sum::<f64>() / adam.len() as f64;
        println!("  SGD-family final loss {sgd_mean:.4}; Adam-family {adam_mean:.4}  \
                  (paper: Adam family lower)");
    }
    Ok(())
}
