"""L2 step-function correctness: each *_loss_pm / *_update artifact function
must equal a straight-line composition of perturb + forward / update math.

These tests call the *same* python callables that aot.py lowers, so passing
here + the HLO round-trip test in Rust ends the correctness chain.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import zo_steps as zs
from compile.aot import rank_schedule
from compile.configs import get_config
from compile.kernels import ref
from compile.model import (flatten_params, init_params, loss_fn,
                           unflatten_params)

CFG = get_config("tiny")


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, seed=0)
    np_params = {k: np.asarray(v) for k, v in params.items()}
    ranks = rank_schedule(CFG, np_params)
    rng = np.random.default_rng(5)
    b, s, v = CFG.batch, CFG.seq_len, CFG.vocab
    tokens = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    mask = jnp.asarray((rng.random((b, s)) < 0.3).astype(np.float32))
    return params, ranks, (tokens, targets, mask)


def _factors(ranks, seed=3):
    rng = np.random.default_rng(seed)
    us, vs, taus = {}, {}, {}
    for name, (m, n) in CFG.matrix_params():
        r = ranks[name]
        us[name] = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
        vs[name] = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
        taus[name] = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    return us, vs, taus


def _flat(params):
    return list(flatten_params(CFG, params))


def test_fwd_loss_builder_equals_loss_fn(setup):
    params, _, batch = setup
    fn, _, in_desc, _ = zs.build_fwd_loss(CFG)
    got = fn(*_flat(params), *batch)[0]
    want = loss_fn(CFG, params, *batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    assert len(in_desc) == len(CFG.param_specs()) + 3


def test_mezo_loss_pm_symmetry(setup):
    """f+(rho) == f-(−rho) must hold by construction: swapping the sign of
    rho swaps the two outputs."""
    params, _, batch = setup
    fn, _, _, _ = zs.build_mezo_loss_pm(CFG)
    seed = jnp.uint32(7)
    fp, fm = fn(*_flat(params), *batch, seed, jnp.float32(1e-3))
    fp2, fm2 = fn(*_flat(params), *batch, seed, jnp.float32(-1e-3))
    np.testing.assert_allclose(np.asarray(fp), np.asarray(fm2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fm), np.asarray(fp2), rtol=1e-6)


def test_mezo_loss_pm_matches_manual_perturbation(setup):
    """loss_pm(seed, rho) == loss(W + rho z) where z is regenerated exactly
    the way mezo_update_sgd regenerates it (same seed -> same z)."""
    params, _, batch = setup
    fn, _, _, _ = zs.build_mezo_loss_pm(CFG)
    upd, _, _, _ = zs.build_mezo_update_sgd(CFG)
    seed = jnp.uint32(123)
    rho = 1e-2
    fp, fm = fn(*_flat(params), *batch, seed, jnp.float32(rho))
    # recover z via the update with coeff = -1 (W' = W + z)
    out = upd(*_flat(params), seed, jnp.float32(-1.0))
    z = {n: o - params[n] for (n, _), o in zip(CFG.param_specs(), out)}
    pos = {n: params[n] + rho * z[n] for n in params}
    neg = {n: params[n] - rho * z[n] for n in params}
    np.testing.assert_allclose(np.asarray(fp),
                               np.asarray(loss_fn(CFG, pos, *batch)),
                               rtol=5e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fm),
                               np.asarray(loss_fn(CFG, neg, *batch)),
                               rtol=5e-5, atol=1e-5)


def test_tezo_loss_pm_matches_manual(setup):
    params, ranks, batch = setup
    us, vs, taus = _factors(ranks)
    fn, _, _, _ = zs.build_tezo_loss_pm(CFG, ranks)
    mats = CFG.matrix_params()
    args = _flat(params) + [us[n] for n, _ in mats] + [vs[n] for n, _ in mats] \
        + [taus[n] for n, _ in mats] + list(batch) \
        + [jnp.uint32(9), jnp.float32(1e-2)]
    fp, fm = fn(*args)
    # manual: 2D via ref.tezo_perturb, 1D via the same seed-folded normals
    vecz = zs._vector_normals(CFG, jnp.uint32(9))
    pos = dict(params)
    for n, _ in mats:
        pos[n] = ref.tezo_perturb(params[n], us[n], vs[n], taus[n], 1e-2)
    for n, z in vecz.items():
        pos[n] = params[n] + 1e-2 * z
    np.testing.assert_allclose(np.asarray(fp),
                               np.asarray(loss_fn(CFG, pos, *batch)),
                               rtol=5e-5, atol=1e-5)


def test_tezo_update_factor_matches_ref(setup):
    params, ranks, _ = setup
    us, vs, taus = _factors(ranks)
    fn, _, _, _ = zs.build_tezo_update_factor(CFG, ranks)
    mats = CFG.matrix_params()
    seed, coeff = jnp.uint32(4), jnp.float32(0.01)
    args = _flat(params) + [us[n] for n, _ in mats] + [vs[n] for n, _ in mats] \
        + [taus[n] for n, _ in mats] + [seed, coeff]
    out = fn(*args)
    vecz = zs._vector_normals(CFG, seed)
    for (name, shape), o in zip(CFG.param_specs(), out):
        if len(shape) == 2:
            want = ref.tezo_sgd_update(params[name], us[name], vs[name],
                                       taus[name])
        else:
            want = params[name] - 0.01 * vecz[name]
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_tezo_update_adam_matches_ref(setup):
    params, ranks, _ = setup
    us, vs, taus = _factors(ranks)
    tau_v = {n: jnp.abs(t) + 1e-4 for n, t in taus.items()}
    fn, _, _, _ = zs.build_tezo_update_adam(CFG, ranks)
    mats = CFG.matrix_params()
    seed = jnp.uint32(4)
    lr, eps, c1 = jnp.float32(1e-3), jnp.float32(1e-5), jnp.float32(1e-3)
    args = _flat(params) + [us[n] for n, _ in mats] + [vs[n] for n, _ in mats] \
        + [taus[n] for n, _ in mats] + [tau_v[n] for n, _ in mats] \
        + [seed, lr, eps, c1]
    out = fn(*args)
    for (name, shape), o in zip(CFG.param_specs(), out):
        if len(shape) == 2:
            want = ref.tezo_adam_update(params[name], us[name], vs[name],
                                        taus[name], tau_v[name], 1e-3, 1e-5)
            np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)


def test_mezo_update_m_state_evolution(setup):
    """m' = b1*m + (1-b1)*kappa*z and W' = W - lr*m'."""
    params, _, _ = setup
    fn, _, _, _ = zs.build_mezo_update_m(CFG)
    upd, _, _, _ = zs.build_mezo_update_sgd(CFG)
    seed = jnp.uint32(77)
    kappa, lr, b1 = 0.5, 1e-2, 0.9
    m0 = [jnp.ones_like(p) * 0.1 for p in _flat(params)]
    out = fn(*_flat(params), *m0, seed, jnp.float32(kappa), jnp.float32(lr),
             jnp.float32(b1))
    n = len(m0)
    new_p, new_m = out[:n], out[n:]
    # recover z
    zrec = upd(*_flat(params), seed, jnp.float32(-1.0))
    for p0, m00, np_, nm, zr in zip(_flat(params), m0, new_p, new_m, zrec):
        z = zr - p0
        want_m = b1 * m00 + (1 - b1) * kappa * z
        np.testing.assert_allclose(np.asarray(nm), np.asarray(want_m),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(np_), np.asarray(p0 - lr * want_m),
                                   rtol=2e-5, atol=2e-5)


def test_lozo_loss_and_update_consistency(setup):
    """The V_t regenerated in lozo_update must equal the one in lozo_loss_pm:
    perturbing with rho then updating with coeff=rho must land on W + rho Z
    (checked via the loss value)."""
    params, _, batch = setup
    rank = 4
    lfn, _, _, _ = zs.build_lozo_loss_pm(CFG, rank)
    ufn, _, _, _ = zs.build_lozo_update_sgd(CFG, rank)
    ifn, _, _, _ = zs.build_lozo_init_u(CFG, rank)
    us = ifn(jnp.uint32(1))
    seed, rho = jnp.uint32(13), 1e-2
    fp, _ = lfn(*_flat(params), *us, *batch, seed, jnp.float32(rho))
    # update with coeff = -rho gives W + rho Z
    out = ufn(*_flat(params), *us, seed, jnp.float32(-rho))
    moved = unflatten_params(CFG, out)
    want = loss_fn(CFG, moved, *batch)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(want),
                               rtol=5e-5, atol=1e-5)


def test_subzo_factors_orthonormal():
    rank = 4
    fn, _, _, _ = zs.build_subzo_factors(CFG, rank)
    outs = fn(jnp.uint32(2))
    k = len(CFG.matrix_params())
    assert len(outs) == 2 * k
    for i in range(0, 2 * k, 2):
        u = np.asarray(outs[i])
        got = u.T @ u
        np.testing.assert_allclose(got, np.eye(rank), atol=1e-4)


def test_subzo_loss_and_update_consistency(setup):
    params, _, batch = setup
    rank = 4
    ffn, _, _, _ = zs.build_subzo_factors(CFG, rank)
    lfn, _, _, _ = zs.build_subzo_loss_pm(CFG, rank)
    ufn, _, _, _ = zs.build_subzo_update(CFG, rank)
    uv = ffn(jnp.uint32(8))
    us, vs = uv[0::2], uv[1::2]
    seed, rho = jnp.uint32(21), 1e-2
    fp, _ = lfn(*_flat(params), *us, *vs, *batch, seed, jnp.float32(rho))
    out = ufn(*_flat(params), *us, *vs, seed, jnp.float32(-rho))
    want = loss_fn(CFG, unflatten_params(CFG, out), *batch)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(want),
                               rtol=5e-5, atol=1e-5)


def test_adamu_update_reduces_loss_direction(setup):
    """One ZO-AdaMU step with the true kappa sign should (usually) not blow
    up: just sanity-check state shapes and finiteness."""
    params, _, batch = setup
    lfn, _, _, _ = zs.build_adamu_loss_pm(CFG)
    ufn, _, _, _ = zs.build_adamu_update(CFG)
    flat = _flat(params)
    m0 = [jnp.zeros_like(p) for p in flat]
    v0 = [jnp.zeros_like(p) for p in flat]
    seed = jnp.uint32(3)
    fp, fm = lfn(*flat, *m0, *batch, seed, jnp.float32(1e-3), jnp.float32(0.2))
    kappa = (float(fp) - float(fm)) / (2 * 1e-3)
    out = ufn(*flat, *m0, *v0, seed, jnp.float32(kappa), jnp.float32(1e-4),
              jnp.float32(0.2), jnp.float32(0.9), jnp.float32(0.99),
              jnp.float32(1e-8), jnp.float32(1.0))
    n = len(flat)
    assert len(out) == 3 * n
    for o in out:
        assert np.isfinite(np.asarray(o)).all()


def test_fo_valgrad_and_adam_update(setup):
    params, _, batch = setup
    gfn, _, _, _ = zs.build_fo_valgrad(CFG)
    ufn, _, _, _ = zs.build_fo_adam_update(CFG)
    flat = _flat(params)
    out = gfn(*flat, *batch)
    loss, grads = out[0], out[1:]
    assert float(loss) > 0
    m0 = [jnp.zeros_like(p) for p in flat]
    v0 = [jnp.zeros_like(p) for p in flat]
    res = ufn(*flat, *grads, *m0, *v0, jnp.float32(1e-3), jnp.float32(0.9),
              jnp.float32(0.999), jnp.float32(1e-8), jnp.float32(1.0))
    n = len(flat)
    new_flat = res[:n]
    l2 = loss_fn(CFG, unflatten_params(CFG, new_flat), *batch)
    assert float(l2) < float(loss), "one FO Adam step should reduce loss"
