"""L2 model correctness: shapes, path equivalence, and loss semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import get_config
from compile.model import (dense_normal_like, eval_logits_fn, flatten_params,
                           init_params, logits_fn, loss_fn, unflatten_params)

CFG = get_config("tiny")


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(1)
    b, s, v = CFG.batch, CFG.seq_len, CFG.vocab
    tokens = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    mask = jnp.asarray((rng.random((b, s)) < 0.3).astype(np.float32))
    return tokens, targets, mask


def test_param_specs_cover_params(params):
    specs = CFG.param_specs()
    assert set(n for n, _ in specs) == set(params.keys())
    for n, shape in specs:
        assert params[n].shape == tuple(shape), n


def test_flatten_roundtrip(params):
    flat = flatten_params(CFG, params)
    back = unflatten_params(CFG, flat)
    for k in params:
        assert (back[k] == params[k]).all()


def test_logits_shape(params, batch):
    tokens, _, _ = batch
    logits = logits_fn(CFG, params, tokens)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_finite_and_positive(params, batch):
    loss = loss_fn(CFG, params, *batch)
    loss = float(loss)
    assert np.isfinite(loss) and loss > 0.0


def test_pallas_and_jnp_paths_agree(params, batch):
    """The use_pallas=True and False forward paths must be interchangeable
    (this is what licenses using the jnp path for big configs and grads)."""
    loss_pallas = loss_fn(CFG, params, *batch)
    cfg_jnp = dataclasses.replace(CFG, use_pallas=False)
    loss_jnp = loss_fn(cfg_jnp, params, *batch)
    np.testing.assert_allclose(np.asarray(loss_pallas), np.asarray(loss_jnp),
                               rtol=2e-5, atol=2e-6)


def test_eval_logits_positions(params, batch):
    tokens, _, _ = batch
    positions = jnp.asarray([0, 1, 2, CFG.seq_len - 1][:CFG.batch], jnp.int32)
    out = eval_logits_fn(CFG, params, tokens, positions)
    assert out.shape == (CFG.batch, CFG.vocab)
    full = logits_fn(CFG, params, tokens)
    for i, p in enumerate(np.asarray(positions)):
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(full[i, p]),
                                   rtol=1e-6)


def test_loss_mask_selects_positions(params, batch):
    """Loss must only depend on masked positions: changing targets outside
    the mask must not change the loss."""
    tokens, targets, mask = batch
    rng = np.random.default_rng(9)
    other = np.asarray(targets).copy()
    outside = np.asarray(mask) == 0.0
    other[outside] = rng.integers(0, CFG.vocab, size=outside.sum())
    l1 = float(loss_fn(CFG, params, tokens, targets, mask))
    l2 = float(loss_fn(CFG, params, tokens, jnp.asarray(other), mask))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_grad_matches_finite_difference(params, batch):
    """jax.grad of the loss vs central finite differences on a few coords."""
    cfg = dataclasses.replace(CFG, use_pallas=False)
    tokens, targets, mask = batch

    def f(flat):
        return loss_fn(cfg, unflatten_params(cfg, flat), tokens, targets, mask)

    flat = flatten_params(cfg, params)
    grads = jax.grad(lambda fl: f(fl))(flat)
    # check the first matrix param at 3 coordinates
    idx = [n for n, (name, s) in enumerate(cfg.param_specs())
           if name == "block0.attn.wq"][0]
    g = np.asarray(grads[idx])
    w = np.asarray(flat[idx])
    eps = 3e-3
    rng = np.random.default_rng(0)
    for _ in range(3):
        i = rng.integers(0, w.shape[0])
        j = rng.integers(0, w.shape[1])
        wp, wm = w.copy(), w.copy()
        wp[i, j] += eps
        wm[i, j] -= eps
        fp = float(f(tuple(jnp.asarray(wp) if k == idx else a
                           for k, a in enumerate(flat))))
        fm = float(f(tuple(jnp.asarray(wm) if k == idx else a
                           for k, a in enumerate(flat))))
        fd = (fp - fm) / (2 * eps)
        assert abs(fd - g[i, j]) < 5e-3 + 0.2 * abs(g[i, j]), \
            f"fd={fd} grad={g[i, j]}"


def test_dense_normal_like_is_deterministic():
    key = jax.random.PRNGKey(42)
    specs = CFG.param_specs()
    a = dense_normal_like(key, specs)
    b = dense_normal_like(key, specs)
    for n, _ in specs:
        assert (a[n] == b[n]).all()
    c = dense_normal_like(jax.random.PRNGKey(43), specs)
    assert not (np.asarray(a["embed.tok"]) == np.asarray(c["embed.tok"])).all()


def test_init_params_planted_low_rank():
    """The planted component must make weights effectively low-rank at the
    config threshold (otherwise Eq.7 degenerates to r_max everywhere)."""
    params = init_params(CFG, seed=0)
    w = np.asarray(params["block0.attn.wq"])
    s = np.linalg.svd(w, compute_uv=False)
    frac_above = np.sum(s > CFG.rank_threshold * s[0]) / len(s)
    assert frac_above < 0.6, f"weights not low-rank enough: {frac_above}"
