"""Cross-form parity: the implicit (factor-form) two-point loss must match
the materialized one to float tolerance on the tiny config.

The implicit artifacts reassociate the perturbed matmuls
(``x @ (W + rho Z)`` -> ``x @ W + ((x @ U) * rho tau) @ V^T``), so the two
forms are not bit-identical — this suite bounds the drift at 1e-4 on |f+|
and |f-|, across perturbation seeds standing in for every TeZO-family
driver (TeZO / TeZO-m / TeZO-Adam share one loss artifact; only the tau
content differs) and for LOZO.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import zo_steps as zs
from compile.aot import forward_form, rank_schedule
from compile.configs import get_config
from compile.model import flatten_params, init_params

CFG = get_config("tiny")
TOL = 1e-4


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, seed=0)
    np_params = {k: np.asarray(v) for k, v in params.items()}
    ranks = rank_schedule(CFG, np_params)
    rng = np.random.default_rng(11)
    b, s, v = CFG.batch, CFG.seq_len, CFG.vocab
    tokens = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    mask = jnp.asarray((rng.random((b, s)) < 0.3).astype(np.float32))
    return params, ranks, (tokens, targets, mask)


def _flat(params):
    return list(flatten_params(CFG, params))


def _tezo_factor_args(ranks, seed):
    """U/V panels + taus the way a driver would draw them, flattened in the
    artifact convention order."""
    rng = np.random.default_rng(seed)
    mats = CFG.matrix_params()
    us = [jnp.asarray(rng.normal(size=(m, ranks[n])), jnp.float32)
          for n, (m, _) in mats]
    vs = [jnp.asarray(rng.normal(size=(nn, ranks[n])), jnp.float32)
          for n, (_, nn) in mats]
    taus = [jnp.asarray(rng.normal(size=(ranks[n],)), jnp.float32)
            for n, _ in mats]
    return us + vs + taus


# one perturbation seed per TeZO-family driver: the loss artifact is shared;
# only the tau vectors (raw, momentum-accumulated, Adam-normalized) differ,
# and all of them are just rank-r vectors — distinct seeds cover the space
TEZO_SEEDS = [("tezo", 3), ("tezo-m", 17), ("tezo-adam", 29)]


@pytest.mark.parametrize("label,seed", TEZO_SEEDS)
def test_tezo_cross_form_parity(setup, label, seed):
    params, ranks, batch = setup
    mat_fn, _, mat_in, _ = zs.build_tezo_loss_pm(CFG, ranks)
    imp_fn, _, imp_in, _ = zs.build_tezo_loss_pm_implicit(CFG, ranks)
    # identical calling convention: the Rust side swaps artifacts by name
    assert [(d["role"], d["name"], d["shape"], d["dtype"]) for d in mat_in] \
        == [(d["role"], d["name"], d["shape"], d["dtype"]) for d in imp_in]
    args = _flat(params) + _tezo_factor_args(ranks, seed) + list(batch) \
        + [jnp.uint32(seed), jnp.float32(1e-2)]
    fp_m, fm_m = mat_fn(*args)
    fp_i, fm_i = imp_fn(*args)
    assert abs(float(fp_m) - float(fp_i)) <= TOL, \
        f"{label}: f+ drift {abs(float(fp_m) - float(fp_i))}"
    assert abs(float(fm_m) - float(fm_i)) <= TOL, \
        f"{label}: f- drift {abs(float(fm_m) - float(fm_i))}"


def test_tezo_implicit_sign_symmetry(setup):
    """Swapping the sign of rho must swap the two outputs — the sign-batched
    tau stacks are the only place the branch sign lives."""
    params, ranks, batch = setup
    fn, _, _, _ = zs.build_tezo_loss_pm_implicit(CFG, ranks)
    args = _flat(params) + _tezo_factor_args(ranks, 7) + list(batch)
    fp, fm = fn(*args, jnp.uint32(7), jnp.float32(1e-3))
    fp2, fm2 = fn(*args, jnp.uint32(7), jnp.float32(-1e-3))
    np.testing.assert_allclose(np.asarray(fp), np.asarray(fm2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fm), np.asarray(fp2), rtol=1e-6)


@pytest.mark.parametrize("seed", [13, 41])
def test_lozo_cross_form_parity(setup, seed):
    params, _, batch = setup
    rank = 4
    mat_fn, _, mat_in, _ = zs.build_lozo_loss_pm(CFG, rank)
    imp_fn, _, imp_in, _ = zs.build_lozo_loss_pm_implicit(CFG, rank)
    assert [(d["role"], d["name"], d["shape"], d["dtype"]) for d in mat_in] \
        == [(d["role"], d["name"], d["shape"], d["dtype"]) for d in imp_in]
    ifn, _, _, _ = zs.build_lozo_init_u(CFG, rank)
    us = ifn(jnp.uint32(1))
    args = _flat(params) + list(us) + list(batch) \
        + [jnp.uint32(seed), jnp.float32(1e-2)]
    fp_m, fm_m = mat_fn(*args)
    fp_i, fm_i = imp_fn(*args)
    assert abs(float(fp_m) - float(fp_i)) <= TOL
    assert abs(float(fm_m) - float(fm_i)) <= TOL


def test_forward_form_tags():
    assert forward_form("tezo_loss_pm") == "materialize"
    assert forward_form("tezo_loss_pm_implicit") == "implicit"
    assert forward_form("lozo_loss_pm") == "materialize"
    assert forward_form("lozo_loss_pm_implicit") == "implicit"
    assert forward_form("adamu_loss_pm") == "materialize"
    assert forward_form("tezo_update_factor") is None
    assert forward_form("fwd_loss") is None
