"""Theorem 1 validation (paper §5): the TeZO estimator is unbiased after
dividing by r, and its relative variance matches
delta = 1 + mn + 2mn/r + 6(m+n)/r + 10/r.

Monte-Carlo over (tau, u, v); we use the rho->0 limit form
   (1/r) <G, Z> Z  with  Z = U diag(tau) V^T,
which is exactly what the SPSA quotient converges to (Thm 1 proof).
"""

import numpy as np
import pytest


def _tezo_sample(rng, g, r):
    m, n = g.shape
    u = rng.normal(size=(m, r))
    v = rng.normal(size=(n, r))
    tau = rng.normal(size=(r,))
    z = (u * tau) @ v.T
    return (np.sum(g * z) * z) / r


def _delta(m, n, r):
    return 1.0 + m * n + 2.0 * m * n / r + 6.0 * (m + n) / r + 10.0 / r


@pytest.mark.parametrize("m,n,r", [(4, 4, 2), (6, 3, 2), (5, 8, 4)])
def test_unbiasedness(m, n, r):
    rng = np.random.default_rng(0)
    g = rng.normal(size=(m, n))
    trials = 400_000
    acc = np.zeros_like(g)
    for _ in range(trials):
        acc += _tezo_sample(rng, g, r)
    est = acc / trials
    # standard error of the mean scales with sqrt(delta/trials)*|g|
    se = np.sqrt(_delta(m, n, r) / trials) * np.linalg.norm(g)
    err = np.linalg.norm(est - g)
    assert err < 6 * se, f"bias too large: {err} vs se {se}"


@pytest.mark.parametrize("m,n,r", [(4, 4, 2), (3, 6, 3)])
def test_variance_matches_delta(m, n, r):
    rng = np.random.default_rng(1)
    g = rng.normal(size=(m, n))
    g_norm2 = np.sum(g * g)
    trials = 300_000
    acc = 0.0
    for _ in range(trials):
        d = _tezo_sample(rng, g, r) - g
        acc += np.sum(d * d)
    var = acc / trials
    want = _delta(m, n, r) * g_norm2
    # 4th-moment estimator: generous 15% tolerance
    assert abs(var - want) / want < 0.15, f"var {var} vs delta*|g|^2 {want}"


def test_variance_formula_vs_mezo_order():
    """The paper's Remark 1: TeZO variance stays within the same order as
    MeZO's (mn); check the formula's dominant term."""
    for (m, n, r) in [(64, 64, 8), (1024, 1024, 16)]:
        d = _delta(m, n, r)
        assert d / (m * n) < 1.0 + 3.0 / r + 1e-2 + 6.0 * (m + n) / (r * m * n) + 2.0 / r
        assert d > m * n  # slightly larger than MeZO, as stated
