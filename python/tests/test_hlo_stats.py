"""compile/hlo_stats.py is the build-time mirror of the Rust analyzer
(rust/src/runtime/hlo_stats.rs). These cases are copied verbatim from the
Rust unit tests — if one side changes behavior, both suites must move."""

from compile.hlo_stats import peak_temp_bytes, stats

LIVENESS = """
ENTRY main {
  %p0 = f32[1000]{0} parameter(0)
  %t1 = f32[1000]{0} add(%p0, %p0)
  %s1 = f32[] reduce(%t1, %p0), dimensions={0}
  %t2 = f32[1000]{0} multiply(%p0, %p0)
  %s2 = f32[] reduce(%t2, %p0), dimensions={0}
  ROOT %out = f32[] add(%s1, %s2)
}
"""

LIVENESS_BOTH = """
ENTRY main {
  %p0 = f32[1000]{0} parameter(0)
  %t1 = f32[1000]{0} add(%p0, %p0)
  %t2 = f32[1000]{0} multiply(%p0, %p0)
  ROOT %out = f32[1000]{0} add(%t1, %t2)
}
"""

PARAM_SHAPED = """
ENTRY main {
  %w = f32[64,256]{1,0} parameter(0)
  %b = f32[64]{0} parameter(1)
  %wp = f32[64,256]{1,0} add(%w, %w)
  %bp = f32[64]{0} add(%b, %b)
  %wp2 = f32[64,256]{1,0} multiply(%wp, %wp)
  ROOT %s = f32[] reduce(%wp2, %bp), dimensions={0,1}
}
"""


def test_liveness_peak_frees_dead_temps():
    # t1 dies at its last use (%s1): high-water mark is t2 + two scalars
    assert peak_temp_bytes(LIVENESS) == 4008


def test_liveness_peak_counts_simultaneously_live_temps():
    assert peak_temp_bytes(LIVENESS_BOTH) == 12000


def test_param_shaped_temps_are_classified():
    s = stats(PARAM_SHAPED)
    assert s["param_temp_total_bytes"] == 2 * 64 * 256 * 4
    assert s["peak_param_temp_bytes"] == 2 * 64 * 256 * 4
    assert s["peak_temp_bytes"] >= s["peak_param_temp_bytes"]


def test_no_param_shaped_temps_when_params_are_1d():
    s = stats(LIVENESS)
    assert s["param_temp_total_bytes"] == 0
    assert s["peak_param_temp_bytes"] == 0


def test_parameters_are_not_temps():
    sample = """
ENTRY main {
  %p0 = f32[64,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  %dot = f32[64,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %rng = u32[2]{0} rng-bit-generator(%p0), algorithm=rng_default
  ROOT %t = (f32[64,64]{1,0}) tuple(%dot)
}
"""
    p = peak_temp_bytes(sample)
    assert p >= 64 * 64 * 4
    assert p < 2 * 64 * 256 * 4
