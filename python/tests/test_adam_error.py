"""Paper Appendix A.2 (and Fig 8): the lightweight separable second moment.

Checks (i) the cross term of Z^2 is negligible vs the separable term for
one step, and (ii) the time-averaged accumulated error E_t = (V_t - V̂_t)/mn
*decreases* as the model dimension grows — the scaling that justifies
dropping the cross term for LLM-sized layers.

Scaled-down shapes (paper uses m=n=4096, r=64; we sweep 128..512, r=16) —
the trend, not the absolute number, is the claim under test.
"""

import numpy as np
import pytest


def _z_terms(rng, m, n, r):
    u = rng.normal(size=(m, r))
    v = rng.normal(size=(n, r))
    tau = rng.normal(size=(r,))
    z = (u * tau) @ v.T
    sep = ((u * u) * (tau * tau)) @ (v * v).T
    return z, sep


def test_cross_term_zero_mean_one_step():
    """The cross term is zero-mean per coordinate (paper Eq. 8/11): its
    average over coordinates must vanish relative to the separable term,
    even though individual entries are not small."""
    rng = np.random.default_rng(0)
    m = n = 256
    r = 16
    z, sep = _z_terms(rng, m, n, r)
    cross = z * z - sep
    assert abs(cross.mean()) < 0.05 * sep.mean(), \
        (cross.mean(), sep.mean())
    # averaging over independent draws of tau kills the cross term ~1/sqrt(T)
    T = 64
    acc = np.zeros((m, n))
    u = rng.normal(size=(m, r))
    v = rng.normal(size=(n, r))
    for _ in range(T):
        tau = rng.normal(size=(r,))
        zz = (u * tau) @ v.T
        ss = ((u * u) * (tau * tau)) @ (v * v).T
        acc += zz * zz - ss
    one = np.linalg.norm(cross)
    avg = np.linalg.norm(acc / T)
    assert avg < one, (avg, one)


@pytest.mark.parametrize("steps", [200])
def test_accumulated_error_decreases_with_size(steps):
    rng = np.random.default_rng(1)
    beta2 = 0.99
    errs = {}
    for size in [64, 128, 256]:
        m = n = size
        r = 8
        u = rng.normal(size=(m, r))
        v = rng.normal(size=(n, r))
        vt = np.zeros((m, n))
        vhat = np.zeros((m, n))
        acc = 0.0
        for t in range(steps):
            tau = rng.normal(size=(r,))
            z = (u * tau) @ v.T
            sep = ((u * u) * (tau * tau)) @ (v * v).T
            vt = beta2 * vt + (1 - beta2) * (z * z)
            vhat = beta2 * vhat + (1 - beta2) * sep
            acc += np.linalg.norm(vt - vhat) / (m * n)
        errs[size] = acc / steps
    assert errs[128] < errs[64]
    assert errs[256] < errs[128]
