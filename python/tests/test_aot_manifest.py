"""AOT pipeline invariants: rank schedule (Eq. 7) and manifest integrity."""

import json
import os

import numpy as np
import pytest

from compile.aot import matrix_rank_threshold, rank_schedule
from compile.configs import get_config
from compile.model import init_params

CFG = get_config("tiny")
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


def test_rank_threshold_on_known_matrix():
    # diag(10, 9, 1, 0.1): threshold 0.25 -> sigma > 2.5 -> rank 2
    w = np.diag([10.0, 9.0, 1.0, 0.1])
    assert matrix_rank_threshold(w, 0.25) == 2
    assert matrix_rank_threshold(w, 0.05) == 3
    assert matrix_rank_threshold(np.zeros((4, 4)), 0.25) == 1


def test_rank_schedule_within_bounds():
    params = init_params(CFG, seed=0)
    np_params = {k: np.asarray(v) for k, v in params.items()}
    ranks = rank_schedule(CFG, np_params)
    for name, (m, n) in CFG.matrix_params():
        assert 1 <= ranks[name] <= CFG.r_max
    # same block -> same rank (Eq.7 is per-block)
    blocks = {}
    for name, _ in CFG.matrix_params():
        blocks.setdefault(CFG.block_of(name), set()).add(ranks[name])
    for b, rs in blocks.items():
        assert len(rs) == 1, f"block {b} has mixed ranks {rs}"


def test_rank_schedule_deterministic():
    params = init_params(CFG, seed=0)
    np_params = {k: np.asarray(v) for k, v in params.items()}
    assert rank_schedule(CFG, np_params) == rank_schedule(CFG, np_params)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_config():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["config"]["name"] == "tiny"
    assert man["config"]["n_params"] == CFG.n_params()
    # every param bin exists with the right byte size
    for p in man["params"]:
        path = os.path.join(ART, p["bin"])
        assert os.path.exists(path), path
        want = 4 * int(np.prod(p["shape"]))
        assert os.path.getsize(path) == want
    # every artifact file exists
    for name, a in man["artifacts"].items():
        assert os.path.exists(os.path.join(ART, a["file"])), name
    # ranks recorded for every matrix param
    names = {e["name"] for e in man["matrix_ranks"]}
    assert names == {n for n, _ in CFG.matrix_params()}


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_tiles_block_is_wellformed():
    """The build-time Pallas tile sweep records one entry per distinct
    weight shape: winning (bm, bn) divisor tile plus per-candidate ns."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["config"]["use_pallas"], "tiny routes through pallas"
    tiles = man["tiles"]
    want_shapes = {f"{k}x{n}" for _, (k, n) in CFG.matrix_params()}
    assert set(tiles) == want_shapes
    m_rows = CFG.batch * CFG.seq_len
    for key, t in tiles.items():
        assert key == f"{t['k']}x{t['n']}"
        assert t["m"] == m_rows
        assert m_rows % t["bm"] == 0, key
        assert t["n"] % t["bn"] == 0, key
        assert t["trials"] >= 1
        # the recorded winner is the argmin over the candidate timings
        best = min(t["candidates"], key=lambda c: c["ns"])
        assert (t["bm"], t["bn"]) == (best["bm"], best["bn"]), key
        for c in t["candidates"]:
            assert c["ns"] >= 0


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_input_roles_are_wellformed():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    valid_roles = {"param", "batch", "scalar", "factor_u", "factor_v", "tau",
                   "tau_eff", "tau_m", "tau_v", "state_m", "state_v",
                   "state_s", "state_mpert", "grad", "tensor"}
    for name, a in man["artifacts"].items():
        for d in a["inputs"] + a["outputs"]:
            assert d["role"] in valid_roles, (name, d)
            assert d["dtype"] in {"f32", "i32", "u32"}
        # params-first convention for step artifacts
        if name.endswith(("_loss_pm", "_update", "_update_sgd", "_update_m",
                          "_update_adam", "_update_factor")):
            nparams = len(CFG.param_specs())
            roles = [d["role"] for d in a["inputs"][:nparams]]
            assert all(r == "param" for r in roles), name
