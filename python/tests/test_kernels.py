"""L1 correctness: Pallas kernels (interpret mode) vs pure-jnp oracles.

Hypothesis sweeps shapes/ranks/scalars; every kernel must match ``ref.py``
to float32 tolerance for all generated cases.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import kernels
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("kernels")


def _np_rng(seed):
    return np.random.default_rng(seed)


dims = st.integers(min_value=1, max_value=96)
ranks = st.integers(min_value=1, max_value=16)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
scalars = st.floats(min_value=1e-4, max_value=2.0, allow_nan=False)


@given(m=dims, n=dims, r=ranks, rho=scalars, seed=seeds)
def test_tezo_perturb_matches_ref(m, n, r, rho, seed):
    rng = _np_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
    tau = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    got = kernels.tezo_perturb(w, u, v, tau, jnp.float32(rho))
    want = ref.tezo_perturb(w, u, v, tau, rho)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(m=dims, n=dims, r=ranks, seed=seeds)
def test_tezo_sgd_update_matches_ref(m, n, r, seed):
    rng = _np_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
    tau = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    got = kernels.tezo_sgd_update(w, u, v, tau)
    want = ref.tezo_sgd_update(w, u, v, tau)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(m=dims, n=dims, r=ranks, lr=scalars, seed=seeds)
def test_tezo_adam_update_matches_ref(m, n, r, lr, seed):
    rng = _np_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
    tau_m = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    tau_v = jnp.asarray(np.abs(rng.normal(size=(r,))) + 1e-3, jnp.float32)
    got = kernels.tezo_adam_update(w, u, v, tau_m, tau_v, lr, 1e-5)
    want = ref.tezo_adam_update(w, u, v, tau_m, tau_v, lr, 1e-5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(m=dims, n=dims, alpha=scalars, seed=seeds)
def test_axpy_matches_ref(m, n, alpha, seed):
    rng = _np_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    got = kernels.axpy_perturb(w, z, alpha)
    want = ref.axpy_perturb(w, z, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(b=st.integers(1, 3), h=st.integers(1, 4),
       s=st.sampled_from([4, 16, 33]), dh=st.sampled_from([4, 8, 32]),
       seed=seeds)
def test_attention_matches_ref(b, h, s, dh, seed):
    rng = _np_rng(seed)
    q, k, v = [jnp.asarray(rng.normal(size=(b, h, s, dh)), jnp.float32)
               for _ in range(3)]
    mask = jnp.where(jnp.tril(jnp.ones((s, s))) > 0, 0.0, -1e9).astype(jnp.float32)
    got = kernels.attention(q, k, v, mask)
    want = ref.attention(q, k, v, mask)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@given(b=st.integers(1, 4), s=st.sampled_from([4, 16, 64]),
       v=st.sampled_from([8, 32, 128]), seed=seeds)
def test_cross_entropy_matches_ref(b, s, v, seed):
    rng = _np_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, s, v)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(b, s)), jnp.float32)
    got = kernels.cross_entropy(logits, tgt, mask)
    want = ref.cross_entropy(logits, tgt, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_cross_entropy_all_masked_is_finite():
    """Zero mask must not divide by zero."""
    logits = jnp.zeros((2, 8, 16), jnp.float32)
    tgt = jnp.zeros((2, 8), jnp.int32)
    mask = jnp.zeros((2, 8), jnp.float32)
    out = kernels.cross_entropy(logits, tgt, mask)
    assert np.isfinite(np.asarray(out))
    assert np.asarray(out) == 0.0


def test_tezo_perturb_block_edge_cases():
    """Non-divisible dims force _pick_block to shrink; result must not change."""
    rng = _np_rng(7)
    for (m, n, r) in [(7, 13, 3), (1, 1, 1), (97, 101, 5)]:
        w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
        tau = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
        got = kernels.tezo_perturb(w, u, v, tau, jnp.float32(0.1))
        want = ref.tezo_perturb(w, u, v, tau, 0.1)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_tezo_perturb_zero_tau_is_identity():
    rng = _np_rng(3)
    w = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(48, 4)), jnp.float32)
    tau = jnp.zeros((4,), jnp.float32)
    out = kernels.tezo_perturb(w, u, v, tau, jnp.float32(123.0))
    np.testing.assert_allclose(out, w, rtol=0, atol=0)


def test_tezo_perturb_plus_minus_roundtrip():
    """perturb(+rho) then perturb(-rho) restores W to float tolerance —
    the resampling-technique invariant the Rust trainer relies on."""
    rng = _np_rng(11)
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    tau = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    w1 = kernels.tezo_perturb(w, u, v, tau, jnp.float32(1e-3))
    w2 = kernels.tezo_perturb(w1, u, v, tau, jnp.float32(-1e-3))
    np.testing.assert_allclose(w2, w, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# sign-batched low-rank matmul (implicit forward building block)
# ---------------------------------------------------------------------------

@given(m=dims, k=dims, n=dims, r=ranks, rho=scalars, seed=seeds)
def test_lowrank_matmul_matches_ref(m, k, n, r, rho, seed):
    rng = _np_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(k, r)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
    t = rng.normal(size=(r,)).astype(np.float32)
    tau = jnp.asarray(np.stack([rho * t, -rho * t]))
    got = kernels.lowrank_matmul(x, w, u, v, tau)
    want = ref.lowrank_matmul(x, w, u, v, tau)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_lowrank_matmul_zero_tau_is_plain_matmul():
    rng = _np_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(24, 4)), jnp.float32)
    tau = jnp.zeros((2, 4), jnp.float32)
    got = kernels.lowrank_matmul(x, w, u, v, tau)
    np.testing.assert_allclose(got, x @ w, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# build-time tile sweep (manifest "tiles" block)
# ---------------------------------------------------------------------------

def test_sweep_tile_picks_min_of_trials_winner():
    """Scripted timer: candidate timings are injected, so the winner is the
    min-of-trials argmin — no wall clock involved."""
    from compile.kernels.lowrank_matmul import sweep_tile
    # at (m=256, n=256) the (256, 512) target legalizes to (256, 256) and
    # dedups, leaving 4 legal candidates; per timed call the fake clock
    # advances by the scripted cost of that tile
    costs = {(64, 128): 50, (128, 128): 30, (128, 256): 40, (256, 256): 70}
    clock = [0]
    current = [None]

    def runner(bm, bn):
        current[0] = (bm, bn)

    def timer():
        # called at trial start and stop; advancing by the scripted cost on
        # every call makes each stop-start delta equal that cost exactly
        clock[0] += costs[current[0]] if current[0] else 0
        return clock[0]

    res = sweep_tile(256, 256, 64, 8, trials=3, timer=timer, runner=runner)
    assert (res["bm"], res["bn"]) == (128, 128)
    assert res["trials"] == 3
    got = {(c["bm"], c["bn"]): c["ns"] for c in res["candidates"]}
    assert got == costs


def test_sweep_tile_dedups_legalized_candidates():
    """At a small shape every target collapses to the same legal tile; the
    sweep must time it once, not len(candidates) times."""
    from compile.kernels.lowrank_matmul import sweep_tile
    calls = []
    res = sweep_tile(32, 32, 16, 4, timer=lambda: len(calls),
                     runner=lambda bm, bn: calls.append((bm, bn)))
    assert len(res["candidates"]) == 1
    assert (res["bm"], res["bn"]) == (32, 32)
    # 1 warm + 2 trials for the single deduped tile
    assert calls == [(32, 32)] * 3


def test_sweep_tile_ties_resolve_by_candidate_order():
    from compile.kernels.lowrank_matmul import sweep_tile
    res = sweep_tile(256, 256, 64, 8, trials=1, timer=lambda: 0,
                     runner=lambda bm, bn: None)
    assert all(c["ns"] == 0 for c in res["candidates"])
    first = res["candidates"][0]
    assert (res["bm"], res["bn"]) == (first["bm"], first["bn"])


def test_sweep_tile_default_runner_runs_real_kernel():
    """Smoke: the default runner path (real lowrank_matmul calls) completes
    and returns a legal divisor tile at a tiny shape."""
    from compile.kernels.lowrank_matmul import sweep_tile
    res = sweep_tile(32, 32, 16, 4, trials=1)
    assert 32 % res["bm"] == 0 and 32 % res["bn"] == 0
    assert all(c["ns"] >= 0 for c in res["candidates"])


# ---------------------------------------------------------------------------
# _pick_block degenerate-tiling guard
# ---------------------------------------------------------------------------

def test_pick_block_divisible_dims_unchanged():
    from compile.kernels.tezo_perturb import _pick_block
    assert _pick_block(512, 256) == 256
    assert _pick_block(96, 256) == 96
    assert _pick_block(768, 256) == 256
    assert _pick_block(48, 16) == 16


def test_pick_block_prime_dims_fall_back_to_whole_dim():
    """Primes (and 2p-style dims) have no divisor above the floor below the
    target; the guard takes the whole dim as one block instead of a 1-wide
    (or 2-wide) stripe grid."""
    from compile.kernels.tezo_perturb import _pick_block
    assert _pick_block(509, 256) == 509        # prime
    assert _pick_block(2 * 509, 256) == 1018   # best divisor would be 2
    assert _pick_block(257, 256) == 257        # prime just above target
    # tiny dims below the floor are their own (exact) block
    assert _pick_block(5, 256) == 5
    assert _pick_block(1, 256) == 1


def test_pick_block_floor_is_respected_when_divisors_exist():
    from compile.kernels.tezo_perturb import _pick_block
    # 272 = 2^4 * 17: largest divisor <= 256 is 136, well above the floor
    assert _pick_block(272, 256) == 136
    # 34 = 2 * 17 with floor 16: best divisor 2 < 16 -> whole dim
    assert _pick_block(34, 16) == 34


def test_tezo_perturb_prime_dims_still_exact():
    """End-to-end through the kernel: prime dims route through the guard."""
    rng = _np_rng(23)
    m, n, r = 509, 13, 3
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, r)), jnp.float32)
    tau = jnp.asarray(rng.normal(size=(r,)), jnp.float32)
    got = kernels.tezo_perturb(w, u, v, tau, jnp.float32(0.5))
    want = ref.tezo_perturb(w, u, v, tau, 0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
