"""Newton-Schulz orthonormalization (the QR substitute for SubZO factors):
convergence across the panel shapes the configs actually use."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.zo_steps import _ns_orthonormalize


@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([48, 64, 128, 256, 1024]),
       r=st.sampled_from([4, 8, 16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_ns_orthonormalizes_gaussian_panels(m, r, seed):
    if r * 3 > m:  # keep panels tall (the SubZO regime)
        r = max(2, m // 4)
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    q = np.asarray(_ns_orthonormalize(g))
    gram = q.T @ q
    err = np.abs(gram - np.eye(r)).max()
    assert err < 1e-3, f"m={m} r={r}: orthonormality err {err}"


def test_ns_preserves_column_space():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    q = np.asarray(_ns_orthonormalize(g))
    # Q and G must span the same subspace: projecting G onto Q keeps norm
    proj = q @ (q.T @ np.asarray(g))
    rel = np.linalg.norm(proj - np.asarray(g)) / np.linalg.norm(np.asarray(g))
    assert rel < 1e-3, rel
