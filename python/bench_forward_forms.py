"""Forward-form benchmark: materialize vs implicit two-point loss.

Produces the BENCH_PR5.json snapshot: per config, the three hlo_stats temp
metrics for both compiled forms (computed with compile/hlo_stats.py, the
build-time mirror of rust/src/runtime/hlo_stats.rs), and the paired
wall-clock of the jitted two-point forward on XLA:CPU (the same HLO the
Rust PJRT runtime executes; `cargo bench --bench bench_walltime`
re-measures the walltime side through the actual prepared-call runtime
and writes its own snapshot to out/BENCH_PR5.json).

Walltime pairs are interleaved and the MIN is reported (shared-machine
noise is one-sided); parity drift |f_materialize - f_implicit| is recorded
for both outputs.

Usage:
    python bench_forward_forms.py --configs tiny,tiny_jnp,small \
        --stats-configs tiny,small,medium --out ../BENCH_PR5.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import zo_steps as zs
from compile.aot import rank_schedule, to_hlo_text
from compile.configs import get_config
from compile.hlo_stats import stats as hlo_stats
from compile.model import flatten_params, init_params


def _example_args(cfg, ranks, seed=5):
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(seed)
    b, s, v = cfg.batch, cfg.seq_len, cfg.vocab
    tokens = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    mask = jnp.asarray((rng.random((b, s)) < 0.3).astype(np.float32))
    mats = cfg.matrix_params()
    us = [jnp.asarray(rng.normal(size=(m, ranks[n])), jnp.float32)
          for n, (m, _) in mats]
    vs = [jnp.asarray(rng.normal(size=(nn, ranks[n])), jnp.float32)
          for n, (_, nn) in mats]
    taus = [jnp.asarray(rng.normal(size=(ranks[n],)), jnp.float32)
            for n, _ in mats]
    return list(flatten_params(cfg, params)) + us + vs + taus + \
        [tokens, targets, mask, jnp.uint32(7), jnp.float32(1e-3)]


def _ranks(cfg):
    params = init_params(cfg, seed=0)
    return rank_schedule(cfg, {k: np.asarray(v) for k, v in params.items()})


def bench_walltime(cfg_name: str, pairs: int):
    cfg = get_config(cfg_name)
    ranks = _ranks(cfg)
    args = _example_args(cfg, ranks)
    jm = jax.jit(zs.build_tezo_loss_pm(cfg, ranks)[0])
    ji = jax.jit(zs.build_tezo_loss_pm_implicit(cfg, ranks)[0])
    rm, ri = jm(*args), ji(*args)
    jax.block_until_ready(rm)
    jax.block_until_ready(ri)
    drift = max(abs(float(rm[0]) - float(ri[0])),
                abs(float(rm[1]) - float(ri[1])))
    tm, ti = [], []
    for _ in range(pairs):
        t0 = time.perf_counter()
        jax.block_until_ready(jm(*args))
        tm.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(ji(*args))
        ti.append(time.perf_counter() - t0)
    m, i = min(tm), min(ti)
    return {"materialize_forward_ms": round(m * 1e3, 3),
            "implicit_forward_ms": round(i * 1e3, 3),
            "implicit_speedup": round(m / i, 3),
            "pairs": pairs,
            "parity_drift": drift}


def bench_stats(cfg_name: str):
    cfg = get_config(cfg_name)
    ranks = _ranks(cfg)
    lozo_rank = max(2, min(8, cfg.r_max))
    out = {}
    for name, (fn, ex, _, _) in {
        "tezo_loss_pm": zs.build_tezo_loss_pm(cfg, ranks),
        "tezo_loss_pm_implicit": zs.build_tezo_loss_pm_implicit(cfg, ranks),
        "lozo_loss_pm": zs.build_lozo_loss_pm(cfg, lozo_rank),
        "lozo_loss_pm_implicit": zs.build_lozo_loss_pm_implicit(cfg, lozo_rank),
    }.items():
        out[name] = hlo_stats(to_hlo_text(fn, ex))
    for fam in ("tezo", "lozo"):
        mat, imp = out[f"{fam}_loss_pm"], out[f"{fam}_loss_pm_implicit"]
        for k in ("peak_param_temp_bytes", "param_temp_total_bytes"):
            base = mat[k]
            imp_k = imp[k]
            out[f"{fam}_reduction_{k}"] = \
                round(1.0 - imp_k / base, 4) if base else None
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default="tiny,tiny_jnp,small",
                    help="configs to measure walltime on (tiny = CLI default)")
    ap.add_argument("--stats-configs", default="tiny,small,medium",
                    help="configs to compute hlo temp stats on")
    ap.add_argument("--pairs", type=int, default=40)
    ap.add_argument("--out", default="../BENCH_PR5.json")
    args = ap.parse_args()

    doc = {
        "snapshot": "PR5 implicit factor-form two-point forward",
        "harness": f"python-jax-{jax.__version__}-cpu (same XLA:CPU the Rust "
                   "PJRT runtime compiles; rerun via rust: cargo bench "
                   "--bench bench_walltime, which writes out/BENCH_PR5.json)",
        "metrics_note": "peak_param_temp_bytes / param_temp_total_bytes are "
                        "the hlo_stats liveness metrics over parameter-shaped "
                        "temporaries (the materialized W+/-rhoZ copies); "
                        "peak_temp_bytes is the full-stream peak, dominated "
                        "by activation temps both forms share. Walltime is "
                        "the min over interleaved pairs.",
        "hlo_temp_stats": {},
        "walltime": {},
    }
    for c in [c.strip() for c in args.stats_configs.split(",") if c.strip()]:
        print(f"[stats] {c} ...")
        doc["hlo_temp_stats"][c] = bench_stats(c)
    for c in [c.strip() for c in args.configs.split(",") if c.strip()]:
        pairs = args.pairs if "tiny" in c else max(8, args.pairs // 4)
        print(f"[walltime] {c} ({pairs} pairs) ...")
        doc["walltime"][c] = bench_walltime(c, pairs)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"-> {args.out}")
    for c, w in doc["walltime"].items():
        print(f"  {c}: {w['materialize_forward_ms']} -> "
              f"{w['implicit_forward_ms']} ms ({w['implicit_speedup']}x)")


if __name__ == "__main__":
    main()
