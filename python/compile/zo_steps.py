"""L2: per-method ZO step functions, AOT-lowered to HLO artifacts.

Every public builder here returns ``(fn, example_args, input_desc,
output_desc)`` where ``fn`` takes *positional* arguments in the exact order
recorded in ``input_desc`` — that order is the Rust calling convention and is
serialized into manifest.json by aot.py.

Conventions shared by all methods
---------------------------------
* Parameters come first, flattened in ``cfg.param_specs()`` order.
* A training *batch* is ``(tokens i32[B,S], targets i32[B,S], mask f32[B,S])``.
* ``seed`` is a u32 scalar; all in-HLO randomness derives from
  ``jax.random.PRNGKey(seed)`` + ``fold_in(param_index)`` — the MeZO
  *resampling technique*: given the step seed, perturb and update regenerate
  identical draws, so no perturbation tensor is ever stored (Rust stores 4
  bytes per step).
* Two-point evaluation is fused: one ``*_loss_pm`` call returns both
  ``f(W + rho Z)`` and ``f(W - rho Z)``; Rust computes the projected gradient
  ``kappa = (f+ - f-) / (2 rho)`` on host (scalar work).
* Low-rank schemes factorize only 2D weights (paper §4.1: "we primarily
  consider the 2D cases"); 1D params (layernorms) are perturbed densely from
  the seed and updated with plain ZO-SGD in the TeZO/LOZO/SubZO variants.
  MeZO variants apply their optimizer to every parameter (their state is
  full-size anyway) — this matches each paper's own memory accounting.
* Scalar knobs (rho, lr, coefficients) are f32 scalar inputs so one compiled
  artifact serves every hyperparameter setting.
* TeZO-m / TeZO-Adam: the temporal factors ``tau_M, tau_V`` are *state held
  by Rust* (r floats per layer — the paper's memory claim); the artifacts
  take the already-accumulated (and bias-corrected) vectors. Momentum
  accumulation itself is O(r) host work.

Naming: ``us/vs/taus`` lists are ordered like ``cfg.matrix_params()``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .configs import ModelConfig
from .kernels import ref
from .model import (Params, dense_normal_like, eval_logits_fn, loss_fn,
                    loss_pm_fn, unflatten_params)

# ---------------------------------------------------------------------------
# descriptor helpers
# ---------------------------------------------------------------------------

F32 = jnp.float32
I32 = jnp.int32
U32 = jnp.uint32


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _desc(role: str, name: str, shape, dtype: str) -> Dict:
    return {"role": role, "name": name, "shape": list(int(s) for s in shape),
            "dtype": dtype}


def _param_inputs(cfg: ModelConfig):
    args, desc = [], []
    for name, shape in cfg.param_specs():
        args.append(_sds(shape))
        desc.append(_desc("param", name, shape, "f32"))
    return args, desc


def _batch_inputs(cfg: ModelConfig):
    b, s = cfg.batch, cfg.seq_len
    args = [_sds((b, s), I32), _sds((b, s), I32), _sds((b, s), F32)]
    desc = [_desc("batch", "tokens", (b, s), "i32"),
            _desc("batch", "targets", (b, s), "i32"),
            _desc("batch", "mask", (b, s), "f32")]
    return args, desc


def _scalar(name: str, dtype=F32):
    d = {F32: "f32", I32: "i32", U32: "u32"}[dtype]
    return _sds((), dtype), _desc("scalar", name, (), d)


def _factor_inputs(cfg: ModelConfig, ranks: Dict[str, int], *,
                   taus: Sequence[str] = ("tau",), with_uv: bool = True):
    """(us, vs, tau-vector-groups) inputs for the TeZO family."""
    args, desc = [], []
    mats = cfg.matrix_params()
    if with_uv:
        for name, (m, n) in mats:
            args.append(_sds((m, ranks[name])))
            desc.append(_desc("factor_u", name, (m, ranks[name]), "f32"))
        for name, (m, n) in mats:
            args.append(_sds((n, ranks[name])))
            desc.append(_desc("factor_v", name, (n, ranks[name]), "f32"))
    for tau_role in taus:
        for name, _ in mats:
            args.append(_sds((ranks[name],)))
            desc.append(_desc(tau_role, name, (ranks[name],), "f32"))
    return args, desc


def _split_factors(cfg: ModelConfig, rest: Sequence, n_tau_groups: int,
                   with_uv: bool = True):
    mats = cfg.matrix_params()
    k = len(mats)
    idx = 0
    us = vs = None
    if with_uv:
        us = {mats[i][0]: rest[idx + i] for i in range(k)}
        idx += k
        vs = {mats[i][0]: rest[idx + i] for i in range(k)}
        idx += k
    tau_groups = []
    for _ in range(n_tau_groups):
        tau_groups.append({mats[i][0]: rest[idx + i] for i in range(k)})
        idx += k
    return us, vs, tau_groups, rest[idx:]


def _vector_normals(cfg: ModelConfig, seed):
    """Dense seed-derived normals for the 1D params only."""
    key = jax.random.PRNGKey(seed)
    specs = cfg.param_specs()
    out = {}
    for idx, (name, shape) in enumerate(specs):
        if len(shape) == 1:
            out[name] = jax.random.normal(jax.random.fold_in(key, idx), shape,
                                          F32)
    return out


def _all_normals(cfg: ModelConfig, seed):
    key = jax.random.PRNGKey(seed)
    return dense_normal_like(key, cfg.param_specs())


def _perturbed(cfg: ModelConfig, params: Params, z: Params, scale) -> Params:
    """W + scale*Z for every param present in z (others pass through).

    Routes through the L1 kernels when the config asks for the pallas path.
    """
    out = dict(params)
    for name, zz in z.items():
        w = params[name]
        if cfg.use_pallas and w.ndim == 2:
            out[name] = kernels.axpy_perturb(w, zz, scale)
        else:
            out[name] = w + scale * zz
    return out


def _tezo_z(cfg: ModelConfig, u, v, tau):
    return ref.tezo_z(u, v, tau)


def _tezo_perturbed(cfg: ModelConfig, params, us, vs, taus, vec_z, scale):
    out = dict(params)
    for name, _ in cfg.matrix_params():
        w = params[name]
        if cfg.use_pallas:
            out[name] = kernels.tezo_perturb(w, us[name], vs[name], taus[name],
                                             jnp.asarray(scale, F32))
        else:
            out[name] = ref.tezo_perturb(w, us[name], vs[name], taus[name],
                                         scale)
    for name, zz in vec_z.items():
        out[name] = params[name] + scale * zz
    return out


def _loss(cfg: ModelConfig, params: Params, tokens, targets, mask):
    return loss_fn(cfg, params, tokens, targets, mask)


def _out_params_desc(cfg: ModelConfig):
    return [_desc("param", n, s, "f32") for n, s in cfg.param_specs()]


# ===========================================================================
# shared forward / eval / first-order
# ===========================================================================

def build_fwd_loss(cfg: ModelConfig):
    p_args, p_desc = _param_inputs(cfg)
    b_args, b_desc = _batch_inputs(cfg)

    def fn(*args):
        params = unflatten_params(cfg, args[:len(p_args)])
        tokens, targets, mask = args[len(p_args):]
        return (_loss(cfg, params, tokens, targets, mask),)

    return fn, p_args + b_args, p_desc + b_desc, [_desc("scalar", "loss", (), "f32")]


def build_eval_logits(cfg: ModelConfig):
    p_args, p_desc = _param_inputs(cfg)
    b = cfg.batch
    extra = [_sds((b, cfg.seq_len), I32), _sds((b,), I32)]
    e_desc = [_desc("batch", "tokens", (b, cfg.seq_len), "i32"),
              _desc("batch", "positions", (b,), "i32")]

    def fn(*args):
        params = unflatten_params(cfg, args[:len(p_args)])
        tokens, positions = args[len(p_args):]
        return (eval_logits_fn(cfg, params, tokens, positions),)

    return fn, p_args + extra, p_desc + e_desc, \
        [_desc("tensor", "logits", (b, cfg.vocab), "f32")]


def build_fo_valgrad(cfg: ModelConfig):
    """loss + grads for the FT baseline and the Fig 1/5/6/7 spectra.

    Always uses the jnp forward path: pallas interpret kernels do not
    support reverse-mode autodiff (and the two paths are numerically
    interchangeable — asserted in python/tests/test_model.py).
    """
    import dataclasses
    dcfg = dataclasses.replace(cfg, use_pallas=False)
    p_args, p_desc = _param_inputs(cfg)
    b_args, b_desc = _batch_inputs(cfg)

    def fn(*args):
        flat = args[:len(p_args)]
        tokens, targets, mask = args[len(p_args):]

        def f(flat_params):
            return _loss(dcfg, unflatten_params(dcfg, flat_params), tokens,
                         targets, mask)

        loss, grads = jax.value_and_grad(f)(tuple(flat))
        return (loss,) + tuple(grads)

    out_desc = [_desc("scalar", "loss", (), "f32")] + \
        [_desc("grad", n, s, "f32") for n, s in cfg.param_specs()]
    return fn, p_args + b_args, p_desc + b_desc, out_desc


def build_fo_adam_update(cfg: ModelConfig):
    """Adam step for the FT baseline: full-size m, v state in/out."""
    p_args, p_desc = _param_inputs(cfg)
    g_args = [_sds(s) for _, s in cfg.param_specs()]
    g_desc = [_desc("grad", n, s, "f32") for n, s in cfg.param_specs()]
    m_args = [_sds(s) for _, s in cfg.param_specs()]
    m_desc = [_desc("state_m", n, s, "f32") for n, s in cfg.param_specs()]
    v_args = [_sds(s) for _, s in cfg.param_specs()]
    v_desc = [_desc("state_v", n, s, "f32") for n, s in cfg.param_specs()]
    s_lr, d_lr = _scalar("lr")
    s_b1, d_b1 = _scalar("beta1")
    s_b2, d_b2 = _scalar("beta2")
    s_eps, d_eps = _scalar("eps")
    s_t, d_t = _scalar("step_t")
    n = len(p_args)

    def fn(*args):
        params, grads = args[:n], args[n:2 * n]
        m, v = args[2 * n:3 * n], args[3 * n:4 * n]
        lr, b1, b2, eps, t = args[4 * n:]
        new_p, new_m, new_v = [], [], []
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        for p, g, mm, vv in zip(params, grads, m, v):
            mm = b1 * mm + (1.0 - b1) * g
            vv = b2 * vv + (1.0 - b2) * g * g
            upd = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            new_p.append(p - lr * upd)
            new_m.append(mm)
            new_v.append(vv)
        return tuple(new_p) + tuple(new_m) + tuple(new_v)

    inputs = p_args + g_args + m_args + v_args + [s_lr, s_b1, s_b2, s_eps, s_t]
    in_desc = p_desc + g_desc + m_desc + v_desc + [d_lr, d_b1, d_b2, d_eps, d_t]
    out_desc = _out_params_desc(cfg) + m_desc + v_desc
    return fn, inputs, in_desc, out_desc


# ===========================================================================
# MeZO family (Malladi et al. 2023) — dense Z from seed
# ===========================================================================

def build_mezo_loss_pm(cfg: ModelConfig):
    p_args, p_desc = _param_inputs(cfg)
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")

    def fn(*args):
        params = unflatten_params(cfg, args[:len(p_args)])
        tokens, targets, mask, seed, rho = args[len(p_args):]
        z = _all_normals(cfg, seed)
        f_plus = _loss(cfg, _perturbed(cfg, params, z, rho), tokens, targets, mask)
        f_minus = _loss(cfg, _perturbed(cfg, params, z, -rho), tokens, targets, mask)
        return f_plus, f_minus

    return fn, p_args + b_args + [s_seed, s_rho], \
        p_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_mezo_update_sgd(cfg: ModelConfig):
    p_args, p_desc = _param_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_c, d_c = _scalar("coeff")  # lr * kappa

    def fn(*args):
        params = unflatten_params(cfg, args[:len(p_args)])
        seed, coeff = args[len(p_args):]
        z = _all_normals(cfg, seed)
        out = _perturbed(cfg, params, z, -coeff)
        return tuple(out[n] for n, _ in cfg.param_specs())

    return fn, p_args + [s_seed, s_c], p_desc + [d_seed, d_c], _out_params_desc(cfg)


def build_mezo_update_m(cfg: ModelConfig):
    """MeZO-m: full-size momentum state in/out (honest memory accounting)."""
    p_args, p_desc = _param_inputs(cfg)
    m_args = [_sds(s) for _, s in cfg.param_specs()]
    m_desc = [_desc("state_m", n, s, "f32") for n, s in cfg.param_specs()]
    s_seed, d_seed = _scalar("seed", U32)
    s_k, d_k = _scalar("kappa")
    s_lr, d_lr = _scalar("lr")
    s_b1, d_b1 = _scalar("beta1")
    n = len(p_args)

    def fn(*args):
        params, m = args[:n], args[n:2 * n]
        seed, kappa, lr, b1 = args[2 * n:]
        z = _all_normals(cfg, seed)
        specs = cfg.param_specs()
        new_p, new_m = [], []
        for (name, _), p, mm in zip(specs, params, m):
            g = kappa * z[name]
            mm = b1 * mm + (1.0 - b1) * g
            new_p.append(p - lr * mm)
            new_m.append(mm)
        return tuple(new_p) + tuple(new_m)

    return fn, p_args + m_args + [s_seed, s_k, s_lr, s_b1], \
        p_desc + m_desc + [d_seed, d_k, d_lr, d_b1], \
        _out_params_desc(cfg) + m_desc


def build_mezo_update_adam(cfg: ModelConfig):
    """MeZO-Adam: full-size m and v state (the 3x memory row of Fig 3a)."""
    p_args, p_desc = _param_inputs(cfg)
    m_args = [_sds(s) for _, s in cfg.param_specs()]
    m_desc = [_desc("state_m", n, s, "f32") for n, s in cfg.param_specs()]
    v_args = [_sds(s) for _, s in cfg.param_specs()]
    v_desc = [_desc("state_v", n, s, "f32") for n, s in cfg.param_specs()]
    s_seed, d_seed = _scalar("seed", U32)
    s_k, d_k = _scalar("kappa")
    s_lr, d_lr = _scalar("lr")
    s_b1, d_b1 = _scalar("beta1")
    s_b2, d_b2 = _scalar("beta2")
    s_eps, d_eps = _scalar("eps")
    s_t, d_t = _scalar("step_t")
    n = len(p_args)

    def fn(*args):
        params, m, v = args[:n], args[n:2 * n], args[2 * n:3 * n]
        seed, kappa, lr, b1, b2, eps, t = args[3 * n:]
        z = _all_normals(cfg, seed)
        specs = cfg.param_specs()
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        new_p, new_m, new_v = [], [], []
        for (name, _), p, mm, vv in zip(specs, params, m, v):
            g = kappa * z[name]
            mm = b1 * mm + (1.0 - b1) * g
            vv = b2 * vv + (1.0 - b2) * g * g
            upd = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            new_p.append(p - lr * upd)
            new_m.append(mm)
            new_v.append(vv)
        return tuple(new_p) + tuple(new_m) + tuple(new_v)

    inputs = p_args + m_args + v_args + [s_seed, s_k, s_lr, s_b1, s_b2, s_eps, s_t]
    in_desc = p_desc + m_desc + v_desc + [d_seed, d_k, d_lr, d_b1, d_b2, d_eps, d_t]
    return fn, inputs, in_desc, _out_params_desc(cfg) + m_desc + v_desc


# ===========================================================================
# TeZO family (this paper)
# ===========================================================================

def build_tezo_loss_pm(cfg: ModelConfig, ranks: Dict[str, int]):
    p_args, p_desc = _param_inputs(cfg)
    f_args, f_desc = _factor_inputs(cfg, ranks)
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    n = len(p_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us, vs, (taus,), rest = _split_factors(cfg, args[n:], 1)
        tokens, targets, mask, seed, rho = rest
        vec_z = _vector_normals(cfg, seed)
        f_plus = _loss(cfg, _tezo_perturbed(cfg, params, us, vs, taus, vec_z, rho),
                       tokens, targets, mask)
        f_minus = _loss(cfg, _tezo_perturbed(cfg, params, us, vs, taus, vec_z, -rho),
                        tokens, targets, mask)
        return f_plus, f_minus

    return fn, p_args + f_args + b_args + [s_seed, s_rho], \
        p_desc + f_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def _pm_vec_params(cfg: ModelConfig, params: Params, seed, rho) -> Params:
    """(2, D) perturbed stacks ``[p + rho z, p - rho z]`` for every 1D param
    — the same seed-folded draws the materialized path and the update use."""
    vec_z = _vector_normals(cfg, seed)
    out = {}
    for name, _ in cfg.vector_params():
        p, z = params[name], vec_z[name]
        out[name] = jnp.stack([p + rho * z, p - rho * z])
    return out


def build_tezo_loss_pm_implicit(cfg: ModelConfig, ranks: Dict[str, int]):
    """Implicit (factor-form) TeZO two-point loss — identical calling
    convention to ``tezo_loss_pm``, but the rank-r perturbation is folded
    into the matmuls instead of materializing ``W +/- rho Z`` (see
    model.loss_pm_fn; manifest ``forward_form: implicit``)."""
    p_args, p_desc = _param_inputs(cfg)
    f_args, f_desc = _factor_inputs(cfg, ranks)
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    n = len(p_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us, vs, (taus,), rest = _split_factors(cfg, args[n:], 1)
        tokens, targets, mask, seed, rho = rest
        corr = {}
        for name, _ in cfg.matrix_params():
            tau_pm = jnp.stack([rho * taus[name], -rho * taus[name]])
            corr[name] = (us[name], vs[name], tau_pm)
        vec_pm = _pm_vec_params(cfg, params, seed, rho)
        return loss_pm_fn(cfg, params, corr, vec_pm, tokens, targets, mask)

    return fn, p_args + f_args + b_args + [s_seed, s_rho], \
        p_desc + f_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_tezo_update_factor(cfg: ModelConfig, ranks: Dict[str, int]):
    """Shared TeZO / TeZO-m update: ``W -= U diag(tau_eff) V^T``.

    tau_eff is computed by the Rust coordinator (lr*kappa*tau for plain TeZO,
    lr*tau_M for TeZO-m) — O(r) host work, which is the paper's entire point:
    momentum lives in the temporal factor.
    1D params: plain dense ZO-SGD with coeff1d = lr*kappa.
    """
    p_args, p_desc = _param_inputs(cfg)
    f_args, f_desc = _factor_inputs(cfg, ranks, taus=("tau_eff",))
    s_seed, d_seed = _scalar("seed", U32)
    s_c, d_c = _scalar("coeff1d")
    n = len(p_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us, vs, (tau_eff,), rest = _split_factors(cfg, args[n:], 1)
        seed, coeff1d = rest
        out = dict(params)
        for name, _ in cfg.matrix_params():
            if cfg.use_pallas:
                out[name] = kernels.tezo_sgd_update(params[name], us[name],
                                                    vs[name], tau_eff[name])
            else:
                out[name] = ref.tezo_sgd_update(params[name], us[name],
                                                vs[name], tau_eff[name])
        vec_z = _vector_normals(cfg, seed)
        for name, zz in vec_z.items():
            out[name] = out[name] - coeff1d * zz
        return tuple(out[nm] for nm, _ in cfg.param_specs())

    return fn, p_args + f_args + [s_seed, s_c], \
        p_desc + f_desc + [d_seed, d_c], _out_params_desc(cfg)


def build_tezo_update_adam(cfg: ModelConfig, ranks: Dict[str, int]):
    """TeZO-Adam lightweight update (paper Eq. 8).

    tau_m / tau_v are the Rust-held factorized moments, already
    bias-corrected host-side (both moments are linear in their tau vector,
    so correction commutes with reconstruction).
    """
    p_args, p_desc = _param_inputs(cfg)
    f_args, f_desc = _factor_inputs(cfg, ranks, taus=("tau_m", "tau_v"))
    s_seed, d_seed = _scalar("seed", U32)
    s_lr, d_lr = _scalar("lr")
    s_eps, d_eps = _scalar("eps")
    s_c, d_c = _scalar("coeff1d")
    n = len(p_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us, vs, (tau_m, tau_v), rest = _split_factors(cfg, args[n:], 2)
        seed, lr, eps, coeff1d = rest
        out = dict(params)
        for name, _ in cfg.matrix_params():
            if cfg.use_pallas:
                out[name] = kernels.tezo_adam_update(
                    params[name], us[name], vs[name], tau_m[name], tau_v[name],
                    lr, eps)
            else:
                out[name] = ref.tezo_adam_update(
                    params[name], us[name], vs[name], tau_m[name], tau_v[name],
                    lr, eps)
        vec_z = _vector_normals(cfg, seed)
        for name, zz in vec_z.items():
            out[name] = out[name] - coeff1d * zz
        return tuple(out[nm] for nm, _ in cfg.param_specs())

    return fn, p_args + f_args + [s_seed, s_lr, s_eps, s_c], \
        p_desc + f_desc + [d_seed, d_lr, d_eps, d_c], _out_params_desc(cfg)


# ===========================================================================
# LOZO (Chen et al. 2024) — Z = U V^T, V resampled per step, U lazy
# ===========================================================================

def _lozo_v(cfg: ModelConfig, seed, rank: int):
    """Per-matrix V_t ~ N(0,1)^{n x r} from fold_in(seed, matrix index)."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for idx, (name, (m, n)) in enumerate(cfg.matrix_params()):
        out[name] = jax.random.normal(jax.random.fold_in(key, 10_000 + idx),
                                      (n, rank), F32)
    return out


def build_lozo_init_u(cfg: ModelConfig, rank: int):
    """U factors for a lazy window: U_l ~ N(0,1)^{m x r} from the seed."""
    s_seed, d_seed = _scalar("seed", U32)

    def fn(seed):
        key = jax.random.PRNGKey(seed)
        outs = []
        for idx, (name, (m, n)) in enumerate(cfg.matrix_params()):
            outs.append(jax.random.normal(jax.random.fold_in(key, idx),
                                          (m, rank), F32))
        return tuple(outs)

    out_desc = [_desc("factor_u", n, (m, rank), "f32")
                for n, (m, _) in cfg.matrix_params()]
    return fn, [s_seed], [d_seed], out_desc


def build_lozo_loss_pm(cfg: ModelConfig, rank: int):
    p_args, p_desc = _param_inputs(cfg)
    u_args = [_sds((m, rank)) for _, (m, n) in cfg.matrix_params()]
    u_desc = [_desc("factor_u", n, (m, rank), "f32")
              for n, (m, _) in cfg.matrix_params()]
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    n = len(p_args)
    k = len(u_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        tokens, targets, mask, seed, rho = args[n + k:]
        v_t = _lozo_v(cfg, seed, rank)
        vec_z = _vector_normals(cfg, seed)

        def perturbed(scale):
            out = dict(params)
            for name, _ in cfg.matrix_params():
                out[name] = params[name] + scale * (us[name] @ v_t[name].T)
            for name, zz in vec_z.items():
                out[name] = params[name] + scale * zz
            return out

        f_plus = _loss(cfg, perturbed(rho), *args[n + k:n + k + 3])
        f_minus = _loss(cfg, perturbed(-rho), *args[n + k:n + k + 3])
        return f_plus, f_minus

    return fn, p_args + u_args + b_args + [s_seed, s_rho], \
        p_desc + u_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_lozo_loss_pm_implicit(cfg: ModelConfig, rank: int):
    """Implicit (factor-form) LOZO two-point loss — same calling convention
    as ``lozo_loss_pm``. ``Z = U V_t^T`` is ``U diag(tau) V_t^T`` with
    tau = 1, so the sign-batched correction is just ``tau_pm = [rho, -rho]``
    broadcast over the rank (manifest ``forward_form: implicit``)."""
    p_args, p_desc = _param_inputs(cfg)
    u_args = [_sds((m, rank)) for _, (m, n) in cfg.matrix_params()]
    u_desc = [_desc("factor_u", n, (m, rank), "f32")
              for n, (m, _) in cfg.matrix_params()]
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    n = len(p_args)
    k = len(u_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        tokens, targets, mask, seed, rho = args[n + k:]
        v_t = _lozo_v(cfg, seed, rank)
        ones = jnp.ones((rank,), F32)
        tau_pm = jnp.stack([rho * ones, -rho * ones])
        corr = {name: (us[name], v_t[name], tau_pm)
                for name, _ in cfg.matrix_params()}
        vec_pm = _pm_vec_params(cfg, params, seed, rho)
        return loss_pm_fn(cfg, params, corr, vec_pm, tokens, targets, mask)

    return fn, p_args + u_args + b_args + [s_seed, s_rho], \
        p_desc + u_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_lozo_update_sgd(cfg: ModelConfig, rank: int):
    p_args, p_desc = _param_inputs(cfg)
    u_args = [_sds((m, rank)) for _, (m, n) in cfg.matrix_params()]
    u_desc = [_desc("factor_u", n, (m, rank), "f32")
              for n, (m, _) in cfg.matrix_params()]
    s_seed, d_seed = _scalar("seed", U32)
    s_c, d_c = _scalar("coeff")
    n = len(p_args)
    k = len(u_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        seed, coeff = args[n + k:]
        v_t = _lozo_v(cfg, seed, rank)
        vec_z = _vector_normals(cfg, seed)
        out = dict(params)
        for name, _ in cfg.matrix_params():
            out[name] = params[name] - coeff * (us[name] @ v_t[name].T)
        for name, zz in vec_z.items():
            out[name] = params[name] - coeff * zz
        return tuple(out[nm] for nm, _ in cfg.param_specs())

    return fn, p_args + u_args + [s_seed, s_c], \
        p_desc + u_desc + [d_seed, d_c], _out_params_desc(cfg)


def build_lozo_update_m(cfg: ModelConfig, rank: int):
    """LOZO-m: momentum accumulated in the V-factor while U is frozen:
    ``S' = b1 S + (1-b1) kappa V_t``; ``W' = W - lr U S'^T``. State S is
    (n x r) per matrix — low-rank, matching LOZO's memory row in Table 7."""
    p_args, p_desc = _param_inputs(cfg)
    u_args = [_sds((m, rank)) for _, (m, n) in cfg.matrix_params()]
    u_desc = [_desc("factor_u", n, (m, rank), "f32")
              for n, (m, _) in cfg.matrix_params()]
    sarg = [_sds((n, rank)) for _, (m, n) in cfg.matrix_params()]
    sdesc = [_desc("state_s", n, (shape[1], rank), "f32")
             for n, shape in cfg.matrix_params()]
    s_seed, d_seed = _scalar("seed", U32)
    s_k, d_k = _scalar("kappa")
    s_lr, d_lr = _scalar("lr")
    s_b1, d_b1 = _scalar("beta1")
    n = len(p_args)
    k = len(u_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        ss = {nm: a for (nm, _), a in zip(cfg.matrix_params(),
                                          args[n + k:n + 2 * k])}
        seed, kappa, lr, b1 = args[n + 2 * k:]
        v_t = _lozo_v(cfg, seed, rank)
        vec_z = _vector_normals(cfg, seed)
        out = dict(params)
        new_s = {}
        for name, _ in cfg.matrix_params():
            s_new = b1 * ss[name] + (1.0 - b1) * kappa * v_t[name]
            new_s[name] = s_new
            out[name] = params[name] - lr * (us[name] @ s_new.T)
        for name, zz in vec_z.items():
            out[name] = params[name] - lr * kappa * zz
        return tuple(out[nm] for nm, _ in cfg.param_specs()) + \
            tuple(new_s[nm] for nm, _ in cfg.matrix_params())

    return fn, p_args + u_args + sarg + [s_seed, s_k, s_lr, s_b1], \
        p_desc + u_desc + sdesc + [d_seed, d_k, d_lr, d_b1], \
        _out_params_desc(cfg) + sdesc


# ===========================================================================
# SubZO (Yu et al. 2024) — Z = U Sigma V^T, orthonormal lazy U/V
# ===========================================================================

def _ns_orthonormalize(a, iters: int = 20):
    """Newton-Schulz polar orthonormalization in plain jnp ops.

    ``jnp.linalg.qr`` lowers to a typed-FFI LAPACK custom call that
    xla_extension 0.5.1 (the Rust runtime) cannot compile, and an unrolled
    Gram-Schmidt produces an O(r^2)-op graph that XLA:CPU is very slow to
    compile. Newton-Schulz needs two small matmuls per iteration
    (``Y <- 1.5 Y - 0.5 Y (Y^T Y)``) and converges quadratically to the
    polar factor (orthonormal columns) once the spectrum is scaled into
    (0, sqrt(3)). For tall Gaussian panels sigma ranges in
    [sqrt(m)-sqrt(r), sqrt(m)+sqrt(r)], so scaling by the upper edge keeps
    the spectrum well inside the basin.
    """
    m, r = a.shape
    scale = jnp.float32((m ** 0.5 + r ** 0.5) * 1.05)
    y = a / scale
    for _ in range(iters):
        y = 1.5 * y - 0.5 * y @ (y.T @ y)
    return y


def build_subzo_factors(cfg: ModelConfig, rank: int):
    """Orthonormal U, V per matrix via MGS of Gaussians (lazy refresh)."""
    s_seed, d_seed = _scalar("seed", U32)

    def fn(seed):
        key = jax.random.PRNGKey(seed)
        outs = []
        for idx, (name, (m, n)) in enumerate(cfg.matrix_params()):
            gu = jax.random.normal(jax.random.fold_in(key, 2 * idx), (m, rank), F32)
            gv = jax.random.normal(jax.random.fold_in(key, 2 * idx + 1), (n, rank), F32)
            outs.append(_ns_orthonormalize(gu))
            outs.append(_ns_orthonormalize(gv))
        return tuple(outs)

    out_desc = []
    for name, (m, n) in cfg.matrix_params():
        out_desc.append(_desc("factor_u", name, (m, rank), "f32"))
        out_desc.append(_desc("factor_v", name, (n, rank), "f32"))
    return fn, [s_seed], [d_seed], out_desc


def _subzo_sigma(cfg: ModelConfig, seed, rank: int):
    key = jax.random.PRNGKey(seed)
    out = {}
    for idx, (name, _) in enumerate(cfg.matrix_params()):
        out[name] = jax.random.normal(jax.random.fold_in(key, 20_000 + idx),
                                      (rank, rank), F32)
    return out


def build_subzo_loss_pm(cfg: ModelConfig, rank: int):
    p_args, p_desc = _param_inputs(cfg)
    uv_args, uv_desc = [], []
    for name, (m, n) in cfg.matrix_params():
        uv_args.append(_sds((m, rank)))
        uv_desc.append(_desc("factor_u", name, (m, rank), "f32"))
    for name, (m, n) in cfg.matrix_params():
        uv_args.append(_sds((n, rank)))
        uv_desc.append(_desc("factor_v", name, (n, rank), "f32"))
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    n = len(p_args)
    k = len(cfg.matrix_params())

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        vs = {nm: a for (nm, _), a in zip(cfg.matrix_params(),
                                          args[n + k:n + 2 * k])}
        tokens, targets, mask, seed, rho = args[n + 2 * k:]
        sig = _subzo_sigma(cfg, seed, rank)
        vec_z = _vector_normals(cfg, seed)

        def perturbed(scale):
            out = dict(params)
            for name, _ in cfg.matrix_params():
                out[name] = params[name] + scale * (us[name] @ sig[name] @ vs[name].T)
            for name, zz in vec_z.items():
                out[name] = params[name] + scale * zz
            return out

        f_plus = _loss(cfg, perturbed(rho), tokens, targets, mask)
        f_minus = _loss(cfg, perturbed(-rho), tokens, targets, mask)
        return f_plus, f_minus

    return fn, p_args + uv_args + b_args + [s_seed, s_rho], \
        p_desc + uv_desc + b_desc + [d_seed, d_rho], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_subzo_update(cfg: ModelConfig, rank: int):
    p_args, p_desc = _param_inputs(cfg)
    uv_args, uv_desc = [], []
    for name, (m, n) in cfg.matrix_params():
        uv_args.append(_sds((m, rank)))
        uv_desc.append(_desc("factor_u", name, (m, rank), "f32"))
    for name, (m, n) in cfg.matrix_params():
        uv_args.append(_sds((n, rank)))
        uv_desc.append(_desc("factor_v", name, (n, rank), "f32"))
    s_seed, d_seed = _scalar("seed", U32)
    s_c, d_c = _scalar("coeff")
    n = len(p_args)
    k = len(cfg.matrix_params())

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        us = {nm: a for (nm, _), a in zip(cfg.matrix_params(), args[n:n + k])}
        vs = {nm: a for (nm, _), a in zip(cfg.matrix_params(),
                                          args[n + k:n + 2 * k])}
        seed, coeff = args[n + 2 * k:]
        sig = _subzo_sigma(cfg, seed, rank)
        vec_z = _vector_normals(cfg, seed)
        out = dict(params)
        for name, _ in cfg.matrix_params():
            out[name] = params[name] - coeff * (us[name] @ sig[name] @ vs[name].T)
        for name, zz in vec_z.items():
            out[name] = params[name] - coeff * zz
        return tuple(out[nm] for nm, _ in cfg.param_specs())

    return fn, p_args + uv_args + [s_seed, s_c], \
        p_desc + uv_desc + [d_seed, d_c], _out_params_desc(cfg)


# ===========================================================================
# ZO-AdaMU (Jiang et al. 2024) — perturbation adapted by momentum+uncertainty
# ===========================================================================

def build_adamu_loss_pm(cfg: ModelConfig):
    """z_t = sqrt(1-alpha) z_rand + sqrt(alpha) m_pert — the perturbation is
    biased toward the momentum of past perturbation directions. m_pert is a
    full-size state tensor (ZO-AdaMU's memory is MeZO-Adam-like)."""
    p_args, p_desc = _param_inputs(cfg)
    m_args = [_sds(s) for _, s in cfg.param_specs()]
    m_desc = [_desc("state_mpert", n, s, "f32") for n, s in cfg.param_specs()]
    b_args, b_desc = _batch_inputs(cfg)
    s_seed, d_seed = _scalar("seed", U32)
    s_rho, d_rho = _scalar("rho")
    s_a, d_a = _scalar("alpha")
    n = len(p_args)

    def fn(*args):
        params = unflatten_params(cfg, args[:n])
        m = {nm: a for (nm, _), a in zip(cfg.param_specs(), args[n:2 * n])}
        tokens, targets, mask, seed, rho, alpha = args[2 * n:]
        z_rand = _all_normals(cfg, seed)
        z = {nm: jnp.sqrt(1.0 - alpha) * z_rand[nm] + jnp.sqrt(alpha) * m[nm]
             for nm in z_rand}
        f_plus = _loss(cfg, _perturbed(cfg, params, z, rho), tokens, targets, mask)
        f_minus = _loss(cfg, _perturbed(cfg, params, z, -rho), tokens, targets, mask)
        return f_plus, f_minus

    return fn, p_args + m_args + b_args + [s_seed, s_rho, s_a], \
        p_desc + m_desc + b_desc + [d_seed, d_rho, d_a], \
        [_desc("scalar", "f_plus", (), "f32"), _desc("scalar", "f_minus", (), "f32")]


def build_adamu_update(cfg: ModelConfig):
    """Adam-style update on g = kappa z, plus momentum of z itself."""
    p_args, p_desc = _param_inputs(cfg)
    m_args = [_sds(s) for _, s in cfg.param_specs()]
    m_desc = [_desc("state_mpert", n, s, "f32") for n, s in cfg.param_specs()]
    v_args = [_sds(s) for _, s in cfg.param_specs()]
    v_desc = [_desc("state_v", n, s, "f32") for n, s in cfg.param_specs()]
    s_seed, d_seed = _scalar("seed", U32)
    s_k, d_k = _scalar("kappa")
    s_lr, d_lr = _scalar("lr")
    s_a, d_a = _scalar("alpha")
    s_b1, d_b1 = _scalar("beta1")
    s_b2, d_b2 = _scalar("beta2")
    s_eps, d_eps = _scalar("eps")
    s_t, d_t = _scalar("step_t")
    n = len(p_args)

    def fn(*args):
        params, m, v = args[:n], args[n:2 * n], args[2 * n:3 * n]
        seed, kappa, lr, alpha, b1, b2, eps, t = args[3 * n:]
        z_rand = _all_normals(cfg, seed)
        specs = cfg.param_specs()
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        new_p, new_m, new_v = [], [], []
        for (name, _), p, mm, vv in zip(specs, params, m, v):
            z = jnp.sqrt(1.0 - alpha) * z_rand[name] + jnp.sqrt(alpha) * mm
            g = kappa * z
            mm_new = b1 * mm + (1.0 - b1) * z
            vv_new = b2 * vv + (1.0 - b2) * g * g
            upd = (g / bc1) / (jnp.sqrt(vv_new / bc2) + eps)
            new_p.append(p - lr * upd)
            new_m.append(mm_new)
            new_v.append(vv_new)
        return tuple(new_p) + tuple(new_m) + tuple(new_v)

    inputs = p_args + m_args + v_args + \
        [s_seed, s_k, s_lr, s_a, s_b1, s_b2, s_eps, s_t]
    in_desc = p_desc + m_desc + v_desc + \
        [d_seed, d_k, d_lr, d_a, d_b1, d_b2, d_eps, d_t]
    return fn, inputs, in_desc, _out_params_desc(cfg) + m_desc + v_desc


# ===========================================================================
# standalone per-shape kernel microbench artifacts (Table 2 / Fig 3b support)
# ===========================================================================

def build_kernel_tezo_perturb(m: int, n: int, r: int):
    """Standalone pallas tezo_perturb for one shape — L1 microbenchmarks."""
    args = [_sds((m, n)), _sds((m, r)), _sds((n, r)), _sds((r,)), _sds((), F32)]
    desc = [_desc("tensor", "w", (m, n), "f32"),
            _desc("factor_u", "u", (m, r), "f32"),
            _desc("factor_v", "v", (n, r), "f32"),
            _desc("tau", "tau", (r,), "f32"),
            _desc("scalar", "rho", (), "f32")]

    def fn(w, u, v, tau, rho):
        return (kernels.tezo_perturb(w, u, v, tau, rho),)

    return fn, args, desc, [_desc("tensor", "out", (m, n), "f32")]


def build_kernel_mezo_perturb(m: int, n: int):
    """Standalone dense seed-based perturb for one shape (MeZO baseline)."""
    args = [_sds((m, n)), _sds((), U32), _sds((), F32)]
    desc = [_desc("tensor", "w", (m, n), "f32"),
            _desc("scalar", "seed", (), "u32"),
            _desc("scalar", "rho", (), "f32")]

    def fn(w, seed, rho):
        z = jax.random.normal(jax.random.PRNGKey(seed), (m, n), F32)
        return (kernels.axpy_perturb(w, z, rho),)

    return fn, args, desc, [_desc("tensor", "out", (m, n), "f32")]
