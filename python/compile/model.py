"""L2: OPTLite — decoder-only transformer LM in JAX, calling the L1 kernels.

Functional style: parameters are a dict ``name -> jax.Array`` whose order is
fixed by ``ModelConfig.param_specs()`` (that order is the artifact calling
convention — see aot.py / manifest.json).

ZO fine-tuning is forward-only, so ``loss_fn`` is the request-path hot spot.
``config.use_pallas`` routes attention + cross-entropy through the Pallas
kernels (exercised end-to-end by the ``tiny`` config artifacts); the jnp path
(``kernels.ref``) is numerically interchangeable and faster under CPU XLA for
the larger experiment configs.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .configs import ModelConfig
from .kernels import ref

Params = Dict[str, jax.Array]

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Initialize parameters with a planted low-rank + dense mixture.

    Pretrained LLM weights are approximately low-rank (paper App. A.1.3); the
    Eq.(7) rank schedule and the Fig 1/5/7 analyses are only meaningful if the
    weights have non-trivial spectra, so each 2D weight is
    ``(1-g) * dense + g * (A @ B) / sqrt(k)`` with ``k = init_rank_frac *
    min(m, n)``. Documented substitution — DESIGN.md §2.
    """
    rng = np.random.default_rng(seed)
    params: Params = {}
    for name, shape in cfg.param_specs():
        if len(shape) == 1:
            if name.endswith(".g"):
                arr = np.ones(shape, np.float32)
            else:
                arr = np.zeros(shape, np.float32)
        else:
            m, n = shape
            std = 0.02
            dense = rng.normal(0.0, std, size=(m, n))
            k = max(2, int(cfg.init_rank_frac * min(m, n)))
            a = rng.normal(0.0, std, size=(m, k))
            b = rng.normal(0.0, 1.0 / np.sqrt(k), size=(k, n))
            g = cfg.init_lowrank_weight
            arr = ((1.0 - g) * dense + g * (a @ b)).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def flatten_params(cfg: ModelConfig, params: Params) -> Tuple[jax.Array, ...]:
    return tuple(params[n] for n, _ in cfg.param_specs())


def unflatten_params(cfg: ModelConfig, flat) -> Params:
    return {n: a for (n, _), a in zip(cfg.param_specs(), flat)}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _causal_mask(s: int) -> jax.Array:
    return jnp.where(jnp.tril(jnp.ones((s, s), jnp.float32)) > 0, 0.0, NEG_INF)


def _block(cfg: ModelConfig, params: Params, i: int, x: jax.Array,
           mask: jax.Array) -> jax.Array:
    p = f"block{i}."
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    attn_in = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
    q = (attn_in @ params[p + "attn.wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (attn_in @ params[p + "attn.wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (attn_in @ params[p + "attn.wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    attn_fn = kernels.attention if cfg.use_pallas else ref.attention
    o = attn_fn(q, k, v, mask)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ params[p + "attn.wo"]
    ffn_in = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
    hdd = jax.nn.gelu(ffn_in @ params[p + "ffn.w1"])
    return x + hdd @ params[p + "ffn.w2"]


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    b, s = tokens.shape
    x = params["embed.tok"][tokens] + params["embed.pos"][None, :s, :]
    mask = _causal_mask(s)
    for i in range(cfg.n_layers):
        x = _block(cfg, params, i, x, mask)
    x = _layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    head = params["embed.tok"].T if cfg.tie_lm_head else params["lm_head"]
    return x @ head


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            targets: jax.Array, loss_mask: jax.Array) -> jax.Array:
    """Masked LM loss — classification-as-LM uses a mask selecting the
    verbalizer position(s), exactly the MeZO evaluation protocol."""
    logits = logits_fn(cfg, params, tokens)
    ce_fn = kernels.cross_entropy if cfg.use_pallas else ref.cross_entropy
    return ce_fn(logits, targets, loss_mask)


def eval_logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """Logits at one position per row (the verbalizer slot).

    positions: (B,) int32 -> (B, V).
    """
    logits = logits_fn(cfg, params, tokens)
    return jax.vmap(lambda row, p: row[p])(logits, positions)


# ---------------------------------------------------------------------------
# Implicit (factor-form) two-point forward
# ---------------------------------------------------------------------------
#
# The materialized two-point path builds two full dense copies of every
# matrix weight (`W + rho Z` and `W - rho Z`) before the forward even starts
# — O(d) temp memory and 4x weight-sized read/write traffic per step. When
# the perturbation is rank-r (`Z = U diag(tau) V^T`, TeZO Eq. 3; `Z = U V^T`,
# LOZO), the correction folds into the matmul itself:
#
#     x @ (W + s Z) = x @ W + ((x @ U) * (s tau)) @ V^T
#
# which reads W once and adds only O((m+n) r) work. The +/- branches ride a
# *leading sign axis of 2*: activations are (2, B, S, D), each dense `x @ W`
# is a single dot whose W operand is read once for both branches, and the
# per-branch signs live in the tiny (2, r) tau stacks. 1D layernorm params
# stay densely seed-perturbed, stacked as (2, D) pairs.
#
# Attention folds the sign axis into its batch dimension (one call for both
# branches — it has no weights, so nothing is re-read), and the
# cross-entropy reduction runs per branch off the shared logits tensor, so
# the softmax temporaries stay single-branch-sized.
#
# The implicit path always lowers through the fused-jnp kernels (ref.*),
# regardless of ``cfg.use_pallas``: interpret-mode Pallas adds per-call
# overhead that this batched lowering exists to avoid, and the implicit
# forward contains no perturbation kernels at all (that is the point). The
# L1 Pallas composition stays exercised by the materialized artifacts —
# still selectable via ``forward_form`` — and the TPU mapping of the fused
# contraction lives in kernels/lowrank_matmul.py with its own oracle tests.

# Per-matrix low-rank correction: u (k, r), v (n, r), tau_pm (2, r) where
# tau_pm already folds the per-branch sign and rho: [rho*tau, -rho*tau].
LowRankPM = Dict[str, Tuple[jax.Array, jax.Array, jax.Array]]


def pm_matmul(x: jax.Array, w: jax.Array, corr) -> jax.Array:
    """Sign-batched perturbed matmul ``x @ (W +/- rho Z)`` in factor form.

    x: (2, ..., k) with the leading sign axis; w: (k, n); corr: None or
    ``(u, v, tau_pm)``. W is read by exactly one dot for both branches.
    """
    y = x @ w
    if corr is not None:
        u, v, tau_pm = corr
        t = tau_pm.reshape((2,) + (1,) * (x.ndim - 2) + (tau_pm.shape[-1],))
        y = y + ((x @ u) * t) @ v.T
    return y


def _pm_ln(x: jax.Array, g_pm: jax.Array, b_pm: jax.Array) -> jax.Array:
    """Layer norm with per-branch (2, D) perturbed gain/bias stacks."""
    return _layer_norm(x, g_pm[:, None, None, :], b_pm[:, None, None, :])


def _pm_attention(cfg: ModelConfig, q, k, v, mask):
    """Attention over sign-batched (2, B, S, D) q/k/v: the sign axis folds
    into the kernel's batch dimension (2B), so one call serves both
    branches. Attention has no weights — nothing is read twice."""
    two, b, s, d = q.shape
    h, dh = cfg.n_heads, cfg.d_head
    attn_fn = ref.attention  # fused-jnp lowering (see module comment above)
    qf = q.reshape(2 * b, s, h, dh).transpose(0, 2, 1, 3)
    kf = k.reshape(2 * b, s, h, dh).transpose(0, 2, 1, 3)
    vf = v.reshape(2 * b, s, h, dh).transpose(0, 2, 1, 3)
    o = attn_fn(qf, kf, vf, mask)
    return o.transpose(0, 2, 1, 3).reshape(2, b, s, d)


def _pm_block(cfg: ModelConfig, params: Params, corr: LowRankPM,
              vec_pm: Params, i: int, x: jax.Array,
              mask: jax.Array) -> jax.Array:
    p = f"block{i}."
    attn_in = _pm_ln(x, vec_pm[p + "ln1.g"], vec_pm[p + "ln1.b"])
    q = pm_matmul(attn_in, params[p + "attn.wq"], corr.get(p + "attn.wq"))
    k = pm_matmul(attn_in, params[p + "attn.wk"], corr.get(p + "attn.wk"))
    v = pm_matmul(attn_in, params[p + "attn.wv"], corr.get(p + "attn.wv"))
    o = _pm_attention(cfg, q, k, v, mask)
    x = x + pm_matmul(o, params[p + "attn.wo"], corr.get(p + "attn.wo"))
    ffn_in = _pm_ln(x, vec_pm[p + "ln2.g"], vec_pm[p + "ln2.b"])
    hdd = jax.nn.gelu(pm_matmul(ffn_in, params[p + "ffn.w1"],
                                corr.get(p + "ffn.w1")))
    return x + pm_matmul(hdd, params[p + "ffn.w2"], corr.get(p + "ffn.w2"))


def _pm_body(cfg: ModelConfig, params: Params, corr: LowRankPM,
             vec_pm: Params, tokens: jax.Array) -> jax.Array:
    """Sign-batched transformer body: tokens (B, S) -> x (2, B, S, D)."""
    b, s = tokens.shape
    tok_w = params["embed.tok"]
    x = tok_w[tokens][None]  # (1, B, S, D); broadcasts to 2 below
    c = corr.get("embed.tok")
    if c is not None:
        u, v, tau_pm = c
        # Z[tokens] = (U[tokens] * tau) @ V^T — the embedding gather only
        # touches the (B*S, r) slice of U, never a dense (V, D) copy
        x = x + ((u[tokens][None] * tau_pm[:, None, None, :]) @ v.T)
    pos = params["embed.pos"][None, None, :s, :]
    cp = corr.get("embed.pos")
    if cp is not None:
        u, v, tau_pm = cp
        pos = pos + ((u[None, :s] * tau_pm[:, None, :]) @ v.T)[:, None, :, :]
    x = x + pos
    x = jnp.broadcast_to(x, (2, b, s, cfg.d_model))
    mask = _causal_mask(s)
    for i in range(cfg.n_layers):
        x = _pm_block(cfg, params, corr, vec_pm, i, x, mask)
    return _pm_ln(x, vec_pm["final_ln.g"], vec_pm["final_ln.b"])


def _pm_head(cfg: ModelConfig, params: Params, corr: LowRankPM,
             x: jax.Array) -> jax.Array:
    """Sign-batched logits (2, B, S, V): the head weight — the single
    largest matrix — is read by one dot for both branches, like every other
    matmul in the body."""
    if cfg.tie_lm_head:
        w = params["embed.tok"]
        logits = x @ w.T
        c = corr.get("embed.tok")
        if c is not None:
            u, v, tau_pm = c
            # (U diag(tau) V^T)^T = V diag(tau) U^T
            logits = logits + ((x @ v) * tau_pm[:, None, None, :]) @ u.T
        return logits
    return pm_matmul(x, params["lm_head"], corr.get("lm_head"))


def loss_pm_fn(cfg: ModelConfig, params: Params, corr: LowRankPM,
               vec_pm: Params, tokens: jax.Array, targets: jax.Array,
               loss_mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Fused two-point loss ``(f(W + rho Z), f(W - rho Z))`` in factor form.

    corr maps matrix names to ``(u, v, tau_pm)`` with tau_pm (2, r) already
    folding sign*rho; vec_pm maps every 1D param name to its (2, D)
    perturbed stack. Matrices absent from corr pass through unperturbed.
    The cross-entropy reduction runs per branch off the shared logits
    tensor, keeping the softmax temporaries single-branch-sized.
    """
    x = _pm_body(cfg, params, corr, vec_pm, tokens)
    logits = _pm_head(cfg, params, corr, x)
    ce_fn = ref.cross_entropy  # fused-jnp lowering (see module comment above)
    f_plus = ce_fn(logits[0], targets, loss_mask)
    f_minus = ce_fn(logits[1], targets, loss_mask)
    return f_plus, f_minus


# ---------------------------------------------------------------------------
# Perturbation builder shared by the ZO step functions (zo_steps.py)
# ---------------------------------------------------------------------------

def dense_normal_like(key: jax.Array, specs: List[Tuple[str, Tuple[int, ...]]]):
    """Per-parameter standard normals, each from fold_in(key, index) — the
    MeZO resampling technique: identical draws for perturb and update given
    the same step seed, no stored state."""
    out = {}
    for idx, (name, shape) in enumerate(specs):
        out[name] = jax.random.normal(jax.random.fold_in(key, idx), shape,
                                      jnp.float32)
    return out
