"""L2: OPTLite — decoder-only transformer LM in JAX, calling the L1 kernels.

Functional style: parameters are a dict ``name -> jax.Array`` whose order is
fixed by ``ModelConfig.param_specs()`` (that order is the artifact calling
convention — see aot.py / manifest.json).

ZO fine-tuning is forward-only, so ``loss_fn`` is the request-path hot spot.
``config.use_pallas`` routes attention + cross-entropy through the Pallas
kernels (exercised end-to-end by the ``tiny`` config artifacts); the jnp path
(``kernels.ref``) is numerically interchangeable and faster under CPU XLA for
the larger experiment configs.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .configs import ModelConfig
from .kernels import ref

Params = Dict[str, jax.Array]

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Initialize parameters with a planted low-rank + dense mixture.

    Pretrained LLM weights are approximately low-rank (paper App. A.1.3); the
    Eq.(7) rank schedule and the Fig 1/5/7 analyses are only meaningful if the
    weights have non-trivial spectra, so each 2D weight is
    ``(1-g) * dense + g * (A @ B) / sqrt(k)`` with ``k = init_rank_frac *
    min(m, n)``. Documented substitution — DESIGN.md §2.
    """
    rng = np.random.default_rng(seed)
    params: Params = {}
    for name, shape in cfg.param_specs():
        if len(shape) == 1:
            if name.endswith(".g"):
                arr = np.ones(shape, np.float32)
            else:
                arr = np.zeros(shape, np.float32)
        else:
            m, n = shape
            std = 0.02
            dense = rng.normal(0.0, std, size=(m, n))
            k = max(2, int(cfg.init_rank_frac * min(m, n)))
            a = rng.normal(0.0, std, size=(m, k))
            b = rng.normal(0.0, 1.0 / np.sqrt(k), size=(k, n))
            g = cfg.init_lowrank_weight
            arr = ((1.0 - g) * dense + g * (a @ b)).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def flatten_params(cfg: ModelConfig, params: Params) -> Tuple[jax.Array, ...]:
    return tuple(params[n] for n, _ in cfg.param_specs())


def unflatten_params(cfg: ModelConfig, flat) -> Params:
    return {n: a for (n, _), a in zip(cfg.param_specs(), flat)}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _causal_mask(s: int) -> jax.Array:
    return jnp.where(jnp.tril(jnp.ones((s, s), jnp.float32)) > 0, 0.0, NEG_INF)


def _block(cfg: ModelConfig, params: Params, i: int, x: jax.Array,
           mask: jax.Array) -> jax.Array:
    p = f"block{i}."
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    attn_in = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
    q = (attn_in @ params[p + "attn.wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (attn_in @ params[p + "attn.wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (attn_in @ params[p + "attn.wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    attn_fn = kernels.attention if cfg.use_pallas else ref.attention
    o = attn_fn(q, k, v, mask)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ params[p + "attn.wo"]
    ffn_in = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
    hdd = jax.nn.gelu(ffn_in @ params[p + "ffn.w1"])
    return x + hdd @ params[p + "ffn.w2"]


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    b, s = tokens.shape
    x = params["embed.tok"][tokens] + params["embed.pos"][None, :s, :]
    mask = _causal_mask(s)
    for i in range(cfg.n_layers):
        x = _block(cfg, params, i, x, mask)
    x = _layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    head = params["embed.tok"].T if cfg.tie_lm_head else params["lm_head"]
    return x @ head


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            targets: jax.Array, loss_mask: jax.Array) -> jax.Array:
    """Masked LM loss — classification-as-LM uses a mask selecting the
    verbalizer position(s), exactly the MeZO evaluation protocol."""
    logits = logits_fn(cfg, params, tokens)
    ce_fn = kernels.cross_entropy if cfg.use_pallas else ref.cross_entropy
    return ce_fn(logits, targets, loss_mask)


def eval_logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """Logits at one position per row (the verbalizer slot).

    positions: (B,) int32 -> (B, V).
    """
    logits = logits_fn(cfg, params, tokens)
    return jax.vmap(lambda row, p: row[p])(logits, positions)


# ---------------------------------------------------------------------------
# Perturbation builder shared by the ZO step functions (zo_steps.py)
# ---------------------------------------------------------------------------

def dense_normal_like(key: jax.Array, specs: List[Tuple[str, Tuple[int, ...]]]):
    """Per-parameter standard normals, each from fold_in(key, index) — the
    MeZO resampling technique: identical draws for perturb and update given
    the same step seed, no stored state."""
    out = {}
    for idx, (name, shape) in enumerate(specs):
        out[name] = jax.random.normal(jax.random.fold_in(key, idx), shape,
                                      jnp.float32)
    return out
