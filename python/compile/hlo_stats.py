"""Build-time mirror of the Rust HLO-text analyzer (rust/src/runtime/hlo_stats.rs).

Same per-computation liveness scan over the SSA instruction stream:
allocate each non-parameter result at its definition, free it after its
last use; the maximum live set is the static peak-temporary footprint.
The AOT pipeline uses this to report the materialize-vs-implicit peak-temp
reduction at build time (python/bench_forward_forms.py emits BENCH_PR5.json
from it); the Rust side computes the identical number at run time for
`tezo inspect --hlo` and the forward_forms test.

Keep the two implementations in lockstep: the acceptance numbers are
stated on this metric.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4, "i32": 4,
    "f16": 2, "bf16": 2, "s16": 2, "u16": 2,
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
}

_IDENT = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789.-_")


def _shape_bytes(shape_part: str) -> int:
    """Total bytes of every array shape in a result type like
    ``f32[64,256]{1,0}`` or ``(f32[2], u32[])``."""
    total = 0
    for m in re.finditer(r"([a-z]+[0-9]*)\[([0-9,\s]*)\]", shape_part):
        dt, dims = m.group(1), m.group(2)
        elems = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                elems *= int(d)
        total += elems * _DTYPE_BYTES.get(dt, 4)
    return total


def _parse_operands(after_shape: str) -> List[str]:
    """Identifiers inside the first top-level paren group after the op."""
    open_i = after_shape.find("(")
    if open_i < 0:
        return []
    depth = 0
    end = len(after_shape)
    for i in range(open_i, len(after_shape)):
        c = after_shape[i]
        if c in "({":
            depth += 1
        elif c in ")}":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = after_shape[open_i + 1:end]
    out, depth, start = [], 0, 0
    for i in range(len(inner) + 1):
        top_comma = i == len(inner) or (inner[i] == "," and depth == 0)
        if i < len(inner):
            if inner[i] in "({[":
                depth += 1
            elif inner[i] in ")}]":
                depth = max(0, depth - 1)
        if top_comma:
            tok = inner[start:i].strip().rsplit(" ", 1)[-1].lstrip("%")
            ident = ""
            for c in tok:
                if c in _IDENT:
                    ident += c
                else:
                    break
            if ident and ident == tok:
                out.append(ident)
            start = i + 1
    return out


def _liveness_peak(comp: List[Tuple[str, int, bool, List[str]]]) -> int:
    if not comp:
        return 0
    index = {name: i for i, (name, _, _, _) in enumerate(comp)}
    last_use: Dict[int, int] = {}
    for i, (_, _, _, operands) in enumerate(comp):
        for op in operands:
            j = index.get(op)
            if j is not None:
                last_use[j] = i
    frees: Dict[int, List[int]] = {}
    for j, i in last_use.items():
        frees.setdefault(i, []).append(j)
    live = peak = 0
    for i, (_, nbytes, is_param, _) in enumerate(comp):
        if not is_param:
            live += nbytes
            peak = max(peak, live)
        for j in frees.get(i, []):
            if not comp[j][2] and j != i:
                live -= comp[j][1]
    return peak


def _computations(text: str):
    """Instruction streams per computation:
    ``(name, bytes, is_param, operands, shape)`` tuples."""
    comp: List[Tuple[str, int, bool, List[str], str]] = []
    for line in text.splitlines():
        t = line.lstrip()
        if t.startswith("}"):
            if comp:
                yield comp
            comp = []
            continue
        eq = t.find(" = ")
        if eq < 0:
            continue
        lhs = t[:eq]
        if lhs.startswith("ROOT "):
            lhs = lhs[len("ROOT "):]
        lhs = lhs.lstrip("%")
        if not lhs or any(c not in _IDENT for c in lhs):
            continue
        rest = t[eq + 3:]
        sp = rest.find(" ")
        if sp < 0:
            continue
        shape_part, after_shape = rest[:sp], rest[sp + 1:]
        op = after_shape.split("(")[0].strip()
        if not op:
            continue
        comp.append((lhs, _shape_bytes(shape_part), op == "parameter",
                     _parse_operands(after_shape),
                     shape_part.split("{")[0]))
    if comp:
        yield comp


def peak_temp_bytes(text: str) -> int:
    """Max per-computation liveness peak over an HLO module text."""
    return max((_liveness_peak([c[:4] for c in comp])
                for comp in _computations(text)), default=0)


def stats(text: str) -> Dict[str, int]:
    """All three temp metrics, mirroring Rust ``HloStats``:

    * ``peak_temp_bytes`` — full liveness peak (every value);
    * ``peak_param_temp_bytes`` — liveness peak over parameter-shaped
      values only (the materialized perturbed-weight copies);
    * ``param_temp_total_bytes`` — total parameter-shaped temp allocation
      per call (the weight-copy traffic of one two-point evaluation).
    """
    out = {"peak_temp_bytes": 0, "peak_param_temp_bytes": 0,
           "param_temp_total_bytes": 0}
    for comp in _computations(text):
        out["peak_temp_bytes"] = max(out["peak_temp_bytes"],
                                     _liveness_peak([c[:4] for c in comp]))
        pshapes = {c[4] for c in comp if c[2] and "," in c[4]}
        scan = [(name, b if shape in pshapes else 0, is_param, ops)
                for (name, b, is_param, ops, shape) in comp]
        out["peak_param_temp_bytes"] = max(out["peak_param_temp_bytes"],
                                           _liveness_peak(scan))
        out["param_temp_total_bytes"] += sum(
            b for (_, b, is_param, _, shape) in comp
            if not is_param and shape in pshapes)
    return out
