"""L1 Pallas kernel: fused causal attention for the forward hot path.

ZO fine-tuning is forward-only, so the model forward IS the request-path
hot spot (two forwards per step). This kernel fuses
``softmax(Q K^T / sqrt(dh) + mask) V`` per (batch, head) with the full
sequence block resident in VMEM — at the paper's fine-tuning sequence
lengths (<= a few hundred tokens) one (S, dh) tile per head fits easily, so
no online-softmax streaming is needed; the QK^T and PV products both run on
the MXU.

interpret=True: see tezo_perturb.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    q = q_ref[0]            # (S, dh)
    k = k_ref[0]
    v = v_ref[0]
    dh = q.shape[-1]
    scale = (1.0 / (dh ** 0.5))
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    logits = logits + mask_ref[...]
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32).astype(v.dtype)


@jax.jit
def attention(q, k, v, mask):
    """Fused causal attention via Pallas.

    q,k,v: (B, H, S, dh); mask: (S, S) additive. Grid over (B, H); one
    (S, dh) block per program instance.
    """
    b, h, s, dh = q.shape
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    out = pl.pallas_call(
        _attn_kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((s, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf, mask)
    return out.reshape(b, h, s, dh)
