"""L1 Pallas kernel: dense fused ``W' = W + alpha * Z``.

The MeZO-family baselines perturb/update with a *dense* Gaussian Z. The
fusion story is the same as tezo_perturb (read W once, write once) but with
arithmetic intensity ~1 FLOP per element — this kernel exists so the
baseline's hot path is optimized identically and Table 8 / Fig 3(b)
comparisons measure the estimator difference, not implementation slack.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tezo_perturb import _pick_block


def _axpy_kernel(w_ref, z_ref, a_ref, o_ref):
    o_ref[...] = w_ref[...] + a_ref[0] * z_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def axpy_perturb(w, z, alpha, *, bm: int = 256, bn: int = 256):
    """``W + alpha * Z`` via Pallas; w, z: (m, n), alpha: scalar."""
    m, n = w.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    a = jnp.reshape(jnp.asarray(alpha, w.dtype), (1,))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, z, a)
