"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/ranks/dtypes with hypothesis and asserts the Pallas kernels
(interpret mode) match these reference implementations to float tolerance.

They are also used directly by the L2 model when ``config.use_pallas`` is
False (the jnp path and the pallas path are interchangeable by construction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# TeZO perturbation / update math (paper Eq. 3, Alg. 1)
# ---------------------------------------------------------------------------

def tezo_z(u: jax.Array, v: jax.Array, tau: jax.Array) -> jax.Array:
    """CPD slice at time t: ``Z_t = sum_s tau_s * (u_s ∘ v_s) = U diag(tau) V^T``.

    u: (m, r), v: (n, r), tau: (r,) -> (m, n).
    """
    return (u * tau[None, :]) @ v.T


def tezo_perturb(w, u, v, tau, rho):
    """``W + rho * Z_t`` — the TeZO perturbation step."""
    return w + rho * tezo_z(u, v, tau)


def tezo_sgd_update(w, u, v, tau_eff):
    """``W - U diag(tau_eff) V^T`` where ``tau_eff`` already folds in
    ``eta * kappa`` (plain TeZO) or ``eta * tau_M`` (TeZO-m)."""
    return w - tezo_z(u, v, tau_eff)


def tezo_adam_update(w, u, v, tau_m, tau_v, lr, eps):
    """Lightweight TeZO-Adam update (paper Eq. 8, separable second moment).

    ``M = U diag(tau_m) V^T``; ``V = U^2 diag(tau_v) (V^2)^T``;
    ``W' = W - lr * M / sqrt(V + eps)``.
    """
    m = tezo_z(u, v, tau_m)
    vv = tezo_z(u * u, v * v, tau_v)
    return w - lr * m / jnp.sqrt(vv + eps)


def axpy_perturb(w, z, alpha):
    """Dense fused ``W + alpha * Z`` (MeZO-family perturb/update)."""
    return w + alpha * z


def lowrank_matmul(x, w, u, v, tau):
    """Sign-batched implicit perturbed matmul (the factor-form forward's
    core contraction): ``y[b] = x[b] @ W + ((x[b] @ U) * tau[b]) @ V^T``.

    x: (2, m, k); w: (k, n); u: (k, r); v: (n, r); tau: (2, r) -> (2, m, n).
    """
    return x @ w + ((x @ u) * tau[:, None, :]) @ v.T


# ---------------------------------------------------------------------------
# Transformer forward-path kernels
# ---------------------------------------------------------------------------

def attention(q, k, v, mask):
    """Causal scaled-dot-product attention.

    q,k,v: (B, H, S, Dh); mask: (S, S) additive (0 / large negative).
    """
    dh = q.shape[-1]
    scale = jnp.asarray(1.0 / (dh ** 0.5), dtype=q.dtype)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    logits = logits + mask[None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v)


def cross_entropy(logits, targets, mask):
    """Masked mean token cross-entropy.

    logits: (B, S, V); targets: (B, S) int32; mask: (B, S) float.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / denom
