"""L1 Pallas kernel: fused TeZO perturbation ``W' = W + rho * U diag(tau) V^T``.

This is the paper's per-step CPD extraction (Eq. 3) fused with the weight
read-modify-write, i.e. the ZO analogue of an axpy with a rank-r
reconstruction on the fly.

TPU mapping (DESIGN.md §4): the weight is tiled into (bm, bn) VMEM blocks;
the (bm, r) slice of U and (bn, r) slice of V ride along via BlockSpec index
maps, so the factor panels are reused across a full row/column of tiles and
the rank-r reconstruction runs on the MXU as a (bm×r)@(r×bn) matmul. W is
read once and written once — arithmetic intensity ~r FLOPs per W byte,
versus 0.5 for the unfused materialize-then-axpy pair.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
on the Rust CPU runtime. Real-TPU perf is estimated analytically
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _perturb_kernel(w_ref, u_ref, v_ref, tau_ref, rho_ref, o_ref):
    """One (bm, bn) tile: ``o = w + rho * (u * tau) @ v^T``."""
    u = u_ref[...]          # (bm, r)
    v = v_ref[...]          # (bn, r)
    tau = tau_ref[...]      # (r,)
    rho = rho_ref[0]
    z = jnp.dot(u * tau[None, :], v.T, preferred_element_type=jnp.float32)
    o_ref[...] = w_ref[...] + rho * z.astype(w_ref.dtype)


def _pick_block(dim: int, target: int, floor: int = 16) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps the grid exact).

    Degenerate-tiling guard: dims with no useful divisor (primes, or
    near-primes like 2p) would fall through to 1-wide blocks — a grid of
    ``dim`` single-lane programs. If the best divisor lands below ``floor``
    we give up on tiling that axis and take the whole dimension as one
    block: grid 1, still exact, and the (bm, bn) tile stays rectangular
    instead of degenerating into a stripe.
    """
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    if b < min(floor, dim):
        return dim
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def tezo_perturb(w, u, v, tau, rho, *, bm: int = 256, bn: int = 256):
    """Fused ``W + rho * U diag(tau) V^T`` via Pallas.

    w: (m, n); u: (m, r); v: (n, r); tau: (r,); rho: scalar.
    """
    m, n = w.shape
    r = tau.shape[0]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    rho_vec = jnp.reshape(rho.astype(w.dtype) if hasattr(rho, "astype")
                          else jnp.asarray(rho, w.dtype), (1,))
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _perturb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),        # W tile
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),          # U row panel
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),          # V col panel
            pl.BlockSpec((r,), lambda i, j: (0,)),               # tau (whole)
            pl.BlockSpec((1,), lambda i, j: (0,)),               # rho
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, u, v, tau, rho_vec)
