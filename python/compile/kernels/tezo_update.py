"""L1 Pallas kernels: fused TeZO parameter updates (paper Alg. 1 lines 11-18).

Two kernels:

* ``tezo_sgd_update`` — ``W' = W - U diag(tau_eff) V^T``. ``tau_eff`` folds
  the scalar chain (``eta * kappa * tau`` for TeZO, ``eta * tau_M`` for
  TeZO-m) so one kernel serves both the plain and momentum variants — that is
  exactly the memory story of the paper: the *whole* optimizer state is the
  r-vector, so the update kernel never sees a full-size moment tensor.

* ``tezo_adam_update`` — the lightweight TeZO-Adam step (paper Eq. 8):
  ``M = U diag(tau_m) V^T``; ``V = U^2 diag(tau_v) (V^2)^T`` (separable term
  only; the cross term has zero expectation and is dropped);
  ``W' = W - lr * M / sqrt(V + eps)``. Reconstructing both moments tile-wise
  in VMEM means Adam costs two rank-r MXU matmuls per tile instead of two
  full-size HBM-resident moment tensors.

See tezo_perturb.py for the tiling/TPU-mapping notes and why interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tezo_perturb import _pick_block


def _sgd_kernel(w_ref, u_ref, v_ref, tau_ref, o_ref):
    u = u_ref[...]
    v = v_ref[...]
    tau = tau_ref[...]
    g = jnp.dot(u * tau[None, :], v.T, preferred_element_type=jnp.float32)
    o_ref[...] = w_ref[...] - g.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def tezo_sgd_update(w, u, v, tau_eff, *, bm: int = 256, bn: int = 256):
    """``W - U diag(tau_eff) V^T`` via Pallas (TeZO / TeZO-m update)."""
    m, n = w.shape
    r = tau_eff.shape[0]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    return pl.pallas_call(
        _sgd_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, u, v, tau_eff)


def _adam_kernel(w_ref, u_ref, v_ref, tm_ref, tv_ref, sc_ref, o_ref):
    u = u_ref[...]
    v = v_ref[...]
    tm = tm_ref[...]
    tv = tv_ref[...]
    lr = sc_ref[0]
    eps = sc_ref[1]
    m = jnp.dot(u * tm[None, :], v.T, preferred_element_type=jnp.float32)
    vv = jnp.dot((u * u) * tv[None, :], (v * v).T,
                 preferred_element_type=jnp.float32)
    g = m / jnp.sqrt(vv + eps)
    o_ref[...] = w_ref[...] - lr * g.astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def tezo_adam_update(w, u, v, tau_m, tau_v, lr, eps, *, bm: int = 256,
                     bn: int = 256):
    """Lightweight TeZO-Adam update via Pallas (paper Eq. 8)."""
    m, n = w.shape
    r = tau_m.shape[0]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    sc = jnp.stack([jnp.asarray(lr, w.dtype), jnp.asarray(eps, w.dtype)])
    return pl.pallas_call(
        _adam_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, u, v, tau_m, tau_v, sc)
