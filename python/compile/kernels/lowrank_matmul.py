"""L1 Pallas kernel: sign-batched fused low-rank-perturbed matmul.

The implicit two-point forward (model.loss_pm_fn) evaluates both branches of

    y[b] = x[b] @ W + ((x[b] @ U) * tau[b]) @ V^T        b in {0, 1}

with ``tau = [rho*t, -rho*t]`` on a leading sign axis of 2, so the dense
weight is read ONCE for the +/- pair. This kernel is the TPU mapping of that
contraction: the (K, bn) weight tile is loaded into VMEM once per grid cell
and consumed by both branch matmuls on the MXU; the rank-r correction rides
along as a (bm, r) x (r, bn) epilogue. Arithmetic intensity per W byte is
2x the per-branch dense matmul's, versus 1x for running the two branches as
separate dense matmuls over materialized W +/- rho Z copies.

The model's implicit forward keeps using the fused-jnp formulation (XLA:CPU
fuses it well and interpret-mode Pallas adds tracing overhead at the sizes
we AOT); this kernel is the standalone L1 building block for real-TPU
deployments and is held to the ref oracle by python/tests/test_kernels.py.

``interpret=True``: see tezo_perturb.py.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tezo_perturb import _pick_block


def _lowrank_matmul_kernel(x_ref, w_ref, u_ref, v_ref, tau_ref, o_ref):
    """One (2, bm, bn) tile: both sign branches off one W tile load."""
    x = x_ref[...]        # (2, bm, K)
    w = w_ref[...]        # (K, bn) — loaded once for both branches
    u = u_ref[...]        # (K, r)
    v = v_ref[...]        # (bn, r)
    tau = tau_ref[...]    # (2, r)
    y = jax.lax.dot_general(x, w, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    xu = jax.lax.dot_general(x, u, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(xu * tau[:, None, :], v,
                                (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


# Candidate (bm, bn) targets for the build-time tile sweep. Spans the MXU
# native 128x128 up to a VMEM-heavy 256x512; every candidate is legalized
# per shape by `_pick_block` before timing, so degenerate dims collapse to
# fewer distinct tiles and the sweep stays cheap.
TILE_CANDIDATES = ((64, 128), (128, 128), (128, 256), (256, 256), (256, 512))

# Fallback tile when no tuned entry is available for a shape (the old fixed
# default, kept so standalone calls keep working without a manifest).
DEFAULT_TILE = (128, 256)


def legalize_tile(m: int, n: int, bm: int, bn: int):
    """Snap a candidate (bm, bn) to divisors of the actual (m, n)."""
    return _pick_block(m, bm), _pick_block(n, bn)


def sweep_tile(m, n, k, r, *, candidates=TILE_CANDIDATES, trials=2,
               timer=None, runner=None):
    """Time every legalized tile candidate and return the winner.

    Runs at artifact-build time (aot.py records the result in the manifest's
    ``tiles`` block), replacing the old fixed ``bm=128, bn=256`` default with
    a measured per-shape choice — the Python analogue of the Rust runtime's
    forward-form autotuner (rust/src/runtime/tune.rs).

    ``timer`` (ns clock, default ``time.perf_counter_ns``) and ``runner``
    (callable of (bm, bn), default: run `lowrank_matmul` on fresh inputs)
    are injectable so tests can script deterministic timings. Each candidate
    gets one untimed warm call (compile) then ``trials`` timed calls;
    min-of-trials wins, ties resolved by candidate order (deterministic).

    Returns ``{"bm", "bn", "trials", "candidates": [{"bm", "bn", "ns"}...]}``.
    """
    if timer is None:
        timer = time.perf_counter_ns
    if runner is None:
        key = jax.random.PRNGKey(0)
        kx, kw, ku, kv, kt = jax.random.split(key, 5)
        x = jax.random.normal(kx, (2, m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        u = jax.random.normal(ku, (k, r), jnp.float32)
        v = jax.random.normal(kv, (n, r), jnp.float32)
        tau = jax.random.normal(kt, (2, r), jnp.float32)

        def runner(bm, bn):
            lowrank_matmul(x, w, u, v, tau, bm=bm, bn=bn).block_until_ready()

    seen, legal = set(), []
    for bm, bn in candidates:
        tile = legalize_tile(m, n, bm, bn)
        if tile not in seen:
            seen.add(tile)
            legal.append(tile)

    timed = []
    for bm, bn in legal:
        runner(bm, bn)  # warm: compile outside the timed region
        best = None
        for _ in range(max(1, trials)):
            t0 = timer()
            runner(bm, bn)
            dt = timer() - t0
            best = dt if best is None else min(best, dt)
        timed.append({"bm": bm, "bn": bn, "ns": int(best)})

    win = min(timed, key=lambda c: c["ns"])  # stable: first-listed tie wins
    return {"bm": win["bm"], "bn": win["bn"], "trials": max(1, trials),
            "candidates": timed}


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lowrank_matmul(x, w, u, v, tau, *,
                   bm: int = DEFAULT_TILE[0], bn: int = DEFAULT_TILE[1]):
    """Sign-batched ``x @ W + ((x @ U) * tau) @ V^T`` via Pallas.

    x: (2, m, k); w: (k, n); u: (k, r); v: (n, r); tau: (2, r) -> (2, m, n).
    ``bm``/``bn`` default to `DEFAULT_TILE`; builds that went through
    `sweep_tile` pass the tuned tile from the manifest instead.
    """
    two, m, k = x.shape
    assert two == 2, "leading axis is the +/- sign pair"
    n = w.shape[1]
    r = tau.shape[1]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _lowrank_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, bm, k), lambda i, j: (0, i, 0)),   # x row panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),          # W tile
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),           # U (whole)
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),          # V col panel
            pl.BlockSpec((2, r), lambda i, j: (0, 0)),           # tau pair
        ],
        out_specs=pl.BlockSpec((2, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, m, n), x.dtype),
        interpret=True,
    )(x, w, u, v, tau)
