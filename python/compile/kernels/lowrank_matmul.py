"""L1 Pallas kernel: sign-batched fused low-rank-perturbed matmul.

The implicit two-point forward (model.loss_pm_fn) evaluates both branches of

    y[b] = x[b] @ W + ((x[b] @ U) * tau[b]) @ V^T        b in {0, 1}

with ``tau = [rho*t, -rho*t]`` on a leading sign axis of 2, so the dense
weight is read ONCE for the +/- pair. This kernel is the TPU mapping of that
contraction: the (K, bn) weight tile is loaded into VMEM once per grid cell
and consumed by both branch matmuls on the MXU; the rank-r correction rides
along as a (bm, r) x (r, bn) epilogue. Arithmetic intensity per W byte is
2x the per-branch dense matmul's, versus 1x for running the two branches as
separate dense matmuls over materialized W +/- rho Z copies.

The model's implicit forward keeps using the fused-jnp formulation (XLA:CPU
fuses it well and interpret-mode Pallas adds tracing overhead at the sizes
we AOT); this kernel is the standalone L1 building block for real-TPU
deployments and is held to the ref oracle by python/tests/test_kernels.py.

``interpret=True``: see tezo_perturb.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tezo_perturb import _pick_block


def _lowrank_matmul_kernel(x_ref, w_ref, u_ref, v_ref, tau_ref, o_ref):
    """One (2, bm, bn) tile: both sign branches off one W tile load."""
    x = x_ref[...]        # (2, bm, K)
    w = w_ref[...]        # (K, bn) — loaded once for both branches
    u = u_ref[...]        # (K, r)
    v = v_ref[...]        # (bn, r)
    tau = tau_ref[...]    # (2, r)
    y = jax.lax.dot_general(x, w, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    xu = jax.lax.dot_general(x, u, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(xu * tau[:, None, :], v,
                                (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def lowrank_matmul(x, w, u, v, tau, *, bm: int = 128, bn: int = 256):
    """Sign-batched ``x @ W + ((x @ U) * tau) @ V^T`` via Pallas.

    x: (2, m, k); w: (k, n); u: (k, r); v: (n, r); tau: (2, r) -> (2, m, n).
    """
    two, m, k = x.shape
    assert two == 2, "leading axis is the +/- sign pair"
    n = w.shape[1]
    r = tau.shape[1]
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _lowrank_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, bm, k), lambda i, j: (0, i, 0)),   # x row panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),          # W tile
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),           # U (whole)
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),          # V col panel
            pl.BlockSpec((2, r), lambda i, j: (0, 0)),           # tau pair
        ],
        out_specs=pl.BlockSpec((2, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, m, n), x.dtype),
        interpret=True,
    )(x, w, u, v, tau)
