"""L1 Pallas kernel: masked token cross-entropy over the vocab dimension.

Fuses logsumexp + gold-logit gather + masking for one batch row per program
instance, so the (S, V) logits tile is read exactly once from HBM. Returns
per-row (sum_nll, sum_mask) partials; the final reduction happens in jnp
(scalar work).

interpret=True: see tezo_perturb.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ce_kernel(logits_ref, tgt_ref, mask_ref, out_ref):
    logits = logits_ref[0]          # (S, V) f32
    tgt = tgt_ref[0]                # (S,) i32
    mask = mask_ref[0]              # (S,) f32
    mx = jnp.max(logits, axis=-1)
    lse = mx + jnp.log(jnp.sum(jnp.exp(logits - mx[:, None]), axis=-1))
    # one-hot gather: pallas interpret handles take_along_axis poorly on
    # some versions; a dot with iota-mask is MXU-friendly anyway.
    s, v = logits.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, v), 1)
    gold = jnp.sum(jnp.where(cols == tgt[:, None], logits, 0.0), axis=-1)
    nll = (lse - gold) * mask
    out_ref[0, 0] = jnp.sum(nll)
    out_ref[0, 1] = jnp.sum(mask)


@jax.jit
def cross_entropy(logits, targets, mask):
    """Masked mean token cross-entropy via Pallas.

    logits: (B, S, V) f32; targets: (B, S) i32; mask: (B, S) f32.
    """
    b, s, v = logits.shape
    partials = pl.pallas_call(
        _ce_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.float32),
        interpret=True,
    )(logits.astype(jnp.float32), targets, mask.astype(jnp.float32))
    total = partials[:, 0].sum()
    denom = jnp.maximum(partials[:, 1].sum(), 1.0)
    return total / denom
