"""L1 Pallas kernels (interpret mode) + pure-jnp oracles.

Import surface used by the L2 model and the AOT pipeline.
"""
from . import ref  # noqa: F401
from .attention import attention  # noqa: F401
from .axpy import axpy_perturb  # noqa: F401
from .cross_entropy import cross_entropy  # noqa: F401
from .lowrank_matmul import lowrank_matmul  # noqa: F401
from .tezo_perturb import tezo_perturb  # noqa: F401
from .tezo_update import tezo_adam_update, tezo_sgd_update  # noqa: F401
