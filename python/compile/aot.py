"""AOT pipeline: lower every step function to HLO text + emit manifest.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model config:

    artifacts/<config>/
      manifest.json            calling conventions for every artifact
      params/<idx>_<name>.bin  raw little-endian f32 initial parameters
      <artifact>.hlo.txt       one per step function

Usage:
    python -m compile.aot --config tiny --out-root ../artifacts
    python -m compile.aot --config tiny,small --kernels-only  (microbenches)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import zo_steps as zs
from .configs import ModelConfig, get_config
from .kernels.lowrank_matmul import sweep_tile
from .model import init_params

# ---------------------------------------------------------------------------
# Eq.(7): layer-wise rank schedule
# ---------------------------------------------------------------------------

def matrix_rank_threshold(w: np.ndarray, threshold: float) -> int:
    """Rank(W) = number of singular values > threshold * sigma_max."""
    s = np.linalg.svd(w, compute_uv=False)
    if s.size == 0 or s[0] <= 0:
        return 1
    return max(1, int(np.sum(s > threshold * s[0])))


def rank_schedule(cfg: ModelConfig, params: Dict[str, np.ndarray]) -> Dict[str, int]:
    """Paper Eq.(7): r_l = min({Rank(W) : W in block(l)}, r_max).

    The min over the *block* preserves rank-propagation transitivity without
    collapsing for deep L. Embeddings share block 0, final LN the last block.
    """
    blocks: Dict[int, List[str]] = {}
    for name, _ in cfg.matrix_params():
        blocks.setdefault(cfg.block_of(name), []).append(name)
    block_rank: Dict[int, int] = {}
    for b, names in blocks.items():
        ranks = [matrix_rank_threshold(np.asarray(params[n]), cfg.rank_threshold)
                 for n in names]
        block_rank[b] = max(1, min(min(ranks), cfg.r_max))
    return {name: block_rank[cfg.block_of(name)]
            for name, _ in cfg.matrix_params()}


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _write(path: str, text: str) -> str:
    with open(path, "w") as f:
        f.write(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# Artifact inventory per config
# ---------------------------------------------------------------------------

def artifact_builders(cfg: ModelConfig, ranks: Dict[str, int],
                      lozo_rank: int, subzo_rank: int):
    """name -> (fn, example_args, input_desc, output_desc)."""
    return {
        "fwd_loss": zs.build_fwd_loss(cfg),
        "eval_logits": zs.build_eval_logits(cfg),
        "fo_valgrad": zs.build_fo_valgrad(cfg),
        "fo_adam_update": zs.build_fo_adam_update(cfg),
        "mezo_loss_pm": zs.build_mezo_loss_pm(cfg),
        "mezo_update_sgd": zs.build_mezo_update_sgd(cfg),
        "mezo_update_m": zs.build_mezo_update_m(cfg),
        "mezo_update_adam": zs.build_mezo_update_adam(cfg),
        "tezo_loss_pm": zs.build_tezo_loss_pm(cfg, ranks),
        "tezo_loss_pm_implicit": zs.build_tezo_loss_pm_implicit(cfg, ranks),
        "tezo_update_factor": zs.build_tezo_update_factor(cfg, ranks),
        "tezo_update_adam": zs.build_tezo_update_adam(cfg, ranks),
        "lozo_init_u": zs.build_lozo_init_u(cfg, lozo_rank),
        "lozo_loss_pm": zs.build_lozo_loss_pm(cfg, lozo_rank),
        "lozo_loss_pm_implicit": zs.build_lozo_loss_pm_implicit(cfg, lozo_rank),
        "lozo_update_sgd": zs.build_lozo_update_sgd(cfg, lozo_rank),
        "lozo_update_m": zs.build_lozo_update_m(cfg, lozo_rank),
        "subzo_factors": zs.build_subzo_factors(cfg, subzo_rank),
        "subzo_loss_pm": zs.build_subzo_loss_pm(cfg, subzo_rank),
        "subzo_update": zs.build_subzo_update(cfg, subzo_rank),
        "adamu_loss_pm": zs.build_adamu_loss_pm(cfg),
        "adamu_update": zs.build_adamu_update(cfg),
    }


def forward_form(artifact_name: str):
    """Manifest ``forward_form`` tag for two-point loss artifacts.

    ``materialize``: the artifact builds dense ``W +/- rho Z`` copies before
    the forward. ``implicit``: the rank-r correction is folded into the
    matmuls (sign-batched; see model.loss_pm_fn). The tag is descriptive
    metadata — `tezo inspect` prints it and tests assert it round-trips;
    the runtime's ``forward_form`` knob resolves artifacts BY NAME
    (``Manifest::loss_artifact``), with the ``*_loss_pm_implicit`` suffix
    as the naming contract. Non-loss artifacts carry no tag.
    """
    if artifact_name.endswith("_loss_pm_implicit"):
        return "implicit"
    if artifact_name.endswith("_loss_pm"):
        return "materialize"
    return None


# ---------------------------------------------------------------------------
# Build-time tile sweep (manifest ``tiles`` block)
# ---------------------------------------------------------------------------

def tile_sweep(cfg: ModelConfig, ranks: Dict[str, int],
               trials: int = 2) -> Dict[str, dict]:
    """Measured (bm, bn) Pallas tile per distinct weight shape.

    Replaces the old fixed ``bm=128, bn=256`` default of the fused low-rank
    matmul with a per-shape sweep (kernels/lowrank_matmul.sweep_tile), keyed
    by ``{k}x{n}`` with ``m = batch * seq_len`` rows. Only meaningful for
    configs that route through the Pallas kernels; jnp-path configs skip it
    (``build_config`` gates on ``cfg.use_pallas``).
    """
    m = cfg.batch * cfg.seq_len
    shapes: Dict[tuple, int] = {}
    for name, (k, n) in cfg.matrix_params():
        shapes[(k, n)] = max(shapes.get((k, n), 1), ranks[name])
    out: Dict[str, dict] = {}
    for (k, n), r in sorted(shapes.items()):
        t = time.time()
        res = sweep_tile(m, n, k, r, trials=trials)
        out[f"{k}x{n}"] = {"m": m, "k": k, "n": n, "r": r, **res}
        print(f"  [{cfg.name}] tile {k}x{n} (r={r}): bm={res['bm']} "
              f"bn={res['bn']} over {len(res['candidates'])} candidates "
              f"({time.time() - t:.1f}s)")
    return out


def retile_config(cfg_name: str, out_root: str) -> None:
    """Re-run the tile sweep against an existing build and patch its
    manifest in place (adds/refreshes the ``tiles`` key; everything else —
    HLO files, hashes, params — is left untouched)."""
    cfg = get_config(cfg_name)
    path = os.path.join(out_root, cfg.name, "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    if not cfg.use_pallas:
        print(f"[{cfg.name}] jnp path — no Pallas tiles to tune")
        return
    ranks = {e["name"]: e["rank"] for e in manifest["matrix_ranks"]}
    manifest["tiles"] = tile_sweep(cfg, ranks)
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{cfg.name}] manifest tiles refreshed -> {path}")


# Per-shape standalone kernel artifacts for the L1 microbenches (Fig 3b /
# Table 8 phase accounting): shapes chosen to span the attention / FFN
# matrices of the experiment configs.
KERNEL_SHAPES = [
    (256, 256, 8), (256, 1024, 8), (512, 512, 16), (512, 2048, 16),
    (1024, 1024, 32), (1024, 4096, 32), (2048, 2048, 64),
]


def kernel_builders():
    out = {}
    for m, n, r in KERNEL_SHAPES:
        out[f"kernel_tezo_perturb_{m}x{n}_r{r}"] = zs.build_kernel_tezo_perturb(m, n, r)
        out[f"kernel_mezo_perturb_{m}x{n}"] = zs.build_kernel_mezo_perturb(m, n)
    return out


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def build_config(cfg_name: str, out_root: str, seed: int = 0,
                 only: List[str] | None = None) -> None:
    cfg = get_config(cfg_name)
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(os.path.join(out_dir, "params"), exist_ok=True)

    t0 = time.time()
    params = init_params(cfg, seed=seed)
    np_params = {k: np.asarray(v) for k, v in params.items()}
    ranks = rank_schedule(cfg, np_params)
    # LOZO uses a small constant rank (paper Table 6: r=8); SubZO a larger
    # one (r in {32,64,128}) scaled down with our model sizes.
    lozo_rank = max(2, min(8, cfg.r_max))
    subzo_rank = max(4, min(32, cfg.r_max * 2))

    # ---- parameters -----------------------------------------------------
    param_entries = []
    for idx, (name, shape) in enumerate(cfg.param_specs()):
        fname = f"params/{idx:03d}_{name.replace('.', '_')}.bin"
        arr = np_params[name].astype("<f4")
        arr.tofile(os.path.join(out_dir, fname))
        param_entries.append({"name": name, "shape": list(shape),
                              "dtype": "f32", "bin": fname})

    # ---- artifacts -------------------------------------------------------
    builders = artifact_builders(cfg, ranks, lozo_rank, subzo_rank)
    if only:
        builders = {k: v for k, v in builders.items() if k in only}
    artifacts = {}
    for name, (fn, example_args, in_desc, out_desc) in builders.items():
        t = time.time()
        text = to_hlo_text(fn, example_args)
        sha = _write(os.path.join(out_dir, f"{name}.hlo.txt"), text)
        artifacts[name] = {"file": f"{name}.hlo.txt", "sha256_16": sha,
                           "inputs": in_desc, "outputs": out_desc}
        form = forward_form(name)
        if form is not None:
            artifacts[name]["forward_form"] = form
        print(f"  [{cfg.name}] {name}: {len(in_desc)} in / {len(out_desc)} out "
              f"({time.time() - t:.1f}s)")

    manifest = {
        "config": {
            "name": cfg.name, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "vocab": cfg.vocab,
            "seq_len": cfg.seq_len, "batch": cfg.batch, "r_max": cfg.r_max,
            "rank_threshold": cfg.rank_threshold, "use_pallas": cfg.use_pallas,
            "n_params": cfg.n_params(), "init_seed": seed,
        },
        "params": param_entries,
        "matrix_ranks": [{"name": n, "m": s[0], "n": s[1], "rank": ranks[n]}
                         for n, s in cfg.matrix_params()],
        "lozo_rank": lozo_rank,
        "subzo_rank": subzo_rank,
        "artifacts": artifacts,
    }
    if cfg.use_pallas:
        manifest["tiles"] = tile_sweep(cfg, ranks)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{cfg.name}] done in {time.time() - t0:.1f}s -> {out_dir}")


def build_kernels(out_root: str) -> None:
    out_dir = os.path.join(out_root, "kernels")
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}
    for name, (fn, example_args, in_desc, out_desc) in kernel_builders().items():
        text = to_hlo_text(fn, example_args)
        sha = _write(os.path.join(out_dir, f"{name}.hlo.txt"), text)
        artifacts[name] = {"file": f"{name}.hlo.txt", "sha256_16": sha,
                           "inputs": in_desc, "outputs": out_desc}
        print(f"  [kernels] {name}")
    # a minimal-but-complete manifest so the Rust Runtime can open the
    # kernels dir with the same loader as model configs
    manifest = {
        "config": {
            "name": "kernels", "d_model": 0, "n_layers": 0, "n_heads": 0,
            "d_ff": 0, "vocab": 0, "seq_len": 0, "batch": 0, "r_max": 0,
            "rank_threshold": 0.0, "use_pallas": True, "n_params": 0,
            "init_seed": 0,
        },
        "params": [],
        "matrix_ranks": [],
        "lozo_rank": 0,
        "subzo_rank": 0,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny,small",
                    help="comma-separated config presets")
    ap.add_argument("--out-root", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact subset (debug)")
    ap.add_argument("--kernels", action="store_true",
                    help="also build standalone kernel microbench artifacts")
    ap.add_argument("--kernels-only", action="store_true")
    ap.add_argument("--retile", action="store_true",
                    help="re-run the tile sweep on an existing build and "
                         "patch manifest.json in place (no re-lowering)")
    args = ap.parse_args()

    if args.retile:
        for cfg_name in args.config.split(","):
            if cfg_name:
                retile_config(cfg_name.strip(), args.out_root)
        return

    if not args.kernels_only:
        for cfg_name in args.config.split(","):
            if cfg_name:
                build_config(cfg_name.strip(), args.out_root, seed=args.seed,
                             only=args.only.split(",") if args.only else None)
    if args.kernels or args.kernels_only:
        build_kernels(args.out_root)


if __name__ == "__main__":
    main()
