"""Model/config presets shared by the AOT pipeline and tests.

Every named config fully determines the artifact set: parameter shapes,
layer-wise rank schedule inputs, batch geometry, and which forward path the
L2 model uses (pallas kernels vs. plain jnp).

The Rust coordinator never sees this file — everything it needs is baked into
``artifacts/<config>/manifest.json`` by ``aot.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """OPTLite decoder-only transformer configuration."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    batch: int
    # --- ZO / TeZO knobs -------------------------------------------------
    r_max: int  # cap in Eq.(7)
    rank_threshold: float = 0.25  # singular-value fraction for Eq.(7)
    # Effective rank of the planted low-rank component of the random init.
    # Pretrained LLM weights are approximately low-rank (paper App. A.1.3);
    # a pure Gaussian init is not, so we plant structure to reproduce the
    # rank-selection behaviour (documented substitution, DESIGN.md §2).
    init_rank_frac: float = 0.125
    init_lowrank_weight: float = 0.7
    # --- implementation knobs -------------------------------------------
    use_pallas: bool = False  # route forward through L1 pallas kernels
    dtype: str = "float32"
    tie_lm_head: bool = True

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # ------------------------------------------------------------------
    # Parameter inventory.  Order here IS the flattened calling convention
    # for every artifact; manifest.json records it verbatim.
    # ------------------------------------------------------------------
    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        d, dff, v, s = self.d_model, self.d_ff, self.vocab, self.seq_len
        specs: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed.tok", (v, d)),
            ("embed.pos", (s, d)),
        ]
        for i in range(self.n_layers):
            p = f"block{i}."
            specs += [
                (p + "ln1.g", (d,)),
                (p + "ln1.b", (d,)),
                (p + "attn.wq", (d, d)),
                (p + "attn.wk", (d, d)),
                (p + "attn.wv", (d, d)),
                (p + "attn.wo", (d, d)),
                (p + "ln2.g", (d,)),
                (p + "ln2.b", (d,)),
                (p + "ffn.w1", (d, dff)),
                (p + "ffn.w2", (dff, d)),
            ]
        specs += [("final_ln.g", (d,)), ("final_ln.b", (d,))]
        if not self.tie_lm_head:
            specs.append(("lm_head", (d, v)))
        return specs

    def matrix_params(self) -> List[Tuple[str, Tuple[int, int]]]:
        """2D parameters — the ones low-rank ZO methods factorize."""
        return [(n, s) for n, s in self.param_specs() if len(s) == 2]

    def vector_params(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """1D parameters — perturbed densely (seed-resampled) by all methods."""
        return [(n, s) for n, s in self.param_specs() if len(s) == 1]

    def n_params(self) -> int:
        return sum(int(_prod(s)) for _, s in self.param_specs())

    def block_of(self, name: str) -> int:
        """Block index used by the Eq.(7) rank schedule (embeddings = block 0,
        final ln = last block)."""
        if name.startswith("block"):
            return int(name[len("block"):name.index(".")])
        if name.startswith("embed"):
            return 0
        return self.n_layers - 1


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


PRESETS: Dict[str, ModelConfig] = {
    # tiny: CI/test config. Routes through the pallas kernels so the full
    # L1->L2->HLO->rust composition is exercised by every integration test.
    "tiny": ModelConfig(
        name="tiny", d_model=64, n_layers=2, n_heads=2, d_ff=256,
        vocab=256, seq_len=64, batch=4, r_max=8, use_pallas=True,
    ),
    # tiny_jnp: identical geometry to tiny but on the jnp forward path —
    # the pallas-interpret vs fused-jnp ablation of EXPERIMENTS.md §Perf.
    "tiny_jnp": ModelConfig(
        name="tiny_jnp", d_model=64, n_layers=2, n_heads=2, d_ff=256,
        vocab=256, seq_len=64, batch=4, r_max=8, use_pallas=False,
    ),
    # small: the workhorse for optimizer-comparison experiments (Tables 4/5
    # analogue, Fig 4 loss curves). ~3.9M params.
    "small": ModelConfig(
        name="small", d_model=256, n_layers=4, n_heads=4, d_ff=1024,
        vocab=2048, seq_len=128, batch=8, r_max=24,
    ),
    # medium: RoBERTa-large stand-in for the Table 3 analogue. ~29M params.
    "medium": ModelConfig(
        name="medium", d_model=512, n_layers=8, n_heads=8, d_ff=2048,
        vocab=8192, seq_len=128, batch=8, r_max=64,
    ),
    # e2e: ~92M param GPT2-small-shaped model for the end-to-end driver.
    "e2e": ModelConfig(
        name="e2e", d_model=768, n_layers=12, n_heads=12, d_ff=3072,
        vocab=8192, seq_len=128, batch=4, r_max=64,
    ),
}


def get_config(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; have {sorted(PRESETS)}")
