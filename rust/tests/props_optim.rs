//! Property tests over optimizer math and coordinator invariants that do
//! not need the PJRT runtime: tau-space momentum oracles, bias-correction
//! commutation, seed schedules, memory-model monotonicity, Table-2 closed
//! forms, preset sanity.

use tezo::config::{Method, TrainConfig};
use tezo::coordinator::counter::closed_form;
use tezo::coordinator::seeds::{SeedSchedule, Stream};
use tezo::memmodel::{self, usage};
use tezo::proplite::{self, prop_assert, prop_close};
use tezo::rngx::normal_rng;
use tezo::tensor::Matrix;

/// tau-space momentum equals full-matrix momentum reconstructed:
/// M_T = sum_t b1^{T-t}(1-b1) k_t Z_t  ==  U diag(tauM_T) V^T
#[test]
fn tau_momentum_commutes_with_reconstruction() {
    proplite::run(25, |g| {
        let m = g.usize_in(2..20);
        let n = g.usize_in(2..20);
        let r = g.usize_in(1..6);
        let steps = g.usize_in(1..10);
        let b1 = 0.9f32;
        let mut gen = normal_rng(g.u64());
        let u = Matrix::randn(m, r, &mut gen);
        let v = Matrix::randn(n, r, &mut gen);

        let mut tau_m = vec![0.0f32; r];
        let mut full_m = Matrix::zeros(m, n);
        for _ in 0..steps {
            let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
            let kappa = gen.next_f32();
            // tau-space update (what TezoM does)
            for i in 0..r {
                tau_m[i] = b1 * tau_m[i] + (1.0 - b1) * kappa * tau[i];
            }
            // full-matrix update (the oracle)
            let z = Matrix::cpd_slice(&u, &v, &tau).unwrap();
            full_m.scale(b1);
            full_m.axpy((1.0 - b1) * kappa, &z).unwrap();
        }
        let recon = Matrix::cpd_slice(&u, &v, &tau_m).unwrap();
        let mut diff = 0.0f64;
        for (a, b) in recon.data.iter().zip(full_m.data.iter()) {
            diff = diff.max((a - b).abs() as f64);
        }
        prop_assert(diff < 1e-4, &format!("momentum mismatch {diff}"))
    });
}

/// The separable second moment in tau space equals accumulating the
/// separable term of Z_t^2 in full space (paper Eq. 8 bookkeeping).
#[test]
fn tau_second_moment_commutes_with_separable_reconstruction() {
    proplite::run(25, |g| {
        let m = g.usize_in(2..16);
        let n = g.usize_in(2..16);
        let r = g.usize_in(1..5);
        let steps = g.usize_in(1..8);
        let b2 = 0.99f32;
        let mut gen = normal_rng(g.u64());
        let u = Matrix::randn(m, r, &mut gen);
        let v = Matrix::randn(n, r, &mut gen);
        let u2 = Matrix::from_vec(m, r, u.data.iter().map(|x| x * x).collect()).unwrap();
        let v2 = Matrix::from_vec(n, r, v.data.iter().map(|x| x * x).collect()).unwrap();

        let mut tau_v = vec![0.0f32; r];
        let mut full_v = Matrix::zeros(m, n);
        for _ in 0..steps {
            let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
            let kappa = gen.next_f32();
            for i in 0..r {
                tau_v[i] = b2 * tau_v[i] + (1.0 - b2) * kappa * kappa * tau[i] * tau[i];
            }
            let tau2: Vec<f32> = tau.iter().map(|t| kappa * kappa * t * t).collect();
            let sep = Matrix::cpd_slice(&u2, &v2, &tau2).unwrap();
            full_v.scale(b2);
            full_v.axpy(1.0 - b2, &sep).unwrap();
        }
        let recon = Matrix::cpd_slice(&u2, &v2, &tau_v).unwrap();
        let mut diff = 0.0f64;
        for (a, b) in recon.data.iter().zip(full_v.data.iter()) {
            diff = diff.max((a - b).abs() as f64);
        }
        prop_assert(diff < 1e-4, &format!("second moment mismatch {diff}"))
    });
}

/// Bias correction commutes with reconstruction because both moments are
/// linear in their tau vectors.
#[test]
fn bias_correction_commutes() {
    proplite::run(50, |g| {
        let m = g.usize_in(2..12);
        let n = g.usize_in(2..12);
        let r = g.usize_in(1..5);
        let mut gen = normal_rng(g.u64());
        let u = Matrix::randn(m, r, &mut gen);
        let v = Matrix::randn(n, r, &mut gen);
        let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
        let bc = g.f32_in(0.1..1.0);
        // correct-then-reconstruct
        let tau_hat: Vec<f32> = tau.iter().map(|t| t / bc).collect();
        let a = Matrix::cpd_slice(&u, &v, &tau_hat).unwrap();
        // reconstruct-then-correct
        let mut b = Matrix::cpd_slice(&u, &v, &tau).unwrap();
        b.scale(1.0 / bc);
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            prop_close(*x as f64, *y as f64, 1e-5, "commute")?;
        }
        Ok(())
    });
}

#[test]
fn seed_schedule_streams_are_independent_for_random_masters() {
    proplite::run(50, |g| {
        let s = SeedSchedule::new(g.u64());
        let step = g.u64() % 100_000;
        let a = s.seed32(Stream::Perturb, step);
        let b = s.seed32(Stream::Data, step);
        let c = s.seed32(Stream::FactorInit, step);
        prop_assert(a != b && b != c && a != c, "stream collision")?;
        prop_assert(a != 0 && b != 0 && c != 0, "zero seed")
    });
}

#[test]
fn memory_model_is_monotone_in_model_size() {
    let sizes = ["125m", "1.3b", "2.7b", "6.7b", "13b", "30b"];
    for m in Method::ALL {
        let mut prev = 0u64;
        for s in sizes {
            let total = usage::memory_usage(&memmodel::opt(s), m).total();
            assert!(total > prev, "{:?} not monotone at {s}", m);
            prev = total;
        }
    }
}

#[test]
fn memory_model_method_ordering_holds_across_families() {
    proplite::run(9, |g| {
        let layout = match g.usize_in(0..3) {
            0 => memmodel::opt(*g.pick(&["1.3b", "6.7b", "13b", "30b"])),
            1 => memmodel::llama(*g.pick(&["7b", "13b", "30b"])),
            _ => memmodel::opt("2.7b"),
        };
        let get = |m: Method| usage::memory_usage(&layout, m).total();
        prop_assert(get(Method::TezoAdam) <= get(Method::Mezo),
                    "tezo-adam <= mezo (the headline claim)")?;
        prop_assert(get(Method::Mezo) < get(Method::MezoM), "mezo < mezo-m")?;
        prop_assert(get(Method::MezoM) < get(Method::MezoAdam), "mezo-m < mezo-adam")?;
        let ratio = get(Method::TezoAdam) as f64 / get(Method::MezoAdam) as f64;
        prop_assert(ratio < 0.45, &format!("tezo-adam/mezo-adam ratio {ratio}"))
    });
}

#[test]
fn table2_closed_forms_scale_correctly() {
    proplite::run(100, |g| {
        let m = g.usize_in(64..4096) as u64;
        let n = g.usize_in(64..4096) as u64;
        let r = g.usize_in(1..128) as u64;
        let t = g.usize_in(1..20_000) as u64;
        // TeZO must always beat LOZO (nu=1 worst case) once T > ~1
        let tezo = closed_form::tezo(m, n, r, t);
        let lozo = closed_form::lozo(m, n, r, t, 50);
        prop_assert(tezo <= lozo + (m + n) * r, "tezo <= lozo + one refresh")?;
        // doubling T adds exactly r*T for TeZO (temporal-only growth)
        let tezo2 = closed_form::tezo(m, n, r, 2 * t);
        prop_assert(tezo2 - tezo == r * t, "TeZO grows only in tau draws")
    });
}

#[test]
fn presets_cover_every_method_and_model() {
    for m in Method::ALL {
        for model in ["tiny", "small", "medium", "e2e"] {
            let cfg = TrainConfig::with_preset(m, model);
            assert!(cfg.lr > 0.0 && cfg.rho > 0.0);
            assert!(cfg.lazy_interval > 0);
        }
    }
}
