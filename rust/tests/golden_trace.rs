//! Golden-trace regression: prepared-call training must be BIT-identical
//! to the recorded seed traces — for the single-process trainer on `tezo`,
//! `mezo`, and `lozo`, and for the 2-worker seed-synchronized fleet on
//! `tezo` (so `train` and `train-dp` cannot drift apart either).
//!
//! Losses are stored as f64 bit patterns (hex), so any change to dispatch,
//! staging, seed derivation, or update arithmetic that perturbs a single
//! ULP fails loudly.
//!
//! Recording: `TEZO_RECORD_GOLDEN=1 cargo test --test golden_trace` writes
//! `tests/golden/loss_traces.json` from the current build — do this once on
//! a trusted revision and commit the file. The test skips (with a notice)
//! when the tiny artifacts or the fixture are missing.

use std::path::PathBuf;

use tezo::config::{FleetConfig, ForwardForm, Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::{task_job_factory, FleetTrainer};
use tezo::jsonx::{self, Value};
use tezo::runtime::{ParamStore, Runtime};

const STEPS: usize = 3;
const SEED: u64 = 1234;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/loss_traces.json")
}

fn run_single(rt: &Runtime, method: Method) -> Vec<f64> {
    run_single_form(rt, method, ForwardForm::Implicit)
}

fn run_single_form(rt: &Runtime, method: Method, form: ForwardForm) -> Vec<f64> {
    let mut cfg = TrainConfig::with_preset(method, "tiny");
    cfg.steps = STEPS;
    cfg.seed = SEED;
    cfg.forward_form = tezo::config::FormPolicy::Pinned(form);
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, SEED);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    Trainer::new(rt, cfg, DataSource::Task(builder))
        .run(&mut params)
        .unwrap()
        .metrics
        .losses
}

fn run_dp_tezo(workers: usize) -> Vec<f64> {
    let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
    cfg.steps = STEPS;
    cfg.seed = SEED;
    // pin the form the golden trace was recorded under — an Auto policy
    // would let the autotuner's measured winner pick the artifact, and
    // the two lowerings are deliberately not bit-identical
    cfg.forward_form = tezo::config::FormPolicy::Pinned(ForwardForm::Implicit);
    let factory = task_job_factory("sst2".to_string(), SEED, 16, 0, None);
    let dir = tezo::artifacts_root().join("tiny");
    let mut trainer = FleetTrainer::new(FleetConfig::new(workers), cfg, dir, factory);
    trainer.run().unwrap().metrics.losses
}

fn bits(losses: &[f64]) -> Vec<String> {
    losses.iter().map(|l| format!("{:016x}", l.to_bits())).collect()
}

fn trace_value(losses: &[f64]) -> Value {
    Value::arr(bits(losses).into_iter().map(Value::str).collect())
}

#[test]
fn training_losses_match_recorded_golden_traces() {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return;
    }
    let rt = Runtime::open(&dir).expect("open runtime");
    // `tezo`/`lozo` run the default (implicit) forward; the `_materialize`
    // trace pins the legacy form so `--forward-form materialize` stays
    // bit-reproducible too (the two forms reassociate float math and are
    // NOT bit-identical to each other — forward_forms.rs bounds the drift)
    let traces: Vec<(&str, Vec<f64>)> = vec![
        ("tezo", run_single(&rt, Method::Tezo)),
        ("tezo_materialize",
         run_single_form(&rt, Method::Tezo, ForwardForm::Materialize)),
        ("mezo", run_single(&rt, Method::Mezo)),
        ("lozo", run_single(&rt, Method::Lozo)),
        ("tezo_dp2", run_dp_tezo(2)),
    ];
    for (name, t) in &traces {
        assert_eq!(t.len(), STEPS, "{name}: wrong trace length");
        assert!(t.iter().all(|l| l.is_finite()), "{name}: non-finite loss");
    }

    let path = golden_path();
    if std::env::var_os("TEZO_RECORD_GOLDEN").is_some() {
        let doc = Value::obj(
            traces.iter().map(|(n, t)| (*n, trace_value(t))).collect());
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, jsonx::to_string_pretty(&doc)).unwrap();
        eprintln!("recorded golden traces -> {}", path.display());
        return;
    }
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipping: no golden fixture at {} (record one with \
                   TEZO_RECORD_GOLDEN=1 on a trusted revision)", path.display());
        return;
    };
    let doc = jsonx::parse(&text).expect("parse golden fixture");
    for (name, t) in &traces {
        let want: Vec<String> = doc
            .get(*name)
            .unwrap_or_else(|_| panic!("fixture missing trace {name:?}"))
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap().to_string())
            .collect();
        assert_eq!(bits(t), want,
                   "{name}: losses diverged from the recorded golden trace \
                    (bit-exact comparison)");
    }
}

// (the fixture-free workers=1 == single-process parity check lives in
// integration_fleet.rs::one_worker_fleet_matches_plain_trainer_bitwise)
