//! Property tests for the prepared-call plan layer: [`CallPlan`] must
//! reject wrong-dtype, wrong-shape, and wrong-arity bindings with exactly
//! the error strings the positional `CallBuilder` has always produced —
//! the builder now delegates to these checks, and this suite pins the
//! contract so neither dispatch path can drift. Runs entirely offline
//! (plans are pure over `ArtifactMeta` — no artifacts, no PJRT).

use tezo::proplite::{self, prop_assert};
use tezo::runtime::plan::{CallPlan, Dtype};
use tezo::runtime::{ArtifactMeta, IoDesc};

const DTYPES: [&str; 3] = ["f32", "i32", "u32"];

fn desc(role: &str, name: &str, shape: Vec<usize>, dtype: &str) -> IoDesc {
    IoDesc {
        role: role.to_string(),
        name: name.to_string(),
        shape,
        dtype: dtype.to_string(),
    }
}

/// A random artifact: a few tensor slots with distinct names + a few
/// scalar slots, mirroring the AOT conventions (params, factors, batch,
/// scalar knobs).
fn random_meta(g: &mut tezo::proplite::Gen) -> ArtifactMeta {
    let n_tensors = g.usize_in(1..5);
    let n_scalars = g.usize_in(1..4);
    let mut inputs = Vec::new();
    for i in 0..n_tensors {
        let shape = vec![g.usize_in(1..8), g.usize_in(1..8)];
        inputs.push(desc("tensor", &format!("t{i}"), shape, *g.pick(&DTYPES)));
    }
    for i in 0..n_scalars {
        let dt = if g.bool() { "f32" } else { "u32" };
        inputs.push(desc("scalar", &format!("s{i}"), vec![], dt));
    }
    ArtifactMeta {
        file: "synthetic.hlo".to_string(),
        inputs,
        outputs: vec![desc("scalar", "out", vec![], "f32")],
        forward_form: None,
    }
}

#[test]
fn plan_resolves_names_to_manifest_positions() {
    proplite::run(200, |g| {
        let meta = random_meta(g);
        let plan = CallPlan::new("art", &meta).map_err(|e| e.to_string())?;
        prop_assert(plan.arity() == meta.inputs.len(), "arity")?;
        for (pos, d) in meta.inputs.iter().enumerate() {
            let found = plan
                .position(&d.role, &d.name)
                .map_err(|e| e.to_string())?;
            prop_assert(found == pos, "position round-trip")?;
        }
        // role groups preserve slot order
        let tensors = plan.role_positions("tensor");
        prop_assert(tensors.windows(2).all(|w| w[0] < w[1]),
                    "role group ordered")?;
        prop_assert(plan.role_positions("nonexistent").is_empty(),
                    "unknown role is empty")
    });
}

#[test]
fn wrong_dtype_binding_reports_the_legacy_error() {
    proplite::run(200, |g| {
        let meta = random_meta(g);
        let plan = CallPlan::new("art", &meta).map_err(|e| e.to_string())?;
        // find a tensor slot and bind the other dtype against it
        let pos = g.usize_in(0..plan.arity());
        let slot = plan.slot(pos).clone();
        let got = if slot.dtype == Dtype::F32 { Dtype::I32 } else { Dtype::F32 };
        let err = plan
            .check_host(pos, got, slot.numel)
            .expect_err("dtype mismatch must fail")
            .to_string();
        let want = format!("art: slot {pos} ({}) wants {}, got {}",
                           slot.name, slot.dtype.name(), got.name());
        prop_assert(err == want, &format!("got {err:?}, want {want:?}"))
    });
}

#[test]
fn wrong_shape_binding_reports_the_legacy_error() {
    proplite::run(200, |g| {
        let meta = random_meta(g);
        let plan = CallPlan::new("art", &meta).map_err(|e| e.to_string())?;
        let pos = g.usize_in(0..plan.arity());
        let slot = plan.slot(pos).clone();
        let bad_len = slot.numel + g.usize_in(1..10);
        let err = plan
            .check_host(pos, slot.dtype, bad_len)
            .expect_err("length mismatch must fail")
            .to_string();
        let want = format!("art: slot {pos} ({}) wants {} elems, got {bad_len}",
                           slot.name, slot.numel);
        prop_assert(err == want, &format!("got {err:?}, want {want:?}"))
    });
}

#[test]
fn scalar_binding_against_tensor_slot_reports_the_legacy_error() {
    proplite::run(200, |g| {
        let meta = random_meta(g);
        let plan = CallPlan::new("art", &meta).map_err(|e| e.to_string())?;
        // tensor slots are 2-D with numel > 1 in random_meta unless both
        // dims are 1 — pick one that genuinely isn't scalar-shaped
        let Some(&pos) = plan
            .role_positions("tensor")
            .iter()
            .find(|&&p| plan.slot(p).numel != 1)
        else {
            return Ok(()); // degenerate 1x1-only case: nothing to test
        };
        let slot = plan.slot(pos).clone();
        let err = plan
            .check_scalar(pos, Dtype::F32)
            .expect_err("non-scalar slot must fail")
            .to_string();
        let want = format!("art: slot {pos} ({}) is not an f32 scalar", slot.name);
        // u32 scalars use the "a u32 scalar" article, matching CallBuilder
        let err_u = plan
            .check_scalar(pos, Dtype::U32)
            .expect_err("non-scalar slot must fail")
            .to_string();
        let want_u = format!("art: slot {pos} ({}) is not a u32 scalar", slot.name);
        prop_assert(err == want, &format!("got {err:?}, want {want:?}"))?;
        prop_assert(err_u == want_u, &format!("got {err_u:?}, want {want_u:?}"))
    });
}

#[test]
fn arity_violations_report_the_legacy_errors() {
    proplite::run(200, |g| {
        let meta = random_meta(g);
        let plan = CallPlan::new("art", &meta).map_err(|e| e.to_string())?;
        let n = plan.arity();
        // one argument past the end — the append-time error
        let err = plan.next_slot(n).expect_err("overflow must fail").to_string();
        prop_assert(
            err == format!("art: too many arguments (expects {n})"),
            &format!("too-many: got {err:?}"),
        )?;
        // short by a random amount — the run-time error
        let bound = g.usize_in(0..n);
        let err = plan
            .check_arity(bound)
            .expect_err("underflow must fail")
            .to_string();
        prop_assert(
            err == format!("art: got {bound} args, artifact expects {n}"),
            &format!("arity: got {err:?}"),
        )?;
        // exact arity passes
        prop_assert(plan.check_arity(n).is_ok(), "exact arity ok")
    });
}

#[test]
fn duplicate_slots_and_bad_dtypes_are_rejected_at_plan_time() {
    let dup = ArtifactMeta {
        file: "x.hlo".to_string(),
        inputs: vec![
            desc("tensor", "w", vec![2, 2], "f32"),
            desc("tensor", "w", vec![2, 2], "f32"),
        ],
        outputs: vec![],
        forward_form: None,
    };
    assert!(CallPlan::new("art", &dup).is_err(), "duplicate (role, name)");

    let bad = ArtifactMeta {
        file: "x.hlo".to_string(),
        inputs: vec![desc("tensor", "w", vec![2], "f64")],
        outputs: vec![],
        forward_form: None,
    };
    assert!(CallPlan::new("art", &bad).is_err(), "unknown dtype");
}

#[test]
fn output_count_check_matches_the_legacy_error() {
    let meta = ArtifactMeta {
        file: "x.hlo".to_string(),
        inputs: vec![],
        outputs: vec![
            desc("scalar", "f_plus", vec![], "f32"),
            desc("scalar", "f_minus", vec![], "f32"),
        ],
        forward_form: None,
    };
    let plan = CallPlan::new("loss", &meta).unwrap();
    assert!(plan.check_outputs(2).is_ok());
    let err = plan.check_outputs(1).unwrap_err().to_string();
    assert_eq!(err, "loss: got 1 outputs, manifest says 2 \
                     (untuple patch missing?)");
}
