//! Property battery for the forward-form autotuner (`runtime::tune`).
//!
//! Properties pinned here:
//! * the persisted tuning table round-trips through its JSON codec
//!   identically (and the re-encode is bit-identical text);
//! * staleness is airtight: any manifest-hash or shape-key mismatch is a
//!   cache miss, never a stale decision;
//! * the winner under injected timings is deterministic — same scripted
//!   (materialize, implicit) ns sequences, same pinned form, every time —
//!   and a second resolve is a pure cache hit: counter emitted, **zero**
//!   interleaved timing spans in the trace (the ISSUE 9 warm-run
//!   criterion);
//! * the coordinator→worker handshake ships the resolved form policy
//!   bitwise: a TCP worker decoding the `HelloAck` sees exactly the config
//!   a loopback worker gets by clone, for all three policy encodings.

use std::path::{Path, PathBuf};

use tezo::config::{FormPolicy, ForwardForm, Method, TrainConfig};
use tezo::fleet::wire::{self, HelloAck, JobSpec};
use tezo::jsonx;
use tezo::proplite::{self, prop_assert, Gen};
use tezo::runtime::manifest::{ArtifactMeta, ConfigMeta, Manifest};
use tezo::runtime::tune::{self, TuneEntry, TuneSource, TuningTable};
use tezo::telemetry::{EventKind, Telemetry, TestClock};

// ---------------------------------------------------------------------------
// generators & fixtures
// ---------------------------------------------------------------------------

fn gen_hex(g: &mut Gen) -> String {
    format!("{:016x}", g.u64())
}

fn gen_shape(g: &mut Gen) -> String {
    format!("b{}s{}d{}L{}v{}", g.usize_in(1..64), g.usize_in(8..512),
            g.usize_in(8..2048), g.usize_in(1..48), g.usize_in(64..65536))
}

fn gen_table(g: &mut Gen) -> TuningTable {
    let mut t = TuningTable::new(gen_hex(g), gen_shape(g));
    let methods = ["tezo", "tezo_m", "tezo_adam", "lozo", "lozo_m"];
    let n = g.usize_in(1..methods.len() + 1);
    for name in methods.iter().take(n) {
        let form = *g.pick(&ForwardForm::ALL);
        t.entries.insert(name.to_string(), TuneEntry {
            artifact: format!("{name}_loss_pm"),
            form,
            materialize_ns: g.u64() % 1_000_000_000,
            implicit_ns: g.u64() % 1_000_000_000,
            trials: 1 + g.u64() % 8,
        });
    }
    t
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tezo-props-tune-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A manifest the tuner accepts without any runtime: real `manifest.json`
/// bytes on disk (for the fingerprint) + an in-memory artifact set that
/// makes TeZO tunable (both lowerings present).
fn synthetic_manifest(dir: &Path, salt: u64) -> Manifest {
    std::fs::write(dir.join("manifest.json"),
                   format!("{{\"synthetic\": {salt}}}")).unwrap();
    let stub = |file: &str, form: Option<ForwardForm>| ArtifactMeta {
        file: file.to_string(),
        inputs: Vec::new(),
        outputs: Vec::new(),
        forward_form: form.map(|f| f.name().to_string()),
    };
    let mut artifacts = std::collections::BTreeMap::new();
    artifacts.insert("tezo_loss_pm".to_string(),
                     stub("tezo_loss_pm.hlo.txt",
                          Some(ForwardForm::Materialize)));
    artifacts.insert("tezo_loss_pm_implicit".to_string(),
                     stub("tezo_loss_pm_implicit.hlo.txt",
                          Some(ForwardForm::Implicit)));
    artifacts.insert("tezo_update_factor".to_string(),
                     stub("tezo_update_factor.hlo.txt", None));
    Manifest {
        dir: dir.to_path_buf(),
        config: ConfigMeta {
            name: "synthetic".to_string(),
            d_model: 64, n_layers: 2, n_heads: 2, d_ff: 256, vocab: 256,
            seq_len: 64, batch: 4, r_max: 8, rank_threshold: 0.25,
            use_pallas: true, n_params: 0, init_seed: 0,
        },
        params: Vec::new(),
        matrix_ranks: Vec::new(),
        lozo_rank: 2,
        subzo_rank: 4,
        artifacts,
    }
}

fn gen_cfg(g: &mut Gen) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.method = *g.pick(&Method::ALL);
    cfg.steps = g.usize_in(1..1000);
    cfg.lr = g.f32_in(1e-6..1.0);
    cfg.rho = g.f32_in(1e-6..1.0);
    cfg.seed = g.u64();
    cfg.eval_every = g.usize_in(1..100);
    cfg.forward_form = *g.pick(&[
        FormPolicy::Auto,
        FormPolicy::Pinned(ForwardForm::Materialize),
        FormPolicy::Pinned(ForwardForm::Implicit),
    ]);
    cfg
}

// ---------------------------------------------------------------------------
// table codec
// ---------------------------------------------------------------------------

#[test]
fn prop_table_json_roundtrip_identity() {
    proplite::run(200, |g| {
        let t = gen_table(g);
        let text = jsonx::to_string_pretty(&t.to_json());
        let back = TuningTable::from_json(&jsonx::parse(&text)
            .map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        prop_assert(back == t, "decoded table differs")?;
        let text2 = jsonx::to_string_pretty(&back.to_json());
        prop_assert(text2 == text, "re-encode is not bit-identical")
    });
}

// ---------------------------------------------------------------------------
// staleness
// ---------------------------------------------------------------------------

#[test]
fn prop_stale_tables_never_load() {
    let dir = scratch_dir("stale");
    proplite::run(60, |g| {
        let t = gen_table(g);
        t.save(&dir).map_err(|e| e.to_string())?;
        prop_assert(
            TuningTable::load(&dir, &t.manifest_hash, &t.shape).as_ref()
                == Some(&t),
            "fresh table must load",
        )?;
        // any perturbation of hash or shape is a miss
        let other_hash = gen_hex(g);
        if other_hash != t.manifest_hash {
            prop_assert(
                TuningTable::load(&dir, &other_hash, &t.shape).is_none(),
                "hash mismatch must be a cache miss",
            )?;
        }
        let other_shape = gen_shape(g);
        if other_shape != t.shape {
            prop_assert(
                TuningTable::load(&dir, &t.manifest_hash, &other_shape)
                    .is_none(),
                "shape mismatch must be a cache miss",
            )?;
        }
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_table_is_a_miss_not_an_error() {
    let dir = scratch_dir("corrupt");
    std::fs::write(TuningTable::path(&dir), "{not json").unwrap();
    assert!(TuningTable::load(&dir, "x", "y").is_none());
    std::fs::write(TuningTable::path(&dir), "{\"version\": 999}").unwrap();
    assert!(TuningTable::load(&dir, "x", "y").is_none());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// deterministic winner under injected timings
// ---------------------------------------------------------------------------

fn events_of(tel: &Telemetry) -> Vec<tezo::telemetry::TraceEvent> {
    tel.events()
}

#[test]
fn prop_injected_timings_make_the_winner_deterministic() {
    let dir = scratch_dir("winner");
    proplite::run(40, |g| {
        let manifest = synthetic_manifest(&dir, g.u64());
        std::fs::remove_file(TuningTable::path(&dir)).ok();
        // scripted per-trial timings; the probe replays them in the
        // interleaved (materialize, implicit) call order
        let m_ns: Vec<u64> =
            (0..tune::TUNE_TRIALS).map(|_| 1 + g.u64() % 1_000_000).collect();
        let i_ns: Vec<u64> =
            (0..tune::TUNE_TRIALS).map(|_| 1 + g.u64() % 1_000_000).collect();
        let best_m = *m_ns.iter().min().unwrap();
        let best_i = *i_ns.iter().min().unwrap();
        let want = tune::winner(best_m, best_i);

        let run = |tel: &Telemetry| {
            let (mut mi, mut ii) = (0usize, 0usize);
            let mut measure = |form: ForwardForm| -> anyhow::Result<u64> {
                Ok(match form {
                    ForwardForm::Materialize => { mi += 1; m_ns[mi - 1] }
                    ForwardForm::Implicit => { ii += 1; i_ns[ii - 1] }
                })
            };
            tune::measure_and_pin(&manifest, Method::Tezo, tel, &mut measure)
        };

        let tel = Telemetry::with_clock(4096, Box::new(TestClock::new(1)));
        let r1 = run(&tel).map_err(|e| e.to_string())?;
        prop_assert(r1.form == want, "winner != argmin of best-of-trials")?;
        prop_assert(r1.source == TuneSource::Measured, "source")?;
        prop_assert(r1.materialize_ns == Some(best_m)
                        && r1.implicit_ns == Some(best_i),
                    "evidence must be best-of-trials")?;
        // measuring run emits the miss counter and one span per timed call
        let evs = events_of(&tel);
        let spans = evs.iter()
            .filter(|e| e.kind == EventKind::Span && e.cat == "tune")
            .count();
        prop_assert(spans as u64 == 2 * tune::TUNE_TRIALS,
                    "one tune span per timed call")?;
        prop_assert(evs.iter().any(|e| e.kind == EventKind::Counter
                                       && e.name == "cache_miss"),
                    "cache_miss counter")?;

        // re-measuring with the same script pins the same form (and the
        // persisted table already holds it)
        std::fs::remove_file(TuningTable::path(&dir)).ok();
        let r2 = run(&Telemetry::off()).map_err(|e| e.to_string())?;
        prop_assert(r2.form == r1.form, "winner must be deterministic")?;

        // warm path: pure cache hit, no timing spans at all
        let warm = Telemetry::with_clock(4096, Box::new(TestClock::new(1)));
        let cached = tune::resolve_cached(&manifest, Method::Tezo, &warm)
            .ok_or("expected a cache hit after measure_and_pin")?;
        prop_assert(cached.form == r1.form, "cached form differs")?;
        prop_assert(cached.source == TuneSource::CacheHit, "source")?;
        let evs = events_of(&warm);
        prop_assert(
            !evs.iter().any(|e| e.kind == EventKind::Span && e.cat == "tune"),
            "cache hit must not emit interleaved timing spans",
        )?;
        prop_assert(evs.iter().any(|e| e.kind == EventKind::Counter
                                       && e.name == "cache_hit"),
                    "cache_hit counter")
    });
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pinned_and_inert_policies_skip_the_table_entirely() {
    let dir = scratch_dir("static");
    let manifest = synthetic_manifest(&dir, 7);
    // explicit pin wins without touching disk
    let r = tune::resolve_static(&manifest, Method::Tezo,
                                 FormPolicy::Pinned(ForwardForm::Materialize))
        .expect("pinned resolves statically");
    assert_eq!(r.form, ForwardForm::Materialize);
    assert_eq!(r.source, TuneSource::Pinned);
    // MeZO has one lowering: Auto is inert, resolved to the fallback
    let r = tune::resolve_static(&manifest, Method::Mezo, FormPolicy::Auto)
        .expect("single-lowering methods resolve statically");
    assert_eq!(r.form, FormPolicy::Auto.resolve_fallback());
    assert_eq!(r.source, TuneSource::Inert);
    // TeZO under Auto genuinely needs a decision
    assert!(tune::resolve_static(&manifest, Method::Tezo,
                                 FormPolicy::Auto).is_none());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// coordinator → worker handshake parity
// ---------------------------------------------------------------------------

#[test]
fn prop_handshake_ships_the_resolved_policy_bitwise() {
    proplite::run(200, |g| {
        // what the coordinator resolved (possibly still Auto for inert
        // methods — the tag must survive that too)
        let cfg = gen_cfg(g);
        let ack = HelloAck {
            slot: (g.u64() % 64) as u32,
            workers: 1 + (g.u64() % 64) as u32,
            cfg: cfg.clone(),
            job: JobSpec::default(),
        };
        // loopback path: the worker receives `cfg` by clone — that IS the
        // reference. TCP path: encode → decode.
        let frame = wire::encode_hello_ack(&ack);
        let decoded = wire::decode_hello_ack(&frame)
            .map_err(|e| format!("{e:?}"))?;
        prop_assert(decoded.cfg == cfg,
                    "TCP worker must see the loopback worker's exact cfg")?;
        prop_assert(decoded.cfg.forward_form == cfg.forward_form,
                    "form policy lost in the handshake")?;
        // canonical codec: re-encode reproduces the frame bit-identically
        let frame2 = wire::encode_hello_ack(&decoded);
        prop_assert(frame2 == frame, "handshake re-encode differs")?;
        // the resolved fallback both worker kinds apply is identical
        prop_assert(decoded.cfg.forward_form.resolve_fallback()
                        == cfg.forward_form.resolve_fallback(),
                    "resolved concrete form differs across transports")
    });
}
