//! Property battery for checkpoint integrity: every corruption a typed
//! error, never wrong params. Runs against *synthetic* checkpoint
//! directories (hand-built descriptors + bins with real FNV digests), so
//! the whole verify/fallback surface is exercised without a PJRT device.
//!
//! Properties pinned here:
//! * an intact checkpoint verifies, and the report reflects the
//!   descriptor (step, bin count, byte total, digest coverage);
//! * any bin corruption — truncation, extension, a single flipped bit,
//!   or a deleted file — fails verification with an error;
//! * a tampered descriptor (wrong digest, wrong recorded length, wrong
//!   shape) fails verification even when the bin itself is intact;
//! * `latest_verified` falls back to the newest *older* retained
//!   checkpoint when the current one is corrupt, and reports every
//!   candidate's failure when none survives;
//! * pre-PR-10 descriptors (no digest fields) stay loadable, verify
//!   length-only, and report `digested = 0`.

use std::path::{Path, PathBuf};

use tezo::proplite::{self, prop_assert};
use tezo::runtime::checkpoint;
use tezo::runtime::journal::fnv1a64;

fn tmp(tag: &str, case: u64) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tezo_props_ckpt_{}_{tag}_{case}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    xs.iter().flat_map(|f| f.to_le_bytes()).collect()
}

/// Write a synthetic checkpoint at `step`: bins under `params/` plus the
/// retained descriptor (and, when `current`, the `checkpoint.json`
/// pointer) — the exact on-disk layout `save_retained` commits.
fn write_ckpt(dir: &Path, step: u64, bins: &[(String, Vec<u8>)],
              digests: bool, current: bool) -> Vec<PathBuf> {
    std::fs::create_dir_all(dir.join("params")).unwrap();
    let mut bin_paths = Vec::new();
    let mut parts = Vec::new();
    for (i, (name, bytes)) in bins.iter().enumerate() {
        let base = format!("s{step:010}_{i:03}_{name}.bin");
        let p = dir.join("params").join(&base);
        std::fs::write(&p, bytes).unwrap();
        bin_paths.push(p);
        let integrity = if digests {
            format!(", \"bytes\": {}, \"digest\": \"{:016x}\"",
                    bytes.len(), fnv1a64(bytes))
        } else {
            String::new()
        };
        parts.push(format!(
            "{{\"name\": \"{name}\", \"shape\": [{}], \
               \"bin\": \"params/{base}\"{integrity}}}",
            bytes.len() / 4
        ));
    }
    let text = format!(
        "{{\"format\": \"tezo-checkpoint-v1\", \"config\": \"synthetic\", \
           \"n_params\": 0, \"step\": {step}, \"params\": [{}]}}",
        parts.join(", ")
    );
    std::fs::write(dir.join(format!("checkpoint_s{step:010}.json")), &text).unwrap();
    if current {
        std::fs::write(dir.join("checkpoint.json"), &text).unwrap();
    }
    bin_paths
}

fn gen_bins(g: &mut tezo::proplite::Gen) -> Vec<(String, Vec<f32>)> {
    let n = g.usize_in(1..4);
    (0..n)
        .map(|i| (format!("p{i}"), g.vec_f32(g.usize_in(1..16), -1.0..1.0)))
        .collect()
}

// ---------------------------------------------------------------------------

#[test]
fn prop_intact_checkpoint_verifies() {
    let mut case = 0u64;
    proplite::run(30, |g| {
        case += 1;
        let dir = tmp("intact", case);
        let step = g.u64() % 10_000;
        let bins: Vec<(String, Vec<u8>)> = gen_bins(g)
            .into_iter()
            .map(|(n, xs)| (n, f32_bytes(&xs)))
            .collect();
        write_ckpt(&dir, step, &bins, true, true);
        let rep = checkpoint::verify(&dir)
            .map_err(|e| format!("intact checkpoint rejected: {e:#}"))?;
        prop_assert(rep.step == step, "report step wrong")?;
        prop_assert(rep.n_bins == bins.len(), "report bin count wrong")?;
        prop_assert(rep.digested == bins.len(), "digest coverage wrong")?;
        let total: u64 = bins.iter().map(|(_, b)| b.len() as u64).sum();
        prop_assert(rep.total_bytes == total, "report byte total wrong")?;
        prop_assert(rep.config == "synthetic", "report config wrong")?;
        let newest = checkpoint::latest_verified(&dir)
            .map_err(|e| format!("latest_verified rejected intact dir: {e:#}"))?;
        prop_assert(newest.step == step, "latest_verified picked wrong step")?;
        Ok(())
    });
}

#[test]
fn prop_every_bin_corruption_is_detected() {
    let mut case = 0u64;
    proplite::run(40, |g| {
        case += 1;
        let dir = tmp("bincorrupt", case);
        let bins: Vec<(String, Vec<u8>)> = gen_bins(g)
            .into_iter()
            .map(|(n, xs)| (n, f32_bytes(&xs)))
            .collect();
        let paths = write_ckpt(&dir, 7, &bins, true, true);
        let victim = paths.get(g.usize_in(0..paths.len()))
            .ok_or("no victim bin")?;
        let mut img = std::fs::read(victim).map_err(|e| e.to_string())?;
        match g.usize_in(0..4) {
            0 => {
                let cut = g.usize_in(0..img.len());
                img.truncate(cut);
                std::fs::write(victim, &img).map_err(|e| e.to_string())?;
            }
            1 => {
                for _ in 0..g.usize_in(1..9) {
                    img.push(g.u64() as u8);
                }
                std::fs::write(victim, &img).map_err(|e| e.to_string())?;
            }
            2 => {
                let off = g.usize_in(0..img.len());
                img[off] ^= 1 << g.usize_in(0..8);
                std::fs::write(victim, &img).map_err(|e| e.to_string())?;
            }
            _ => {
                std::fs::remove_file(victim).map_err(|e| e.to_string())?;
            }
        }
        prop_assert(checkpoint::verify(&dir).is_err(),
                    "corrupt bin passed verification")?;
        // the retained descriptor references the same bins, so with a
        // single checkpoint there is nothing to fall back to
        prop_assert(checkpoint::latest_verified(&dir).is_err(),
                    "latest_verified survived with every candidate corrupt")?;
        Ok(())
    });
}

#[test]
fn prop_descriptor_tamper_is_detected() {
    let mut case = 0u64;
    proplite::run(40, |g| {
        case += 1;
        let dir = tmp("doctamper", case);
        std::fs::create_dir_all(dir.join("params")).unwrap();
        let xs = g.vec_f32(g.usize_in(1..16), -1.0..1.0);
        let bytes = f32_bytes(&xs);
        let base = "s0000000007_000_p0.bin";
        std::fs::write(dir.join("params").join(base), &bytes)
            .map_err(|e| e.to_string())?;
        // one descriptor field lies; the bin itself is intact
        let (len_field, shape, digest) = match g.usize_in(0..3) {
            0 => (bytes.len() + 4, xs.len(), fnv1a64(&bytes)),
            1 => (bytes.len(), xs.len() + 1, fnv1a64(&bytes)),
            _ => (bytes.len(), xs.len(), fnv1a64(&bytes) ^ 1),
        };
        let text = format!(
            "{{\"format\": \"tezo-checkpoint-v1\", \"config\": \"synthetic\", \
               \"n_params\": 0, \"step\": 7, \"params\": [{{\
               \"name\": \"p0\", \"shape\": [{shape}], \
               \"bin\": \"params/{base}\", \"bytes\": {len_field}, \
               \"digest\": \"{digest:016x}\"}}]}}"
        );
        std::fs::write(dir.join("checkpoint.json"), &text)
            .map_err(|e| e.to_string())?;
        prop_assert(checkpoint::verify(&dir).is_err(),
                    "lying descriptor passed verification")?;
        Ok(())
    });
}

#[test]
fn prop_latest_verified_falls_back_to_older_retained() {
    let mut case = 0u64;
    proplite::run(30, |g| {
        case += 1;
        let dir = tmp("fallback", case);
        let old_step = g.u64() % 100;
        let old_bins: Vec<(String, Vec<u8>)> = gen_bins(g)
            .into_iter()
            .map(|(n, xs)| (n, f32_bytes(&xs)))
            .collect();
        write_ckpt(&dir, old_step, &old_bins, true, false);
        let new_bins: Vec<(String, Vec<u8>)> = gen_bins(g)
            .into_iter()
            .map(|(n, xs)| (n, f32_bytes(&xs)))
            .collect();
        let new_paths = write_ckpt(&dir, old_step + 1, &new_bins, true, true);
        // corrupt the newest checkpoint's first bin
        let victim = new_paths.first().ok_or("no new bin")?;
        let mut img = std::fs::read(victim).map_err(|e| e.to_string())?;
        let off = g.usize_in(0..img.len());
        img[off] ^= 0x10;
        std::fs::write(victim, &img).map_err(|e| e.to_string())?;
        let rep = checkpoint::latest_verified(&dir)
            .map_err(|e| format!("no fallback found: {e:#}"))?;
        prop_assert(rep.step == old_step,
                    "fallback did not pick the older retained checkpoint")?;
        // now corrupt the older one too: every candidate must fail, and
        // the error must name each candidate's failure
        for (i, (name, _)) in old_bins.iter().enumerate() {
            let p = dir
                .join("params")
                .join(format!("s{old_step:010}_{i:03}_{name}.bin"));
            let mut img = std::fs::read(&p).map_err(|e| e.to_string())?;
            if let Some(b) = img.first().copied() {
                img[0] = b ^ 0x01;
            }
            std::fs::write(&p, &img).map_err(|e| e.to_string())?;
        }
        let err = match checkpoint::latest_verified(&dir) {
            Ok(_) => return Err("all-corrupt dir verified".to_string()),
            Err(e) => format!("{e:#}"),
        };
        prop_assert(err.contains("candidate"),
                    "all-corrupt error does not enumerate candidates")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// deterministic shape checks
// ---------------------------------------------------------------------------

#[test]
fn legacy_descriptor_without_digests_verifies_length_only() {
    let dir = tmp("legacy", 0);
    let bins = vec![("w".to_string(), f32_bytes(&[1.0, 2.0, 3.0]))];
    let paths = write_ckpt(&dir, 3, &bins, false, true);
    let rep = checkpoint::verify(&dir).unwrap();
    assert_eq!(rep.digested, 0, "legacy descriptor must report no digests");
    assert_eq!(rep.n_bins, 1);
    // truncation is still caught by the shape-derived length check
    let p = paths.first().unwrap();
    let img = std::fs::read(p).unwrap();
    std::fs::write(p, &img[..8]).unwrap();
    assert!(checkpoint::verify(&dir).is_err(),
            "truncated legacy bin passed verification");
}

#[test]
fn candidates_are_newest_first_with_current_last() {
    let dir = tmp("order", 0);
    let bins = vec![("w".to_string(), f32_bytes(&[0.5]))];
    write_ckpt(&dir, 3, &bins, true, false);
    write_ckpt(&dir, 1, &bins, true, false);
    write_ckpt(&dir, 2, &bins, true, true);
    let got = checkpoint::candidates(&dir);
    assert_eq!(got, vec![
        "checkpoint_s0000000003.json".to_string(),
        "checkpoint_s0000000002.json".to_string(),
        "checkpoint_s0000000001.json".to_string(),
        "checkpoint.json".to_string(),
    ]);
}
