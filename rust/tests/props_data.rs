//! Property tests over the data substrate: tokenizer, tasks, batching,
//! corpus — the invariants the training protocol depends on.

use tezo::data::tasks::{self, Task};
use tezo::data::tokenizer::{Tokenizer, BOS, PAD, SEP, WORD_BASE};
use tezo::data::{BatchBuilder, Corpus};
use tezo::proplite::{self, prop_assert};

fn any_task(g: &mut proplite::Gen, seq_len: usize, vocab: usize) -> Task {
    let spec = *g.pick(&tasks::ALL_TASKS);
    let spec = tasks::spec_by_name(spec.name).unwrap();
    Task::new(spec, Tokenizer::new(vocab), seq_len, g.u64())
}

#[test]
fn examples_always_encode_the_protocol() {
    proplite::run(150, |g| {
        let seq_len = *g.pick(&[48usize, 64, 96, 128]);
        let vocab = *g.pick(&[256usize, 512, 2048]);
        let t = any_task(g, seq_len, vocab);
        let ex = t.example(g.usize_in(0..2) as u32, g.u64() % 10_000);
        prop_assert(ex.tokens.len() == seq_len, "tokens padded to seq_len")?;
        prop_assert(ex.targets.len() == seq_len && ex.mask.len() == seq_len, "lens")?;
        prop_assert(ex.tokens[0] == BOS, "starts with BOS")?;
        prop_assert(ex.tokens[ex.sep_pos] == SEP, "SEP at sep_pos")?;
        prop_assert(ex.label < t.spec.n_classes, "label in range")?;
        // the single mask position predicts the label token
        let masked: Vec<usize> =
            (0..seq_len).filter(|&i| ex.mask[i] > 0.0).collect();
        prop_assert(masked == vec![ex.sep_pos], "mask selects only SEP")?;
        prop_assert(ex.targets[ex.sep_pos] == t.tok.label_token(ex.label),
                    "target at SEP is the verbalizer")?;
        // all tokens within vocab
        prop_assert(ex.tokens.iter().all(|&tk| (tk as usize) < vocab && tk >= 0),
                    "tokens in vocab")
    });
}

#[test]
fn train_and_eval_splits_are_disjoint_streams() {
    proplite::run(50, |g| {
        let t = any_task(g, 64, 512);
        let idx = g.u64() % 1000;
        let train = t.example(0, idx);
        let eval = t.example(1, idx);
        prop_assert(train.tokens != eval.tokens, "splits differ")
    });
}

#[test]
fn eval_examples_never_leak_the_label() {
    proplite::run(100, |g| {
        let t = any_task(g, 64, 512);
        let ex = t.eval_example(g.u64() % 5000);
        prop_assert(ex.tokens[ex.sep_pos + 1] == PAD, "label hidden")
    });
}

#[test]
fn batch_builder_pools_are_balanced_for_any_k() {
    proplite::run(30, |g| {
        let t = any_task(g, 64, 512);
        let classes = t.spec.n_classes;
        let k = *g.pick(&[4usize, 16, 32]);
        let bb = BatchBuilder::new(t, 4, k);
        let mut per_class = vec![0usize; classes];
        for &idx in &bb.pool {
            per_class[bb.task.example(0, idx).label] += 1;
        }
        prop_assert(per_class.iter().all(|&c| c == k),
                    &format!("pool balance {per_class:?} for k={k}"))
    });
}

#[test]
fn train_batches_only_contain_pool_examples() {
    proplite::run(20, |g| {
        let t = any_task(g, 64, 512);
        let k = 8;
        let bb = BatchBuilder::new(t, 4, k);
        // labels observed over many batches must include every class
        let classes = bb.task.spec.n_classes;
        let mut seen = vec![false; classes];
        for step in 0..50 {
            let b = bb.train_batch(g.u64(), step);
            for &l in &b.labels {
                seen[l] = true;
            }
        }
        prop_assert(seen.iter().all(|&s| s), &format!("all classes sampled {seen:?}"))
    });
}

#[test]
fn corpus_tokens_stay_in_word_region() {
    proplite::run(50, |g| {
        let vocab = *g.pick(&[256usize, 2048]);
        let c = Corpus::new(Tokenizer::new(vocab), 64, g.u64());
        let (tokens, targets, mask) = c.sequence(g.u64() % 100_000);
        prop_assert(tokens[0] == BOS, "BOS first")?;
        prop_assert(tokens[1..].iter().all(|&t| t >= WORD_BASE && (t as usize) < vocab),
                    "words in region")?;
        // targets shifted
        for i in 0..tokens.len() - 1 {
            if mask[i] > 0.0 {
                prop_assert(targets[i] == tokens[i + 1], "shifted targets")?;
            }
        }
        Ok(())
    });
}

#[test]
fn tokenizer_labels_never_collide_with_words() {
    proplite::run(100, |g| {
        let vocab = g.usize_in(64..8192);
        let t = Tokenizer::new(vocab);
        let c = g.usize_in(0..8);
        let w = g.usize_in(0..100_000);
        prop_assert(t.label_token(c) < WORD_BASE, "label region")?;
        prop_assert(t.word_token(w) >= WORD_BASE, "word region")?;
        prop_assert((t.word_token(w) as usize) < vocab, "word below vocab")
    });
}
