//! Fleet determinism integration: the seed-synchronized data-parallel
//! trainer must (a) reproduce single-process training bit-identically with
//! one worker, and (b) be invariant to worker scheduling order with many
//! workers. Both gate on the tiny artifacts being present, like the other
//! PJRT integration suites.

use std::path::PathBuf;

use tezo::config::{FleetConfig, Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::{task_job_factory, FleetOutcome, FleetTrainer};
use tezo::runtime::{ParamStore, Runtime};

fn tiny_dir() -> Option<PathBuf> {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    Some(dir)
}

fn cfg_for(method: Method, steps: usize, seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::with_preset(method, "tiny");
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.eval_every = steps / 2;
    // pin the form: these parity tests compare the fleet against the plain
    // trainer, and an Auto policy resolves differently on the two paths
    // (the fleet probes and may pin the measured winner; the embedded
    // trainer takes the static fallback)
    cfg.forward_form =
        tezo::config::FormPolicy::Pinned(tezo::config::ForwardForm::Implicit);
    cfg
}

/// The same per-worker job construction the `train-dp` CLI performs.
fn job_factory(seed: u64) -> Box<tezo::fleet::worker::JobFactory> {
    task_job_factory("sst2".to_string(), seed, 16, 64, None)
}

fn run_fleet(dir: &PathBuf, method: Method, workers: usize, steps: usize,
             seed: u64) -> FleetOutcome {
    let cfg = cfg_for(method, steps, seed);
    let mut ft = FleetTrainer::new(FleetConfig::new(workers), cfg,
                                   dir.clone(), job_factory(seed));
    ft.run().expect("fleet run")
}

#[test]
fn one_worker_fleet_matches_plain_trainer_bitwise() {
    let Some(dir) = tiny_dir() else { return };
    let seed = 3u64;
    let steps = 8usize;
    for method in [Method::Tezo, Method::Mezo, Method::TezoAdam] {
        // single-process reference
        let rt = Runtime::open(&dir).unwrap();
        let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                             rt.manifest.config.seq_len, seed);
        let labels = task.label_tokens();
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        let evals = builder.eval_batches(64);
        let mut trainer = Trainer::new(&rt, cfg_for(method, steps, seed),
                                       DataSource::Task(builder))
            .with_eval(evals, labels);
        let plain = trainer.run(&mut params).unwrap();

        let fleet = run_fleet(&dir, method, 1, steps, seed);
        assert_eq!(plain.metrics.losses, fleet.metrics.losses,
                   "{}: 1-worker fleet diverged from plain trainer",
                   method.name());
        assert_eq!(plain.metrics.evals, fleet.metrics.evals,
                   "{}: eval accuracy diverged", method.name());
        assert_eq!(plain.skipped, fleet.skipped);
        assert_eq!(plain.state_bytes, fleet.state_bytes);
        // every worker sampled the same elements the trainer did
        assert_eq!(plain.counter, fleet.workers[0].counter,
                   "{}: sampled-element accounting diverged", method.name());
    }
}

#[test]
fn four_worker_fleet_is_invariant_to_scheduling() {
    let Some(dir) = tiny_dir() else { return };
    // repeated runs exercise different thread interleavings; the slotted
    // scalar aggregation must make the result bitwise reproducible anyway
    let a = run_fleet(&dir, Method::Tezo, 4, 6, 11);
    let b = run_fleet(&dir, Method::Tezo, 4, 6, 11);
    assert_eq!(a.metrics.losses, b.metrics.losses,
               "4-worker fleet is scheduling-dependent");
    assert_eq!(a.metrics.evals, b.metrics.evals);
    assert_eq!(a.fleet.comm, b.fleet.comm, "comm accounting must be exact");
    // a different master seed must change the trajectory
    let c = run_fleet(&dir, Method::Tezo, 4, 6, 12);
    assert_ne!(a.metrics.losses, c.metrics.losses, "seed ignored");
}

#[test]
fn more_workers_change_the_data_but_not_the_protocol() {
    let Some(dir) = tiny_dir() else { return };
    let one = run_fleet(&dir, Method::Tezo, 1, 5, 7);
    let two = run_fleet(&dir, Method::Tezo, 2, 5, 7);
    // different shard unions -> different two-point measurements
    assert_ne!(one.metrics.losses, two.metrics.losses,
               "2 workers must average over a larger shard union");
    // comm volume is O(workers), model-size independent
    assert_eq!(two.fleet.comm.tickets, 2 * one.fleet.comm.tickets);
    assert_eq!(two.fleet.comm.results, 2 * one.fleet.comm.results);
    let per_step = two.fleet.comm.total_bytes() / 5;
    assert_eq!(per_step,
               tezo::memmodel::comm::zo_scalar_step_bytes(2, 1),
               "runtime counter must match the analytic model");
    // every replica reports identical optimizer state size
    assert!(two.workers.iter().all(|r| r.state_bytes == two.state_bytes));
}

#[test]
fn fleet_rejects_first_order_methods() {
    // no artifacts needed: validation fails before any worker spawns
    let cfg = cfg_for(Method::FoAdam, 4, 0);
    let mut ft = FleetTrainer::new(FleetConfig::new(2), cfg,
                                   PathBuf::from("artifacts/none"),
                                   job_factory(0));
    assert!(ft.run().is_err(), "FO methods need gradient all-reduce");
}
