//! Runtime integration: artifacts load, execute, and obey their contracts.
//!
//! Requires `make artifacts` (tiny config). These tests close the
//! correctness chain started in python: the same step functions that
//! passed pytest are exercised here *through the HLO text -> PJRT path*.

use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::exec::{scalar_f32, to_vec_f32};
use tezo::runtime::{ArgValue, ParamStore, Runtime};

fn open_tiny() -> Option<(Runtime, ParamStore)> {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::open(&dir).expect("open runtime");
    let params = ParamStore::load(&rt.client, &rt.manifest).expect("load params");
    Some((rt, params))
}

fn tiny_batch(rt: &Runtime) -> tezo::data::Batch {
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let bb = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    bb.train_batch(0, 0)
}

#[test]
fn manifest_is_consistent_with_params() {
    let Some((rt, params)) = open_tiny() else { return };
    assert_eq!(params.len(), rt.manifest.params.len());
    assert_eq!(params.numel(), rt.manifest.config.n_params);
    // every artifact's leading param inputs match the param shapes
    let meta = rt.manifest.artifact("fwd_loss").unwrap();
    for (d, p) in meta.inputs.iter().zip(&rt.manifest.params) {
        assert_eq!(d.role, "param");
        assert_eq!(d.shape, p.shape, "{}", p.name);
    }
}

#[test]
fn fwd_loss_runs_and_is_finite() {
    let Some((rt, params)) = open_tiny() else { return };
    let b = tiny_batch(&rt);
    let out = rt
        .call("fwd_loss").unwrap()
        .bufs(params.bufs()).unwrap()
        .arg(ArgValue::I32(&b.tokens)).unwrap()
        .arg(ArgValue::I32(&b.targets)).unwrap()
        .arg(ArgValue::F32(&b.mask)).unwrap()
        .run().unwrap();
    assert_eq!(out.len(), 1);
    let loss = scalar_f32(&out[0]).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // vocab=256 -> random-init loss should be near ln(256) ~ 5.5
    assert!(loss > 2.0 && loss < 12.0, "loss {loss} implausible");
}

#[test]
fn mezo_loss_pm_is_seed_deterministic_and_symmetric() {
    let Some((rt, params)) = open_tiny() else { return };
    let b = tiny_batch(&rt);
    let run = |seed: u32, rho: f32| -> (f32, f32) {
        let out = rt
            .call("mezo_loss_pm").unwrap()
            .bufs(params.bufs()).unwrap()
            .arg(ArgValue::I32(&b.tokens)).unwrap()
            .arg(ArgValue::I32(&b.targets)).unwrap()
            .arg(ArgValue::F32(&b.mask)).unwrap()
            .arg(ArgValue::ScalarU32(seed)).unwrap()
            .arg(ArgValue::ScalarF32(rho)).unwrap()
            .run().unwrap();
        (scalar_f32(&out[0]).unwrap(), scalar_f32(&out[1]).unwrap())
    };
    let (fp1, fm1) = run(99, 1e-3);
    let (fp2, fm2) = run(99, 1e-3);
    assert_eq!(fp1, fp2, "same seed must replay identically");
    assert_eq!(fm1, fm2);
    // sign flip swaps the outputs (z is shared)
    let (fp3, fm3) = run(99, -1e-3);
    assert!((fp1 - fm3).abs() < 1e-5, "{fp1} vs {fm3}");
    assert!((fm1 - fp3).abs() < 1e-5);
    // different seed -> different perturbation
    let (fp4, _) = run(100, 1e-3);
    assert_ne!(fp1, fp4);
}

#[test]
fn mezo_update_roundtrip_restores_params() {
    // W' = update(W, seed, c); W'' = update(W', seed, -c) must equal W
    // exactly (same z regenerated from the seed — the resampling invariant
    // the whole training loop depends on).
    let Some((rt, mut params)) = open_tiny() else { return };
    let before = params.fetch(2).unwrap();
    let step = |params: &ParamStore, coeff: f32| -> Vec<xla::PjRtBuffer> {
        rt.call("mezo_update_sgd").unwrap()
            .bufs(params.bufs()).unwrap()
            .arg(ArgValue::ScalarU32(7)).unwrap()
            .arg(ArgValue::ScalarF32(coeff)).unwrap()
            .run().unwrap()
    };
    let out = step(&params, 0.125); // power of two: exact float arithmetic
    params.replace_all(out).unwrap();
    let mid = params.fetch(2).unwrap();
    assert_ne!(before, mid, "update must change params");
    let out = step(&params, -0.125);
    params.replace_all(out).unwrap();
    let after = params.fetch(2).unwrap();
    let max_err = before
        .iter()
        .zip(after.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-6, "roundtrip error {max_err}");
}

#[test]
fn tezo_loss_pm_matches_host_cpd_oracle() {
    // Reconstruct W + rho * U diag(tau) V^T on host for one weight and
    // check the artifact's f+ equals fwd_loss of the host-perturbed params.
    let Some((rt, params)) = open_tiny() else { return };
    let b = tiny_batch(&rt);
    let mats = rt.manifest.matrix_params();
    // Exact checks through the HLO path: identical (seed, taus) replay
    // bit-identically; zero taus must differ from nonzero taus; factors are
    // supplied as host F32 args (CallBuilder stages them to device).
    let (us, vs): (Vec<Vec<f32>>, Vec<Vec<f32>>) = mats
        .iter()
        .map(|p| {
            let r = rt.manifest.rank_of(&p.name).unwrap();
            (tezo::rngx::normal_vec(1, p.shape[0] * r),
             tezo::rngx::normal_vec(2, p.shape[1] * r))
        })
        .unzip();
    let run2 = |taus: &[Vec<f32>]| -> (f32, f32) {
        let mut call = rt.call("tezo_loss_pm").unwrap()
            .bufs(params.bufs()).unwrap();
        for u in &us {
            call = call.arg(ArgValue::F32(u)).unwrap();
        }
        for v in &vs {
            call = call.arg(ArgValue::F32(v)).unwrap();
        }
        for t in taus {
            call = call.arg(ArgValue::F32(t)).unwrap();
        }
        let out = call
            .arg(ArgValue::I32(&b.tokens)).unwrap()
            .arg(ArgValue::I32(&b.targets)).unwrap()
            .arg(ArgValue::F32(&b.mask)).unwrap()
            .arg(ArgValue::ScalarU32(11)).unwrap()
            .arg(ArgValue::ScalarF32(1e-2)).unwrap()
            .run().unwrap();
        (scalar_f32(&out[0]).unwrap(), scalar_f32(&out[1]).unwrap())
    };
    let zero_taus: Vec<Vec<f32>> = mats
        .iter()
        .map(|p| vec![0.0; rt.manifest.rank_of(&p.name).unwrap()])
        .collect();
    let taus: Vec<Vec<f32>> = mats
        .iter()
        .enumerate()
        .map(|(i, p)| tezo::rngx::normal_vec(100 + i as u64,
                                             rt.manifest.rank_of(&p.name).unwrap()))
        .collect();
    let a = run2(&zero_taus);
    let a2 = run2(&zero_taus);
    assert_eq!(a, a2, "deterministic replay");
    let c = run2(&taus);
    assert_ne!(a.0, c.0, "nonzero taus must perturb the loss");
}

#[test]
fn eval_logits_shape_and_determinism() {
    let Some((rt, params)) = open_tiny() else { return };
    let b = tiny_batch(&rt);
    let run = || -> Vec<f32> {
        let out = rt
            .call("eval_logits").unwrap()
            .bufs(params.bufs()).unwrap()
            .arg(ArgValue::I32(&b.tokens)).unwrap()
            .arg(ArgValue::I32(&b.positions)).unwrap()
            .run().unwrap();
        to_vec_f32(&out[0]).unwrap()
    };
    let logits = run();
    assert_eq!(logits.len(), rt.manifest.config.batch * rt.manifest.config.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert_eq!(logits, run());
}

#[test]
fn rank_schedule_rust_matches_python() {
    let Some((rt, params)) = open_tiny() else { return };
    let mismatches =
        tezo::coordinator::rank::verify_against_manifest(&rt.manifest, &params).unwrap();
    assert!(mismatches.is_empty(),
            "rank schedule mismatches (python vs rust SVD): {mismatches:?}");
}

#[test]
fn fo_valgrad_grad_direction_reduces_loss() {
    let Some((rt, mut params)) = open_tiny() else { return };
    let b = tiny_batch(&rt);
    let out = rt
        .call("fo_valgrad").unwrap()
        .bufs(params.bufs()).unwrap()
        .arg(ArgValue::I32(&b.tokens)).unwrap()
        .arg(ArgValue::I32(&b.targets)).unwrap()
        .arg(ArgValue::F32(&b.mask)).unwrap()
        .run().unwrap();
    let loss0 = scalar_f32(&out[0]).unwrap();
    // one small SGD step on host: W -= lr * g
    let n = params.len();
    let mut new_bufs = Vec::with_capacity(n);
    for i in 0..n {
        let w = params.fetch(i).unwrap();
        let g = to_vec_f32(&out[1 + i]).unwrap();
        let lr = 5e-2f32;
        let upd: Vec<f32> = w.iter().zip(g.iter()).map(|(w, g)| w - lr * g).collect();
        new_bufs.push(rt.client
            .buffer_from_host_buffer(&upd, &params.entries[i].shape, None)
            .unwrap());
    }
    params.replace_all(new_bufs).unwrap();
    let out = rt
        .call("fwd_loss").unwrap()
        .bufs(params.bufs()).unwrap()
        .arg(ArgValue::I32(&b.tokens)).unwrap()
        .arg(ArgValue::I32(&b.targets)).unwrap()
        .arg(ArgValue::F32(&b.mask)).unwrap()
        .run().unwrap();
    let loss1 = scalar_f32(&out[0]).unwrap();
    assert!(loss1 < loss0, "gradient step must reduce loss: {loss0} -> {loss1}");
}

#[test]
fn subzo_factors_are_orthonormal_through_hlo() {
    let Some((rt, _params)) = open_tiny() else { return };
    let out = rt
        .call("subzo_factors").unwrap()
        .arg(ArgValue::ScalarU32(5)).unwrap()
        .run().unwrap();
    let r = rt.manifest.subzo_rank;
    // check the first U factor: U^T U = I
    let meta = rt.manifest.artifact("subzo_factors").unwrap();
    let m = meta.outputs[0].shape[0];
    let u = to_vec_f32(&out[0]).unwrap();
    assert_eq!(u.len(), m * r);
    for a in 0..r {
        for b in 0..r {
            let mut dot = 0.0f64;
            for row in 0..m {
                dot += (u[row * r + a] as f64) * (u[row * r + b] as f64);
            }
            let want = if a == b { 1.0 } else { 0.0 };
            assert!((dot - want).abs() < 1e-3, "U^T U [{a},{b}] = {dot}");
        }
    }
}
