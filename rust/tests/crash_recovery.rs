//! Crash-recovery battery: interrupted runs resumed from (checkpoint +
//! journal tail) must be *bitwise* indistinguishable from the
//! uninterrupted run — the survivability claim of PR 10.
//!
//! Two tiers:
//! * artifact-gated (PJRT + `artifacts/tiny`): single-process `Trainer`
//!   with `--checkpoint-every`/`--resume`, including a torn journal tail
//!   and a corrupted newest checkpoint (fallback to the older retained
//!   descriptor + deeper journal replay);
//! * artifact-free: a 2-worker loopback sim fleet resumed across two
//!   `FleetTrainer::run` invocations from the coordinator journal, plus
//!   the divergence guard rolling a live fleet back to its last published
//!   checkpoint after an injected NaN — both bitwise against
//!   `sim::run_oracle`.

use std::path::PathBuf;

use tezo::config::{FleetConfig, Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, TrainOutcome, Trainer};
use tezo::coordinator::GuardPolicy;
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::sim::{self, SimReplica};
use tezo::fleet::worker::{JobFactory, Replica, ReplicaFactory};
use tezo::fleet::FleetTrainer;
use tezo::runtime::{ParamStore, Runtime};

// ---------------------------------------------------------------------------
// artifact-gated: single-process trainer
// ---------------------------------------------------------------------------

const STEPS: usize = 10;
const SEED: u64 = 42;

fn open_tiny() -> Option<Runtime> {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tezo_crashrec_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn data_source(rt: &Runtime) -> DataSource {
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, SEED);
    DataSource::Task(BatchBuilder::new(task, rt.manifest.config.batch, 16))
}

/// Run `steps` steps; `ckpt` = (dir, every) arms checkpoint + journal;
/// returns the outcome plus every parameter's final bits.
fn run_proc(rt: &Runtime, steps: usize, ckpt: Option<(&PathBuf, u64)>,
            resume: bool) -> (TrainOutcome, Vec<Vec<u32>>) {
    let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
    cfg.steps = steps;
    cfg.seed = SEED;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let mut trainer = Trainer::new(rt, cfg, data_source(rt));
    if let Some((dir, every)) = ckpt {
        trainer = trainer.with_checkpointing(dir.clone(), every, 2);
    }
    trainer = trainer.with_resume(resume);
    let out = trainer.run(&mut params).expect("train run");
    let bits = (0..params.entries.len())
        .map(|i| {
            params.fetch(i).unwrap().iter().map(|x| x.to_bits()).collect()
        })
        .collect();
    (out, bits)
}

/// The shared postcondition: the resumed run's losses are a bitwise suffix
/// of the golden run's, and the final parameters match bitwise.
fn assert_resumed_matches_golden(golden: &(TrainOutcome, Vec<Vec<u32>>),
                                 resumed: &(TrainOutcome, Vec<Vec<u32>>),
                                 label: &str) {
    let n = resumed.0.metrics.losses.len();
    assert!(n >= 1 && n <= STEPS, "{label}: {n} resumed losses");
    let tail = &golden.0.metrics.losses[STEPS - n..];
    assert!(
        resumed.0.metrics.losses.iter().zip(tail)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "{label}: resumed losses diverge from the golden run"
    );
    assert_eq!(resumed.1, golden.1,
               "{label}: final params diverge from the golden run");
}

#[test]
fn interrupted_run_resumes_bitwise() {
    let Some(rt) = open_tiny() else { return };
    let golden = run_proc(&rt, STEPS, None, false);
    let dir = tmp("resume");
    // "interrupted" at step 8: checkpoints at 3 and 6 retained, journal
    // carrying the replay tail for steps 6..8
    run_proc(&rt, 8, Some((&dir, 3)), false);
    let resumed = run_proc(&rt, STEPS, Some((&dir, 3)), true);
    assert_eq!(resumed.0.metrics.resumed_from, Some(6));
    // steps 6..8 replayed update-only from the journal, 8..10 run live
    assert_eq!(resumed.0.metrics.losses.len(), 2);
    assert_resumed_matches_golden(&golden, &resumed, "resume");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_journal_tail_is_truncated_and_rerun() {
    let Some(rt) = open_tiny() else { return };
    let golden = run_proc(&rt, STEPS, None, false);
    let dir = tmp("torn");
    run_proc(&rt, 8, Some((&dir, 3)), false);
    // simulate a crash mid-append: tear the last frame and add garbage
    let jpath = dir.join("journal.bin");
    let mut img = std::fs::read(&jpath).expect("journal written");
    img.truncate(img.len().saturating_sub(5));
    img.extend_from_slice(&[0xAB; 17]);
    std::fs::write(&jpath, &img).unwrap();
    let resumed = run_proc(&rt, STEPS, Some((&dir, 3)), true);
    assert_eq!(resumed.0.metrics.resumed_from, Some(6));
    // the torn record costs at most one journaled step — it is re-run live
    assert!(resumed.0.metrics.losses.len() >= 2,
            "torn tail lost committed steps");
    assert_resumed_matches_golden(&golden, &resumed, "torn-journal");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_newest_checkpoint_falls_back_to_retained() {
    let Some(rt) = open_tiny() else { return };
    let golden = run_proc(&rt, STEPS, None, false);
    let dir = tmp("fallback");
    run_proc(&rt, 8, Some((&dir, 3)), false);
    // flip one byte in every step-6 bin: checkpoint_s..6 and the current
    // pointer both fail verification; resume must fall back to step 3 and
    // replay the deeper journal tail (3..8)
    let rd = std::fs::read_dir(dir.join("params")).expect("params dir");
    let mut corrupted = 0;
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.starts_with("s0000000006_") {
            let mut img = std::fs::read(entry.path()).unwrap();
            if let Some(b) = img.first().copied() {
                img[0] = b ^ 0x40;
            }
            std::fs::write(entry.path(), &img).unwrap();
            corrupted += 1;
        }
    }
    assert!(corrupted > 0, "no step-6 bins found to corrupt");
    let resumed = run_proc(&rt, STEPS, Some((&dir, 3)), true);
    assert_eq!(resumed.0.metrics.resumed_from, Some(3),
               "resume did not fall back to the older retained checkpoint");
    assert_resumed_matches_golden(&golden, &resumed, "ckpt-fallback");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// artifact-free: loopback sim fleet
// ---------------------------------------------------------------------------

const DIM: usize = 16;
const WORKERS: usize = 2;

fn unused_jobs() -> Box<JobFactory> {
    Box::new(|_, _| Err(anyhow::anyhow!("sim fleets inject their replicas")))
}

fn sim_cfg(steps: usize) -> TrainConfig {
    TrainConfig { steps, lr: 0.05, seed: 7, ..TrainConfig::default() }
}

fn sim_factory(dir: &PathBuf, cfg: &TrainConfig,
               nan_once_at: Vec<(u64, u32)>) -> Box<ReplicaFactory> {
    let cfg = cfg.clone();
    let dir = dir.clone();
    Box::new(move |w, n| {
        let mut r = SimReplica::new(w, n, &cfg, DIM)
            .with_checkpoint_path(dir.join("ckpt.bin"))
            .with_save_to(dir.join(format!("final_{w}.bin")));
        if w == 0 {
            r = r.with_nan_once_at(nan_once_at.clone());
        }
        Ok(Box::new(r) as Box<dyn Replica>)
    })
}

fn final_param_bits(dir: &PathBuf, steps: u64) -> Vec<Vec<u32>> {
    (0..WORKERS)
        .map(|w| {
            let path = dir.join(format!("final_{w}.bin"));
            let (step, p) = sim::read_sim_params(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            assert_eq!(step, steps, "worker {w} stopped early");
            p.iter().map(|x| x.to_bits()).collect()
        })
        .collect()
}

/// A 10-step fleet run interrupted at step 5: the second invocation picks
/// up from the coordinator journal (checkpoint-free, so the full durable
/// log replays from init) and the combined run matches the uninterrupted
/// oracle bitwise — trace, kappa bits, live losses, and final params.
#[test]
fn fleet_resumes_from_coordinator_journal_bitwise() {
    let dir = tmp("fleet_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let fc = FleetConfig { checkpoint_every: 0, ..FleetConfig::new(WORKERS) };

    let half = sim_cfg(5);
    FleetTrainer::new(fc, half.clone(), PathBuf::from("unused"), unused_jobs())
        .with_replica_factory(sim_factory(&dir, &half, vec![]))
        .with_checkpoint_dir(dir.clone())
        .run()
        .expect("first half");

    let full = sim_cfg(10);
    let out = FleetTrainer::new(fc, full.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(sim_factory(&dir, &full, vec![]))
        .with_checkpoint_dir(dir.clone())
        .with_resume(true)
        .run()
        .expect("resumed half");

    let oracle = sim::run_oracle(&full, WORKERS as u32, DIM);
    assert_eq!(out.metrics.resumed_from, Some(0));
    assert_eq!(out.trace, oracle.trace, "resumed trace diverged");
    assert!(out.trace.iter().zip(&oracle.trace).all(|(a, b)| {
        a.kappa.map(f32::to_bits) == b.kappa.map(f32::to_bits)
    }), "kappa stream not bit-identical");
    // the resumed invocation runs steps 5..10 live; its losses must be a
    // bitwise suffix of the oracle's
    let n = out.metrics.losses.len();
    assert_eq!(n, 5, "resume replayed instead of restarting at step 5");
    assert!(out.metrics.losses.iter().zip(&oracle.losses[10 - n..])
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "live losses diverge from the oracle");
    assert_eq!(final_param_bits(&dir, 10),
               vec![oracle.params.iter().map(|p| p.to_bits()).collect::<Vec<u32>>();
                    WORKERS],
               "final params diverge from the oracle");
    std::fs::remove_dir_all(&dir).ok();
}

/// Same resume, but the journal's tail is torn mid-frame (crash during
/// append) and garbage follows: recovery truncates the damage, replays the
/// committed prefix, and re-runs the lost step live — still bitwise.
#[test]
fn fleet_resume_survives_torn_journal_tail() {
    let dir = tmp("fleet_torn");
    std::fs::create_dir_all(&dir).unwrap();
    let fc = FleetConfig { checkpoint_every: 0, ..FleetConfig::new(WORKERS) };

    let half = sim_cfg(5);
    FleetTrainer::new(fc, half.clone(), PathBuf::from("unused"), unused_jobs())
        .with_replica_factory(sim_factory(&dir, &half, vec![]))
        .with_checkpoint_dir(dir.clone())
        .run()
        .expect("first half");

    let jpath = dir.join("journal.bin");
    let mut img = std::fs::read(&jpath).expect("journal written");
    img.truncate(img.len().saturating_sub(7));
    img.extend_from_slice(&[0xCD; 11]);
    std::fs::write(&jpath, &img).unwrap();

    let full = sim_cfg(10);
    let out = FleetTrainer::new(fc, full.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(sim_factory(&dir, &full, vec![]))
        .with_checkpoint_dir(dir.clone())
        .with_resume(true)
        .run()
        .expect("resumed half");

    let oracle = sim::run_oracle(&full, WORKERS as u32, DIM);
    assert_eq!(out.trace, oracle.trace, "trace diverged after torn tail");
    let n = out.metrics.losses.len();
    assert!((5..=6).contains(&n),
            "torn tail should cost at most the torn step, lost {}", 10 - n);
    assert!(out.metrics.losses.iter().zip(&oracle.losses[10 - n..])
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "live losses diverge from the oracle");
    assert_eq!(final_param_bits(&dir, 10),
               vec![oracle.params.iter().map(|p| p.to_bits()).collect::<Vec<u32>>();
                    WORKERS],
               "final params diverge from the oracle");
    std::fs::remove_dir_all(&dir).ok();
}

/// Divergence guard on a live fleet: worker 0 reports one NaN forward at
/// step 4; the guard rolls the fleet back to the step-3 checkpoint, the
/// re-run is clean, and the final trace and params still match the oracle
/// bitwise (`skip_steps: 0` keeps the replay footprint oracle-identical).
#[test]
fn fleet_guard_rolls_back_to_checkpoint_and_recovers_bitwise() {
    let dir = tmp("fleet_guard");
    std::fs::create_dir_all(&dir).unwrap();
    let fc = FleetConfig { checkpoint_every: 3, ..FleetConfig::new(WORKERS) };
    let cfg = sim_cfg(9);
    let guard = GuardPolicy {
        nonfinite_streak: 1,
        max_rollbacks: 3,
        skip_steps: 0,
        ..GuardPolicy::default()
    };
    let out = FleetTrainer::new(fc, cfg.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(sim_factory(&dir, &cfg, vec![(4, 0)]))
        .with_guard(guard)
        .run()
        .expect("guarded fleet run");

    assert_eq!(out.metrics.rollbacks, 1, "expected exactly one rollback");
    assert_eq!(out.skipped, 1, "the NaN step must be skipped in lockstep");
    let oracle = sim::run_oracle(&cfg, WORKERS as u32, DIM);
    assert_eq!(out.trace, oracle.trace,
               "post-rollback trace diverged from the oracle");
    assert!(out.trace.iter().zip(&oracle.trace).all(|(a, b)| {
        a.kappa.map(f32::to_bits) == b.kappa.map(f32::to_bits)
    }), "kappa stream not bit-identical after rollback");
    // first pass records steps 0..4 and the NaN, the re-run records 3..9:
    // the re-run's tail must be bitwise the oracle's steps 3..9
    assert_eq!(out.metrics.losses.len(), 9 + 2);
    assert!(out.metrics.losses[5..].iter().zip(&oracle.losses[3..])
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "re-run losses diverge from the oracle");
    assert_eq!(final_param_bits(&dir, 9),
               vec![oracle.params.iter().map(|p| p.to_bits()).collect::<Vec<u32>>();
                    WORKERS],
               "final params diverge from the oracle");
    std::fs::remove_dir_all(&dir).ok();
}

/// The rollback budget is a hard stop: a second NaN after the only allowed
/// rollback turns into a typed error instead of a livelock.
#[test]
fn fleet_guard_budget_exhaustion_is_a_typed_error() {
    let dir = tmp("fleet_budget");
    std::fs::create_dir_all(&dir).unwrap();
    let fc = FleetConfig { checkpoint_every: 3, ..FleetConfig::new(WORKERS) };
    let cfg = sim_cfg(9);
    let guard = GuardPolicy {
        nonfinite_streak: 1,
        max_rollbacks: 1,
        skip_steps: 0,
        ..GuardPolicy::default()
    };
    let err = FleetTrainer::new(fc, cfg.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(sim_factory(&dir, &cfg, vec![(4, 0), (4, 0)]))
        .with_guard(guard)
        .run()
        .expect_err("budget exhaustion must error");
    assert!(format!("{err:#}").contains("rollback budget"),
            "unexpected error: {err:#}");
    std::fs::remove_dir_all(&dir).ok();
}
