//! Property tests over the substrate modules (proplite harness).

use tezo::jsonx::{self, Value};
use tezo::proplite::{self, prop_assert, prop_close};
use tezo::rngx::{self, SplitMix64, Xoshiro256};
use tezo::tensor::{stats, svd, Matrix};

#[test]
fn json_roundtrip_random_trees() {
    proplite::run(200, |g| {
        let v = random_json(g, 3);
        let text = jsonx::to_string_pretty(&v);
        let back = jsonx::parse(&text).map_err(|e| e.to_string())?;
        prop_assert(back == v, &format!("roundtrip mismatch for {text}"))
    });
}

fn random_json(g: &mut proplite::Gen, depth: usize) -> Value {
    let choice = if depth == 0 { g.usize_in(0..4) } else { g.usize_in(0..6) };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(g.bool()),
        2 => Value::Int(g.u64() as i64 / 2),
        3 => {
            // float with exact decimal repr to survive roundtrip comparisons
            Value::Float((g.u64() % 1_000_000) as f64 / 64.0)
        }
        4 => Value::Array((0..g.usize_in(0..5))
            .map(|_| random_json(g, depth - 1))
            .collect()),
        _ => Value::Object((0..g.usize_in(0..5))
            .map(|i| (format!("k{i}_{}", g.usize_in(0..100)), random_json(g, depth - 1)))
            .collect()),
    }
}

#[test]
fn json_strings_with_escapes_roundtrip() {
    proplite::run(100, |g| {
        let chars: Vec<char> = vec!['a', '"', '\\', '\n', '\t', 'é', '中', '\u{1F600}', ' '];
        let n = g.usize_in(0..12);
        let s: String = (0..n).map(|_| *g.pick(&chars)).collect();
        let v = Value::Str(s.clone());
        let text = jsonx::to_string_pretty(&v);
        let back = jsonx::parse(&text).map_err(|e| e.to_string())?;
        prop_assert(back == v, &format!("string roundtrip: {s:?}"))
    });
}

#[test]
fn splitmix_mix_avalanche() {
    // flipping one input bit should flip ~half the output bits
    proplite::run(100, |g| {
        let a = g.u64();
        let b = g.u64();
        let bit = 1u64 << g.usize_in(0..64);
        let x = SplitMix64::mix(a, b);
        let y = SplitMix64::mix(a ^ bit, b);
        let flipped = (x ^ y).count_ones();
        prop_assert((16..=48).contains(&flipped),
                    &format!("avalanche {flipped} bits"))
    });
}

#[test]
fn gaussian_matrix_spectrum_obeys_marchenko_pastur_edge() {
    // sigma_max of an m x n Gaussian ~ sqrt(m) + sqrt(n); check within 25%
    proplite::run(8, |g| {
        let m = g.usize_in(40..80);
        let n = g.usize_in(40..80);
        let seed = g.u64();
        let mut gen = rngx::normal_rng(seed);
        let a = Matrix::randn(m, n, &mut gen);
        let s = svd::singular_values_exact(&a);
        let edge = (m as f64).sqrt() + (n as f64).sqrt();
        prop_close(s[0], edge, 0.25, "spectral edge")
    });
}

#[test]
fn svd_top_values_match_exact_for_random_shapes() {
    proplite::run(10, |g| {
        let m = g.usize_in(10..60);
        let n = g.usize_in(10..60);
        let mut gen = rngx::normal_rng(g.u64());
        let a = Matrix::randn(m, n, &mut gen);
        let exact = svd::singular_values_exact(&a);
        let k = g.usize_in(1..m.min(n).min(6));
        let fast = svd::top_singular_values(&a, k, g.u64()).map_err(|e| e.to_string())?;
        for (f, e) in fast.iter().zip(exact.iter()) {
            prop_close(*f, *e, 0.02, "top singular value")?;
        }
        Ok(())
    });
}

#[test]
fn cpd_slice_frobenius_matches_factor_norms_rank1() {
    // for rank 1: ||tau * u v^T||_F = |tau| * ||u|| * ||v||
    proplite::run(50, |g| {
        let m = g.usize_in(2..40);
        let n = g.usize_in(2..40);
        let mut gen = rngx::normal_rng(g.u64());
        let u = Matrix::randn(m, 1, &mut gen);
        let v = Matrix::randn(n, 1, &mut gen);
        let tau = [g.f32_in(-2.0..2.0)];
        let z = Matrix::cpd_slice(&u, &v, &tau).map_err(|e| e.to_string())?;
        let want = (tau[0].abs() as f64) * u.fro_norm() * v.fro_norm();
        prop_close(z.fro_norm(), want, 1e-4, "rank-1 norm")
    });
}

#[test]
fn matmul_is_associative_enough() {
    proplite::run(20, |g| {
        let a_dim = g.usize_in(2..12);
        let b_dim = g.usize_in(2..12);
        let c_dim = g.usize_in(2..12);
        let d_dim = g.usize_in(2..12);
        let mut gen = rngx::normal_rng(g.u64());
        let a = Matrix::randn(a_dim, b_dim, &mut gen);
        let b = Matrix::randn(b_dim, c_dim, &mut gen);
        let c = Matrix::randn(c_dim, d_dim, &mut gen);
        let left = a.matmul(&b).unwrap().matmul(&c).unwrap();
        let right = a.matmul(&b.matmul(&c).unwrap()).unwrap();
        let diff = left
            .data
            .iter()
            .zip(right.data.iter())
            .map(|(x, y)| (x - y).abs() as f64)
            .fold(0.0, f64::max);
        prop_assert(diff < 1e-3, &format!("associativity diff {diff}"))
    });
}

#[test]
fn quantile_is_monotone_and_bounded() {
    proplite::run(100, |g| {
        let n = g.usize_in(1..200);
        let xs: Vec<f64> = (0..n).map(|_| g.f64_in(-100.0..100.0)).collect();
        let q1 = g.f64_in(0.0..1.0);
        let q2 = g.f64_in(0.0..1.0);
        let (lo, hi) = if q1 <= q2 { (q1, q2) } else { (q2, q1) };
        let v_lo = stats::quantile(&xs, lo);
        let v_hi = stats::quantile(&xs, hi);
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        prop_assert(v_lo <= v_hi + 1e-12, "monotone")?;
        prop_assert(v_lo >= min - 1e-12 && v_hi <= max + 1e-12, "bounded")
    });
}

#[test]
fn xoshiro_streams_do_not_correlate() {
    proplite::run(20, |g| {
        let s1 = g.u64();
        let s2 = s1 ^ (1 << g.usize_in(0..64));
        let mut a = Xoshiro256::seed_from(s1);
        let mut b = Xoshiro256::seed_from(s2);
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        prop_assert(same == 0, &format!("{same} collisions in adjacent streams"))
    });
}
