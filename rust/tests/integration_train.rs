//! End-to-end training integration over the tiny artifacts: every optimizer
//! driver runs real steps through the PJRT path, losses decrease on the
//! planted-signal task, and runs replay deterministically from the seed.

use tezo::config::{Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Corpus, Task, Tokenizer};
use tezo::runtime::{ParamStore, Runtime};

fn open_tiny() -> Option<Runtime> {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

fn run_method(rt: &Runtime, method: Method, steps: usize, seed: u64)
              -> tezo::coordinator::trainer::TrainOutcome {
    let mut cfg = TrainConfig::with_preset(method, "tiny");
    cfg.steps = steps;
    cfg.seed = seed;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, seed);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let mut trainer = Trainer::new(rt, cfg, DataSource::Task(builder));
    trainer.run(&mut params).unwrap()
}

#[test]
fn every_zo_method_trains_without_nans() {
    let Some(rt) = open_tiny() else { return };
    for method in [Method::Mezo, Method::MezoM, Method::MezoAdam, Method::Lozo,
                   Method::LozoM, Method::Subzo, Method::ZoAdamu,
                   Method::Tezo, Method::TezoM, Method::TezoAdam] {
        let out = run_method(&rt, method, 8, 0);
        assert_eq!(out.skipped, 0, "{}: skipped steps", method.name());
        assert_eq!(out.metrics.losses.len(), 8);
        assert!(out.metrics.losses.iter().all(|l| l.is_finite()),
                "{}: non-finite loss", method.name());
    }
}

#[test]
fn tezo_loss_decreases_over_training() {
    let Some(rt) = open_tiny() else { return };
    let out = run_method(&rt, Method::Tezo, 60, 1);
    let first = out.metrics.initial_loss_avg(10);
    let last = out.metrics.final_loss_avg(10);
    assert!(last < first - 0.05,
            "tezo loss should decrease: {first:.4} -> {last:.4}");
}

#[test]
fn fo_adam_decreases_fastest() {
    // sanity on relative optimizer strength at equal steps: the FO
    // reference should beat plain ZO (it uses exact gradients)
    let Some(rt) = open_tiny() else { return };
    let zo = run_method(&rt, Method::Tezo, 30, 2);
    let fo = run_method(&rt, Method::FoAdam, 30, 2);
    assert!(fo.metrics.final_loss_avg(5) < zo.metrics.final_loss_avg(5),
            "fo {} vs zo {}", fo.metrics.final_loss_avg(5), zo.metrics.final_loss_avg(5));
}

#[test]
fn runs_replay_bit_identically_from_seed() {
    let Some(rt) = open_tiny() else { return };
    for method in [Method::Mezo, Method::Tezo, Method::TezoAdam] {
        let a = run_method(&rt, method, 6, 42);
        let b = run_method(&rt, method, 6, 42);
        assert_eq!(a.metrics.losses, b.metrics.losses,
                   "{}: non-deterministic", method.name());
        let c = run_method(&rt, method, 6, 43);
        assert_ne!(a.metrics.losses, c.metrics.losses,
                   "{}: seed ignored", method.name());
    }
}

#[test]
fn sampled_element_counts_match_table2_closed_forms() {
    use tezo::coordinator::counter::closed_form;
    let Some(rt) = open_tiny() else { return };
    let t = 7u64;
    // expected totals summed over matrix params
    let mats = rt.manifest.matrix_params();
    let lazy = 50u64; // preset lazy interval

    let mezo_expect: u64 = mats.iter()
        .map(|p| closed_form::mezo(p.shape[0] as u64, p.shape[1] as u64, t))
        .sum();
    let out = run_method(&rt, Method::Mezo, t as usize, 0);
    assert_eq!(out.counter.matrix_elements, mezo_expect);

    let tezo_expect: u64 = mats.iter()
        .map(|p| closed_form::tezo(p.shape[0] as u64, p.shape[1] as u64,
                                   rt.manifest.rank_of(&p.name).unwrap() as u64, t))
        .sum();
    let out = run_method(&rt, Method::Tezo, t as usize, 0);
    assert_eq!(out.counter.matrix_elements, tezo_expect);

    let r = rt.manifest.lozo_rank as u64;
    let lozo_expect: u64 = mats.iter()
        .map(|p| closed_form::lozo(p.shape[0] as u64, p.shape[1] as u64, r, t, lazy))
        .sum();
    let out = run_method(&rt, Method::Lozo, t as usize, 0);
    assert_eq!(out.counter.matrix_elements, lozo_expect);

    let r = rt.manifest.subzo_rank as u64;
    let subzo_expect: u64 = mats.iter()
        .map(|p| closed_form::subzo(p.shape[0] as u64, p.shape[1] as u64, r, t, lazy))
        .sum();
    let out = run_method(&rt, Method::Subzo, t as usize, 0);
    assert_eq!(out.counter.matrix_elements, subzo_expect);
}

#[test]
fn state_bytes_ordering_matches_memory_model() {
    let Some(rt) = open_tiny() else { return };
    let tezo_adam = run_method(&rt, Method::TezoAdam, 3, 0).state_bytes;
    let mezo_m = run_method(&rt, Method::MezoM, 3, 0).state_bytes;
    let mezo_adam = run_method(&rt, Method::MezoAdam, 3, 0).state_bytes;
    let mezo = run_method(&rt, Method::Mezo, 3, 0).state_bytes;
    assert!(mezo < tezo_adam, "mezo {mezo} tezo-adam {tezo_adam}");
    assert!(tezo_adam < mezo_m, "tezo-adam {tezo_adam} mezo-m {mezo_m}");
    assert!(mezo_m < mezo_adam);
}

#[test]
fn qspsa_multi_perturbation_trains() {
    // q-SPSA with q=4 on plain TeZO: averaged-direction updates must run,
    // stay finite, and differ from the q=1 trajectory
    let Some(rt) = open_tiny() else { return };
    let run_q = |q: usize| {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
        cfg.steps = 6;
        cfg.n_perturb = q;
        let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        Trainer::new(&rt, cfg, DataSource::Task(builder)).run(&mut params).unwrap()
    };
    let q1 = run_q(1);
    let q4 = run_q(4);
    assert!(q4.metrics.losses.iter().all(|l| l.is_finite()));
    assert_ne!(q1.metrics.losses, q4.metrics.losses);
    // q=4 samples 4x the tau draws per step (plus the same one-time panels)
    assert!(q4.counter.matrix_elements > q1.counter.matrix_elements);
}

#[test]
fn qspsa_rejected_for_stateful_methods() {
    let Some(rt) = open_tiny() else { return };
    let mut cfg = TrainConfig::with_preset(Method::TezoAdam, "tiny");
    cfg.steps = 2;
    cfg.n_perturb = 4;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let err = Trainer::new(&rt, cfg, DataSource::Task(builder)).run(&mut params);
    assert!(err.is_err(), "stateful method must reject q > 1");
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let Some(rt) = open_tiny() else { return };
    // train a few steps so the params differ from init
    let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
    cfg.steps = 4;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    Trainer::new(&rt, cfg, DataSource::Task(builder)).run(&mut params).unwrap();

    let dir = std::env::temp_dir().join(format!("tezo_ckpt_{}", std::process::id()));
    tezo::runtime::checkpoint::save(&dir, &rt.manifest, &params, 4).unwrap();
    let (restored, step) = tezo::runtime::checkpoint::load(&dir, &rt.client,
                                                           &rt.manifest).unwrap();
    assert_eq!(step, 4);
    for i in 0..params.len() {
        assert_eq!(params.fetch(i).unwrap(), restored.fetch(i).unwrap(),
                   "param {i} mismatch after checkpoint roundtrip");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kappa_probe_reports_sane_statistics() {
    let Some(rt) = open_tiny() else { return };
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let batch = builder.train_batch(0, 0);
    let s = tezo::coordinator::probe::kappa_distribution(
        &rt, &mut params, &batch, Method::Tezo, 1e-3, 12, 3).unwrap();
    assert_eq!(s.samples, 12);
    assert!(s.second_moment.is_finite() && s.second_moment > 0.0);
    assert!(s.sign_consistency >= 0.5 && s.sign_consistency <= 1.0);
}

#[test]
fn greedy_generation_extends_prompts() {
    let Some(rt) = open_tiny() else { return };
    let params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let corpus = tezo::data::Corpus::new(tok, rt.manifest.config.seq_len, 1);
    let prompts: Vec<Vec<i32>> = (0..2)
        .map(|i| corpus.sequence(i).0[..8].to_vec())
        .collect();
    let out = tezo::coordinator::generate::greedy_generate(&rt, &params,
                                                           &prompts, 6).unwrap();
    assert_eq!(out.len(), 2);
    for (row, p) in out.iter().zip(&prompts) {
        assert_eq!(row.len(), p.len() + 6);
        assert_eq!(&row[..p.len()], &p[..], "prompt must be preserved");
        assert!(row[p.len()..].iter().all(|&t| t != 0), "no PAD emitted");
    }
    // deterministic
    let again = tezo::coordinator::generate::greedy_generate(&rt, &params,
                                                             &prompts, 6).unwrap();
    assert_eq!(out, again);
}

#[test]
fn lr_schedule_changes_trajectory() {
    let Some(rt) = open_tiny() else { return };
    let run_sched = |sched| {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
        cfg.steps = 6;
        cfg.lr_schedule = sched;
        let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        Trainer::new(&rt, cfg, DataSource::Task(builder)).run(&mut params).unwrap()
    };
    let a = run_sched(tezo::config::LrSchedule::Constant);
    let b = run_sched(tezo::config::LrSchedule::Linear { final_frac: 0.0 });
    // same seeds, different lr after step 0 -> different losses from step 2
    assert_eq!(a.metrics.losses[0], b.metrics.losses[0]);
    assert_ne!(a.metrics.losses[5], b.metrics.losses[5]);
}

#[test]
fn corpus_lm_training_runs() {
    let Some(rt) = open_tiny() else { return };
    let mut cfg = TrainConfig::with_preset(Method::TezoAdam, "tiny");
    cfg.steps = 10;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let corpus = Corpus::new(tok, rt.manifest.config.seq_len, 3);
    let mut trainer = Trainer::new(&rt, cfg,
        DataSource::Corpus { corpus, batch: rt.manifest.config.batch });
    let out = trainer.run(&mut params).unwrap();
    assert_eq!(out.metrics.losses.len(), 10);
    assert!(out.metrics.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn eval_accuracy_improves_with_training() {
    let Some(rt) = open_tiny() else { return };
    let mut cfg = TrainConfig::with_preset(Method::FoAdam, "tiny");
    cfg.steps = 60;
    cfg.eval_every = 30;
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let labels = task.label_tokens();
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let evals = builder.eval_batches(128);
    let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder))
        .with_eval(evals, labels);
    let out = trainer.run(&mut params).unwrap();
    let final_acc = out.metrics.evals.last().unwrap().1;
    // binary task, planted signal, FO optimizer: must beat chance clearly
    assert!(final_acc > 0.6, "final accuracy {final_acc}");
}
