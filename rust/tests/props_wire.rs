//! Fuzz battery for the fleet wire codec (`fleet::wire`).
//!
//! Properties pinned here:
//! * every `Command`/`Event`/handshake message round-trips through the
//!   codec **bit-identically** (encode -> decode -> encode reproduces the
//!   exact frame, NaN payloads included);
//! * the codec is canonical: any frame that decodes at all re-encodes to
//!   the same bytes;
//! * malformed input — truncation at every byte boundary, random byte
//!   flips, unknown tags, oversized length prefixes, non-finite
//!   control-plane floats — yields a typed [`WireError`], never a panic;
//! * the frame sizes cross-check the analytic model in `memmodel::comm`:
//!   the constants there are exactly what the real encoder produces.

use tezo::config::LrSchedule;
use tezo::fleet::protocol::{CatchUp, Command, Event, LogEntry, Ticket,
                            WorkerReport};
use tezo::fleet::wire::{self, WireError};
use tezo::memmodel::comm;
use tezo::proplite::{self, prop_assert, Gen};

// Wire tags, restated independently of the private constants in
// `fleet::wire` — a tag renumbering is a protocol break and must fail here.
const TAG_APPLY: u8 = 0x02;
const TAG_TWO_POINT: u8 = 0x41;

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

fn gen_ticket(g: &mut Gen) -> Ticket {
    Ticket {
        step: g.u64() % 1_000_000,
        sub: (g.u64() % 64) as u32,
        perturb_seed: g.u64() as u32,
    }
}

fn gen_entry(g: &mut Gen) -> LogEntry {
    LogEntry {
        step: g.u64() % 100_000,
        sub: (g.u64() % 8) as u32,
        perturb_seed: g.u64() as u32,
        kappa: g.bool().then(|| g.f32_in(-100.0..100.0)),
    }
}

fn gen_string(g: &mut Gen) -> String {
    let pool = ['a', 'Z', '0', ' ', ':', 'λ', '≠', '🦀'];
    let n = g.usize_in(0..33);
    (0..n).map(|_| *g.pick(&pool)).collect()
}

fn gen_command(g: &mut Gen) -> Command {
    match g.usize_in(0..7) {
        0 => Command::Forward(gen_ticket(g)),
        1 => Command::Apply { ticket: gen_ticket(g), kappa: g.f32_in(-1e6..1e6) },
        2 => Command::Skip { ticket: gen_ticket(g) },
        3 => Command::Eval { step: g.u64() },
        4 => Command::Stop,
        5 => Command::Checkpoint { step: g.u64() },
        _ => {
            let n = g.usize_in(0..24);
            Command::CatchUp(CatchUp {
                // u64::MAX is the on-wire None sentinel, never a real step
                checkpoint_step: g.bool().then(|| g.u64() % (u64::MAX - 1)),
                entries: (0..n).map(|_| gen_entry(g)).collect(),
            })
        }
    }
}

fn gen_event(g: &mut Gen) -> Event {
    let worker = g.usize_in(0..1024);
    match g.usize_in(0..6) {
        0 => Event::TwoPoint {
            worker,
            step: g.u64() % 1_000_000,
            sub: (g.u64() % 64) as u32,
            // arbitrary bit patterns: the loss pair is carried bit-exactly,
            // NaN/inf included (loss poisoning is in-band)
            f_plus: f32::from_bits(g.u64() as u32),
            f_minus: f32::from_bits(g.u64() as u32),
            forward_secs: g.f64_in(0.0..1e6),
        },
        1 => Event::Applied {
            worker,
            step: g.u64() % 1_000_000,
            sub: (g.u64() % 64) as u32,
            update_secs: g.f64_in(0.0..1e6),
        },
        2 => Event::EvalDone {
            worker,
            step: g.u64() % 1_000_000,
            // NaN = "no eval set here", a legal bit-exact payload
            accuracy: if g.bool() { f64::NAN } else { g.f64_in(0.0..1.0) },
        },
        3 => Event::Failed { worker, error: gen_string(g) },
        4 => {
            let secs = [
                g.f64_in(0.0..100.0),
                g.f64_in(0.0..100.0),
                g.f64_in(0.0..100.0),
                g.f64_in(0.0..100.0),
                g.f64_in(0.0..100.0),
            ];
            let counts = [g.u64() % 1000, g.u64() % 1000, g.u64() % 1000,
                          g.u64() % 1000, g.u64() % 1000];
            Event::Report(Box::new(WorkerReport {
                worker,
                timers: tezo::coordinator::metrics::PhaseTimers::from_parts(
                    secs, counts, g.u64() % 100_000, g.u64() % 100_000),
                counter: tezo::coordinator::counter::SampleCounter {
                    matrix_elements: g.u64() % 1_000_000,
                    vector_elements: g.u64() % 1_000_000,
                },
                state_bytes: g.u64() % 1_000_000,
            }))
        }
        _ => Event::CheckpointDone { worker, step: g.u64() },
    }
}

/// Build a raw frame by hand: `[payload_len u32 LE][tag][body]`.
fn raw_frame(tag: u8, body: &[u8]) -> Vec<u8> {
    let mut f = ((body.len() + 1) as u32).to_le_bytes().to_vec();
    f.push(tag);
    f.extend_from_slice(body);
    f
}

// ---------------------------------------------------------------------------
// round trips
// ---------------------------------------------------------------------------

#[test]
fn commands_round_trip_bit_identically() {
    proplite::run(300, |g| {
        let cmd = gen_command(g);
        let frame = wire::encode_command(&cmd);
        let back = wire::decode_command(&frame)
            .map_err(|e| format!("decode of {cmd:?} failed: {e}"))?;
        prop_assert(back == cmd, &format!("value drift: {cmd:?} vs {back:?}"))?;
        prop_assert(wire::encode_command(&back) == frame,
                    "re-encode is not bit-identical")?;
        prop_assert(wire::command_frame_len(&cmd) == frame.len() as u64,
                    "command_frame_len disagrees with the encoder")
    });
}

#[test]
fn events_round_trip_bit_identically() {
    // Event has no PartialEq (f32 NaN payloads are meaningful), so bitwise
    // frame equality after a decode/encode cycle IS the equality check —
    // and the stronger one.
    proplite::run(300, |g| {
        let ev = gen_event(g);
        let frame = wire::encode_event(&ev);
        let back = wire::decode_event(&frame)
            .map_err(|e| format!("decode of {ev:?} failed: {e}"))?;
        prop_assert(wire::encode_event(&back) == frame,
                    &format!("re-encode drift for {ev:?}"))?;
        prop_assert(wire::event_frame_len(&ev) == frame.len() as u64,
                    "event_frame_len disagrees with the encoder")
    });
}

#[test]
fn handshake_round_trips_with_fuzzed_config() {
    proplite::run(120, |g| {
        let mut cfg = tezo::config::TrainConfig::default();
        cfg.steps = g.usize_in(1..10_000);
        cfg.lr = g.f32_in(1e-8..1.0);
        cfg.rho = g.f32_in(1e-6..1.0);
        cfg.seed = g.u64();
        cfg.eval_every = g.usize_in(0..100);
        cfg.kappa_clip = g.f32_in(0.0..1e4);
        cfg.n_perturb = g.usize_in(1..8);
        cfg.lr_schedule = match g.usize_in(0..3) {
            0 => LrSchedule::Constant,
            1 => LrSchedule::Linear { final_frac: g.f32_in(0.0..1.0) },
            _ => LrSchedule::Cosine { final_frac: g.f32_in(0.0..1.0) },
        };
        let ack = wire::HelloAck {
            slot: (g.u64() % 64) as u32,
            workers: (g.u64() % 64) as u32,
            cfg,
            job: wire::JobSpec {
                task: gen_string(g),
                k_shot: (g.u64() % 64) as u32,
                eval_n: (g.u64() % 64) as u32,
            },
        };
        let frame = wire::encode_hello_ack(&ack);
        let back = wire::decode_hello_ack(&frame)
            .map_err(|e| format!("hello_ack decode failed: {e}"))?;
        prop_assert(back == ack, "hello_ack value drift")?;
        prop_assert(wire::encode_hello_ack(&back) == frame,
                    "hello_ack re-encode drift")
    });
}

// ---------------------------------------------------------------------------
// malformed input: typed errors, never panics
// ---------------------------------------------------------------------------

#[test]
fn every_truncation_is_a_typed_error() {
    proplite::run(80, |g| {
        let frame = wire::encode_command(&gen_command(g));
        for cut in 0..frame.len() {
            match wire::decode_command(&frame[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => {
                    return Err(format!(
                        "cut at {cut}/{}: expected Truncated, got {other:?}",
                        frame.len()));
                }
            }
        }
        let frame = wire::encode_event(&gen_event(g));
        for cut in 0..frame.len() {
            match wire::decode_event(&frame[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => {
                    return Err(format!(
                        "event cut at {cut}/{}: expected Truncated, got \
                         {other:?}", frame.len()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn random_byte_flips_never_panic_and_stay_canonical() {
    proplite::run(400, |g| {
        let mut frame = wire::encode_command(&gen_command(g));
        let i = g.usize_in(0..frame.len());
        let flip = (g.u64() % 255) as u8 + 1; // never a no-op flip
        frame[i] ^= flip;
        // any outcome is legal except a panic; an accepted frame must be
        // canonical (decode-then-encode reproduces the mutated bytes)
        if let Ok(cmd) = wire::decode_command(&frame) {
            prop_assert(wire::encode_command(&cmd) == frame,
                        "accepted a non-canonical mutated frame")?;
        }
        let mut frame = wire::encode_event(&gen_event(g));
        let i = g.usize_in(0..frame.len());
        frame[i] ^= flip;
        if let Ok(ev) = wire::decode_event(&frame) {
            prop_assert(wire::encode_event(&ev) == frame,
                        "accepted a non-canonical mutated event frame")?;
        }
        Ok(())
    });
}

#[test]
fn unknown_tags_are_rejected_in_both_directions() {
    proplite::run(100, |g| {
        // tags outside every assigned range (commands 0x01-0x07, events
        // 0x41-0x46, handshake 0x21-0x22)
        let tag = 0x80 | (g.u64() % 128) as u8;
        let frame = raw_frame(tag, &[]);
        prop_assert(
            wire::decode_command(&frame) == Err(WireError::UnknownTag { tag }),
            "command decoder accepted an unassigned tag")?;
        prop_assert(
            matches!(wire::decode_event(&frame),
                     Err(WireError::UnknownTag { tag: t }) if t == tag),
            "event decoder accepted an unassigned tag")?;
        // cross-direction confusion: a command frame is not an event and
        // vice versa (the tag ranges are disjoint by design)
        let cmd_frame = wire::encode_command(&gen_command(g));
        prop_assert(
            matches!(wire::decode_event(&cmd_frame),
                     Err(WireError::UnknownTag { .. })),
            "event decoder accepted a command frame")?;
        let ev_frame = wire::encode_event(&gen_event(g));
        prop_assert(
            matches!(wire::decode_command(&ev_frame),
                     Err(WireError::UnknownTag { .. })),
            "command decoder accepted an event frame")
    });
}

#[test]
fn oversized_length_prefixes_are_rejected_before_allocation() {
    proplite::run(60, |g| {
        let len = wire::MAX_FRAME as u64 + 1 + g.u64() % (u32::MAX as u64
            - wire::MAX_FRAME as u64 - 1);
        let mut frame = (len as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&[0u8; 16]); // far less than it declares
        prop_assert(
            matches!(wire::decode_command(&frame),
                     Err(WireError::Oversize { .. })),
            "oversized command length prefix not rejected")?;
        prop_assert(
            matches!(wire::decode_event(&frame),
                     Err(WireError::Oversize { .. })),
            "oversized event length prefix not rejected")
    });
}

#[test]
fn non_finite_control_floats_are_typed_errors() {
    let ticket_body = |step: u64, sub: u32, seed: u32| {
        let mut b = step.to_le_bytes().to_vec();
        b.extend_from_slice(&sub.to_le_bytes());
        b.extend_from_slice(&seed.to_le_bytes());
        b
    };
    for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
        // Apply.kappa is control-plane: a non-finite value is corruption,
        // not a payload (the lockstep-skip path uses Skip, never NaN kappa)
        let mut body = ticket_body(3, 1, 99);
        body.extend_from_slice(&bad.to_bits().to_le_bytes());
        assert_eq!(
            wire::decode_command(&raw_frame(TAG_APPLY, &body)),
            Err(WireError::NonFinite { field: "apply.kappa" }),
        );
    }
    // TwoPoint.forward_secs is control-plane even though the loss pair
    // beside it is bit-exact
    let mut body = 7u32.to_le_bytes().to_vec(); // worker
    body.extend_from_slice(&5u64.to_le_bytes()); // step
    body.extend_from_slice(&0u32.to_le_bytes()); // sub
    body.extend_from_slice(&f32::NAN.to_bits().to_le_bytes()); // f+ (legal)
    body.extend_from_slice(&0.5f32.to_bits().to_le_bytes()); // f-
    body.extend_from_slice(&f64::INFINITY.to_bits().to_le_bytes()); // secs
    assert!(matches!(
        wire::decode_event(&raw_frame(TAG_TWO_POINT, &body)),
        Err(WireError::NonFinite { field: "two_point.forward_secs" }),
    ));
}

#[test]
fn catch_up_count_bombs_are_rejected() {
    proplite::run(40, |g| {
        // declared entry count far beyond what the payload could hold
        let mut body = u64::MAX.to_le_bytes().to_vec(); // checkpoint: None
        let count = 1_000_000 + (g.u64() % 1_000_000) as u32;
        body.extend_from_slice(&count.to_le_bytes());
        let frame = raw_frame(0x07, &body); // TAG_CATCH_UP
        prop_assert(
            matches!(wire::decode_command(&frame),
                     Err(WireError::BadCount { .. })),
            "catch-up count bomb not rejected")
    });
}

// ---------------------------------------------------------------------------
// the analytic comm model is the real frame sizes (satellite cross-check)
// ---------------------------------------------------------------------------

#[test]
fn frame_sizes_pin_the_memmodel_constants() {
    let t = Ticket { step: 12, sub: 2, perturb_seed: 0xFEED };
    let fwd = wire::command_frame_len(&Command::Forward(t));
    assert_eq!(fwd, comm::FRAME_HEADER_BYTES + comm::TICKET_BYTES);
    let apply = wire::command_frame_len(&Command::Apply { ticket: t, kappa: 0.5 });
    assert_eq!(apply, comm::FRAME_HEADER_BYTES + comm::KAPPA_BYTES);
    let skip = wire::command_frame_len(&Command::Skip { ticket: t });
    assert_eq!(skip, comm::FRAME_HEADER_BYTES + comm::TICKET_BYTES);
    let tp = wire::event_frame_len(&Event::TwoPoint {
        worker: 0,
        step: 0,
        sub: 0,
        f_plus: 0.0,
        f_minus: 0.0,
        forward_secs: 0.0,
    });
    assert_eq!(
        tp,
        comm::FRAME_HEADER_BYTES + comm::TWO_POINT_BYTES + comm::RESULT_META_BYTES
    );
    // wire.rs re-exports the same header constant the memmodel pins
    assert_eq!(wire::FRAME_HEADER_BYTES, comm::FRAME_HEADER_BYTES);

    // the analytic per-step wire model is exactly the sum of real frames
    for workers in [1u64, 2, 3, 8] {
        for q in [1u64, 4] {
            assert_eq!(
                comm::zo_scalar_step_wire_bytes(workers, q),
                q * workers * (fwd + tp + apply),
                "analytic wire model drifted from the encoder (W={workers}, q={q})"
            );
        }
    }
    // and the logical model remains the payload-only view of the same round
    assert_eq!(
        comm::zo_scalar_step_bytes(1, 1),
        comm::TICKET_BYTES + comm::TWO_POINT_BYTES + comm::KAPPA_BYTES
    );
}
