//! Ordering-determinism properties of the fleet aggregation path, plus the
//! transport-parity test: the same fleet run over in-process loopback
//! channels and over real TCP sockets must produce bit-identical
//! trajectories.
//!
//! The coordinator receives two-point results in thread-scheduling order
//! but slots them by worker index before reducing (see
//! `fleet/protocol.rs::aggregate_two_point` and the audit notes in
//! docs/invariants.md). These properties pin the contract: the global
//! measurement — and therefore the broadcast kappa — must be *bitwise*
//! invariant to arrival order, and a single-worker fleet must reproduce
//! that worker's own measurement exactly.

use std::net::TcpListener;
use std::path::PathBuf;
use std::time::Duration;

use tezo::config::{FleetConfig, TrainConfig};
use tezo::fleet::metrics::FleetMetrics;
use tezo::fleet::protocol::aggregate_two_point;
use tezo::fleet::sim::{self, SimReplica};
use tezo::fleet::tcp::{JoinInfo, Reconnect};
use tezo::fleet::wire;
use tezo::fleet::worker::{serve_tcp, JobFactory, Replica, ReplicaFactory};
use tezo::fleet::{FleetTrainer, JobSpec, Transport};
use tezo::proplite::{self, prop_assert, Gen};

/// Fisher–Yates permutation of `0..n` driven by the property generator.
fn arrival_order(g: &mut Gen, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = g.usize_in(0..i + 1);
        idx.swap(i, j);
    }
    idx
}

#[test]
fn slotted_aggregation_is_permutation_invariant() {
    proplite::run(60, |g| {
        let w = g.usize_in(1..33);
        let results: Vec<(f32, f32)> = (0..w)
            .map(|_| (g.f32_in(-10.0..10.0), g.f32_in(-10.0..10.0)))
            .collect();
        let baseline = aggregate_two_point(&results);

        // out-of-order arrival: slot each event by worker index, then
        // reduce in index order — exactly what the coordinator does
        let mut slots: Vec<Option<(f32, f32)>> = vec![None; w];
        for &wi in &arrival_order(g, w) {
            slots[wi] = Some(results[wi]);
        }
        let slotted: Vec<(f32, f32)> =
            slots.into_iter().map(|s| s.expect("every worker reported")).collect();
        let agg = aggregate_two_point(&slotted);

        prop_assert(
            baseline.0.to_bits() == agg.0.to_bits()
                && baseline.1.to_bits() == agg.1.to_bits(),
            &format!("aggregate drifted under arrival permutation: \
                      {baseline:?} vs {agg:?}"),
        )
    });
}

#[test]
fn broadcast_kappa_is_permutation_invariant() {
    proplite::run(60, |g| {
        let w = g.usize_in(2..17);
        let rho = g.f32_in(1e-4..1e-1);
        let results: Vec<(f32, f32)> = (0..w)
            .map(|_| (g.f32_in(0.0..8.0), g.f32_in(0.0..8.0)))
            .collect();
        let kappa = |rs: &[(f32, f32)]| {
            let (fp, fm) = aggregate_two_point(rs);
            (fp - fm) / (2.0 * rho)
        };
        let baseline = kappa(&results);
        let mut slots = vec![(0.0f32, 0.0f32); w];
        for &wi in &arrival_order(g, w) {
            slots[wi] = results[wi];
        }
        prop_assert(
            baseline.to_bits() == kappa(&slots).to_bits(),
            "broadcast kappa must not depend on result arrival order",
        )
    });
}

#[test]
fn single_worker_aggregate_is_bit_identical() {
    proplite::run(60, |g| {
        let pair = (g.f32_in(-100.0..100.0), g.f32_in(-100.0..100.0));
        let agg = aggregate_two_point(&[pair]);
        prop_assert(
            agg.0.to_bits() == pair.0.to_bits()
                && agg.1.to_bits() == pair.1.to_bits(),
            "W=1 fleet must reproduce the worker's own measurement bitwise",
        )
    });
}

#[test]
fn non_finite_measurements_poison_the_aggregate() {
    // a NaN from any replica must surface in the global measurement (the
    // coordinator then broadcasts Skip to every replica together)
    proplite::run(40, |g| {
        let w = g.usize_in(1..9);
        let mut results: Vec<(f32, f32)> =
            (0..w).map(|_| (g.f32_in(-1.0..1.0), g.f32_in(-1.0..1.0))).collect();
        results[g.usize_in(0..w)].0 = f32::NAN;
        let (fp, _) = aggregate_two_point(&results);
        prop_assert(!fp.is_finite(), "NaN measurement vanished in aggregation")
    });
}

#[test]
fn metrics_rows_stay_in_worker_order() {
    let mut m = FleetMetrics::new(3);
    m.record_forward_round(&[0.5, 0.1, 0.9]);
    m.record_update_round(&[0.2, 0.3, 0.1]);
    let rows = m.per_worker();
    let ids: Vec<usize> = rows.iter().map(|&(w, _, _)| w).collect();
    assert_eq!(ids, vec![0, 1, 2], "reporting rows must be worker-ordered");
}

// ---------------------------------------------------------------------------
// transport parity: loopback vs TCP
// ---------------------------------------------------------------------------

/// Sim fleets inject replicas directly; the runtime-backed job factory must
/// never be consulted.
fn unused_jobs() -> Box<JobFactory> {
    Box::new(|_, _| Err(anyhow::anyhow!("sim fleets inject their replicas")))
}

fn sim_cfg(steps: usize, seed: u64) -> TrainConfig {
    TrainConfig { steps, lr: 0.05, seed, ..TrainConfig::default() }
}

/// Read the `{prefix}_{w}.bin` param snapshots a fleet run saved, checking
/// each one stopped at `steps`, and return the raw bit patterns.
fn final_param_bits(dir: &std::path::Path, prefix: &str, workers: usize,
                    steps: u64) -> Vec<Vec<u32>> {
    (0..workers)
        .map(|w| {
            let path = dir.join(format!("{prefix}_{w}.bin"));
            let (step, params) = sim::read_sim_params(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            assert_eq!(step, steps, "{prefix}_{w} stopped early");
            params.iter().map(|p| p.to_bits()).collect()
        })
        .collect()
}

/// The tentpole parity claim: the identical fleet driven over in-process
/// loopback channels and over real localhost TCP sockets produces the
/// same (seed, kappa) trace, the same loss stream, and the same final
/// parameters on every worker — all *bitwise* — and both match the
/// single-process oracle replay. The framed byte counters may differ only
/// by the TCP handshake (one Hello up + one HelloAck down per worker).
#[test]
fn loopback_and_tcp_fleets_are_bit_identical() {
    const DIM: usize = 24;
    const WORKERS: usize = 2;
    let cfg = sim_cfg(10, 41);

    // sandboxes without localhost networking: skip rather than fail
    let probe = match TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(e) => {
            eprintln!("skipping TCP parity test: cannot bind localhost: {e}");
            return;
        }
    };
    let addr = probe.local_addr().expect("probe addr").to_string();
    drop(probe);

    let dir = std::env::temp_dir()
        .join(format!("tezo_parity_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");

    // ---- loopback run -----------------------------------------------------
    let lb = {
        let cfg_w = cfg.clone();
        let dir_w = dir.clone();
        let make: Box<ReplicaFactory> = Box::new(move |w, workers| {
            Ok(Box::new(
                SimReplica::new(w, workers, &cfg_w, DIM)
                    .with_save_to(dir_w.join(format!("lb_{w}.bin"))),
            ) as Box<dyn Replica>)
        });
        FleetTrainer::new(FleetConfig::new(WORKERS), cfg.clone(),
                          PathBuf::from("unused"), unused_jobs())
            .with_replica_factory(make)
            .run()
            .expect("loopback fleet run")
    };

    // ---- TCP run: external worker processes, modeled as threads -----------
    let rc = Reconnect {
        attempts: 80,
        base_delay: Duration::from_millis(25),
        max_delay: Duration::from_millis(200),
    };
    let worker_threads: Vec<_> = (0..WORKERS)
        .map(|_| {
            let (addr, dir) = (addr.clone(), dir.clone());
            std::thread::spawn(move || {
                serve_tcp(&addr, rc, &mut |info: &JoinInfo| {
                    // config arrives over the handshake, not shared memory
                    Ok(Box::new(
                        SimReplica::new(info.slot, info.workers, &info.cfg, DIM)
                            .with_save_to(
                                dir.join(format!("tcp_{}.bin", info.slot)),
                            ),
                    ) as Box<dyn Replica>)
                })
            })
        })
        .collect();
    let tcp = FleetTrainer::new(FleetConfig::new(WORKERS), cfg.clone(),
                                PathBuf::from("unused"), unused_jobs())
        .with_transport(Transport::TcpListen(addr))
        .run()
        .expect("tcp fleet run");
    for h in worker_threads {
        h.join().expect("worker thread panicked").expect("tcp worker");
    }

    // ---- bitwise parity ---------------------------------------------------
    let oracle = sim::run_oracle(&cfg, WORKERS as u32, DIM);
    assert_eq!(lb.trace, oracle.trace, "loopback trace vs oracle");
    assert_eq!(tcp.trace, oracle.trace, "tcp trace vs oracle");
    for (a, b) in lb.trace.iter().zip(&tcp.trace) {
        assert_eq!(a.kappa.map(f32::to_bits), b.kappa.map(f32::to_bits),
                   "kappa stream must be bit-identical across transports");
    }
    let bits = |ls: &[f64]| ls.iter().map(|l| l.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&lb.metrics.losses), bits(&oracle.losses));
    assert_eq!(bits(&tcp.metrics.losses), bits(&oracle.losses));

    let steps = cfg.steps as u64;
    let lb_params = final_param_bits(&dir, "lb", WORKERS, steps);
    let tcp_params = final_param_bits(&dir, "tcp", WORKERS, steps);
    let oracle_bits: Vec<u32> =
        oracle.params.iter().map(|p| p.to_bits()).collect();
    for w in 0..WORKERS {
        assert_eq!(lb_params[w], oracle_bits, "loopback worker {w} params");
        assert_eq!(tcp_params[w], oracle_bits, "tcp worker {w} params");
    }

    // logical payload accounting is transport-independent...
    let (lc, tc) = (&lb.fleet.comm, &tcp.fleet.comm);
    assert_eq!(lc.tickets, tc.tickets);
    assert_eq!(lc.results, tc.results);
    assert_eq!(lc.broadcasts, tc.broadcasts);
    assert_eq!(lc.bytes_down, tc.bytes_down);
    assert_eq!(lc.bytes_up, tc.bytes_up);

    // ...and the framed counters differ by exactly one handshake per
    // worker (Hello length is slot-independent; the coordinator ships
    // this cfg and the default job spec in every HelloAck)
    let hello_len = wire::encode_hello(
        &wire::Hello { requested_slot: u32::MAX }).len() as u64;
    let ack_len = wire::encode_hello_ack(&wire::HelloAck {
        slot: 0,
        workers: WORKERS as u32,
        cfg: cfg.clone(),
        job: JobSpec::default(),
    })
    .len() as u64;
    let w = WORKERS as u64;
    assert_eq!(tc.wire_up, lc.wire_up + w * hello_len,
               "tcp up-wire must exceed loopback by exactly the Hellos");
    assert_eq!(tc.wire_down, lc.wire_down + w * ack_len,
               "tcp down-wire must exceed loopback by exactly the HelloAcks");
    assert_eq!(tc.frames_up, lc.frames_up + w);
    assert_eq!(tc.frames_down, lc.frames_down + w);

    std::fs::remove_dir_all(&dir).ok();
}
