//! Ordering-determinism properties of the fleet aggregation path.
//!
//! The coordinator receives two-point results in thread-scheduling order
//! but slots them by worker index before reducing (see
//! `fleet/protocol.rs::aggregate_two_point` and the audit notes in
//! docs/invariants.md). These properties pin the contract: the global
//! measurement — and therefore the broadcast kappa — must be *bitwise*
//! invariant to arrival order, and a single-worker fleet must reproduce
//! that worker's own measurement exactly.

use tezo::fleet::metrics::FleetMetrics;
use tezo::fleet::protocol::aggregate_two_point;
use tezo::proplite::{self, prop_assert, Gen};

/// Fisher–Yates permutation of `0..n` driven by the property generator.
fn arrival_order(g: &mut Gen, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = g.usize_in(0..i + 1);
        idx.swap(i, j);
    }
    idx
}

#[test]
fn slotted_aggregation_is_permutation_invariant() {
    proplite::run(60, |g| {
        let w = g.usize_in(1..33);
        let results: Vec<(f32, f32)> = (0..w)
            .map(|_| (g.f32_in(-10.0..10.0), g.f32_in(-10.0..10.0)))
            .collect();
        let baseline = aggregate_two_point(&results);

        // out-of-order arrival: slot each event by worker index, then
        // reduce in index order — exactly what the coordinator does
        let mut slots: Vec<Option<(f32, f32)>> = vec![None; w];
        for &wi in &arrival_order(g, w) {
            slots[wi] = Some(results[wi]);
        }
        let slotted: Vec<(f32, f32)> =
            slots.into_iter().map(|s| s.expect("every worker reported")).collect();
        let agg = aggregate_two_point(&slotted);

        prop_assert(
            baseline.0.to_bits() == agg.0.to_bits()
                && baseline.1.to_bits() == agg.1.to_bits(),
            &format!("aggregate drifted under arrival permutation: \
                      {baseline:?} vs {agg:?}"),
        )
    });
}

#[test]
fn broadcast_kappa_is_permutation_invariant() {
    proplite::run(60, |g| {
        let w = g.usize_in(2..17);
        let rho = g.f32_in(1e-4..1e-1);
        let results: Vec<(f32, f32)> = (0..w)
            .map(|_| (g.f32_in(0.0..8.0), g.f32_in(0.0..8.0)))
            .collect();
        let kappa = |rs: &[(f32, f32)]| {
            let (fp, fm) = aggregate_two_point(rs);
            (fp - fm) / (2.0 * rho)
        };
        let baseline = kappa(&results);
        let mut slots = vec![(0.0f32, 0.0f32); w];
        for &wi in &arrival_order(g, w) {
            slots[wi] = results[wi];
        }
        prop_assert(
            baseline.to_bits() == kappa(&slots).to_bits(),
            "broadcast kappa must not depend on result arrival order",
        )
    });
}

#[test]
fn single_worker_aggregate_is_bit_identical() {
    proplite::run(60, |g| {
        let pair = (g.f32_in(-100.0..100.0), g.f32_in(-100.0..100.0));
        let agg = aggregate_two_point(&[pair]);
        prop_assert(
            agg.0.to_bits() == pair.0.to_bits()
                && agg.1.to_bits() == pair.1.to_bits(),
            "W=1 fleet must reproduce the worker's own measurement bitwise",
        )
    });
}

#[test]
fn non_finite_measurements_poison_the_aggregate() {
    // a NaN from any replica must surface in the global measurement (the
    // coordinator then broadcasts Skip to every replica together)
    proplite::run(40, |g| {
        let w = g.usize_in(1..9);
        let mut results: Vec<(f32, f32)> =
            (0..w).map(|_| (g.f32_in(-1.0..1.0), g.f32_in(-1.0..1.0))).collect();
        results[g.usize_in(0..w)].0 = f32::NAN;
        let (fp, _) = aggregate_two_point(&results);
        prop_assert(!fp.is_finite(), "NaN measurement vanished in aggregation")
    });
}

#[test]
fn metrics_rows_stay_in_worker_order() {
    let mut m = FleetMetrics::new(3);
    m.record_forward_round(&[0.5, 0.1, 0.9]);
    m.record_update_round(&[0.2, 0.3, 0.1]);
    let rows = m.per_worker();
    let ids: Vec<usize> = rows.iter().map(|&(w, _, _)| w).collect();
    assert_eq!(ids, vec![0, 1, 2], "reporting rows must be worker-ordered");
}
