//! Property battery for the durability layer — journal framing and
//! recovery, failpoint-injected IO faults, replay planning, and the
//! divergence-guard policy. Artifact-free: no PJRT runtime, no compiled
//! artifacts, every case runs against its own temp directory.
//!
//! Properties pinned here:
//! * every committed journal record survives reopen with bitwise kappas,
//!   and any corrupt suffix (garbage tail, torn frame, bit flip) loses at
//!   most the corrupted tail — never a committed prefix record;
//! * a torn `append_sync` (failpoint) is invisible after recovery: the
//!   journal reopens to exactly the pre-fault entries and keeps accepting
//!   appends;
//! * `plan_replay` accepts every journal a crashed WAL writer can actually
//!   produce (complete steps, terminal skips, one trailing partial) and
//!   rejects gaps, sub disorder, and mid-log incomplete steps;
//! * the guard trips exactly at its thresholds and `rolled_back` re-arms
//!   the detectors from scratch.

use tezo::coordinator::guard::{GuardPolicy, GuardState};
use tezo::proplite::{self, prop_assert, Gen};
use tezo::runtime::durable::{self, failpoint};
use tezo::runtime::journal::{self, Journal, JournalEntry};

fn tmp(tag: &str, case: u64) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tezo_props_journal_{}_{tag}_{case}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A random but *valid* WAL tail starting at `ckpt_step`: complete steps
/// (all `q` subs applied, or cut short by a terminal skip), optionally one
/// trailing partial step — exactly the set of files a crashed writer that
/// honors WAL ordering can leave behind.
fn gen_valid_tail(g: &mut Gen, ckpt_step: u64, q: u32)
                  -> (Vec<JournalEntry>, usize, Option<u64>) {
    let n_steps = g.usize_in(0..6);
    let mut entries = Vec::new();
    for i in 0..n_steps {
        let step = ckpt_step + i as u64;
        let skip_at = if g.bool() { Some(g.usize_in(0..q as usize)) } else { None };
        for sub in 0..q {
            if skip_at == Some(sub as usize) {
                entries.push(JournalEntry {
                    step, sub, perturb_seed: g.u64() as u32, kappa: None,
                });
                break;
            }
            entries.push(JournalEntry {
                step,
                sub,
                perturb_seed: g.u64() as u32,
                kappa: Some(g.f32_in(-2.0..2.0)),
            });
        }
    }
    // a trailing partial needs q > 1 (with q = 1 any applied sub completes
    // the step) and at least one applied-but-not-final sub
    let partial = if q > 1 && g.bool() {
        let step = ckpt_step + n_steps as u64;
        let cut = g.usize_in(1..q as usize);
        for sub in 0..cut as u32 {
            entries.push(JournalEntry {
                step,
                sub,
                perturb_seed: g.u64() as u32,
                kappa: Some(g.f32_in(-2.0..2.0)),
            });
        }
        Some(step)
    } else {
        None
    };
    (entries, n_steps, partial)
}

// ---------------------------------------------------------------------------
// journal framing & recovery
// ---------------------------------------------------------------------------

#[test]
fn prop_journal_roundtrips_bitwise() {
    let mut case = 0u64;
    proplite::run(40, |g| {
        case += 1;
        let p = tmp("roundtrip", case).join("journal.bin");
        let seed = g.u64();
        let n = g.usize_in(0..40);
        let want: Vec<JournalEntry> = (0..n)
            .map(|i| JournalEntry {
                step: i as u64 / 2,
                sub: (i % 2) as u32,
                perturb_seed: g.u64() as u32,
                // exercise the full bit space, NaNs included
                kappa: if g.bool() {
                    Some(f32::from_bits(g.u64() as u32))
                } else {
                    None
                },
            })
            .collect();
        {
            let (mut j, prior) = Journal::open(&p, seed).unwrap();
            prop_assert(prior.is_empty(), "fresh journal not empty")?;
            for e in &want {
                j.append(e).unwrap();
            }
        }
        let got = Journal::read(&p, seed).unwrap();
        prop_assert(got.len() == want.len(), "entry count changed on reopen")?;
        for (a, b) in got.iter().zip(want.iter()) {
            prop_assert(a.step == b.step && a.sub == b.sub
                            && a.perturb_seed == b.perturb_seed,
                        "ids changed on reopen")?;
            prop_assert(a.kappa.map(f32::to_bits) == b.kappa.map(f32::to_bits),
                        "kappa bits changed on reopen")?;
        }
        Ok(())
    });
}

#[test]
fn prop_corrupt_suffix_only_loses_the_tail() {
    let mut case = 0u64;
    proplite::run(40, |g| {
        case += 1;
        let p = tmp("suffix", case).join("journal.bin");
        let n = g.usize_in(1..20);
        {
            let (mut j, _) = Journal::open(&p, 3).unwrap();
            for s in 0..n as u64 {
                j.append(&JournalEntry {
                    step: s, sub: 0, perturb_seed: s as u32,
                    kappa: Some(s as f32),
                }).unwrap();
            }
        }
        let clean = std::fs::read(&p).unwrap();
        // corrupt: either append garbage (torn final frame) or flip a byte
        // inside some frame (bit rot) — committed records BEFORE the damage
        // must all survive
        let mut img = clean.clone();
        let intact = if g.bool() {
            let garbage = g.usize_in(1..33);
            for _ in 0..garbage {
                img.push(g.u64() as u8);
            }
            n
        } else {
            let victim = g.usize_in(0..n);
            let off = 20 + victim * 33 + g.usize_in(0..33);
            img[off] ^= 1 << g.usize_in(0..8);
            victim
        };
        std::fs::write(&p, &img).unwrap();
        let got = Journal::read(&p, 3).unwrap();
        prop_assert(got.len() >= intact,
                    "recovery lost a committed record before the damage")?;
        for (s, e) in got.iter().take(intact).enumerate() {
            prop_assert(e.step == s as u64 && e.kappa == Some(s as f32),
                        "recovered prefix entry mutated")?;
        }
        Ok(())
    });
}

#[test]
fn prop_torn_append_is_invisible_after_recovery() {
    let mut case = 0u64;
    proplite::run(30, |g| {
        case += 1;
        let p = tmp("torn", case).join("journal.bin");
        let n = g.usize_in(0..10);
        let (mut j, _) = Journal::open(&p, 11).unwrap();
        for s in 0..n as u64 {
            j.append(&JournalEntry {
                step: s, sub: 0, perturb_seed: 0, kappa: Some(0.5),
            }).unwrap();
        }
        // tear the next frame at a random byte (possibly zero bytes land)
        failpoint::arm(failpoint::Failure::Torn { keep: g.usize_in(0..33) });
        let torn = j.append(&JournalEntry {
            step: n as u64, sub: 0, perturb_seed: 0, kappa: Some(1.0),
        });
        failpoint::reset();
        prop_assert(torn.is_err(), "torn append must error")?;
        drop(j);
        // recovery: only the committed prefix, and the handle still appends
        let (mut j, got) = Journal::open(&p, 11).unwrap();
        prop_assert(got.len() == n, "torn frame leaked into recovery")?;
        j.append(&JournalEntry {
            step: n as u64, sub: 0, perturb_seed: 0, kappa: Some(2.0),
        }).unwrap();
        prop_assert(Journal::read(&p, 11).unwrap().len() == n + 1,
                    "append after torn recovery lost")?;
        Ok(())
    });
}

#[test]
fn prop_enospc_leaves_previous_image_intact() {
    let mut case = 0u64;
    proplite::run(20, |g| {
        case += 1;
        let d = tmp("enospc", case);
        let p = d.join("x.bin");
        let before = g.vec_f32(4, -1.0..1.0);
        let bytes: Vec<u8> = before.iter().flat_map(|f| f.to_le_bytes()).collect();
        durable::write_atomic(&p, &bytes).unwrap();
        failpoint::arm(failpoint::Failure::Enospc);
        let res = durable::write_atomic(&p, b"overwrite");
        failpoint::reset();
        prop_assert(res.is_err(), "ENOSPC write must error")?;
        prop_assert(std::fs::read(&p).unwrap() == bytes,
                    "failed write mutated the committed file")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// replay planning
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_replay_accepts_every_valid_wal_tail() {
    proplite::run(60, |g| {
        let ckpt = g.u64() % 1000;
        let q = g.usize_in(1..5) as u32;
        let (entries, n_complete, partial) = gen_valid_tail(g, ckpt, q);
        let r = journal::plan_replay(&entries, ckpt, q)
            .map_err(|e| format!("valid tail rejected: {e:#}"))?;
        prop_assert(r.steps.len() == n_complete, "complete step count wrong")?;
        prop_assert(r.partial == partial, "partial step mis-detected")?;
        for (i, (s, group)) in r.steps.iter().enumerate() {
            prop_assert(*s == ckpt + i as u64, "replay steps not contiguous")?;
            let terminal_skip =
                group.last().map(|e| e.kappa.is_none()).unwrap_or(false);
            prop_assert(terminal_skip || group.len() as u32 == q,
                        "incomplete group classified complete")?;
        }
        Ok(())
    });
}

#[test]
fn prop_plan_replay_rejects_gaps_and_disorder() {
    proplite::run(60, |g| {
        let ckpt = g.u64() % 100;
        let q = g.usize_in(1..4) as u32;
        let (mut entries, n_complete, _) = gen_valid_tail(g, ckpt, q);
        if entries.len() < 2 || n_complete < 2 {
            return Ok(()); // nothing to corrupt; trivially pass
        }
        match g.usize_in(0..3) {
            0 => {
                // open a step gap by shifting the tail up
                let cut = g.usize_in(1..entries.len());
                for e in entries.iter_mut().skip(cut) {
                    e.step += 1 + (g.u64() % 3);
                }
            }
            1 => {
                // scramble sub order inside some step
                let i = g.usize_in(0..entries.len());
                entries[i].sub += 1;
            }
            _ => {
                // delete the terminal record of a step strictly before the
                // last group: the step turns incomplete mid-log (or, if it
                // was a single record, vanishes and opens a gap) — never
                // the accepted trailing-partial shape
                let last_step = match entries.last() {
                    Some(e) => e.step,
                    None => return Ok(()),
                };
                let i = entries.iter().enumerate().position(|(i, e)| {
                    e.step < last_step
                        && entries.get(i + 1).map(|n| n.step != e.step)
                                  .unwrap_or(true)
                });
                match i {
                    Some(i) => { entries.remove(i); }
                    None => return Ok(()),
                }
            }
        }
        prop_assert(journal::plan_replay(&entries, ckpt, q).is_err(),
                    "corrupted tail accepted")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// guard policy
// ---------------------------------------------------------------------------

#[test]
fn prop_guard_trips_exactly_at_the_nonfinite_threshold() {
    proplite::run(60, |g| {
        let streak = g.usize_in(1..6);
        let policy = GuardPolicy { nonfinite_streak: streak,
                                   ..GuardPolicy::default() };
        policy.validate().map_err(|e| e.to_string())?;
        let mut guard = GuardState::new(policy);
        // random prefix of finite losses never trips
        for _ in 0..g.usize_in(0..10) {
            let loss = g.f64_in(0.01..10.0);
            prop_assert(guard.observe(loss).is_none(),
                        "finite loss tripped the non-finite detector")?;
        }
        // exactly `streak` non-finite losses trip on the last one
        for i in 1..=streak {
            let bad = if g.bool() { f64::NAN } else { f64::INFINITY };
            let fired = guard.observe(bad).is_some();
            prop_assert(fired == (i == streak),
                        "streak detector fired at the wrong count")?;
        }
        Ok(())
    });
}

#[test]
fn prop_guard_finite_loss_resets_the_streak() {
    proplite::run(60, |g| {
        let streak = g.usize_in(2..6);
        let policy = GuardPolicy { nonfinite_streak: streak,
                                   ..GuardPolicy::default() };
        let mut guard = GuardState::new(policy);
        // interleave: up to streak-1 NaNs, then a finite loss, repeated —
        // the detector must never fire
        for _ in 0..g.usize_in(1..8) {
            for _ in 0..g.usize_in(0..streak) {
                prop_assert(guard.observe(f64::NAN).is_none(),
                            "sub-threshold streak tripped")?;
            }
            prop_assert(guard.observe(g.f64_in(0.01..5.0)).is_none(),
                        "finite loss tripped")?;
        }
        Ok(())
    });
}

#[test]
fn prop_guard_rollback_rearms_and_budget_is_exact() {
    proplite::run(40, |g| {
        let budget = g.usize_in(1..5);
        let policy = GuardPolicy { nonfinite_streak: 1, max_rollbacks: budget,
                                   ..GuardPolicy::default() };
        let mut guard = GuardState::new(policy);
        for used in 0..budget {
            prop_assert(guard.can_roll_back(),
                        "budget exhausted early")?;
            prop_assert(guard.observe(f64::NAN).is_some(),
                        "re-armed detector failed to trip")?;
            guard.rolled_back();
            prop_assert(guard.rollbacks() == used + 1, "rollback count")?;
        }
        prop_assert(!guard.can_roll_back(), "budget not enforced")?;
        Ok(())
    });
}

#[test]
fn prop_guard_spike_needs_warmup_and_factor() {
    proplite::run(40, |g| {
        let warmup = g.usize_in(1..8);
        let factor = g.f64_in(1.5..5.0);
        let policy = GuardPolicy { spike_factor: factor, ewma_alpha: 0.5,
                                   warmup, ..GuardPolicy::default() };
        policy.validate().map_err(|e| e.to_string())?;
        let mut guard = GuardState::new(policy);
        let base = g.f64_in(0.5..2.0);
        // during warmup even a huge jump does not trip
        for _ in 0..warmup {
            prop_assert(guard.observe(base).is_none(), "tripped in warmup")?;
        }
        // at trend `base`, a loss just under the threshold passes...
        prop_assert(guard.observe(base * factor * 0.99).is_none(),
                    "sub-threshold loss tripped")?;
        // ...and rebuilding the trend back down, a clear blowup trips
        for _ in 0..4 {
            if guard.observe(base).is_some() {
                return Err("settling loss tripped".to_string());
            }
        }
        prop_assert(guard.observe(base * factor * 10.0).is_some(),
                    "blowup did not trip")?;
        Ok(())
    });
}
