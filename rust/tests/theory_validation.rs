//! Theorem 1 validation in Rust (paper §5), mirroring the python test but
//! through the in-tree RNG + tensor substrate: the TeZO estimator
//! (1/r) <G, Z> Z with Z = U diag(tau) V^T is unbiased, and its relative
//! variance matches delta = 1 + mn + 2mn/r + 6(m+n)/r + 10/r.

use tezo::rngx::normal_rng;
use tezo::tensor::Matrix;

fn delta(m: f64, n: f64, r: f64) -> f64 {
    1.0 + m * n + 2.0 * m * n / r + 6.0 * (m + n) / r + 10.0 / r
}

/// One TeZO estimate of G from fresh (u, v, tau).
fn tezo_sample(gen: &mut tezo::rngx::NormalGen, g: &Matrix, r: usize) -> Matrix {
    let (m, n) = (g.rows, g.cols);
    let u = Matrix::randn(m, r, gen);
    let v = Matrix::randn(n, r, gen);
    let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
    let z = Matrix::cpd_slice(&u, &v, &tau).unwrap();
    let proj: f64 = g
        .data
        .iter()
        .zip(z.data.iter())
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum();
    let mut out = z;
    out.scale((proj / r as f64) as f32);
    out
}

#[test]
fn estimator_is_unbiased() {
    let (m, n, r) = (5, 4, 2);
    let mut gen = normal_rng(1);
    let g = Matrix::randn(m, n, &mut gen);
    let trials = 300_000;
    let mut acc = Matrix::zeros(m, n);
    for _ in 0..trials {
        let s = tezo_sample(&mut gen, &g, r);
        acc.axpy(1.0, &s).unwrap();
    }
    acc.scale(1.0 / trials as f32);
    // ||mean - g|| must be within a few standard errors
    let se = (delta(m as f64, n as f64, r as f64) / trials as f64).sqrt() * g.fro_norm();
    let mut err2 = 0.0f64;
    for (a, b) in acc.data.iter().zip(g.data.iter()) {
        err2 += ((a - b) as f64).powi(2);
    }
    let err = err2.sqrt();
    assert!(err < 6.0 * se, "bias {err} vs se {se}");
}

#[test]
fn variance_matches_theorem_1_delta() {
    let (m, n, r) = (4, 4, 2);
    let mut gen = normal_rng(2);
    let g = Matrix::randn(m, n, &mut gen);
    let g_norm2 = g.fro_norm().powi(2);
    let trials = 250_000;
    let mut acc = 0.0f64;
    for _ in 0..trials {
        let s = tezo_sample(&mut gen, &g, r);
        let mut d2 = 0.0f64;
        for (a, b) in s.data.iter().zip(g.data.iter()) {
            d2 += ((a - b) as f64).powi(2);
        }
        acc += d2;
    }
    let var = acc / trials as f64;
    let want = delta(m as f64, n as f64, r as f64) * g_norm2;
    let rel = (var - want).abs() / want;
    assert!(rel < 0.15, "variance {var} vs delta*|g|^2 {want} (rel {rel})");
}

#[test]
fn variance_grows_as_delta_predicts_with_rank() {
    // delta decreases in r (for the 1/r terms): higher rank -> lower
    // relative variance. Verify the *ordering* empirically.
    let (m, n) = (6, 6);
    let mut gen = normal_rng(3);
    let g = Matrix::randn(m, n, &mut gen);
    let g_norm2 = g.fro_norm().powi(2);
    let trials = 120_000;
    let mut measured = Vec::new();
    for r in [1usize, 4] {
        let mut acc = 0.0f64;
        for _ in 0..trials {
            let s = tezo_sample(&mut gen, &g, r);
            let mut d2 = 0.0f64;
            for (a, b) in s.data.iter().zip(g.data.iter()) {
                d2 += ((a - b) as f64).powi(2);
            }
            acc += d2;
        }
        measured.push(acc / trials as f64 / g_norm2);
    }
    assert!(measured[1] < measured[0],
            "variance should shrink with rank: {measured:?}");
    // and both should be within 25% of their delta predictions
    for (i, r) in [1usize, 4].iter().enumerate() {
        let want = delta(m as f64, n as f64, *r as f64);
        let rel = (measured[i] - want).abs() / want;
        assert!(rel < 0.25, "r={r}: measured {} want {want}", measured[i]);
    }
}

/// Fig 8 / App A.2: the accumulated lightweight-second-moment error,
/// normalized by mn, decreases with model size.
#[test]
fn fig8_accumulated_error_shrinks_with_size() {
    let beta2 = 0.99f32;
    let steps = 150;
    let r = 8;
    let mut errs = Vec::new();
    for size in [32usize, 64, 128] {
        let (m, n) = (size, size);
        let mut gen = normal_rng(size as u64);
        let u = Matrix::randn(m, r, &mut gen);
        let v = Matrix::randn(n, r, &mut gen);
        let u2 = Matrix::from_vec(m, r, u.data.iter().map(|x| x * x).collect()).unwrap();
        let v2 = Matrix::from_vec(n, r, v.data.iter().map(|x| x * x).collect()).unwrap();
        let mut vt = Matrix::zeros(m, n);
        let mut vhat = Matrix::zeros(m, n);
        let mut acc = 0.0f64;
        for _ in 0..steps {
            let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
            let z = Matrix::cpd_slice(&u, &v, &tau).unwrap();
            let z2 = Matrix::from_vec(m, n, z.data.iter().map(|x| x * x).collect()).unwrap();
            let tau2: Vec<f32> = tau.iter().map(|t| t * t).collect();
            let sep = Matrix::cpd_slice(&u2, &v2, &tau2).unwrap();
            vt.scale(beta2);
            vt.axpy(1.0 - beta2, &z2).unwrap();
            vhat.scale(beta2);
            vhat.axpy(1.0 - beta2, &sep).unwrap();
            let mut d = Matrix::zeros(m, n);
            d.axpy(1.0, &vt).unwrap();
            d.axpy(-1.0, &vhat).unwrap();
            acc += d.fro_norm() / (m * n) as f64;
        }
        errs.push(acc / steps as f64);
    }
    assert!(errs[1] < errs[0] && errs[2] < errs[1],
            "E_t must shrink with size: {errs:?}");
}
