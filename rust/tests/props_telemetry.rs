//! Property tests for the telemetry layer (PR 8): histogram bucket
//! invariants, merge algebra, quantile bounds, and trace determinism.
//!
//! The histogram properties are what make fleet aggregation trustworthy:
//! bucket selection must be monotone and containing (a duration lands in
//! a bucket that brackets it), merges must be associative and commutative
//! (worker arrival order cannot change a merged readout — TZ-DET), and
//! quantile readouts must be bracketed by the recorded min/max. The
//! determinism test pins the export path: the same event sequence under a
//! [`TestClock`] serializes to byte-identical trace files.

use tezo::proplite::{self, prop_assert};
use tezo::telemetry::export::chrome_trace_string;
use tezo::telemetry::{LatencyHist, Telemetry, TestClock};

/// Random duration spanning the full magnitude range (0 ns .. ~500 years),
/// not just the uniform-u64 regime where every value is astronomically
/// large.
fn random_ns(g: &mut tezo::proplite::Gen) -> u64 {
    let shift = g.usize_in(0..64);
    g.u64() >> shift
}

/// Random duration bounded to 2^55 ns so test-side sums of ~100 samples
/// cannot overflow u64 (the histogram itself saturates; the assertions
/// below use plain `+`).
fn bounded_ns(g: &mut tezo::proplite::Gen) -> u64 {
    g.u64() >> g.usize_in(9..64)
}

fn random_hist(g: &mut tezo::proplite::Gen, max_n: usize) -> LatencyHist {
    let mut h = LatencyHist::new();
    for _ in 0..g.usize_in(0..max_n) {
        h.record_ns(bounded_ns(g));
    }
    h
}

#[test]
fn buckets_contain_their_values_and_order_monotonically() {
    proplite::run(500, |g| {
        let v = random_ns(g);
        let i = LatencyHist::bucket_index(v);
        prop_assert(LatencyHist::bucket_lo(i) <= v, "lo <= v")?;
        prop_assert(v <= LatencyHist::bucket_hi(i), "v <= hi")?;
        // monotone: a larger value never lands in an earlier bucket
        let w = random_ns(g);
        let (small, big) = if v <= w { (v, w) } else { (w, v) };
        prop_assert(
            LatencyHist::bucket_index(small) <= LatencyHist::bucket_index(big),
            "bucket index monotone in value")
    });
}

#[test]
fn bucket_edges_tile_the_u64_range() {
    // deterministic exhaustive check over every bucket boundary: edges are
    // strictly increasing and adjacent buckets meet with no gap
    for i in 0..tezo::telemetry::hist::N_BUCKETS - 1 {
        let hi = LatencyHist::bucket_hi(i);
        let next_lo = LatencyHist::bucket_lo(i + 1);
        assert_eq!(hi.wrapping_add(1), next_lo, "gap/overlap at bucket {i}");
        assert!(LatencyHist::bucket_lo(i) <= hi, "inverted bucket {i}");
    }
    assert_eq!(LatencyHist::bucket_hi(tezo::telemetry::hist::N_BUCKETS - 1),
               u64::MAX);
}

#[test]
fn merge_is_commutative_and_matches_pooled_recording() {
    proplite::run(200, |g| {
        let a = random_hist(g, 40);
        let b = random_hist(g, 40);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        prop_assert(ab == ba, "merge commutes")?;
        prop_assert(ab.count() == a.count() + b.count(), "counts add")?;
        prop_assert(ab.sum_ns() == a.sum_ns() + b.sum_ns(), "sums add")
    });
}

#[test]
fn merge_is_associative() {
    proplite::run(200, |g| {
        let a = random_hist(g, 25);
        let b = random_hist(g, 25);
        let c = random_hist(g, 25);
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        prop_assert(left == right, "(a+b)+c == a+(b+c)")
    });
}

#[test]
fn quantiles_are_bracketed_and_monotone_in_q() {
    proplite::run(300, |g| {
        let mut h = LatencyHist::new();
        let n = g.usize_in(1..60);
        for _ in 0..n {
            h.record_ns(random_ns(g));
        }
        let p50 = h.p50_ns();
        let p95 = h.p95_ns();
        let p99 = h.p99_ns();
        prop_assert(p50 <= p95 && p95 <= p99, "quantiles monotone in q")?;
        prop_assert(p99 <= h.max_ns(), "p99 <= max")?;
        // a quantile readout is the covering bucket's upper edge clamped
        // to max: it can never undershoot the bucket holding min
        prop_assert(p50 >= LatencyHist::bucket_lo(
                        LatencyHist::bucket_index(h.min_ns())),
                    "p50 >= min bucket lo")
    });
}

#[test]
fn single_value_histogram_reads_back_its_bucket() {
    proplite::run(300, |g| {
        let v = random_ns(g);
        let mut h = LatencyHist::new();
        h.record_ns(v);
        prop_assert(h.min_ns() == v && h.max_ns() == v, "min/max exact")?;
        // every quantile of a one-sample hist is clamped to the sample
        prop_assert(h.p50_ns() == v && h.p99_ns() == v,
                    "quantiles clamp to the single sample")
    });
}

/// One scripted event sequence — spans, counters, marks, and enough
/// events on a tiny ring to exercise the overwrite path.
fn scripted_run(ring: usize, tick_ns: u64) -> Telemetry {
    let t = Telemetry::with_clock(ring, Box::new(TestClock::new(tick_ns)));
    let run0 = t.now_ns();
    for step in 0..20i64 {
        let s0 = t.now_ns();
        t.span_from("phase", "sampling", s0, 0, step);
        let f0 = t.now_ns();
        t.span_from("phase", "forward", f0, 0, step);
        t.span_dur("round", "forward", 1_500 * (step as u64 + 1), 1, step);
        t.counter("step", "loss", 2.0 / (step + 1) as f64, step);
        if step % 7 == 0 {
            t.mark("fleet", "checkpoint", 0, step);
        }
    }
    t.span_from("run", "train", run0, 0, -1);
    t
}

#[test]
fn identical_runs_export_byte_identical_traces() {
    let a = scripted_run(64, 250);
    let b = scripted_run(64, 250);
    let ta = chrome_trace_string(&a.events(), "tezo determinism", a.dropped());
    let tb = chrome_trace_string(&b.events(), "tezo determinism", b.dropped());
    assert_eq!(ta, tb, "same script + same TestClock must be byte-identical");
    // and the file-writing path preserves the bytes exactly
    let dir = std::env::temp_dir().join("tezo_props_telemetry");
    let pa = dir.join("a.jsonl");
    let pb = dir.join("b.jsonl");
    tezo::telemetry::export::write_trace_file(&pa, &a, "tezo determinism")
        .expect("write a");
    tezo::telemetry::export::write_trace_file(&pb, &b, "tezo determinism")
        .expect("write b");
    let ba = std::fs::read(&pa).expect("read a");
    let bb = std::fs::read(&pb).expect("read b");
    assert_eq!(ba, bb, "trace files must be byte-identical");
    assert!(!ba.is_empty());
}

#[test]
fn ring_overwrite_keeps_newest_events_and_counts_drops() {
    let t = scripted_run(16, 250);
    let events = t.events();
    assert_eq!(events.len(), 16, "ring caps the snapshot");
    assert!(t.dropped() > 0, "overflow must be visible");
    // the run-close span (latest event) survived the overwrites
    assert_eq!(events.last().map(|e| e.cat), Some("run"));
}

#[test]
fn trace_parses_as_strict_json_with_expected_schema() {
    let t = scripted_run(64, 250);
    let body = chrome_trace_string(&t.events(), "tezo schema", t.dropped());
    let v = tezo::jsonx::parse(&body).expect("strict JSON");
    let rows = v.as_array().expect("array");
    assert!(rows.len() > 2);
    assert_eq!(rows[0].get_str("ph").unwrap(), "M");
    for row in &rows[1..] {
        let ph = row.get_str("ph").expect("ph");
        assert!(matches!(ph, "X" | "C" | "i"), "unexpected ph {ph:?}");
        assert!(row.get("args").is_ok(), "args present");
    }
}
