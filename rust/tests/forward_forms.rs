//! Cross-form contract for the implicit (factor-form) two-point loss:
//!
//! * parity: `|f_implicit - f_materialized| <= 1e-4` on the tiny config,
//!   across perturbation seeds standing in for every TeZO-family driver
//!   (they share one loss artifact — only the tau content differs) and for
//!   LOZO;
//! * memory: the implicit artifact's parameter-shaped temp metrics
//!   (`hlo_stats`) are >= 40% below the materialized one's — statically,
//!   no execution needed;
//! * resolution: `Manifest::loss_artifact` honors the `forward_form` knob
//!   and falls back to materialize for methods (or manifests) without an
//!   implicit artifact.
//!
//! Needs `make artifacts` (tiny); tests skip with a notice otherwise.

use tezo::config::{ForwardForm, Method};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::exec::scalar_f32;
use tezo::runtime::hlo_stats::HloStats;
use tezo::runtime::{ArgValue, ParamStore, Runtime};

const TOL: f32 = 1e-4;

fn open_tiny() -> Option<(Runtime, ParamStore)> {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny missing (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::open(&dir).expect("open runtime");
    let params = ParamStore::load(&rt.client, &rt.manifest).expect("load params");
    Some((rt, params))
}

fn tiny_batch(rt: &Runtime) -> tezo::data::Batch {
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    BatchBuilder::new(task, rt.manifest.config.batch, 16).train_batch(0, 0)
}

/// Run one tezo loss artifact with host-supplied factors.
fn run_tezo(rt: &Runtime, params: &ParamStore, artifact: &str, seed: u32,
            us: &[Vec<f32>], vs: &[Vec<f32>], taus: &[Vec<f32>]) -> (f32, f32) {
    let b = tiny_batch(rt);
    let mut call = rt.call(artifact).unwrap().bufs(params.bufs()).unwrap();
    for u in us {
        call = call.arg(ArgValue::F32(u)).unwrap();
    }
    for v in vs {
        call = call.arg(ArgValue::F32(v)).unwrap();
    }
    for t in taus {
        call = call.arg(ArgValue::F32(t)).unwrap();
    }
    let out = call
        .arg(ArgValue::I32(&b.tokens)).unwrap()
        .arg(ArgValue::I32(&b.targets)).unwrap()
        .arg(ArgValue::F32(&b.mask)).unwrap()
        .arg(ArgValue::ScalarU32(seed)).unwrap()
        .arg(ArgValue::ScalarF32(1e-2)).unwrap()
        .run().unwrap();
    (scalar_f32(&out[0]).unwrap(), scalar_f32(&out[1]).unwrap())
}

#[test]
fn tezo_cross_form_parity_within_tolerance() {
    let Some((rt, params)) = open_tiny() else { return };
    if rt.manifest.artifact("tezo_loss_pm_implicit").is_err() {
        eprintln!("skipping: manifest predates tezo_loss_pm_implicit");
        return;
    }
    let mats = rt.manifest.matrix_params();
    let (us, vs): (Vec<Vec<f32>>, Vec<Vec<f32>>) = mats
        .iter()
        .map(|p| {
            let r = rt.manifest.rank_of(&p.name).unwrap();
            (tezo::rngx::normal_vec(1, p.shape[0] * r),
             tezo::rngx::normal_vec(2, p.shape[1] * r))
        })
        .unzip();
    // one seed per TeZO-family driver: the artifact is shared, only the
    // tau vectors (raw / momentum / Adam-normalized) differ, and all are
    // rank-r vectors — distinct draws cover the space
    for (label, seed) in [("tezo", 11u32), ("tezo-m", 23), ("tezo-adam", 37)] {
        let taus: Vec<Vec<f32>> = mats
            .iter()
            .enumerate()
            .map(|(i, p)| tezo::rngx::normal_vec(
                seed as u64 * 100 + i as u64,
                rt.manifest.rank_of(&p.name).unwrap()))
            .collect();
        let (fp_m, fm_m) = run_tezo(&rt, &params, "tezo_loss_pm", seed,
                                    &us, &vs, &taus);
        let (fp_i, fm_i) = run_tezo(&rt, &params, "tezo_loss_pm_implicit",
                                    seed, &us, &vs, &taus);
        assert!((fp_m - fp_i).abs() <= TOL,
                "{label}: f+ drift {} (mat {fp_m}, imp {fp_i})",
                (fp_m - fp_i).abs());
        assert!((fm_m - fm_i).abs() <= TOL,
                "{label}: f- drift {} (mat {fm_m}, imp {fm_i})",
                (fm_m - fm_i).abs());
        // the two-point difference is the quantity kappa is made of
        assert!(((fp_m - fm_m) - (fp_i - fm_i)).abs() <= TOL, "{label}: delta");
    }
}

#[test]
fn lozo_cross_form_parity_within_tolerance() {
    let Some((rt, params)) = open_tiny() else { return };
    if rt.manifest.artifact("lozo_loss_pm_implicit").is_err() {
        eprintln!("skipping: manifest predates lozo_loss_pm_implicit");
        return;
    }
    // U panels from the artifact initializer, exactly like the driver
    let us = rt
        .call("lozo_init_u").unwrap()
        .arg(ArgValue::ScalarU32(1)).unwrap()
        .run().unwrap();
    let b = tiny_batch(&rt);
    let run = |artifact: &str| -> (f32, f32) {
        let mut call = rt.call(artifact).unwrap().bufs(params.bufs()).unwrap();
        for u in &us {
            call = call.arg(ArgValue::Buf(u)).unwrap();
        }
        let out = call
            .arg(ArgValue::I32(&b.tokens)).unwrap()
            .arg(ArgValue::I32(&b.targets)).unwrap()
            .arg(ArgValue::F32(&b.mask)).unwrap()
            .arg(ArgValue::ScalarU32(13)).unwrap()
            .arg(ArgValue::ScalarF32(1e-2)).unwrap()
            .run().unwrap();
        (scalar_f32(&out[0]).unwrap(), scalar_f32(&out[1]).unwrap())
    };
    let (fp_m, fm_m) = run("lozo_loss_pm");
    let (fp_i, fm_i) = run("lozo_loss_pm_implicit");
    assert!((fp_m - fp_i).abs() <= TOL, "f+ drift {}", (fp_m - fp_i).abs());
    assert!((fm_m - fm_i).abs() <= TOL, "f- drift {}", (fm_m - fm_i).abs());
}

#[test]
fn implicit_artifact_drops_param_shaped_temps() {
    let Some((rt, _)) = open_tiny() else { return };
    for fam in ["tezo", "lozo"] {
        let (mat, imp) = (format!("{fam}_loss_pm"),
                          format!("{fam}_loss_pm_implicit"));
        if rt.manifest.artifact(&imp).is_err() {
            eprintln!("skipping: manifest predates {imp}");
            return;
        }
        let stats_of = |name: &str| {
            let meta = rt.manifest.artifact(name).unwrap();
            HloStats::from_file(&rt.manifest.dir.join(&meta.file)).unwrap()
        };
        let m = stats_of(&mat);
        let i = stats_of(&imp);
        // acceptance: >= 40% below on the perturbed-weight temp metrics
        assert!(i.peak_param_temp_bytes as f64
                    <= 0.6 * m.peak_param_temp_bytes as f64,
                "{fam}: peak param temps {} vs {}",
                i.peak_param_temp_bytes, m.peak_param_temp_bytes);
        assert!(i.param_temp_total_bytes as f64
                    <= 0.6 * m.param_temp_total_bytes as f64,
                "{fam}: param temp traffic {} vs {}",
                i.param_temp_total_bytes, m.param_temp_total_bytes);
    }
}

#[test]
fn manifest_resolves_forward_forms() {
    let Some((rt, _)) = open_tiny() else { return };
    let man = &rt.manifest;
    if man.artifact("tezo_loss_pm_implicit").is_err() {
        eprintln!("skipping: manifest predates the implicit artifacts");
        return;
    }
    for m in [Method::Tezo, Method::TezoM, Method::TezoAdam] {
        assert_eq!(man.loss_artifact(m, ForwardForm::Implicit),
                   "tezo_loss_pm_implicit");
        assert_eq!(man.loss_artifact(m, ForwardForm::Materialize),
                   "tezo_loss_pm");
    }
    for m in [Method::Lozo, Method::LozoM] {
        assert_eq!(man.loss_artifact(m, ForwardForm::Implicit),
                   "lozo_loss_pm_implicit");
        assert_eq!(man.loss_artifact(m, ForwardForm::Materialize),
                   "lozo_loss_pm");
    }
    // dense-Z methods ignore the knob
    assert_eq!(man.loss_artifact(Method::Mezo, ForwardForm::Implicit),
               "mezo_loss_pm");
    assert_eq!(man.loss_artifact(Method::Subzo, ForwardForm::Implicit),
               "subzo_loss_pm");
    // manifest tags round-trip
    assert_eq!(man.artifact("tezo_loss_pm_implicit").unwrap()
                   .forward_form.as_deref(), Some("implicit"));
    assert_eq!(man.artifact("tezo_loss_pm").unwrap()
                   .forward_form.as_deref(), Some("materialize"));
    // warmup of both forms' sets resolves + compiles
    rt.warmup_method(Method::Tezo, ForwardForm::Implicit).unwrap();
    rt.warmup_method(Method::Tezo, ForwardForm::Materialize).unwrap();
}

#[test]
fn implicit_and_materialized_training_converge_similarly() {
    // One short tezo run per form: losses track within the two-point
    // tolerance accumulated over a few steps (forms are swappable without
    // retuning).
    use tezo::config::TrainConfig;
    use tezo::coordinator::trainer::{DataSource, Trainer};
    let Some((rt, _)) = open_tiny() else { return };
    if rt.manifest.artifact("tezo_loss_pm_implicit").is_err() {
        eprintln!("skipping: manifest predates tezo_loss_pm_implicit");
        return;
    }
    let run = |form: ForwardForm| -> Vec<f64> {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
        cfg.steps = 4;
        cfg.seed = 99;
        cfg.forward_form = tezo::config::FormPolicy::Pinned(form);
        let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                             rt.manifest.config.seq_len, 99);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        Trainer::new(&rt, cfg, DataSource::Task(builder))
            .run(&mut params)
            .unwrap()
            .metrics
            .losses
    };
    let mat = run(ForwardForm::Materialize);
    let imp = run(ForwardForm::Implicit);
    assert_eq!(mat.len(), imp.len());
    for (a, b) in mat.iter().zip(imp.iter()) {
        assert!((a - b).abs() < 5e-3, "loss drift {} vs {}", a, b);
    }
}
