//! Chaos battery for the fault-tolerant fleet: kill and revive workers at
//! fuzzed step boundaries and demand the run stays *bitwise* identical to
//! the uninterrupted oracle replay — the strongest statement the seed-log
//! catch-up protocol can make. Covers both catch-up modes (full log replay
//! and checkpoint + log tail) plus replica-side crashes through the
//! `Event::Failed` path.
//!
//! Each case appends a line to `out/chaos_fleet_log.txt`; CI uploads the
//! log as an artifact when the job fails.

use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use tezo::config::{FleetConfig, TrainConfig};
use tezo::fleet::sim::{self, SimReplica};
use tezo::fleet::worker::{JobFactory, Replica, ReplicaFactory};
use tezo::fleet::{FleetOutcome, FleetTrainer, KillPlan};
use tezo::proplite::{self, prop_assert, Gen};

const DIM: usize = 16;

/// Sim fleets inject replicas directly; the runtime-backed job factory must
/// never be consulted.
fn unused_jobs() -> Box<JobFactory> {
    Box::new(|_, _| Err(anyhow::anyhow!("sim fleets inject their replicas")))
}

fn sim_cfg(steps: usize, seed: u64) -> TrainConfig {
    TrainConfig { steps, lr: 0.05, seed, ..TrainConfig::default() }
}

/// Append one case record to the CI-collected chaos log (best effort).
fn log_case(line: &str) {
    std::fs::create_dir_all("out").ok();
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("out/chaos_fleet_log.txt")
    {
        writeln!(f, "{line}").ok();
    }
}

/// Run a loopback sim fleet with `kills` = (step, worker) kick injections
/// at step boundaries; return the outcome plus every worker's final
/// parameter bits.
fn run_chaos(cfg: &TrainConfig, workers: usize, checkpoint_every: usize,
             max_restarts: usize, kills: Vec<(u64, usize)>, tag: &str)
             -> (FleetOutcome, Vec<Vec<u32>>) {
    let dir = std::env::temp_dir()
        .join(format!("tezo_chaos_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");

    let make: Box<ReplicaFactory> = {
        let cfg = cfg.clone();
        let dir = dir.clone();
        Box::new(move |w, n| {
            // one shared checkpoint file: exactly one live worker writes
            // each checkpoint, and a rejoining incarnation loads it
            Ok(Box::new(
                SimReplica::new(w, n, &cfg, DIM)
                    .with_checkpoint_path(dir.join("ckpt.bin"))
                    .with_save_to(dir.join(format!("final_{w}.bin"))),
            ) as Box<dyn Replica>)
        })
    };
    let plan: KillPlan = Box::new(move |step| {
        kills.iter().filter(|&&(s, _)| s == step).map(|&(_, w)| w).collect()
    });
    let fc = FleetConfig {
        checkpoint_every,
        max_restarts,
        ..FleetConfig::new(workers)
    };
    let out = FleetTrainer::new(fc, cfg.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(make)
        .with_kill_plan(plan)
        .run()
        .expect("chaos fleet run");

    let params = (0..workers)
        .map(|w| {
            let path = dir.join(format!("final_{w}.bin"));
            let (step, p) = sim::read_sim_params(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            assert_eq!(step, cfg.steps as u64, "worker {w} stopped early");
            p.iter().map(|x| x.to_bits()).collect()
        })
        .collect();
    std::fs::remove_dir_all(&dir).ok();
    (out, params)
}

/// The shared postcondition: trace, kappa bits, loss bits, and every
/// worker's final parameters match the uninterrupted oracle exactly.
fn assert_bitwise_oracle_match(out: &FleetOutcome, params: &[Vec<u32>],
                               cfg: &TrainConfig, workers: usize,
                               label: &str) -> Result<(), String> {
    let oracle = sim::run_oracle(cfg, workers as u32, DIM);
    prop_assert(out.trace == oracle.trace,
                &format!("{label}: (seed, kappa) trace diverged"))?;
    prop_assert(
        out.trace.iter().zip(&oracle.trace).all(|(a, b)| {
            a.kappa.map(f32::to_bits) == b.kappa.map(f32::to_bits)
        }),
        &format!("{label}: kappa stream not bit-identical"),
    )?;
    prop_assert(
        out.metrics.losses.len() == oracle.losses.len()
            && out
                .metrics
                .losses
                .iter()
                .zip(&oracle.losses)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
        &format!("{label}: loss stream not bit-identical"),
    )?;
    let oracle_bits: Vec<u32> =
        oracle.params.iter().map(|p| p.to_bits()).collect();
    for (w, bits) in params.iter().enumerate() {
        prop_assert(*bits == oracle_bits,
                    &format!("{label}: worker {w} final params diverged"))?;
    }
    Ok(())
}

/// Draw `n` kill events at distinct step boundaries in `1..steps` (distinct
/// steps: the fleet is fully staffed at every boundary, so each kick is
/// guaranteed to hit a live worker and be charged to the restart budget).
fn gen_kills(g: &mut Gen, n: usize, steps: usize, workers: usize)
             -> Vec<(u64, usize)> {
    let mut pool: Vec<u64> = (1..steps as u64).collect();
    (0..n)
        .map(|_| {
            let s = pool.swap_remove(g.usize_in(0..pool.len()));
            (s, g.usize_in(0..workers))
        })
        .collect()
}

#[test]
fn kills_with_full_replay_catch_up_stay_bitwise() {
    proplite::run(6, |g| {
        let workers = 2 + g.usize_in(0..2);
        let steps = 6 + g.usize_in(0..6);
        let cfg = sim_cfg(steps, g.u64() % 1000);
        let n_kills = 2 + g.usize_in(0..2);
        let kills = gen_kills(g, n_kills, steps, workers);
        let (out, params) =
            run_chaos(&cfg, workers, 0, n_kills, kills.clone(), "replay");
        log_case(&format!(
            "replay: workers={workers} steps={steps} seed={} kills={kills:?} \
             rejoins={}", cfg.seed, out.fleet.rejoins));
        prop_assert(out.fleet.rejoins == n_kills as u64,
                    &format!("expected {n_kills} rejoins, saw {}",
                             out.fleet.rejoins))?;
        assert_bitwise_oracle_match(&out, &params, &cfg, workers,
                                    "full-replay")
    });
}

#[test]
fn kills_with_checkpoint_catch_up_stay_bitwise() {
    proplite::run(6, |g| {
        let workers = 2 + g.usize_in(0..2);
        let steps = 6 + g.usize_in(0..6);
        let checkpoint_every = 2 + g.usize_in(0..3);
        let cfg = sim_cfg(steps, g.u64() % 1000);
        let n_kills = 2 + g.usize_in(0..2);
        let kills = gen_kills(g, n_kills, steps, workers);
        let (out, params) = run_chaos(&cfg, workers, checkpoint_every,
                                      n_kills, kills.clone(), "ckpt");
        log_case(&format!(
            "ckpt: workers={workers} steps={steps} every={checkpoint_every} \
             seed={} kills={kills:?} rejoins={} checkpoints={}",
            cfg.seed, out.fleet.rejoins, out.fleet.checkpoints));
        prop_assert(out.fleet.rejoins == n_kills as u64,
                    &format!("expected {n_kills} rejoins, saw {}",
                             out.fleet.rejoins))?;
        prop_assert(
            out.fleet.checkpoints == (steps / checkpoint_every) as u64,
            &format!("expected {} checkpoints, saw {}",
                     steps / checkpoint_every, out.fleet.checkpoints),
        )?;
        assert_bitwise_oracle_match(&out, &params, &cfg, workers,
                                    "checkpoint")
    });
}

/// Replica-side crashes (the `Event::Failed` path, not a coordinator kick):
/// the first incarnation of two different workers dies mid-forward; the
/// respawned incarnations catch up from the step-3 checkpoint + log tail
/// and the run still matches the oracle bitwise.
#[test]
fn injected_forward_crashes_recover_bitwise() {
    const WORKERS: usize = 2;
    let cfg = sim_cfg(9, 7);
    let dir = std::env::temp_dir()
        .join(format!("tezo_chaos_{}_crash", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create temp dir");

    let incarnations: Arc<Vec<AtomicUsize>> =
        Arc::new((0..WORKERS).map(|_| AtomicUsize::new(0)).collect());
    let make: Box<ReplicaFactory> = {
        let cfg = cfg.clone();
        let dir = dir.clone();
        let incarnations = Arc::clone(&incarnations);
        Box::new(move |w, n| {
            let mut r = SimReplica::new(w, n, &cfg, DIM)
                .with_checkpoint_path(dir.join("ckpt.bin"))
                .with_save_to(dir.join(format!("final_{w}.bin")));
            // only the first incarnation carries the crash plan — its
            // replacement must come up clean or it would die forever
            if incarnations[w].fetch_add(1, Ordering::SeqCst) == 0 {
                r = r.with_die_at(match w {
                    0 => vec![(5, 0)],
                    _ => vec![(2, 0)],
                });
            }
            Ok(Box::new(r) as Box<dyn Replica>)
        })
    };
    let fc = FleetConfig {
        checkpoint_every: 3,
        max_restarts: 2,
        ..FleetConfig::new(WORKERS)
    };
    let out = FleetTrainer::new(fc, cfg.clone(), PathBuf::from("unused"),
                                unused_jobs())
        .with_replica_factory(make)
        .run()
        .expect("crash fleet run");

    let params: Vec<Vec<u32>> = (0..WORKERS)
        .map(|w| {
            let (step, p) =
                sim::read_sim_params(&dir.join(format!("final_{w}.bin")))
                    .expect("final params");
            assert_eq!(step, cfg.steps as u64);
            p.iter().map(|x| x.to_bits()).collect()
        })
        .collect();
    std::fs::remove_dir_all(&dir).ok();

    log_case(&format!("crash: workers={WORKERS} steps={} rejoins={}",
                      cfg.steps, out.fleet.rejoins));
    assert_eq!(out.fleet.rejoins, 2, "both crashed workers must rejoin");
    assert_bitwise_oracle_match(&out, &params, &cfg, WORKERS, "crash")
        .unwrap_or_else(|e| panic!("{e}"));
}
