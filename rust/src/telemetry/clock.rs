//! Clock access for the telemetry layer.
//!
//! Every wall-clock read in the workspace lives in this file (enforced by
//! tezo-lint TZ-OBS001): the rest of the crate measures elapsed time
//! through [`Stopwatch`] and the tracer reads timestamps through a
//! [`Clock`] handle, so tests can substitute [`TestClock`] and compare
//! trace files byte-for-byte.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic nanosecond clock behind the tracer.
///
/// `Send + Sync` so one clock can stamp events from the coordinator and
/// every fleet worker thread; `Debug` so tracer handles stay debuggable.
pub trait Clock: fmt::Debug + Send + Sync {
    /// Nanoseconds since the clock's zero anchor.
    fn now_ns(&self) -> u64;
}

/// Real monotonic clock, zero-anchored at construction so trace
/// timestamps start near zero and fit comfortably in microseconds.
#[derive(Clone, Copy, Debug)]
pub struct MonotonicClock {
    zero: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { zero: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        duration_ns(self.zero.elapsed())
    }
}

/// Deterministic clock for tests: every read advances time by a fixed
/// tick, so two identical call sequences observe identical timestamps
/// and produce byte-identical trace files.
#[derive(Debug)]
pub struct TestClock {
    now: AtomicU64,
    tick_ns: u64,
}

impl TestClock {
    pub fn new(tick_ns: u64) -> Self {
        Self { now: AtomicU64::new(0), tick_ns }
    }
}

impl Clock for TestClock {
    fn now_ns(&self) -> u64 {
        self.now.fetch_add(self.tick_ns, Ordering::Relaxed)
    }
}

/// Free-running elapsed timer: the one sanctioned way for code outside
/// `telemetry/` to measure wall time (TZ-OBS001 denies raw `Instant`
/// elsewhere). Deliberately read-only — it exposes durations, never
/// absolute timestamps, so its readings cannot leak into seeds or wire
/// frames as entropy.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { t0: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> u64 {
        duration_ns(self.t0.elapsed())
    }
}

fn duration_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Seconds (as measured by a [`Stopwatch`]) to integer nanoseconds for
/// histogram recording; negative and non-finite inputs clamp to zero.
pub fn secs_to_ns(secs: f64) -> u64 {
    if secs.is_finite() && secs > 0.0 {
        let ns = secs * 1e9;
        if ns >= u64::MAX as f64 {
            u64::MAX
        } else {
            ns as u64
        }
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_clock_is_deterministic() {
        let a = TestClock::new(100);
        let b = TestClock::new(100);
        for _ in 0..5 {
            assert_eq!(a.now_ns(), b.now_ns());
        }
        assert_eq!(a.now_ns(), 500);
    }

    #[test]
    fn monotonic_clock_never_goes_backwards() {
        let c = MonotonicClock::new();
        let t0 = c.now_ns();
        let t1 = c.now_ns();
        assert!(t1 >= t0);
    }

    #[test]
    fn stopwatch_reports_consistent_units() {
        let sw = Stopwatch::start();
        let ns = sw.elapsed_ns();
        let secs = sw.elapsed_secs();
        assert!(secs >= ns as f64 / 1e9);
    }

    #[test]
    fn secs_to_ns_clamps_garbage() {
        assert_eq!(secs_to_ns(-1.0), 0);
        assert_eq!(secs_to_ns(f64::NAN), 0);
        assert_eq!(secs_to_ns(f64::INFINITY), u64::MAX);
        assert_eq!(secs_to_ns(1.5e-6), 1500);
    }
}
