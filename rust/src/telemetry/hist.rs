//! Fixed-log-bucket latency histograms.
//!
//! Values are integer nanoseconds. Bucket selection is pure integer
//! arithmetic (`leading_zeros` plus two mantissa bits — no floats, per
//! TZ-DET), and merging is an elementwise saturating add, which is
//! associative and commutative: the order workers report in can never
//! change a merged readout. Quantiles are read out as the inclusive
//! upper bound of the covering bucket — deterministic, never below the
//! true quantile, and at four sub-buckets per octave never more than
//! ~25% above it.

/// Total bucket count: 16 exact buckets below 16 ns, then 4 sub-buckets
/// for each of the 60 octaves up to `u64::MAX` (16 + 60*4 = 256).
pub const N_BUCKETS: usize = 256;

const LINEAR_MAX: u64 = 16;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyHist {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self {
            counts: [0; N_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    /// Bucket index for a value: exact buckets below 16 ns, then four
    /// sub-buckets per power of two. Monotone non-decreasing in `v`.
    pub fn bucket_index(v: u64) -> usize {
        if v < LINEAR_MAX {
            v as usize
        } else {
            // highest set bit position; v >= 16 so octave >= 4
            let octave = 63 - v.leading_zeros() as usize;
            let sub = ((v >> (octave - 2)) & 3) as usize;
            LINEAR_MAX as usize + (octave - 4) * 4 + sub
        }
    }

    /// Inclusive lower bound of bucket `i`.
    pub fn bucket_lo(i: usize) -> u64 {
        if i < LINEAR_MAX as usize {
            i as u64
        } else {
            let rel = i - LINEAR_MAX as usize;
            let octave = 4 + rel / 4;
            let sub = (rel % 4) as u64;
            (1u64 << octave) + (sub << (octave - 2))
        }
    }

    /// Inclusive upper bound of bucket `i`.
    pub fn bucket_hi(i: usize) -> u64 {
        if i + 1 < N_BUCKETS {
            Self::bucket_lo(i + 1) - 1
        } else {
            u64::MAX
        }
    }

    pub fn record_ns(&mut self, ns: u64) {
        let i = Self::bucket_index(ns);
        self.counts[i] = self.counts[i].saturating_add(1);
        self.count = self.count.saturating_add(1);
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Merge another histogram in. Saturating elementwise adds keep the
    /// operation associative and commutative, so fleet-side merges are
    /// invariant to worker arrival order.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a = a.saturating_add(*b);
        }
        self.count = self.count.saturating_add(other.count);
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min_ns }
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.sum_ns / self.count }
    }

    /// Upper bound of the bucket holding the sample of rank
    /// `ceil(q_num * count / q_den)` (clamped to `[1, count]`), capped at
    /// the exact observed maximum. Returns 0 on an empty histogram.
    /// Integer arithmetic throughout: the readout is a deterministic
    /// function of the merged counts alone.
    pub fn quantile_ns(&self, q_num: u64, q_den: u64) -> u64 {
        if self.count == 0 || q_den == 0 {
            return 0;
        }
        let rank = q_num
            .saturating_mul(self.count)
            .div_ceil(q_den)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen = seen.saturating_add(*c);
            if seen >= rank {
                return Self::bucket_hi(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn p50_ns(&self) -> u64 {
        self.quantile_ns(50, 100)
    }

    pub fn p95_ns(&self) -> u64 {
        self.quantile_ns(95, 100)
    }

    pub fn p99_ns(&self) -> u64 {
        self.quantile_ns(99, 100)
    }

    /// Occupied buckets as `(index, count)` pairs, in bucket order.
    pub fn nonzero(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| (i, *c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_cover_and_order() {
        for v in [0u64, 1, 15, 16, 17, 28, 31, 32, 1000, 1 << 20, u64::MAX] {
            let i = LatencyHist::bucket_index(v);
            assert!(i < N_BUCKETS);
            assert!(LatencyHist::bucket_lo(i) <= v, "lo({i}) > {v}");
            assert!(v <= LatencyHist::bucket_hi(i), "{v} > hi({i})");
        }
        for i in 0..N_BUCKETS - 1 {
            assert!(LatencyHist::bucket_hi(i) < LatencyHist::bucket_lo(i + 1));
        }
    }

    #[test]
    fn quantiles_on_known_data() {
        let mut h = LatencyHist::new();
        for v in 1..=100u64 {
            h.record_ns(v * 1000);
        }
        let p50 = h.p50_ns();
        assert!(p50 >= 50_000 && p50 <= 50_000 + 50_000 / 4 + 1, "{p50}");
        assert_eq!(h.quantile_ns(100, 100), 100_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.min_ns(), 1000);
        assert_eq!(h.max_ns(), 100_000);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.p50_ns(), 0);
        assert_eq!(h.mean_ns(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert!(h.is_empty());
    }

    #[test]
    fn merge_equals_pooled_recording() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        let mut pooled = LatencyHist::new();
        for v in [3u64, 500, 999_999, 42] {
            a.record_ns(v);
            pooled.record_ns(v);
        }
        for v in [7u64, 123_456, 1] {
            b.record_ns(v);
            pooled.record_ns(v);
        }
        a.merge(&b);
        assert_eq!(a, pooled);
    }
}
