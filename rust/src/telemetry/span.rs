//! Span and event tracer over a preallocated ring buffer.
//!
//! The span model is a fixed hierarchy — run → step → phase → dispatch
//! on the trainer side, round → worker on the fleet side — flattened
//! into one event row per span so recording is a single ring push under
//! a mutex (no open-span stack, no allocation after construction). A
//! disabled tracer ([`Telemetry::off`], the default) records nothing and
//! costs one `Option` check per call site.

use std::sync::{Arc, Mutex};

use super::clock::{Clock, MonotonicClock};

/// What an event row means (maps onto Chrome trace-event phases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A duration `ts_ns .. ts_ns + dur_ns` (Chrome `ph:"X"`).
    Span,
    /// A sampled numeric series, e.g. loss per step (Chrome `ph:"C"`).
    Counter,
    /// A point event, e.g. a worker rejoin (Chrome `ph:"i"`).
    Mark,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub cat: &'static str,
    pub name: &'static str,
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// Trace row: fleet worker slot for round spans, 0 otherwise.
    pub lane: u32,
    /// Training step the event belongs to, -1 when not step-scoped.
    pub step: i64,
    /// Counter payload; 0.0 for spans and marks.
    pub value: f64,
}

/// Fixed-capacity ring: once full, the oldest event is overwritten, so a
/// long run keeps its most recent window plus an exact drop count.
#[derive(Debug)]
struct Ring {
    buf: Vec<TraceEvent>,
    start: usize,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap), start: 0, cap, dropped: 0 }
    }

    fn push(&mut self, e: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else if self.cap > 0 {
            self.buf[self.start] = e;
            self.start = (self.start + 1) % self.cap;
            self.dropped += 1;
        } else {
            self.dropped += 1;
        }
    }

    fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.start..]);
        out.extend_from_slice(&self.buf[..self.start]);
        out
    }
}

#[derive(Debug)]
struct Inner {
    clock: Box<dyn Clock>,
    ring: Mutex<Ring>,
}

/// Cloneable tracer handle. The default ([`Telemetry::off`]) is a no-op
/// shell: every record call returns immediately, so instrumented code
/// pays one branch when tracing is disabled. Clones share one ring and
/// one clock, so the coordinator and fleet workers stamp events on a
/// common timeline.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    inner: Option<Arc<Inner>>,
}

impl Telemetry {
    /// Disabled tracer (same as `Telemetry::default()`).
    pub fn off() -> Self {
        Self { inner: None }
    }

    /// Enabled tracer on the real monotonic clock.
    pub fn new(capacity: usize) -> Self {
        Self::with_clock(capacity, Box::new(MonotonicClock::new()))
    }

    /// Enabled tracer on an explicit clock (tests use [`super::TestClock`]).
    pub fn with_clock(capacity: usize, clock: Box<dyn Clock>) -> Self {
        Self {
            inner: Some(Arc::new(Inner { clock, ring: Mutex::new(Ring::new(capacity)) })),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Current timestamp on the tracer's clock; 0 when disabled.
    pub fn now_ns(&self) -> u64 {
        match &self.inner {
            Some(inner) => inner.clock.now_ns(),
            None => 0,
        }
    }

    fn push(&self, e: TraceEvent) {
        if let Some(inner) = &self.inner {
            if let Ok(mut ring) = inner.ring.lock() {
                ring.push(e);
            }
        }
    }

    /// Record a span with an explicit start and duration (both already
    /// observed on this tracer's clock). Does not read the clock, so a
    /// timing measured once lands verbatim in the ring.
    pub fn span_at(&self, cat: &'static str, name: &'static str, ts_ns: u64, dur_ns: u64, lane: u32, step: i64) {
        self.push(TraceEvent {
            kind: EventKind::Span,
            cat,
            name,
            ts_ns,
            dur_ns,
            lane,
            step,
            value: 0.0,
        });
    }

    /// Record a span that started at `start_ns` (a prior `now_ns` read)
    /// and ends now.
    pub fn span_from(&self, cat: &'static str, name: &'static str, start_ns: u64, lane: u32, step: i64) {
        if let Some(inner) = &self.inner {
            let now = inner.clock.now_ns();
            self.span_at(cat, name, start_ns, now.saturating_sub(start_ns), lane, step);
        }
    }

    /// Record a span of known duration ending now (used when the
    /// duration was measured externally, e.g. by a `Stopwatch` or a
    /// worker-reported timing).
    pub fn span_dur(&self, cat: &'static str, name: &'static str, dur_ns: u64, lane: u32, step: i64) {
        if let Some(inner) = &self.inner {
            let now = inner.clock.now_ns();
            self.push(TraceEvent {
                kind: EventKind::Span,
                cat,
                name,
                ts_ns: now.saturating_sub(dur_ns),
                dur_ns,
                lane,
                step,
                value: 0.0,
            });
        }
    }

    /// Record a sampled numeric series point (loss, kappa, bytes, ...).
    pub fn counter(&self, cat: &'static str, name: &'static str, value: f64, step: i64) {
        if let Some(inner) = &self.inner {
            let now = inner.clock.now_ns();
            self.push(TraceEvent {
                kind: EventKind::Counter,
                cat,
                name,
                ts_ns: now,
                dur_ns: 0,
                lane: 0,
                step,
                value,
            });
        }
    }

    /// Record a point event (rejoin, drop, checkpoint, ...).
    pub fn mark(&self, cat: &'static str, name: &'static str, lane: u32, step: i64) {
        if let Some(inner) = &self.inner {
            let now = inner.clock.now_ns();
            self.push(TraceEvent {
                kind: EventKind::Mark,
                cat,
                name,
                ts_ns: now,
                dur_ns: 0,
                lane,
                step,
                value: 0.0,
            });
        }
    }

    /// Snapshot of the ring in timestamp (insertion) order.
    pub fn events(&self) -> Vec<TraceEvent> {
        match &self.inner {
            Some(inner) => match inner.ring.lock() {
                Ok(ring) => ring.snapshot(),
                Err(_) => Vec::new(),
            },
            None => Vec::new(),
        }
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        match &self.inner {
            Some(inner) => match inner.ring.lock() {
                Ok(ring) => ring.dropped,
                Err(_) => 0,
            },
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::clock::TestClock;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Telemetry::off();
        t.counter("step", "loss", 1.0, 0);
        t.mark("fleet", "rejoin", 2, 5);
        assert!(!t.enabled());
        assert!(t.events().is_empty());
        assert_eq!(t.now_ns(), 0);
    }

    #[test]
    fn spans_and_counters_land_in_order() {
        let t = Telemetry::with_clock(16, Box::new(TestClock::new(10)));
        let s0 = t.now_ns();
        t.span_from("phase", "forward", s0, 0, 3);
        t.counter("step", "loss", 0.5, 3);
        let ev = t.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].kind, EventKind::Span);
        assert_eq!(ev[0].ts_ns, 0);
        assert_eq!(ev[0].dur_ns, 10);
        assert_eq!(ev[1].kind, EventKind::Counter);
        assert_eq!(ev[1].value, 0.5);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = Telemetry::with_clock(2, Box::new(TestClock::new(1)));
        for i in 0..5i64 {
            t.mark("fleet", "tick", 0, i);
        }
        let ev = t.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].step, 3);
        assert_eq!(ev[1].step, 4);
        assert_eq!(t.dropped(), 3);
    }

    #[test]
    fn clones_share_one_ring() {
        let t = Telemetry::with_clock(8, Box::new(TestClock::new(1)));
        let t2 = t.clone();
        t.mark("a", "x", 0, 0);
        t2.mark("a", "y", 0, 1);
        assert_eq!(t.events().len(), 2);
    }
}
