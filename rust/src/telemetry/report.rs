//! `trace-report`: summarize an exported Chrome trace file.
//!
//! Reads a trace written by [`super::export::write_trace_file`], checks
//! every event against the schema, and prints per-phase latency
//! histograms, the slowest steps, and a per-worker skew table. The same
//! walk backs the CI schema check (`--check`), so the validation CI runs
//! is exactly the validation users run.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::hist::LatencyHist;
use crate::jsonx::{self, Value};

/// One validated trace row (metadata rows are passed through as `Meta`).
enum Row {
    Meta,
    Span { cat: String, name: String, lane: u32, step: i64, dur_ns: u64 },
    Counter { name: String },
    Mark { name: String },
}

fn parse_row(v: &Value) -> Result<Row> {
    let ph = v.get_str("ph").context("event missing \"ph\"")?;
    match ph {
        "M" => {
            v.get_str("name").context("metadata missing \"name\"")?;
            Ok(Row::Meta)
        }
        "X" => {
            let cat = v.get_str("cat")?.to_string();
            let name = v.get_str("name")?.to_string();
            let ts = v.get("ts")?.as_i64().context("\"ts\" must be integer microseconds")?;
            let dur = v.get("dur")?.as_i64().context("\"dur\" must be integer microseconds")?;
            if ts < 0 || dur < 0 {
                bail!("negative ts/dur in span {name:?}");
            }
            let lane = u32::try_from(v.get("tid")?.as_i64()?).context("\"tid\" out of range")?;
            let args = v.get("args")?;
            let step = args.get("step")?.as_i64()?;
            let dur_ns = u64::try_from(args.get("dur_ns")?.as_i64()?)
                .context("\"dur_ns\" out of range")?;
            Ok(Row::Span { cat, name, lane, step, dur_ns })
        }
        "C" => {
            let name = v.get_str("name")?.to_string();
            let args = v.get("args")?;
            let value = args.get("value")?;
            if !value.is_null() {
                value.as_f64().context("counter \"value\" must be numeric or null")?;
            }
            args.get("step")?.as_i64()?;
            Ok(Row::Counter { name })
        }
        "i" => {
            let name = v.get_str("name")?.to_string();
            v.get("args")?.get("step")?.as_i64()?;
            Ok(Row::Mark { name })
        }
        other => bail!("unknown event phase {other:?} (expected M/X/C/i)"),
    }
}

fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Summarize (and optionally just schema-check) a trace file.
pub fn trace_report(path: &str, check_only: bool, slowest: usize) -> Result<()> {
    let body =
        std::fs::read_to_string(path).with_context(|| format!("read trace file {path}"))?;
    let root = jsonx::parse(&body).context("trace is not valid JSON")?;
    let rows = root.as_array().context("trace root must be a JSON array")?;

    let mut phase_hists: BTreeMap<String, LatencyHist> = BTreeMap::new();
    let mut step_spans: Vec<(i64, u64)> = Vec::new();
    let mut worker_hists: BTreeMap<u32, LatencyHist> = BTreeMap::new();
    let mut counters = 0usize;
    let mut marks: BTreeMap<String, usize> = BTreeMap::new();
    let mut spans = 0usize;

    for (i, row) in rows.iter().enumerate() {
        let parsed = parse_row(row).with_context(|| format!("trace event #{i}"))?;
        match parsed {
            Row::Meta => {}
            Row::Span { cat, name, lane, step, dur_ns } => {
                spans += 1;
                match cat.as_str() {
                    "phase" | "dispatch" => {
                        phase_hists.entry(name).or_default().record_ns(dur_ns);
                    }
                    "step" | "run" => step_spans.push((step, dur_ns)),
                    "round" => {
                        worker_hists.entry(lane).or_default().record_ns(dur_ns);
                    }
                    _ => {}
                }
            }
            Row::Counter { .. } => counters += 1,
            Row::Mark { name } => *marks.entry(name).or_default() += 1,
        }
    }

    println!(
        "trace {path}: {} events ({spans} spans, {counters} counters, {} marks)",
        rows.len().saturating_sub(1),
        marks.values().sum::<usize>()
    );
    if check_only {
        println!("schema check passed");
        return Ok(());
    }

    if !phase_hists.is_empty() {
        println!("\nper-phase latency:");
        println!(
            "  {:<12} {:>8} {:>10} {:>10} {:>10} {:>10}",
            "phase", "count", "p50", "p95", "p99", "max"
        );
        for (name, h) in &phase_hists {
            println!(
                "  {:<12} {:>8} {:>10} {:>10} {:>10} {:>10}",
                name,
                h.count(),
                fmt_ns(h.p50_ns()),
                fmt_ns(h.p95_ns()),
                fmt_ns(h.p99_ns()),
                fmt_ns(h.max_ns())
            );
        }
    }

    // slowest step spans (cat "step"/"run"; run spans carry step = -1 and
    // are excluded from the ranking)
    let mut ranked: Vec<(i64, u64)> =
        step_spans.iter().copied().filter(|(s, _)| *s >= 0).collect();
    ranked.sort_by_key(|(s, d)| (std::cmp::Reverse(*d), *s));
    if !ranked.is_empty() {
        println!("\nslowest steps:");
        for (step, dur) in ranked.iter().take(slowest.max(1)) {
            println!("  step {:<6} {}", step, fmt_ns(*dur));
        }
    }

    if !worker_hists.is_empty() {
        let best_p50 = worker_hists.values().map(|h| h.p50_ns()).min().unwrap_or(0);
        println!("\nper-worker round skew:");
        println!(
            "  {:<8} {:>8} {:>10} {:>10} {:>10} {:>8}",
            "worker", "rounds", "p50", "p95", "max", "vs-best"
        );
        for (w, h) in &worker_hists {
            let skew = if best_p50 > 0 {
                h.p50_ns() as f64 / best_p50 as f64
            } else {
                1.0
            };
            println!(
                "  {:<8} {:>8} {:>10} {:>10} {:>10} {:>7.2}x",
                w,
                h.count(),
                fmt_ns(h.p50_ns()),
                fmt_ns(h.p95_ns()),
                fmt_ns(h.max_ns()),
                skew
            );
        }
    }

    if !marks.is_empty() {
        println!("\nevents:");
        for (name, n) in &marks {
            println!("  {name:<20} {n}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::clock::TestClock;
    use crate::telemetry::export::chrome_trace_string;
    use crate::telemetry::span::Telemetry;

    #[test]
    fn roundtrip_written_trace_validates() {
        let t = Telemetry::with_clock(32, Box::new(TestClock::new(1000)));
        let s0 = t.now_ns();
        t.span_from("phase", "forward", s0, 0, 0);
        t.counter("step", "loss", 2.0, 0);
        t.mark("fleet", "rejoin", 1, 3);
        let body = chrome_trace_string(&t.events(), "tezo test", t.dropped());
        let root = jsonx::parse(&body).unwrap();
        for (i, row) in root.as_array().unwrap().iter().enumerate() {
            parse_row(row).unwrap_or_else(|e| panic!("event #{i}: {e:#}"));
        }
    }

    #[test]
    fn schema_check_rejects_malformed_events() {
        for bad in [
            r#"[{"ph":"X","pid":0,"tid":0,"ts":1,"cat":"phase","name":"f","args":{"step":0}}]"#,
            r#"[{"ph":"Q","name":"x"}]"#,
            r#"[{"pid":0}]"#,
        ] {
            let root = jsonx::parse(bad).unwrap();
            let rows = root.as_array().unwrap();
            assert!(rows.iter().any(|r| parse_row(r).is_err()), "{bad}");
        }
    }
}
