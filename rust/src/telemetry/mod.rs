//! Unified telemetry: span tracing, latency histograms, exporters.
//!
//! The observability substrate for the trainer, runtime, and fleet
//! (PR 8). Four invariants shape the design:
//!
//! 1. **Clock confinement** — every wall-clock read lives in
//!    [`clock`]; the rest of the crate uses [`Stopwatch`] for durations
//!    and the tracer's [`Clock`] for timestamps (tezo-lint TZ-OBS001).
//! 2. **Determinism (TZ-DET)** — histogram bucket selection is pure
//!    integer arithmetic and merging is elementwise saturating addition,
//!    so merged readouts are invariant to worker arrival order; under a
//!    [`TestClock`] two identical runs export byte-identical traces.
//! 3. **Observational only** — telemetry values never flow into seeds,
//!    kappa, or wire frames (lint-enforced by TZ-OBS001's flow check).
//!    The layer watches the run; it must not steer it.
//! 4. **Near-zero cost when off** — [`Telemetry::off`] is the default;
//!    every record call is one `Option` check, the ring is never
//!    allocated, and no files are written.
//!
//! Exporters: Chrome trace-event JSON (Perfetto-loadable, one event per
//! line), a Prometheus-style text snapshot, and summary blocks folded
//! into the existing `TrainOutcome` JSON. See `docs/observability.md`.

pub mod clock;
pub mod export;
pub mod hist;
pub mod report;
pub mod span;

pub use clock::{secs_to_ns, Clock, MonotonicClock, Stopwatch, TestClock};
pub use hist::LatencyHist;
pub use span::{EventKind, Telemetry, TraceEvent};

/// Default ring capacity behind `--telemetry-dir` (one event is ~80 B,
/// so the full ring is ~5 MB; a 1000-step single-worker run emits on the
/// order of 10 events per step and fits with wide margin).
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;
