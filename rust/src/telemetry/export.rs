//! Exporters: Chrome trace-event JSON (Perfetto-loadable) and a
//! Prometheus-style text snapshot.
//!
//! The trace file is a strict JSON array with one event object per line,
//! so it loads in Perfetto / `chrome://tracing` and still greps like a
//! JSONL stream. All numeric formatting is deterministic (integer
//! microseconds for `ts`/`dur`, shortest-roundtrip `Display` for f64
//! payloads), so identical event sequences serialize byte-identically.

use std::path::Path;

use anyhow::{Context, Result};

use super::hist::LatencyHist;
use super::span::{EventKind, Telemetry, TraceEvent};

/// Minimal JSON string escape (quotes, backslash, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Deterministic f64 → JSON: shortest-roundtrip for finite values,
/// `null` for NaN/inf (matching the jsonx writer's convention).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn event_line(e: &TraceEvent) -> String {
    let ts_us = e.ts_ns / 1000;
    match e.kind {
        EventKind::Span => format!(
            "{{\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"cat\":\"{}\",\"name\":\"{}\",\"args\":{{\"step\":{},\"dur_ns\":{}}}}}",
            e.lane,
            ts_us,
            e.dur_ns / 1000,
            esc(e.cat),
            esc(e.name),
            e.step,
            e.dur_ns,
        ),
        EventKind::Counter => format!(
            "{{\"ph\":\"C\",\"pid\":0,\"tid\":0,\"ts\":{},\"cat\":\"{}\",\"name\":\"{}\",\"args\":{{\"value\":{},\"step\":{}}}}}",
            ts_us,
            esc(e.cat),
            esc(e.name),
            fmt_f64(e.value),
            e.step,
        ),
        EventKind::Mark => format!(
            "{{\"ph\":\"i\",\"s\":\"g\",\"pid\":0,\"tid\":{},\"ts\":{},\"cat\":\"{}\",\"name\":\"{}\",\"args\":{{\"step\":{}}}}}",
            e.lane,
            ts_us,
            esc(e.cat),
            esc(e.name),
            e.step,
        ),
    }
}

/// Render events as a Chrome trace-event JSON array (one event per
/// line). `process` labels the trace in the viewer; `dropped` > 0 adds a
/// metadata counter so truncated rings are visible in the artifact.
pub fn chrome_trace_string(events: &[TraceEvent], process: &str, dropped: u64) -> String {
    let mut out = String::new();
    out.push_str("[\n");
    out.push_str(&format!(
        "{{\"ph\":\"M\",\"pid\":0,\"name\":\"process_name\",\"args\":{{\"name\":\"{}\"}}}}",
        esc(process)
    ));
    for e in events {
        out.push_str(",\n");
        out.push_str(&event_line(e));
    }
    if dropped > 0 {
        out.push_str(&format!(
            ",\n{{\"ph\":\"C\",\"pid\":0,\"tid\":0,\"ts\":0,\"cat\":\"telemetry\",\"name\":\"dropped_events\",\"args\":{{\"value\":{dropped},\"step\":-1}}}}"
        ));
    }
    out.push_str("\n]\n");
    out
}

/// Write `tel`'s ring to `path` as a Perfetto-loadable trace.
pub fn write_trace_file(path: &Path, tel: &Telemetry, process: &str) -> Result<()> {
    let body = chrome_trace_string(&tel.events(), process, tel.dropped());
    write_text(path, &body)
}

/// Write a text artifact, creating parent directories as needed.
pub fn write_text(path: &Path, body: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create telemetry dir {}", dir.display()))?;
    }
    std::fs::write(path, body).with_context(|| format!("write {}", path.display()))
}

/// Prometheus text-format builder. Histograms are emitted as cumulative
/// `_bucket{le=...}` series over the occupied log buckets plus `_sum` /
/// `_count`, with deterministic `quantile=...` gauges read from the same
/// bucket state (so the snapshot always matches `LatencyHist` readout).
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
    last_type: String,
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn type_line(&mut self, metric: &str, kind: &str) {
        let key = format!("{metric}/{kind}");
        if self.last_type != key {
            self.out.push_str(&format!("# TYPE {metric} {kind}\n"));
            self.last_type = key;
        }
    }

    fn labels(base: &[(&str, &str)], extra: Option<(&str, &str)>) -> String {
        let mut parts: Vec<String> = base
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", esc(v)))
            .collect();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{v}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }

    pub fn gauge(&mut self, metric: &str, labels: &[(&str, &str)], v: f64) {
        self.type_line(metric, "gauge");
        self.out
            .push_str(&format!("{metric}{} {}\n", Self::labels(labels, None), fmt_f64(v)));
    }

    pub fn counter_total(&mut self, metric: &str, labels: &[(&str, &str)], v: u64) {
        self.type_line(metric, "counter");
        self.out
            .push_str(&format!("{metric}{} {v}\n", Self::labels(labels, None)));
    }

    pub fn hist(&mut self, metric: &str, labels: &[(&str, &str)], h: &LatencyHist) {
        self.type_line(metric, "histogram");
        let mut cum = 0u64;
        for (i, c) in h.nonzero() {
            cum = cum.saturating_add(c);
            let le = LatencyHist::bucket_hi(i).to_string();
            self.out.push_str(&format!(
                "{metric}_bucket{} {cum}\n",
                Self::labels(labels, Some(("le", &le)))
            ));
        }
        self.out.push_str(&format!(
            "{metric}_bucket{} {}\n",
            Self::labels(labels, Some(("le", "+Inf"))),
            h.count()
        ));
        self.out
            .push_str(&format!("{metric}_sum{} {}\n", Self::labels(labels, None), h.sum_ns()));
        self.out
            .push_str(&format!("{metric}_count{} {}\n", Self::labels(labels, None), h.count()));
        for (q, v) in [("0.5", h.p50_ns()), ("0.95", h.p95_ns()), ("0.99", h.p99_ns())] {
            self.out.push_str(&format!(
                "{metric}{} {v}\n",
                Self::labels(labels, Some(("quantile", q)))
            ));
        }
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::clock::TestClock;

    fn sample_events() -> Telemetry {
        let t = Telemetry::with_clock(16, Box::new(TestClock::new(1_000_000)));
        let s0 = t.now_ns();
        t.span_from("phase", "forward", s0, 0, 0);
        t.counter("step", "loss", 1.25, 0);
        t.mark("fleet", "rejoin", 2, 4);
        t
    }

    #[test]
    fn trace_is_strict_json_and_stable() {
        let t = sample_events();
        let body = chrome_trace_string(&t.events(), "tezo test", t.dropped());
        let v = crate::jsonx::parse(&body).expect("trace must be strict JSON");
        let rows = v.as_array().expect("array");
        assert_eq!(rows.len(), 4); // metadata + 3 events
        assert_eq!(rows[1].get_str("ph").unwrap(), "X");
        assert_eq!(rows[1].get("args").unwrap().get_f64("dur_ns").unwrap(), 1e6);
        assert_eq!(rows[2].get_str("ph").unwrap(), "C");
        assert_eq!(rows[2].get("args").unwrap().get_f64("value").unwrap(), 1.25);
        // identical event sequences serialize byte-identically
        let t2 = sample_events();
        let body2 = chrome_trace_string(&t2.events(), "tezo test", t2.dropped());
        assert_eq!(body, body2);
    }

    #[test]
    fn non_finite_counter_serializes_as_null() {
        let t = Telemetry::with_clock(4, Box::new(TestClock::new(1)));
        t.counter("step", "loss", f64::NAN, 0);
        let body = chrome_trace_string(&t.events(), "x", 0);
        assert!(crate::jsonx::parse(&body).is_ok());
        assert!(body.contains("\"value\":null"));
    }

    #[test]
    fn prometheus_buckets_are_cumulative_and_quantiles_match() {
        let mut h = LatencyHist::new();
        for v in [100u64, 200, 300, 40_000] {
            h.record_ns(v);
        }
        let mut w = PromWriter::new();
        w.hist("tezo_phase_latency_ns", &[("phase", "forward")], &h);
        let txt = w.finish();
        assert!(txt.contains("# TYPE tezo_phase_latency_ns histogram"));
        assert!(txt.contains("le=\"+Inf\"} 4"));
        assert!(txt.contains(&format!("quantile=\"0.5\"}} {}", h.p50_ns())));
        assert!(txt.contains("tezo_phase_latency_ns_count{phase=\"forward\"} 4"));
    }
}
