//! Summary statistics used by metrics, benches, and analyses.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Quantile by linear interpolation on the sorted copy; q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Cosine similarity of two vectors (0 if either is ~zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    let denom = na.sqrt() * nb.sqrt();
    if denom < 1e-30 {
        0.0
    } else {
        dot / denom
    }
}

/// Simple exponential moving average helper (loss-curve smoothing; the
/// paper's Fig 4 uses a Gaussian filter — an EMA with matched bandwidth
/// produces the same qualitative curve).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(next);
        out.push(next);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn variance_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.571428).abs() < 1e-4);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ema_is_smoothing() {
        let xs = [0.0, 10.0, 0.0, 10.0];
        let s = ema(&xs, 0.5);
        assert_eq!(s[0], 0.0);
        assert!(s[3] > 3.0 && s[3] < 8.0);
    }
}
