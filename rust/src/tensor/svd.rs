//! Singular-value routines for the rank schedule and spectral analyses.
//!
//! * [`singular_values_exact`] — full spectrum via one-sided Jacobi on the
//!   Gram matrix (for matrices with min-dim up to a few hundred; used as the
//!   oracle in property tests and for the Fig 1/5/6 spectra).
//! * [`top_singular_values`] — randomized subspace iteration returning the
//!   top-k values (used by the Eq.(7) rank schedule on large weights).
//! * [`rank_at_threshold`] — #{sigma_i > threshold * sigma_max}, the
//!   definition the paper uses for Rank(W).

use anyhow::Result;

use super::Matrix;
use crate::rngx::normal_rng;

/// Jacobi eigenvalues of a symmetric matrix (in-place sweeps).
/// Returns eigenvalues sorted descending.
pub fn symmetric_eigenvalues(a: &Matrix, sweeps: usize) -> Vec<f64> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i * n + j;
    for _ in 0..sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[idx(i, j)] * m[idx(i, j)];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for k in 0..n {
                    let akp = m[idx(k, p)];
                    let akq = m[idx(k, q)];
                    m[idx(k, p)] = c * akp - s * akq;
                    m[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[idx(p, k)];
                    let aqk = m[idx(q, k)];
                    m[idx(p, k)] = c * apk - s * aqk;
                    m[idx(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| m[idx(i, i)]).collect();
    eig.sort_by(|a, b| b.total_cmp(a));
    eig
}

/// Full singular-value spectrum (descending) via Jacobi on the smaller Gram
/// matrix. Exact up to Jacobi convergence; O(min(m,n)^3) — use for analysis
/// and small oracles.
pub fn singular_values_exact(a: &Matrix) -> Vec<f64> {
    let gram = if a.rows >= a.cols { a.gram() } else { a.transpose().gram() };
    symmetric_eigenvalues(&gram, 30)
        .into_iter()
        .map(|e| e.max(0.0).sqrt())
        .collect()
}

/// Modified Gram-Schmidt QR: returns Q (same shape, orthonormal columns).
pub fn orthonormalize(a: &Matrix) -> Matrix {
    let mut q = a.clone();
    let (m, n) = (q.rows, q.cols);
    for j in 0..n {
        for i in 0..j {
            let mut dot = 0.0f64;
            for k in 0..m {
                dot += q.at(k, i) as f64 * q.at(k, j) as f64;
            }
            for k in 0..m {
                let v = q.at(k, i) * dot as f32;
                *q.at_mut(k, j) -= v;
            }
        }
        let mut norm = 0.0f64;
        for k in 0..m {
            norm += (q.at(k, j) as f64).powi(2);
        }
        let norm = norm.sqrt().max(1e-30) as f32;
        for k in 0..m {
            *q.at_mut(k, j) /= norm;
        }
    }
    q
}

/// Top-k singular values via randomized subspace iteration with
/// oversampling `p` and `iters` power steps.
pub fn top_singular_values(a: &Matrix, k: usize, seed: u64) -> Result<Vec<f64>> {
    let k = k.min(a.rows.min(a.cols));
    if k == 0 {
        return Ok(vec![]);
    }
    // small matrices: exact is cheaper and more accurate
    if a.rows.min(a.cols) <= 192 {
        let mut s = singular_values_exact(a);
        s.truncate(k);
        return Ok(s);
    }
    let p = (k / 2 + 8).min(a.cols.saturating_sub(k)).max(2);
    let l = (k + p).min(a.rows.min(a.cols));
    let mut gen = normal_rng(seed);
    let omega = Matrix::randn(a.cols, l, &mut gen);
    let at = a.transpose();
    let mut y = a.matmul(&omega)?; // (m, l)
    for _ in 0..3 {
        y = orthonormalize(&y);
        let z = at.matmul(&y)?; // (n, l)
        let zq = orthonormalize(&z);
        y = a.matmul(&zq)?;
    }
    let q = orthonormalize(&y); // (m, l)
    let b = q.transpose().matmul(a)?; // (l, n)
    let mut s = singular_values_exact(&b);
    s.truncate(k);
    Ok(s)
}

/// Paper's Rank(W): #{sigma_i > threshold * sigma_max}, at least 1.
/// `k_cap` bounds the work (ranks above the cap are clipped anyway by
/// Eq.(7)'s r_max).
pub fn rank_at_threshold(a: &Matrix, threshold: f64, k_cap: usize, seed: u64) -> Result<usize> {
    let k = (k_cap + 4).min(a.rows.min(a.cols));
    let s = top_singular_values(a, k, seed)?;
    if s.is_empty() || s[0] <= 0.0 {
        return Ok(1);
    }
    let cut = threshold * s[0];
    Ok(s.iter().filter(|&&x| x > cut).count().max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::normal_rng;

    #[test]
    fn exact_svd_of_diagonal() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [10.0f32, 5.0, 2.0, 0.5].iter().enumerate() {
            a.data[i * 4 + i] = *v;
        }
        let s = singular_values_exact(&a);
        for (got, want) in s.iter().zip([10.0, 5.0, 2.0, 0.5]) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn exact_svd_rank_one() {
        let mut g = normal_rng(0);
        let u = Matrix::randn(20, 1, &mut g);
        let v = Matrix::randn(15, 1, &mut g);
        let a = u.matmul(&v.transpose()).unwrap();
        let s = singular_values_exact(&a);
        assert!(s[0] > 0.1);
        assert!(s[1] < 1e-3 * s[0], "rank-1 matrix has tiny sigma_2: {:?}", &s[..3]);
    }

    #[test]
    fn orthonormalize_gives_orthonormal_columns() {
        let mut g = normal_rng(1);
        let a = Matrix::randn(30, 6, &mut g);
        let q = orthonormalize(&a);
        let gram = q.gram();
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((gram.at(i, j) - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn randomized_matches_exact_on_lowrank() {
        let mut g = normal_rng(2);
        // 256x200 matrix with planted rank-8 structure + small noise
        let u = Matrix::randn(256, 8, &mut g);
        let v = Matrix::randn(200, 8, &mut g);
        let mut a = u.matmul(&v.transpose()).unwrap();
        let noise = Matrix::randn(256, 200, &mut g);
        a.axpy(0.01, &noise).unwrap();
        let exact = singular_values_exact(&a);
        let fast = top_singular_values(&a, 8, 7).unwrap();
        for (f, e) in fast.iter().zip(exact.iter()) {
            assert!((f - e).abs() / e < 0.02, "{f} vs {e}");
        }
    }

    #[test]
    fn rank_threshold_detects_planted_rank() {
        let mut g = normal_rng(3);
        let u = Matrix::randn(120, 5, &mut g);
        let v = Matrix::randn(90, 5, &mut g);
        let mut a = u.matmul(&v.transpose()).unwrap();
        let noise = Matrix::randn(120, 90, &mut g);
        a.axpy(0.005, &noise).unwrap();
        let r = rank_at_threshold(&a, 0.25, 32, 11).unwrap();
        assert!((3..=7).contains(&r), "rank {r}");
    }
}
