//! Host linear-algebra substrate.
//!
//! Row-major f32 [`Matrix`] with the operations the coordinator needs:
//! matmul / transpose / axpy for oracles, [`svd::top_singular_values`]
//! (randomized subspace iteration) for the Eq.(7) rank schedule and the
//! Fig 1/5/6/7 spectral analyses, and [`stats`] summaries for metrics.
//!
//! This is deliberately *host* math: the request path runs on PJRT; these
//! routines serve analysis, verification oracles, and O(r) optimizer-state
//! updates.

mod matrix;
pub mod stats;
pub mod svd;

pub use matrix::Matrix;
