//! Row-major f32 matrix with the ops used by oracles and analyses.

use anyhow::{ensure, Result};

use crate::rngx::NormalGen;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        ensure!(data.len() == rows * cols,
                "data len {} != {rows}x{cols}", data.len());
        Ok(Self { rows, cols, data })
    }

    /// i.i.d. standard-normal entries from the given generator.
    pub fn randn(rows: usize, cols: usize, gen: &mut NormalGen) -> Self {
        let mut m = Self::zeros(rows, cols);
        for x in m.data.iter_mut() {
            *x = gen.next_f32();
        }
        m
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.at(i, j);
            }
        }
        t
    }

    /// `self @ other` — blocked ikj loop; f64 accumulation for stability.
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        ensure!(self.cols == other.rows,
                "matmul dims {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    /// `self + alpha * other` in place.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) -> Result<()> {
        ensure!(self.rows == other.rows && self.cols == other.cols, "axpy shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
        Ok(())
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Gram matrix `self^T @ self` (used by the SVD routines).
    pub fn gram(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols, self.cols);
        for i in 0..self.rows {
            let r = self.row(i);
            for a in 0..self.cols {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = &mut g.data[a * self.cols..(a + 1) * self.cols];
                for (gv, &rb) in grow.iter_mut().zip(r.iter()) {
                    *gv += ra * rb;
                }
            }
        }
        g
    }

    /// TeZO reconstruction: `U diag(tau) V^T` (host oracle for runtime tests).
    pub fn cpd_slice(u: &Matrix, v: &Matrix, tau: &[f32]) -> Result<Matrix> {
        ensure!(u.cols == v.cols && u.cols == tau.len(), "cpd rank mismatch");
        let mut out = Matrix::zeros(u.rows, v.rows);
        for s in 0..tau.len() {
            let t = tau[s];
            if t == 0.0 {
                continue;
            }
            for i in 0..u.rows {
                let ui = u.at(i, s) * t;
                let orow = &mut out.data[i * v.rows..(i + 1) * v.rows];
                for (o, j) in orow.iter_mut().zip(0..v.rows) {
                    *o += ui * v.at(j, s);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::normal_rng;

    #[test]
    fn matmul_identity() {
        let mut g = normal_rng(1);
        let a = Matrix::randn(5, 7, &mut g);
        let i = Matrix::identity(7);
        assert_eq!(a.matmul(&i).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut g = normal_rng(2);
        let a = Matrix::randn(4, 9, &mut g);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut g = normal_rng(3);
        let a = Matrix::randn(6, 4, &mut g);
        let want = a.transpose().matmul(&a).unwrap();
        let got = a.gram();
        for (x, y) in got.data.iter().zip(want.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn cpd_slice_matches_naive() {
        let mut g = normal_rng(4);
        let u = Matrix::randn(5, 3, &mut g);
        let v = Matrix::randn(7, 3, &mut g);
        let tau = [0.5f32, -1.0, 2.0];
        let got = Matrix::cpd_slice(&u, &v, &tau).unwrap();
        for i in 0..5 {
            for j in 0..7 {
                let mut want = 0.0f32;
                for s in 0..3 {
                    want += tau[s] * u.at(i, s) * v.at(j, s);
                }
                assert!((got.at(i, j) - want).abs() < 1e-5);
            }
        }
    }
}
