//! JSON serialization (pretty, 1-space indent like the python manifest).

use super::Value;

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                if f.fract() == 0.0 && f.abs() < 1e15 {
                    // keep a float marker so parse() round-trips the type
                    out.push_str(&format!("{f:.1}"));
                } else {
                    out.push_str(&format!("{f}"));
                }
            } else {
                out.push_str("null"); // JSON has no NaN/Inf
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                push_indent(indent + 1, out);
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push(']');
        }
        Value::Object(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                push_indent(indent + 1, out);
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push('}');
        }
    }
}

fn push_indent(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push(' ');
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}
