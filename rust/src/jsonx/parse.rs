//! Recursive-descent JSON parser (strict; RFC 8259 subset we emit/consume).

use anyhow::{anyhow, bail, Result};

use super::Value;

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        if got != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at byte {}", c as char, self.i),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        let rest = self.b.get(self.i..).unwrap_or(&[]);
        if rest.starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect_byte(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Object(out)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Array(out)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.bump()?;
            match c {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            self.expect_byte(b'\\')?;
                            self.expect_byte(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?);
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                    }
                    e => bail!("invalid escape \\{}", e as char),
                },
                0x20.. => {
                    // copy the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    if start + len > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    self.i = start + len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| anyhow!("invalid UTF-8"))?,
                    );
                }
                _ => bail!("control character in string"),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = (c as char).to_digit(16).ok_or_else(|| anyhow!("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            let d0 = self.i;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
            if self.i == d0 {
                bail!("missing digits after '.'");
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            let d0 = self.i;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
            if self.i == d0 {
                bail!("missing exponent digits");
            }
        }
        let bytes = self
            .b
            .get(start..self.i)
            .ok_or_else(|| anyhow!("number span out of range at byte {start}"))?;
        let text =
            std::str::from_utf8(bytes).map_err(|_| anyhow!("non-ASCII number at byte {start}"))?;
        if text.is_empty() || text == "-" {
            bail!("invalid number at byte {start}");
        }
        if is_float {
            Ok(Value::Float(text.parse::<f64>().map_err(|e| anyhow!("bad float: {e}"))?))
        } else {
            match text.parse::<i64>() {
                Ok(i) => Ok(Value::Int(i)),
                Err(_) => Ok(Value::Float(
                    text.parse::<f64>().map_err(|e| anyhow!("bad number: {e}"))?,
                )),
            }
        }
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7F => Ok(1),
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte"),
    }
}
