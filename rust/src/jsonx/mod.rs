//! JSON substrate (the offline registry has no `serde`/`serde_json`).
//!
//! A small, strict JSON parser + writer sufficient for manifest.json and
//! metric/report emission. Parses into a [`Value`] tree with typed accessors
//! that return `anyhow` errors carrying the access path.

mod parse;
mod value;
mod write;

pub use parse::parse;
pub use value::Value;
pub use write::to_string_pretty;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        assert!(v.get("b").unwrap().get("d").unwrap().as_bool().unwrap());
        assert!(v.get("b").unwrap().get("e").unwrap().is_null());
        // re-serialize and re-parse: must be identical trees
        let txt = to_string_pretty(&v);
        let v2 = parse(&txt).unwrap();
        assert_eq!(to_string_pretty(&v2), txt);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "\"\\q\"", "1.2.3", ""] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn integers_preserved() {
        let v = parse("[0, 9007199254740993, -12]").unwrap();
        assert_eq!(v.idx(1).unwrap().as_i64().unwrap(), 9007199254740993);
        assert_eq!(v.idx(2).unwrap().as_i64().unwrap(), -12);
    }
}
