//! JSON value tree + typed accessors.

use anyhow::{anyhow, Result};

/// A parsed JSON value. Numbers keep an integer/float distinction so that
/// shape/seed fields survive exactly.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {}", other.kind())),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Float(f) if f.fract() == 0.0 => Ok(*f as i64),
            other => Err(anyhow!("expected integer, got {}", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| anyhow!("negative integer {i}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            other => Err(anyhow!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {}", other.kind())),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => Err(anyhow!("expected array, got {}", other.kind())),
        }
    }

    pub fn as_object(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Object(o) => Ok(o),
            other => Err(anyhow!("expected object, got {}", other.kind())),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Value> {
        let obj = self.as_object()?;
        obj.iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Array index lookup.
    pub fn idx(&self, i: usize) -> Result<&Value> {
        let arr = self.as_array()?;
        arr.get(i).ok_or_else(|| anyhow!("index {i} out of bounds ({})", arr.len()))
    }

    /// Convenience: `get(key)` then `as_usize`.
    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.as_usize().map_err(|e| anyhow!("{key}: {e}"))
    }

    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.get(key)?.as_str().map_err(|e| anyhow!("{key}: {e}"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.as_f64().map_err(|e| anyhow!("{key}: {e}"))
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }

    // ---- builders for report emission ---------------------------------

    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Value>) -> Value {
        Value::Array(items)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn f(x: f64) -> Value {
        Value::Float(x)
    }

    pub fn i(x: i64) -> Value {
        Value::Int(x)
    }
}
