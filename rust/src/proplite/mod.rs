//! Property-testing substrate (the offline registry has no `proptest`).
//!
//! Minimal but genuinely useful: seeded generators, a runner that reports
//! the failing seed + case index, and input shrinking for the common
//! numeric/vector generators (halving toward a minimal failing case).
//!
//! ```ignore
//! proplite::run(100, |g| {
//!     let n = g.usize_in(1..64);
//!     let v = g.vec_f32(n, -1.0..1.0);
//!     prop_assert(v.len() == n, "len")
//! });
//! ```

use crate::rngx::{NormalGen, SplitMix64, Xoshiro256};

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Xoshiro256,
    normal: NormalGen,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::seed_from(seed),
            normal: NormalGen::new(Xoshiro256::seed_from(seed ^ 0xABCD_EF01)),
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end);
        range.start + self.rng.index(range.end - range.start)
    }

    pub fn f64_in(&mut self, range: std::ops::Range<f64>) -> f64 {
        range.start + self.rng.next_f64() * (range.end - range.start)
    }

    pub fn f32_in(&mut self, range: std::ops::Range<f32>) -> f32 {
        self.f64_in(range.start as f64..range.end as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal.next_f32()
    }

    pub fn vec_f32(&mut self, n: usize, range: std::ops::Range<f32>) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(range.clone())).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Property outcome.
pub type PropResult = Result<(), String>;

/// Assertion helper.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Approximate-equality assertion helper.
pub fn prop_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed so the
/// case can be replayed with [`replay`]. Base seed comes from
/// `TEZO_PROP_SEED` (default 0xC0FFEE) for reproducible CI.
pub fn run<F>(cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base: u64 = std::env::var("TEZO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0_FFEE);
    for case in 0..cases {
        let seed = SplitMix64::mix(base, case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with proplite::replay({seed:#x}, prop)"
            );
        }
    }
}

/// Replay one specific failing seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_passes_trivial_property() {
        run(50, |g| {
            let n = g.usize_in(1..10);
            prop_assert(n >= 1 && n < 10, "range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn run_reports_failure_with_seed() {
        run(50, |g| {
            let x = g.f64_in(0.0..1.0);
            prop_assert(x < 0.95, "x too large")
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}
