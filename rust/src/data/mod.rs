//! Dataset substrate: synthetic tasks + corpus shaped like the paper's
//! evaluation suite.
//!
//! The paper fine-tunes pretrained LLMs on 16 GLUE/SuperGLUE/QA datasets
//! with the MeZO protocol (classification-as-LM: the prompt ends in a
//! verbalizer slot; the loss is the LM loss at that slot; accuracy is the
//! argmax over per-class verbalizer tokens). Offline we reproduce the
//! *protocol* exactly and substitute the text with planted-signal synthetic
//! tasks ([`tasks`]): each class is correlated with a set of signal tokens,
//! so fine-tuning has a real, learnable objective and optimizers separate by
//! convergence speed. DESIGN.md §2 documents the substitution.
//!
//! [`corpus`] provides a Markov-chain language for the end-to-end LM
//! training driver; [`tokenizer`] owns the vocabulary layout shared by all
//! of it.

pub mod batch;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use batch::{Batch, BatchBuilder};
pub use corpus::Corpus;
pub use tasks::{Example, Task, TaskSpec, ALL_TASKS};
pub use tokenizer::Tokenizer;
