//! Synthetic corpus for the end-to-end LM training driver.
//!
//! A first-order Markov chain over the word region with Zipf-ish unigram
//! marginals: enough sequential structure that a causal LM has real bits to
//! learn (loss drops substantially below the uniform baseline), generated
//! deterministically so runs reproduce.

use crate::rngx::{SplitMix64, Xoshiro256};

use super::tokenizer::{Tokenizer, BOS, PAD};

/// Markov-chain corpus generator.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub tok: Tokenizer,
    pub seq_len: usize,
    seed: u64,
    /// per-state successor tables: state -> K candidate next words
    branch: usize,
    states: usize,
}

impl Corpus {
    pub fn new(tok: Tokenizer, seq_len: usize, seed: u64) -> Self {
        let states = tok.n_words().min(4096);
        Self { tok, seq_len, seed, branch: 8, states }
    }

    /// The successor table of `state` (deterministic function).
    fn successors(&self, state: usize) -> Vec<usize> {
        let mut rng =
            Xoshiro256::seed_from(SplitMix64::mix(self.seed ^ CORPUS_SALT, state as u64));
        (0..self.branch).map(|_| rng.index(self.states)).collect()
    }

    /// Sequence `index`: (tokens, targets, mask) padded to seq_len.
    pub fn sequence(&self, index: u64) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from(SplitMix64::mix(self.seed, index));
        let mut tokens = Vec::with_capacity(self.seq_len);
        tokens.push(BOS);
        let mut state = rng.index(self.states);
        for _ in 1..self.seq_len {
            tokens.push(self.tok.word_token(state));
            let succ = self.successors(state);
            // Zipf-ish: lower branch indices much more likely
            let u = rng.next_f64();
            let pick = ((self.branch as f64).powf(u) - 1.0) as usize;
            state = succ[pick.min(self.branch - 1)];
        }
        let mut targets = vec![PAD; self.seq_len];
        let mut mask = vec![0.0f32; self.seq_len];
        for i in 0..self.seq_len - 1 {
            targets[i] = tokens[i + 1];
            mask[i] = 1.0;
        }
        (tokens, targets, mask)
    }
}

/// Seed salt separating the transition-table stream from the data stream.
const CORPUS_SALT: u64 = 0x1234_5678_9ABC_DEF0;

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(Tokenizer::new(2048), 64, 1)
    }

    #[test]
    fn sequences_are_deterministic() {
        let c = corpus();
        assert_eq!(c.sequence(3), c.sequence(3));
        assert_ne!(c.sequence(3).0, c.sequence(4).0);
    }

    #[test]
    fn structure_is_learnable() {
        // bigram entropy must be far below unigram entropy: count distinct
        // successors actually observed per state
        let c = corpus();
        use std::collections::{HashMap, HashSet};
        let mut succ: HashMap<i32, HashSet<i32>> = HashMap::new();
        for idx in 0..200 {
            let (toks, _, _) = c.sequence(idx);
            for w in toks.windows(2) {
                if w[0] >= 11 && w[1] >= 11 {
                    succ.entry(w[0]).or_default().insert(w[1]);
                }
            }
        }
        let avg: f64 = succ.values().map(|s| s.len() as f64).sum::<f64>() / succ.len() as f64;
        assert!(avg <= c.branch as f64 + 1.0, "avg successors {avg} too high");
    }

    #[test]
    fn mask_covers_all_but_last() {
        let c = corpus();
        let (_, _, mask) = c.sequence(0);
        assert_eq!(mask.iter().filter(|&&m| m > 0.0).count(), c.seq_len - 1);
    }
}
