//! The 16 synthetic evaluation tasks (paper Tables 3/4/5 datasets).
//!
//! Each task plants per-class *signal tokens*: an example of class `c` mixes
//! background words with signal words drawn from class `c`'s signal set. The
//! label verbalizer follows a SEP marker, MeZO-style, and the LM loss is
//! taken at the SEP position only. Difficulty is controlled by the signal
//! fraction and the signal-set overlap; the per-task shapes (class count,
//! prompt length) mirror the original datasets.

use crate::rngx::{SplitMix64, Xoshiro256};

use super::tokenizer::{Tokenizer, BOS, PAD, SEP};

/// Static description of one task.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub n_classes: usize,
    /// words in the prompt body
    pub prompt_len: usize,
    /// fraction of prompt words drawn from the class signal set
    pub signal_frac: f64,
    /// signal words per class
    pub signal_words: usize,
    /// which paper table the task appears in (3, 4, or 5)
    pub table: u8,
}

/// The 16 datasets of the paper, shaped like the originals (class counts;
/// longer prompts for the QA-style sets). Generation-style tasks (ReCoRD,
/// SQuAD, DROP) are represented as verbalized classification over candidate
/// answers, which is how their ZO accuracy is scored in our harness.
pub const ALL_TASKS: [TaskSpec; 16] = [
    TaskSpec { name: "sst2", n_classes: 2, prompt_len: 24, signal_frac: 0.30, signal_words: 12, table: 4 },
    TaskSpec { name: "sst5", n_classes: 5, prompt_len: 24, signal_frac: 0.35, signal_words: 12, table: 3 },
    TaskSpec { name: "snli", n_classes: 3, prompt_len: 36, signal_frac: 0.30, signal_words: 14, table: 3 },
    TaskSpec { name: "mnli", n_classes: 3, prompt_len: 40, signal_frac: 0.28, signal_words: 14, table: 3 },
    TaskSpec { name: "qnli", n_classes: 2, prompt_len: 40, signal_frac: 0.26, signal_words: 12, table: 3 },
    TaskSpec { name: "trec", n_classes: 6, prompt_len: 16, signal_frac: 0.40, signal_words: 10, table: 3 },
    TaskSpec { name: "rte", n_classes: 2, prompt_len: 44, signal_frac: 0.24, signal_words: 12, table: 4 },
    TaskSpec { name: "cb", n_classes: 3, prompt_len: 44, signal_frac: 0.30, signal_words: 10, table: 4 },
    TaskSpec { name: "boolq", n_classes: 2, prompt_len: 52, signal_frac: 0.22, signal_words: 12, table: 4 },
    TaskSpec { name: "wsc", n_classes: 2, prompt_len: 28, signal_frac: 0.18, signal_words: 8, table: 4 },
    TaskSpec { name: "wic", n_classes: 2, prompt_len: 30, signal_frac: 0.20, signal_words: 8, table: 4 },
    TaskSpec { name: "multirc", n_classes: 2, prompt_len: 56, signal_frac: 0.20, signal_words: 12, table: 4 },
    TaskSpec { name: "copa", n_classes: 2, prompt_len: 20, signal_frac: 0.34, signal_words: 8, table: 4 },
    TaskSpec { name: "record", n_classes: 4, prompt_len: 56, signal_frac: 0.26, signal_words: 12, table: 4 },
    TaskSpec { name: "squad", n_classes: 4, prompt_len: 60, signal_frac: 0.26, signal_words: 12, table: 4 },
    TaskSpec { name: "drop", n_classes: 4, prompt_len: 60, signal_frac: 0.18, signal_words: 10, table: 4 },
];

pub fn spec_by_name(name: &str) -> Option<&'static TaskSpec> {
    ALL_TASKS.iter().find(|t| t.name == name)
}

/// One encoded example.
#[derive(Clone, Debug)]
pub struct Example {
    /// padded token row, length = seq_len; includes the gold label token
    /// after SEP (teacher forcing for training)
    pub tokens: Vec<i32>,
    /// next-token targets (tokens shifted left; PAD beyond)
    pub targets: Vec<i32>,
    /// 1.0 exactly at the SEP position (predicting the verbalizer)
    pub mask: Vec<f32>,
    /// position of SEP (where eval reads logits)
    pub sep_pos: usize,
    pub label: usize,
}

/// A materialized task bound to a tokenizer + sequence length.
#[derive(Clone, Debug)]
pub struct Task {
    pub spec: &'static TaskSpec,
    pub tok: Tokenizer,
    pub seq_len: usize,
    /// per-class signal word ids
    signal: Vec<Vec<i32>>,
    /// task-level seed
    seed: u64,
}

impl Task {
    pub fn new(spec: &'static TaskSpec, tok: Tokenizer, seq_len: usize, seed: u64) -> Self {
        let task_seed = SplitMix64::mix(seed, fnv(spec.name));
        let mut rng = Xoshiro256::seed_from(task_seed);
        // disjoint-ish signal sets per class
        let mut signal = Vec::with_capacity(spec.n_classes);
        for c in 0..spec.n_classes {
            let mut words = Vec::with_capacity(spec.signal_words);
            for w in 0..spec.signal_words {
                // deterministic per (class, w) with random offset per task
                let base = rng.index(tok.n_words() / 2);
                words.push(tok.word_token(base * 2 + (c + w) % 2));
            }
            signal.push(words);
        }
        Self { spec, tok, seq_len, signal, seed: task_seed }
    }

    /// Deterministically generate example `index` of `split` (0=train,1=eval).
    pub fn example(&self, split: u32, index: u64) -> Example {
        let ex_seed = SplitMix64::mix(self.seed ^ (split as u64) << 32, index);
        let mut rng = Xoshiro256::seed_from(ex_seed);
        let label = rng.index(self.spec.n_classes);
        let body_len = self.spec.prompt_len.min(self.seq_len.saturating_sub(4));
        let mut tokens = Vec::with_capacity(self.seq_len);
        tokens.push(BOS);
        for _ in 0..body_len {
            let is_signal = rng.next_f64() < self.spec.signal_frac;
            if is_signal {
                let sig = &self.signal[label];
                tokens.push(sig[rng.index(sig.len())]);
            } else {
                tokens.push(self.tok.word_token(rng.index(self.tok.n_words())));
            }
        }
        let sep_pos = tokens.len();
        tokens.push(SEP);
        tokens.push(self.tok.label_token(label));
        // pad
        while tokens.len() < self.seq_len {
            tokens.push(PAD);
        }
        tokens.truncate(self.seq_len);
        // next-token targets + mask at sep
        let mut targets = vec![PAD; self.seq_len];
        for i in 0..self.seq_len - 1 {
            targets[i] = tokens[i + 1];
        }
        let mut mask = vec![0.0f32; self.seq_len];
        if sep_pos < self.seq_len {
            mask[sep_pos] = 1.0;
        }
        Example { tokens, targets, mask, sep_pos, label }
    }

    /// Eval-time variant: label token replaced by PAD (no leakage).
    pub fn eval_example(&self, index: u64) -> Example {
        let mut ex = self.example(1, index);
        if ex.sep_pos + 1 < ex.tokens.len() {
            ex.tokens[ex.sep_pos + 1] = PAD;
        }
        ex
    }

    /// The candidate verbalizer token ids for accuracy scoring.
    pub fn label_tokens(&self) -> Vec<i32> {
        (0..self.spec.n_classes).map(|c| self.tok.label_token(c)).collect()
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(name: &str) -> Task {
        Task::new(spec_by_name(name).unwrap(), Tokenizer::new(512), 64, 0)
    }

    #[test]
    fn sixteen_tasks_match_paper_inventory() {
        assert_eq!(ALL_TASKS.len(), 16);
        let t3: Vec<_> = ALL_TASKS.iter().filter(|t| t.table == 3).collect();
        assert_eq!(t3.len(), 5); // Table 3: SST-5, SNLI, MNLI, QNLI, TREC
    }

    #[test]
    fn examples_are_deterministic() {
        let t = task("sst2");
        let a = t.example(0, 7);
        let b = t.example(0, 7);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.label, b.label);
        let c = t.example(0, 8);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn example_encodes_protocol() {
        let t = task("snli");
        let ex = t.example(0, 3);
        assert_eq!(ex.tokens[0], BOS);
        assert_eq!(ex.tokens[ex.sep_pos], SEP);
        assert_eq!(ex.tokens[ex.sep_pos + 1], t.tok.label_token(ex.label));
        // mask selects exactly the SEP position
        assert_eq!(ex.mask.iter().filter(|&&m| m > 0.0).count(), 1);
        assert!(ex.mask[ex.sep_pos] > 0.0);
        // target at SEP is the label token
        assert_eq!(ex.targets[ex.sep_pos], t.tok.label_token(ex.label));
    }

    #[test]
    fn eval_example_hides_label() {
        let t = task("rte");
        let ex = t.eval_example(5);
        assert_eq!(ex.tokens[ex.sep_pos + 1], PAD);
    }

    #[test]
    fn signal_tokens_differ_by_class() {
        let t = task("sst2");
        // count signal-set overlap between the two classes
        let s0: std::collections::HashSet<_> = t.signal[0].iter().collect();
        let overlap = t.signal[1].iter().filter(|w| s0.contains(w)).count();
        assert!(overlap < t.spec.signal_words, "classes fully overlap");
    }

    #[test]
    fn labels_are_roughly_balanced() {
        let t = task("sst2");
        let n = 2000;
        let ones = (0..n).filter(|&i| t.example(0, i).label == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "label balance {frac}");
    }
}
