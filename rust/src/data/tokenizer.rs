//! Vocabulary layout + word-piece-lite tokenizer.
//!
//! Layout within a vocab of size `V` (V comes from the model manifest):
//!   0            PAD
//!   1            BOS
//!   2            SEP (the verbalizer slot marker)
//!   3..3+C_MAX   verbalizer/label tokens (one per class, C_MAX = 8)
//!   11..V        word tokens
//!
//! Synthetic words are strings; [`Tokenizer::word_id`] maps them into the
//! word region deterministically (FNV-1a hash). This is the piece of a real
//! tokenizer the protocol needs: a stable string->id map with reserved
//! specials.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const LABEL_BASE: i32 = 3;
pub const MAX_CLASSES: usize = 8;
pub const WORD_BASE: i32 = LABEL_BASE + MAX_CLASSES as i32;

/// Deterministic tokenizer over a fixed-size vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab as i32 > WORD_BASE + 16, "vocab too small: {vocab}");
        Self { vocab }
    }

    /// Number of distinct word tokens.
    pub fn n_words(&self) -> usize {
        self.vocab - WORD_BASE as usize
    }

    /// Label token for class `c`.
    pub fn label_token(&self, c: usize) -> i32 {
        assert!(c < MAX_CLASSES);
        LABEL_BASE + c as i32
    }

    /// Map a word string into the word region (FNV-1a, stable).
    pub fn word_id(&self, word: &str) -> i32 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in word.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        WORD_BASE + (h % self.n_words() as u64) as i32
    }

    /// Word token for an integer "word index" (synthetic streams).
    pub fn word_token(&self, idx: usize) -> i32 {
        WORD_BASE + (idx % self.n_words()) as i32
    }

    /// Is `tok` a padding token?
    pub fn is_pad(&self, tok: i32) -> bool {
        tok == PAD
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserved_regions_disjoint() {
        let t = Tokenizer::new(256);
        for c in 0..MAX_CLASSES {
            let l = t.label_token(c);
            assert!(l >= LABEL_BASE && l < WORD_BASE);
        }
        assert!(t.word_id("hello") >= WORD_BASE);
        assert!(t.word_token(0) >= WORD_BASE);
        assert!((t.word_token(12345) as usize) < t.vocab);
    }

    #[test]
    fn word_id_is_stable() {
        let t = Tokenizer::new(2048);
        assert_eq!(t.word_id("gradient"), t.word_id("gradient"));
        assert_ne!(t.word_id("gradient"), t.word_id("hessian"));
    }
}
