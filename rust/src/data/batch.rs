//! Few-shot sampling and batching (the paper's k=16 / k=512 protocol).

use crate::rngx::{SplitMix64, Xoshiro256};

use super::corpus::Corpus;
use super::tasks::Task;

/// One model-ready batch (row-major, shapes [B, S]).
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    /// SEP positions (eval) — one per row
    pub positions: Vec<i32>,
    /// gold labels — one per row (classification tasks)
    pub labels: Vec<usize>,
}

impl Batch {
    pub fn empty(batch: usize, seq_len: usize) -> Self {
        Self {
            batch,
            seq_len,
            tokens: vec![0; batch * seq_len],
            targets: vec![0; batch * seq_len],
            mask: vec![0.0; batch * seq_len],
            positions: vec![0; batch],
            labels: vec![0; batch],
        }
    }

    fn set_row(&mut self, row: usize, tokens: &[i32], targets: &[i32], mask: &[f32],
               pos: usize, label: usize) {
        let s = self.seq_len;
        self.tokens[row * s..(row + 1) * s].copy_from_slice(tokens);
        self.targets[row * s..(row + 1) * s].copy_from_slice(targets);
        self.mask[row * s..(row + 1) * s].copy_from_slice(mask);
        self.positions[row] = pos as i32;
        self.labels[row] = label;
    }
}

/// Few-shot training pool + batch sampler for one task.
///
/// `k` examples **per class** form the training pool (the paper's k=16 /
/// k=512 settings); batches sample uniformly from the pool with the step
/// seed, so the whole data order is reproducible from the master seed.
#[derive(Clone, Debug)]
pub struct BatchBuilder {
    pub task: Task,
    pub batch: usize,
    pub k_shot: usize,
    /// train-pool example indices (k per class, deterministic)
    pub pool: Vec<u64>,
}

impl BatchBuilder {
    pub fn new(task: Task, batch: usize, k_shot: usize) -> Self {
        // scan split-0 example indices until k per class are collected
        let classes = task.spec.n_classes;
        let mut per_class = vec![0usize; classes];
        let mut pool = Vec::with_capacity(classes * k_shot);
        let mut idx = 0u64;
        while pool.len() < classes * k_shot && idx < (classes * k_shot * 64) as u64 {
            let ex = task.example(0, idx);
            if per_class[ex.label] < k_shot {
                per_class[ex.label] += 1;
                pool.push(idx);
            }
            idx += 1;
        }
        Self { task, batch, k_shot, pool }
    }

    /// Training batch for `step` (seeded by `master_seed`).
    pub fn train_batch(&self, master_seed: u64, step: u64) -> Batch {
        let mut rng = Xoshiro256::seed_from(SplitMix64::mix(master_seed ^ 0xBA7C, step));
        let mut b = Batch::empty(self.batch, self.task.seq_len);
        for row in 0..self.batch {
            let pick = self.pool[rng.index(self.pool.len())];
            let ex = self.task.example(0, pick);
            b.set_row(row, &ex.tokens, &ex.targets, &ex.mask, ex.sep_pos, ex.label);
        }
        b
    }

    /// Deterministic eval batches covering `n_eval` held-out examples.
    pub fn eval_batches(&self, n_eval: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0u64;
        while (i as usize) < n_eval {
            let mut b = Batch::empty(self.batch, self.task.seq_len);
            for row in 0..self.batch {
                let ex = self.task.eval_example(i);
                b.set_row(row, &ex.tokens, &ex.targets, &ex.mask, ex.sep_pos, ex.label);
                i += 1;
            }
            out.push(b);
        }
        out
    }

    /// LM batch from a corpus (end-to-end driver).
    pub fn corpus_batch(corpus: &Corpus, batch: usize, master_seed: u64, step: u64) -> Batch {
        let mut b = Batch::empty(batch, corpus.seq_len);
        for row in 0..batch {
            let idx = SplitMix64::mix(master_seed, step * batch as u64 + row as u64);
            let (tokens, targets, mask) = corpus.sequence(idx % (1 << 20));
            b.set_row(row, &tokens, &targets, &mask, corpus.seq_len - 1, 0);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::spec_by_name;
    use crate::data::tokenizer::Tokenizer;

    fn builder(k: usize) -> BatchBuilder {
        let task = Task::new(spec_by_name("sst2").unwrap(), Tokenizer::new(512), 64, 0);
        BatchBuilder::new(task, 4, k)
    }

    #[test]
    fn pool_is_class_balanced() {
        let bb = builder(16);
        assert_eq!(bb.pool.len(), 32);
        let labels: Vec<usize> = bb.pool.iter().map(|&i| bb.task.example(0, i).label).collect();
        assert_eq!(labels.iter().filter(|&&l| l == 0).count(), 16);
        assert_eq!(labels.iter().filter(|&&l| l == 1).count(), 16);
    }

    #[test]
    fn train_batches_are_reproducible() {
        let bb = builder(16);
        let a = bb.train_batch(42, 3);
        let b = bb.train_batch(42, 3);
        assert_eq!(a.tokens, b.tokens);
        let c = bb.train_batch(42, 4);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn eval_batches_cover_requested_count() {
        let bb = builder(4);
        let evs = bb.eval_batches(10);
        assert_eq!(evs.len(), 3); // ceil(10/4)
        // eval rows never contain the gold label after SEP
        for b in &evs {
            for row in 0..b.batch {
                let pos = b.positions[row] as usize;
                assert_eq!(b.tokens[row * b.seq_len + pos + 1], 0);
            }
        }
    }

    #[test]
    fn batch_rows_match_examples() {
        let bb = builder(8);
        let b = bb.train_batch(7, 0);
        assert_eq!(b.tokens.len(), 4 * 64);
        for row in 0..4 {
            let pos = b.positions[row] as usize;
            assert!(b.mask[row * 64 + pos] > 0.0);
        }
    }
}
