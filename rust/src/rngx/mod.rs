//! Deterministic RNG substrate (the offline registry has no `rand` crate).
//!
//! * [`SplitMix64`] — seeding / stream derivation (also used by the seed
//!   schedule in [`crate::coordinator::seeds`]).
//! * [`Xoshiro256`] — xoshiro256++ bulk generator.
//! * Gaussian sampling via the polar (Marsaglia) method with cached spare.
//!
//! Everything is reproducible from a single u64 seed; the training loop's
//! statistical tests (Theorem 1 validation) and the proplite harness both
//! run on these generators.

mod normal;
mod xoshiro;

pub use normal::NormalGen;
pub use xoshiro::{SplitMix64, Xoshiro256};

/// Convenience: a seeded Gaussian generator.
pub fn normal_rng(seed: u64) -> NormalGen {
    NormalGen::new(Xoshiro256::seed_from(seed))
}

/// Fill a slice with standard normals from `seed` (one-shot helper).
pub fn fill_normal(seed: u64, out: &mut [f32]) {
    let mut g = normal_rng(seed);
    for x in out.iter_mut() {
        *x = g.next_f32();
    }
}

/// A fresh vector of `n` standard normals from `seed`.
pub fn normal_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    fill_normal(seed, &mut v);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a = normal_vec(42, 64);
        let b = normal_vec(42, 64);
        assert_eq!(a, b);
        let c = normal_vec(43, 64);
        assert_ne!(a, c);
    }

    #[test]
    fn normal_moments_are_sane() {
        let v = normal_vec(7, 200_000);
        let n = v.len() as f64;
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 = v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn xoshiro_uniformity_buckets() {
        let mut rng = Xoshiro256::seed_from(1);
        let mut buckets = [0usize; 16];
        for _ in 0..160_000 {
            buckets[(rng.next_u64() >> 60) as usize] += 1;
        }
        for &b in &buckets {
            assert!((b as f64 - 10_000.0).abs() < 500.0, "bucket {b}");
        }
    }
}
