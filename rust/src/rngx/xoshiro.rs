//! xoshiro256++ and splitmix64 (Blackman & Vigna reference constants).

/// splitmix64 — used to expand a single u64 seed into generator state and to
/// derive per-step / per-layer seed streams without correlation.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Stateless mix: hash two words into one (seed derivation).
    #[inline]
    pub fn mix(a: u64, b: u64) -> u64 {
        let mut s = SplitMix64::new(a ^ b.rotate_left(32).wrapping_mul(0xD6E8_FEB8_6659_FD93));
        s.next_u64()
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 (the canonical seeding recipe).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // all-zero state is invalid (cannot happen from splitmix64, but be safe)
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire rejection).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // first outputs for seed 0 (reference implementation)
        let mut s = SplitMix64::new(0);
        assert_eq!(s.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(s.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Xoshiro256::seed_from(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = rng.next_below(7);
            assert!(x < 7);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Xoshiro256::seed_from(9);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
