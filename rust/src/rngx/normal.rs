//! Gaussian sampling: Marsaglia polar method with cached spare.

use super::Xoshiro256;

/// Standard-normal generator over a [`Xoshiro256`] stream.
#[derive(Clone, Debug)]
pub struct NormalGen {
    rng: Xoshiro256,
    spare: Option<f64>,
}

impl NormalGen {
    pub fn new(rng: Xoshiro256) -> Self {
        Self { rng, spare: None }
    }

    /// One standard-normal draw (f64 internal precision).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.rng.next_f64() - 1.0;
            let v = 2.0 * self.rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * k);
                return u * k;
            }
        }
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// N(mu, sigma^2) draw.
    #[inline]
    pub fn next_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.next_f64()
    }

    /// Borrow the underlying uniform stream.
    pub fn uniform(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fourth_moment_is_three() {
        let mut g = NormalGen::new(Xoshiro256::seed_from(11));
        let n = 400_000;
        let mut m4 = 0.0;
        for _ in 0..n {
            let x = g.next_f64();
            m4 += x.powi(4);
        }
        m4 /= n as f64;
        assert!((m4 - 3.0).abs() < 0.1, "E[x^4] = {m4}");
    }

    #[test]
    fn tail_probability() {
        let mut g = NormalGen::new(Xoshiro256::seed_from(13));
        let n = 200_000;
        let beyond2 = (0..n).filter(|_| g.next_f64().abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        // P(|Z| > 2) = 0.0455
        assert!((frac - 0.0455).abs() < 0.004, "frac {frac}");
    }
}
