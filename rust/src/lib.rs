//! # TeZO — temporal low-rank zeroth-order optimization for fine-tuning LLMs
//!
//! Rust + JAX + Pallas reproduction of *TeZO: Empowering the Low-Rankness on
//! the Temporal Dimension in the Zeroth-Order Optimization for Fine-tuning
//! LLMs* (CS.LG 2025).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`).
//! * **L2** — JAX model + per-optimizer step functions, AOT-lowered to HLO
//!   text artifacts (`python/compile/`).
//! * **L3** — this crate: the fine-tuning coordinator. It loads the HLO
//!   artifacts through PJRT ([`runtime`]), owns all training state
//!   ([`coordinator`]), scales out via the seed-synchronized data-parallel
//!   [`fleet`], and provides the datasets, memory model, benchmark
//!   harness, and CLI of the evaluation suite.
//!
//! Python never runs at training time: after `make artifacts` the `tezo`
//! binary is self-contained.
//!
//! ## Substrate modules
//!
//! The offline build environment provides only the `xla` crate, so the
//! usual ecosystem crates are replaced by in-tree substrates: [`rngx`]
//! (deterministic RNG), [`jsonx`] (JSON), [`clix`] (CLI parsing),
//! [`benchkit`] (criterion-style benching), [`proplite`] (property
//! testing), [`tensor`] (host linear algebra incl. top-k SVD), and
//! [`telemetry`] (span tracing, latency histograms, trace export).

pub mod benchkit;
pub mod clix;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fleet;
pub mod jsonx;
pub mod memmodel;
pub mod proplite;
pub mod rngx;
pub mod runtime;
pub mod telemetry;
pub mod tensor;

/// Repository-level version string (also printed by `tezo --version`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Resolve the artifacts root: `$TEZO_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> std::path::PathBuf {
    std::env::var_os("TEZO_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
