//! Paper Table 6: hyperparameter recommendations and search ranges.
//!
//! The paper reports per-model-scale learning rates (RoBERTa-large / OPT-13B
//! / LLaMA-7B). Our substitute configs map: `medium` ~ RoBERTa-large row,
//! `small`/`tiny` ~ the OPT/LLaMA rows scaled. The *search space* itself is
//! reproduced verbatim so `tezo sweep --list` regenerates Table 6.

use super::Method;

/// One recommended-hyperparameter row.
#[derive(Clone, Copy, Debug)]
pub struct PresetRow {
    pub lr: f32,
    pub rho: f32,
    pub lazy_interval: usize,
}

/// Paper-recommended settings adapted to our scaled models. ZO-SGD-family
/// lr is higher than the paper's absolute values because our substitute
/// models are randomly initialized (larger gradients than fine-tuning a
/// pretrained LLM); the *relative* method settings match Table 6
/// (SGD-family share one lr; Adam-family get ~30x larger).
pub fn preset_for(method: Method, model: &str) -> PresetRow {
    let (sgd_lr, adam_lr): (f32, f32) = match model {
        "tiny" => (2e-4, 2e-3),
        "small" => (1e-4, 1e-3),
        "medium" => (5e-5, 5e-4),
        "e2e" => (5e-5, 5e-4),
        _ => (1e-4, 1e-3),
    };
    let fo_lr: f32 = 1e-3;
    let rho: f32 = 1e-3; // fixed across all methods, as in Table 6
    let lazy = 50;
    let lr = match method {
        Method::MezoAdam | Method::TezoAdam | Method::ZoAdamu => adam_lr,
        Method::FoAdam => fo_lr,
        _ => sgd_lr,
    };
    PresetRow { lr, rho, lazy_interval: lazy }
}

/// Table 6 search ranges, reproduced for `tezo sweep --list`.
pub fn search_space(method: Method) -> Vec<(&'static str, Vec<&'static str>)> {
    let mut rows: Vec<(&'static str, Vec<&'static str>)> = vec![
        ("batchsize", vec!["16", "32", "64"]),
        ("perturbation rate", vec!["1e-3"]),
    ];
    match method {
        Method::Mezo | Method::MezoM => {
            rows.insert(1, ("learning rate", vec!["1e-4", "1e-5", "1e-6", "1e-7"]));
        }
        Method::MezoAdam => {
            rows.insert(1, ("learning rate", vec!["1e-4", "3e-5", "1e-5", "3e-6"]));
        }
        Method::Subzo => {
            rows.insert(1, ("learning rate", vec!["1e-4", "1e-5", "1e-6", "1e-7"]));
            rows.push(("rank", vec!["32", "64", "128"]));
            rows.push(("lazy update interval", vec!["50", "100", "500"]));
        }
        Method::Lozo | Method::LozoM => {
            rows.insert(1, ("learning rate", vec!["1e-4", "1e-5", "1e-6", "1e-7"]));
            rows.push(("rank", vec!["8", "16", "32"]));
            rows.push(("lazy update interval", vec!["50", "100", "500"]));
        }
        Method::Tezo | Method::TezoM => {
            rows.insert(1, ("learning rate", vec!["1e-4", "1e-5", "1e-6", "1e-7"]));
            rows.push(("threshold to select rank", vec!["20%", "25%", "30%", "35%"]));
            rows.push(("maximum threshold of rank", vec!["32", "64", "128", "256"]));
        }
        Method::TezoAdam => {
            rows.insert(1, ("learning rate", vec!["1e-4", "3e-5", "1e-5", "3e-6"]));
            rows.push(("threshold to select rank", vec!["20%", "25%", "30%", "35%"]));
            rows.push(("maximum threshold of rank", vec!["32", "64", "128", "256"]));
        }
        Method::ZoAdamu => {
            rows.insert(1, ("learning rate", vec!["1e-4", "3e-5", "1e-5", "3e-6"]));
            rows.push(("alpha (momentum mix)", vec!["0.1", "0.2", "0.3"]));
        }
        Method::FoAdam => {
            rows.insert(1, ("learning rate", vec!["1e-3", "1e-4", "1e-5"]));
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_presets_have_larger_lr() {
        for model in ["tiny", "small", "medium"] {
            let sgd = preset_for(Method::Tezo, model);
            let adam = preset_for(Method::TezoAdam, model);
            assert!(adam.lr > sgd.lr);
            assert_eq!(sgd.rho, adam.rho);
        }
    }

    #[test]
    fn search_space_has_core_rows() {
        for m in Method::ALL {
            let rows = search_space(m);
            assert!(rows.iter().any(|(k, _)| *k == "batchsize"));
            assert!(rows.iter().any(|(k, _)| k.contains("learning rate")));
        }
        // TeZO rows carry the rank-threshold knobs (Table 6)
        let tezo = search_space(Method::Tezo);
        assert!(tezo.iter().any(|(k, _)| k.contains("threshold")));
    }
}
