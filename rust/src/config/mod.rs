//! Configuration system: optimizer methods, training hyperparameters, and
//! the paper's Table-6 preset grid.
//!
//! Model geometry is *not* configured here — it is baked into
//! `artifacts/<config>/manifest.json` by the AOT pipeline and read by
//! [`crate::runtime::Manifest`]. This module owns everything the L3
//! coordinator decides at run time.

mod presets;

pub use presets::{preset_for, search_space, PresetRow};

use anyhow::{bail, Result};

/// Every optimizer driver the coordinator implements (paper baselines +
/// TeZO variants + the first-order FT reference).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Mezo,
    MezoM,
    MezoAdam,
    Lozo,
    LozoM,
    Subzo,
    ZoAdamu,
    Tezo,
    TezoM,
    TezoAdam,
    FoAdam,
}

impl Method {
    pub const ALL: [Method; 11] = [
        Method::Mezo, Method::MezoM, Method::MezoAdam,
        Method::Lozo, Method::LozoM, Method::Subzo, Method::ZoAdamu,
        Method::Tezo, Method::TezoM, Method::TezoAdam, Method::FoAdam,
    ];

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "mezo" => Method::Mezo,
            "mezo-m" => Method::MezoM,
            "mezo-adam" => Method::MezoAdam,
            "lozo" => Method::Lozo,
            "lozo-m" => Method::LozoM,
            "subzo" => Method::Subzo,
            "zo-adamu" | "adamu" => Method::ZoAdamu,
            "tezo" => Method::Tezo,
            "tezo-m" => Method::TezoM,
            "tezo-adam" => Method::TezoAdam,
            "fo-adam" | "ft" | "fo" => Method::FoAdam,
            other => bail!("unknown method {other:?} (see `tezo train --help`)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Mezo => "mezo",
            Method::MezoM => "mezo-m",
            Method::MezoAdam => "mezo-adam",
            Method::Lozo => "lozo",
            Method::LozoM => "lozo-m",
            Method::Subzo => "subzo",
            Method::ZoAdamu => "zo-adamu",
            Method::Tezo => "tezo",
            Method::TezoM => "tezo-m",
            Method::TezoAdam => "tezo-adam",
            Method::FoAdam => "fo-adam",
        }
    }

    /// Is this a zeroth-order method (two forwards) vs first-order?
    pub fn is_zo(&self) -> bool {
        !matches!(self, Method::FoAdam)
    }

    /// Is the method's update a pure function of `(step, perturb_seed,
    /// kappa)` on top of the current parameters? True for the stateless
    /// SGD-form methods; false for momentum/Adam variants, whose state a
    /// `(seed, kappa)` log does not capture. This is the gate for every
    /// replay-based recovery path: fleet catch-up, `--resume` journal
    /// replay, and guard rollback (see docs/robustness.md).
    pub fn statelessly_replayable(&self) -> bool {
        matches!(self, Method::Mezo | Method::Lozo | Method::Subzo | Method::Tezo)
    }

    /// Does the method keep full-parameter-size optimizer state?
    /// (Drives the memory model and the Fig 3a reproduction.)
    pub fn full_size_state_copies(&self) -> usize {
        match self {
            Method::Mezo | Method::Lozo | Method::LozoM | Method::Subzo
            | Method::Tezo | Method::TezoM | Method::TezoAdam => 0,
            Method::MezoM => 1,
            Method::MezoAdam | Method::ZoAdamu => 2,
            Method::FoAdam => 3, // grads + m + v
        }
    }
}

/// Which compiled form of the two-point loss forward a run dispatches.
///
/// * `Implicit` (default): the factor-form artifact (`*_loss_pm_implicit`)
///   — the rank-r perturbation is folded into the matmuls, sign-batched on
///   a leading axis of 2, so no dense `W +/- rho Z` copies materialize and
///   each weight is read once for the +/- pair.
/// * `Materialize`: the legacy artifact (`*_loss_pm`) that builds two full
///   perturbed weight sets before the forward. Still needed as the
///   reference for cross-form parity, and it is what the *update* path
///   necessarily does (the update must write dense weights anyway).
///
/// Methods without an implicit artifact (dense-Z MeZO family, SubZO,
/// ZO-AdaMU, the FO reference) ignore the knob; so do artifact dirs built
/// before the implicit artifacts existed (the manifest lookup falls back).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ForwardForm {
    Materialize,
    Implicit,
}

impl ForwardForm {
    pub const ALL: [ForwardForm; 2] = [ForwardForm::Materialize, ForwardForm::Implicit];

    pub fn parse(s: &str) -> Result<ForwardForm> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "implicit" => ForwardForm::Implicit,
            "materialize" | "materialized" | "dense" => ForwardForm::Materialize,
            other => bail!("unknown forward form {other:?} (implicit|materialize)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ForwardForm::Materialize => "materialize",
            ForwardForm::Implicit => "implicit",
        }
    }
}

/// What `--forward-form` accepts: a concrete [`ForwardForm`] pin, or
/// `auto` — let the shape-aware autotuner pick per (artifact dir, method)
/// and persist the decision in `tuning.json` (see `runtime::tune` and
/// docs/runtime.md "Autotuning").
///
/// `Auto` is resolved to a concrete form exactly once per run, *before*
/// the step engine or any fleet worker is built; the fleet coordinator
/// ships the pinned result in the handshake so every replica dispatches
/// the same artifact (forms are numerically close but not bitwise equal).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormPolicy {
    /// measure (or read the cached decision) at warmup and pin the winner
    Auto,
    /// dispatch exactly this form, no measurement
    Pinned(ForwardForm),
}

/// The CLI default for `--forward-form` (train and train-dp share it).
/// Lives here so the flag table carries no raw form literal (TZ-TUNE001).
pub const FORWARD_FORM_ARG_DEFAULT: &str = "auto";

impl FormPolicy {
    pub fn parse(s: &str) -> Result<FormPolicy> {
        if s.eq_ignore_ascii_case(FORWARD_FORM_ARG_DEFAULT) {
            return Ok(FormPolicy::Auto);
        }
        Ok(FormPolicy::Pinned(ForwardForm::parse(s)?))
    }

    pub fn name(&self) -> &'static str {
        match self {
            FormPolicy::Auto => FORWARD_FORM_ARG_DEFAULT,
            FormPolicy::Pinned(f) => f.name(),
        }
    }

    /// The concrete form when pinned; `None` while still `Auto`.
    pub fn pinned(&self) -> Option<ForwardForm> {
        match self {
            FormPolicy::Auto => None,
            FormPolicy::Pinned(f) => Some(*f),
        }
    }

    /// Last-resort concrete form for contexts that never ran resolution
    /// (an engine built straight from an `Auto` config, a worker warming
    /// up before its handshake config arrives). Falls back to the
    /// factor-form forward — the memory winner and the pre-autotuner
    /// default — so behavior degrades to the PR 5 semantics, never an
    /// error. The train/train-dp entry points pin before building, so in
    /// practice this only fires in tests and embedding uses.
    pub fn resolve_fallback(&self) -> ForwardForm {
        match self {
            FormPolicy::Auto => ForwardForm::Implicit,
            FormPolicy::Pinned(f) => *f,
        }
    }
}

/// Learning-rate schedule over the run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Constant,
    /// linear decay to `final_frac * lr` at the last step
    Linear { final_frac: f32 },
    /// cosine decay to `final_frac * lr`
    Cosine { final_frac: f32 },
}

impl LrSchedule {
    /// Effective lr at `step` of `total` steps.
    pub fn at(&self, lr: f32, step: u64, total: usize) -> f32 {
        let t = if total <= 1 { 0.0 } else { step as f32 / (total - 1) as f32 };
        match self {
            LrSchedule::Constant => lr,
            LrSchedule::Linear { final_frac } => {
                lr * (1.0 - t + t * final_frac)
            }
            LrSchedule::Cosine { final_frac } => {
                let lo = lr * final_frac;
                lo + 0.5 * (lr - lo) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    pub fn parse(s: &str) -> Result<LrSchedule> {
        Ok(match s {
            "constant" | "" => LrSchedule::Constant,
            "linear" => LrSchedule::Linear { final_frac: 0.1 },
            "cosine" => LrSchedule::Cosine { final_frac: 0.1 },
            other => bail!("unknown lr schedule {other:?} (constant|linear|cosine)"),
        })
    }
}

/// Run-time training configuration (one fine-tuning job).
///
/// `PartialEq` because the fleet's TCP handshake ships the whole config to
/// joining workers and tests assert the round trip is lossless.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub method: Method,
    pub steps: usize,
    pub lr: f32,
    pub rho: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// ZO-AdaMU perturbation-momentum mixing weight.
    pub adamu_alpha: f32,
    /// Lazy refresh interval for LOZO-U / SubZO factors (paper Table 6).
    pub lazy_interval: usize,
    /// Master seed: drives the per-step seed schedule, data order, factors.
    pub seed: u64,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    /// Bias-correct the TeZO/MeZO Adam moments.
    pub bias_correction: bool,
    /// Learning-rate schedule.
    pub lr_schedule: LrSchedule,
    /// Clip |kappa| (the projected gradient) at this value; 0 disables.
    /// Two-point ZO occasionally measures huge finite differences on sharp
    /// minibatches — clipping stabilizes the SGD-family without changing
    /// the estimator in expectation materially.
    pub kappa_clip: f32,
    /// q-SPSA: average over this many independent perturbations per step
    /// (paper's baselines use q=1). Supported by the stateless SGD-form
    /// methods (mezo/lozo/subzo/tezo); momentum/Adam variants require q=1.
    pub n_perturb: usize,
    /// Which compiled two-point forward the low-rank methods dispatch:
    /// a concrete pin, or `Auto` — resolved once per run by the
    /// autotuner (see [`FormPolicy`] and `runtime::tune`).
    pub forward_form: FormPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            method: Method::Tezo,
            steps: 100,
            lr: 1e-6,
            rho: 1e-3,
            beta1: 0.9,
            beta2: 0.99,
            eps: 1e-5,
            adamu_alpha: 0.2,
            lazy_interval: 50,
            seed: 0,
            eval_every: 0,
            bias_correction: true,
            lr_schedule: LrSchedule::Constant,
            kappa_clip: 0.0,
            n_perturb: 1,
            forward_form: FormPolicy::Auto,
        }
    }
}

impl TrainConfig {
    /// Validate cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.n_perturb == 0 || self.n_perturb > 64 {
            bail!("n_perturb must be in 1..=64");
        }
        if self.n_perturb > 1 {
            let ok = matches!(self.method,
                Method::Mezo | Method::Lozo | Method::Subzo | Method::Tezo);
            if !ok {
                bail!("n_perturb > 1 requires a stateless SGD-form method \
                       (mezo|lozo|subzo|tezo), got {}", self.method.name());
            }
        }
        if self.rho <= 0.0 {
            bail!("rho must be positive");
        }
        Ok(())
    }
}

/// What the coordinator does about workers that miss a round deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StragglerPolicy {
    /// wait indefinitely for every live worker (the original semantics;
    /// straggling is *measured* via the critical-path spread but never
    /// acted on)
    Wait,
    /// after `timeout_ms` without the round completing, kick the workers
    /// that have not answered and broadcast a lockstep skip for the round
    /// (replicas stay bit-identical; the step's loss is recorded as NaN)
    DropSkip { timeout_ms: u64 },
}

/// Data-parallel fleet configuration (the seed-synchronized ZO fleet of
/// [`crate::fleet`]; see docs/fleet.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetConfig {
    /// worker replicas; each owns a private runtime + parameter replica and
    /// one disjoint data shard
    pub workers: usize,
    /// round-deadline policy (default: wait forever, as before)
    pub straggler: StragglerPolicy,
    /// publish a step checkpoint every N completed steps so rejoining
    /// workers can catch up from it instead of replaying the whole run
    /// (0 = no intermediate checkpoints; the catch-up log is never pruned)
    pub checkpoint_every: usize,
    /// how many worker deaths the run tolerates before aborting
    /// (0 = the original fail-fast behavior)
    pub max_restarts: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            straggler: StragglerPolicy::Wait,
            checkpoint_every: 0,
            max_restarts: 0,
        }
    }
}

impl FleetConfig {
    pub fn new(workers: usize) -> Self {
        Self { workers, ..Self::default() }
    }

    /// Validate against the training config the fleet will replicate.
    pub fn validate(&self, train: &TrainConfig) -> Result<()> {
        if self.workers == 0 || self.workers > 256 {
            bail!("fleet workers must be in 1..=256, got {}", self.workers);
        }
        if !train.method.is_zo() {
            bail!("fleet data parallelism requires a ZO method: {} needs \
                   gradient-sized all-reduce, which the scalar-sync fleet \
                   exists to avoid",
                  train.method.name());
        }
        if self.max_restarts > 0 || self.checkpoint_every > 0 {
            // catch-up replay rebuilds a rejoining replica from
            // (perturb_seed, kappa) scalars alone; that is only exact for
            // methods whose update is a pure function of those scalars —
            // momentum/Adam variants carry state the log does not capture
            if !train.method.statelessly_replayable() {
                bail!("fleet fault tolerance (max_restarts/checkpoint_every) \
                       requires a stateless SGD-form method \
                       (mezo|lozo|subzo|tezo): {} keeps optimizer state the \
                       catch-up log cannot replay", train.method.name());
            }
        }
        Ok(())
    }
}

impl TrainConfig {
    /// The paper's recommended hyperparameters for (method, model scale)
    /// from Table 6, scaled to our substitute models.
    pub fn with_preset(method: Method, model: &str) -> Self {
        let row = preset_for(method, model);
        Self {
            method,
            lr: row.lr,
            rho: row.rho,
            lazy_interval: row.lazy_interval,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn zo_flags() {
        assert!(Method::Tezo.is_zo());
        assert!(!Method::FoAdam.is_zo());
        assert_eq!(Method::TezoAdam.full_size_state_copies(), 0);
        assert_eq!(Method::MezoAdam.full_size_state_copies(), 2);
    }

    #[test]
    fn lr_schedules_interpolate() {
        let lr = 1.0f32;
        let c = LrSchedule::Constant;
        assert_eq!(c.at(lr, 0, 100), 1.0);
        assert_eq!(c.at(lr, 99, 100), 1.0);
        let l = LrSchedule::Linear { final_frac: 0.1 };
        assert!((l.at(lr, 0, 100) - 1.0).abs() < 1e-6);
        assert!((l.at(lr, 99, 100) - 0.1).abs() < 1e-6);
        let mid = l.at(lr, 49, 100);
        assert!(mid < 1.0 && mid > 0.1);
        let cos = LrSchedule::Cosine { final_frac: 0.1 };
        assert!((cos.at(lr, 0, 100) - 1.0).abs() < 1e-6);
        assert!((cos.at(lr, 99, 100) - 0.1).abs() < 1e-5);
        // cosine decays slower than linear early on
        assert!(cos.at(lr, 20, 100) > l.at(lr, 20, 100));
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let ok = TrainConfig::default();
        assert!(ok.validate().is_ok());
        let mut bad = TrainConfig::default();
        bad.steps = 0;
        assert!(bad.validate().is_err());
        let mut bad = TrainConfig::default();
        bad.n_perturb = 4;
        bad.method = Method::TezoAdam; // stateful: q-SPSA unsupported
        assert!(bad.validate().is_err());
        bad.method = Method::Tezo;
        assert!(bad.validate().is_ok());
        let mut bad = TrainConfig::default();
        bad.rho = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fleet_config_validation() {
        let zo = TrainConfig::default(); // tezo
        assert!(FleetConfig::new(1).validate(&zo).is_ok());
        assert!(FleetConfig::new(8).validate(&zo).is_ok());
        assert!(FleetConfig::new(0).validate(&zo).is_err());
        assert!(FleetConfig::new(1000).validate(&zo).is_err());
        let mut fo = TrainConfig::default();
        fo.method = Method::FoAdam;
        assert!(FleetConfig::new(2).validate(&fo).is_err(),
                "first-order methods cannot ride the scalar-sync fleet");
        // fault tolerance needs an exactly replayable (stateless) method
        let mut stateful = TrainConfig::default();
        stateful.method = Method::TezoAdam;
        let mut ft = FleetConfig::new(2);
        ft.max_restarts = 1;
        assert!(ft.validate(&stateful).is_err());
        assert!(ft.validate(&TrainConfig::default()).is_ok());
        let mut ck = FleetConfig::new(2);
        ck.checkpoint_every = 10;
        assert!(ck.validate(&stateful).is_err());
    }

    #[test]
    fn forward_form_parse_and_default() {
        for f in ForwardForm::ALL {
            assert_eq!(ForwardForm::parse(f.name()).unwrap(), f);
            assert_eq!(FormPolicy::parse(f.name()).unwrap(),
                       FormPolicy::Pinned(f));
        }
        assert_eq!(ForwardForm::parse("materialized").unwrap(),
                   ForwardForm::Materialize);
        assert!(ForwardForm::parse("nope").is_err());
        assert!(FormPolicy::parse("nope").is_err());
        // auto is the default: the tuner picks the per-shape winner
        assert_eq!(FormPolicy::parse(FORWARD_FORM_ARG_DEFAULT).unwrap(),
                   FormPolicy::Auto);
        assert_eq!(TrainConfig::default().forward_form, FormPolicy::Auto);
    }

    #[test]
    fn form_policy_resolution() {
        assert_eq!(FormPolicy::Auto.pinned(), None);
        assert_eq!(FormPolicy::Pinned(ForwardForm::Materialize).pinned(),
                   Some(ForwardForm::Materialize));
        // the documented last-resort fallback for unresolved Auto
        assert_eq!(FormPolicy::Auto.resolve_fallback(), ForwardForm::Implicit);
        assert_eq!(FormPolicy::Pinned(ForwardForm::Materialize)
                       .resolve_fallback(),
                   ForwardForm::Materialize);
        assert_eq!(FormPolicy::Auto.name(), FORWARD_FORM_ARG_DEFAULT);
    }

    #[test]
    fn lr_schedule_parse() {
        assert_eq!(LrSchedule::parse("constant").unwrap(), LrSchedule::Constant);
        assert!(matches!(LrSchedule::parse("linear").unwrap(),
                         LrSchedule::Linear { .. }));
        assert!(matches!(LrSchedule::parse("cosine").unwrap(),
                         LrSchedule::Cosine { .. }));
        assert!(LrSchedule::parse("nope").is_err());
    }
}
