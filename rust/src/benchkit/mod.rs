//! Benchmark harness substrate (the offline registry has no `criterion`).
//!
//! Criterion-style methodology on a small footprint: warmup phase, timed
//! sampling until a time or iteration budget is reached, robust statistics
//! (median/p95 + MAD-based outlier count), and table/CSV reporting used by
//! the `rust/benches/*` targets to regenerate the paper's tables.

use std::time::{Duration, Instant};

use crate::tensor::stats;

/// One benchmark measurement series.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// per-iteration wall-clock seconds
    pub iters: Vec<f64>,
}

impl Sample {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.iters)
    }

    pub fn median(&self) -> f64 {
        stats::median(&self.iters)
    }

    pub fn p95(&self) -> f64 {
        stats::quantile(&self.iters, 0.95)
    }

    pub fn std_dev(&self) -> f64 {
        stats::std_dev(&self.iters)
    }

    /// Outliers beyond 5 MADs from the median.
    pub fn outliers(&self) -> usize {
        let med = self.median();
        let mut devs: Vec<f64> = self.iters.iter().map(|&x| (x - med).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = stats::median(&devs).max(1e-12);
        self.iters.iter().filter(|&&x| (x - med).abs() > 5.0 * 1.4826 * mad).count()
    }
}

/// Bench runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 10_000,
        }
    }
}

impl BenchOpts {
    /// Faster profile for CI / smoke runs (`TEZO_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var_os("TEZO_BENCH_FAST").is_some() {
            Self {
                warmup: Duration::from_millis(50),
                budget: Duration::from_millis(300),
                min_iters: 3,
                max_iters: 200,
            }
        } else {
            Self::default()
        }
    }
}

/// Run `f` under the harness; each call is one iteration.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> Sample {
    // warmup
    let w0 = Instant::now();
    while w0.elapsed() < opts.warmup {
        f();
    }
    // sampling
    let mut iters = Vec::new();
    let b0 = Instant::now();
    while (b0.elapsed() < opts.budget || iters.len() < opts.min_iters)
        && iters.len() < opts.max_iters
    {
        let t = Instant::now();
        f();
        iters.push(t.elapsed().as_secs_f64());
    }
    Sample { name: name.to_string(), iters }
}

/// Pretty time with adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:7.2} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:7.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:7.2} ms", secs * 1e3)
    } else {
        format!("{secs:7.3} s ")
    }
}

/// Report writer: aligned console table + optional CSV file.
pub struct Report {
    title: String,
    rows: Vec<(String, Vec<String>)>,
    header: Vec<String>,
}

impl Report {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            rows: Vec::new(),
            header: header.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn add_row(&mut self, label: &str, cells: Vec<String>) {
        self.rows.push((label.to_string(), cells));
    }

    pub fn add_sample(&mut self, s: &Sample) {
        self.rows.push((
            s.name.clone(),
            vec![
                fmt_time(s.median()),
                fmt_time(s.mean()),
                fmt_time(s.p95()),
                format!("{}", s.iters.len()),
                format!("{}", s.outliers()),
            ],
        ));
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap_or(8);
        for (_, cells) in &self.rows {
            for (i, c) in cells.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        print!("{:label_w$}", "");
        for (h, w) in self.header.iter().zip(&widths) {
            print!("  {h:>w$}");
        }
        println!();
        for (label, cells) in &self.rows {
            print!("{label:label_w$}");
            for (c, w) in cells.iter().zip(&widths) {
                print!("  {c:>w$}");
            }
            println!();
        }
    }

    /// Write `label,cell1,cell2,...` CSV.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::new();
        out.push_str("label");
        for h in &self.header {
            out.push(',');
            out.push_str(h);
        }
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(label);
            for c in cells {
                out.push(',');
                out.push_str(c.trim());
            }
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Write any jsonx [`Value`](crate::jsonx::Value) to `path`, creating
/// parent directories — the JSON emitter behind the perf-trajectory
/// snapshots (`bench_walltime` writes out/BENCH_PR5.json through it).
pub fn write_json_value(path: &std::path::Path,
                        v: &crate::jsonx::Value) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, crate::jsonx::to_string_pretty(v))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 5,
            max_iters: 1000,
        };
        let mut counter = 0u64;
        let s = bench("noop", opts, || {
            counter = counter.wrapping_add(1);
        });
        assert!(s.iters.len() >= 5);
        assert!(s.median() >= 0.0);
    }

    #[test]
    fn json_value_roundtrips_through_disk() {
        use crate::jsonx::Value;
        let doc = Value::obj(vec![
            ("snapshot", Value::str("s")),
            ("n", Value::i(3)),
        ]);
        let path = std::env::temp_dir()
            .join(format!("tezo_benchkit_{}", std::process::id()))
            .join("snap.json");
        write_json_value(&path, &doc).unwrap();
        let v = crate::jsonx::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(v.get_str("snapshot").unwrap(), "s");
        assert_eq!(v.get("n").unwrap().as_i64().unwrap(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
    }
}
