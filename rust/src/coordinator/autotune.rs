//! The coordinator-side autotune probe: supplies `runtime::tune` with a
//! real timed two-point forward.
//!
//! `runtime::tune` owns the decision logic and the persisted table but
//! cannot measure anything itself — a timed forward needs a driver, a
//! parameter replica, and a batch, all of which live in this layer. The
//! probe here builds throwaway copies of all three (the real run's driver
//! state, sample counters, and staged parameters are never touched),
//! compiles both loss artifacts, runs one untimed flush per form, and
//! then hands `tune::measure_and_pin` a closure timing interleaved
//! two-point forwards with the telemetry [`Stopwatch`].
//!
//! Entry points:
//! * [`resolve`] — for callers with an open [`Runtime`] (`tezo train`);
//! * [`resolve_for_dir`] — for the fleet coordinator, which normally only
//!   loads the manifest: a cache hit or static pin costs no PJRT client,
//!   and only a genuine miss opens a probe runtime.

use std::path::Path;

use anyhow::Result;

use crate::config::{ForwardForm, TrainConfig};
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;
use crate::coordinator::optimizer::{build_optimizer, StepCtx, ZoOptimizer};
use crate::coordinator::seeds::SeedSchedule;
use crate::data::{Batch, BatchBuilder, Corpus, Tokenizer};
use crate::runtime::{tune, Manifest, ParamStore, Runtime};
use crate::telemetry::{Stopwatch, Telemetry};

/// Deterministic probe batch: LM rows from the synthetic corpus at the
/// run's master seed. Only the shape matters for timing; using the seed
/// keeps repeated probes identical.
fn probe_batch(manifest: &Manifest, seed: u64) -> Batch {
    let c = &manifest.config;
    let corpus = Corpus::new(Tokenizer::new(c.vocab), c.seq_len, seed);
    BatchBuilder::corpus_batch(&corpus, c.batch, seed, 0)
}

/// One two-point forward under `form` against throwaway state, returning
/// the measured wall nanoseconds (dispatch + execution — the real
/// per-step cost a form decides).
#[allow(clippy::too_many_arguments)]
fn forward_once(rt: &Runtime, cfg: &TrainConfig, seeds: &SeedSchedule,
                driver: &mut dyn ZoOptimizer, params: &mut ParamStore,
                batch: &Batch, form: ForwardForm, step: u64) -> Result<u64> {
    let mut timers = PhaseTimers::default();
    let mut counter = SampleCounter::default();
    let arena = rt.step_arena(step);
    let mut ctx = StepCtx {
        rt,
        params,
        batch,
        cfg,
        seeds,
        step,
        sub: 0,
        lr: cfg.lr,
        form,
        timers: &mut timers,
        counter: &mut counter,
        arena: &arena,
    };
    let t0 = Stopwatch::start();
    driver.forward(&mut ctx)?;
    Ok(t0.elapsed_ns())
}

/// Resolve `cfg.forward_form` against an open runtime: static pin, then
/// the persisted table, then a live measurement that pins and persists
/// the winner. The measurement compiles *both* loss artifacts (it has
/// to); every other path leaves the loser uncompiled, which is the
/// cold-start saving `Runtime::warmup_method` banks on.
pub fn resolve(rt: &Runtime, cfg: &TrainConfig, tel: &Telemetry)
               -> Result<tune::Resolution> {
    if let Some(r) = tune::resolve_static(&rt.manifest, cfg.method,
                                          cfg.forward_form) {
        return Ok(r);
    }
    if let Some(r) = tune::resolve_cached(&rt.manifest, cfg.method, tel) {
        return Ok(r);
    }
    // cache miss: build the throwaway probe state once, reuse it for
    // every trial. The driver is form-agnostic (the form lives in the
    // ctx), so one driver serves both sides of each interleaved pair.
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let batch = probe_batch(&rt.manifest, cfg.seed);
    let seeds = SeedSchedule::new(cfg.seed);
    let mut driver = build_optimizer(rt, cfg, &seeds)?;
    // compile both forms' artifact sets up front and flush one untimed
    // forward per form, so the timed trials see a hot cache (compile and
    // first-call costs are warmup, not form evidence)
    for form in ForwardForm::ALL {
        rt.warmup_method(cfg.method, form)?;
    }
    let mut probe_step: u64 = 0;
    for form in ForwardForm::ALL {
        forward_once(rt, cfg, &seeds, driver.as_mut(), &mut params, &batch,
                     form, probe_step)?;
        probe_step += 1;
    }
    let mut measure = |form: ForwardForm| -> Result<u64> {
        let ns = forward_once(rt, cfg, &seeds, driver.as_mut(), &mut params,
                              &batch, form, probe_step)?;
        probe_step += 1;
        Ok(ns)
    };
    tune::measure_and_pin(&rt.manifest, cfg.method, tel, &mut measure)
}

/// Resolve for an artifact directory without requiring an open runtime.
///
/// The fleet coordinator calls this before spawning workers: a pin, an
/// untunable method, or a warm `tuning.json` resolves from the manifest
/// alone; only a genuine miss opens a private probe [`Runtime`] (the
/// workers still open their own), measures, and persists the decision
/// the handshake then ships.
pub fn resolve_for_dir(dir: &Path, cfg: &TrainConfig, tel: &Telemetry)
                       -> Result<tune::Resolution> {
    let manifest = Manifest::load(dir)?;
    if let Some(r) = tune::resolve_static(&manifest, cfg.method,
                                          cfg.forward_form) {
        return Ok(r);
    }
    if let Some(r) = tune::resolve_cached(&manifest, cfg.method, tel) {
        return Ok(r);
    }
    let rt = Runtime::open(dir)?;
    resolve(&rt, cfg, tel)
}
