//! Greedy autoregressive generation through the `eval_logits` artifact —
//! the inference path of the fine-tuned model (`tezo generate`).
//!
//! The artifact has fixed shapes (B, S), so generation fills a padded token
//! matrix left-to-right: at each position the artifact returns the logits
//! at the last committed position per row, and the argmax token is
//! committed at the next slot.

use anyhow::{ensure, Result};

use crate::data::tokenizer::PAD;
use crate::runtime::exec::to_vec_f32;
use crate::runtime::{ParamStore, Runtime};

/// Greedily extend each prompt row by `new_tokens` tokens.
///
/// `prompts`: one token vector per row (<= batch rows; padded/truncated to
/// the artifact's geometry). Returns the full generated rows.
pub fn greedy_generate(rt: &Runtime, params: &ParamStore,
                       prompts: &[Vec<i32>], new_tokens: usize)
                       -> Result<Vec<Vec<i32>>> {
    let b = rt.manifest.config.batch;
    let s = rt.manifest.config.seq_len;
    ensure!(!prompts.is_empty() && prompts.len() <= b,
            "need 1..={b} prompt rows, got {}", prompts.len());
    let min_len = prompts.iter().map(|p| p.len()).min().unwrap_or(0);
    ensure!(min_len >= 1, "prompts must be non-empty");
    let max_len = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
    ensure!(max_len + new_tokens <= s,
            "prompt ({max_len}) + new_tokens ({new_tokens}) exceeds seq_len {s}");

    // token matrix (B, S), PAD-filled; rows beyond the prompts stay PAD
    let mut tokens = vec![PAD; b * s];
    let mut lens: Vec<usize> = Vec::with_capacity(b);
    for (row, p) in prompts.iter().enumerate() {
        tokens[row * s..row * s + p.len()].copy_from_slice(p);
        lens.push(p.len());
    }
    for _ in prompts.len()..b {
        lens.push(1); // dummy rows decode from position 0
    }

    for it in 0..new_tokens {
        // each decode position is its own staging epoch: the token matrix
        // mutates every iteration, so stale stagings are evicted as the
        // arena advances (prompt-only decode reuses nothing, by design)
        let arena = rt.step_arena(it as u64);
        let positions: Vec<i32> = lens.iter().map(|&l| (l - 1) as i32).collect();
        let mut call = rt.prepared("eval_logits")?;
        call.bind_bufs("param", params.bufs())?;
        call.bind_i32("batch", "tokens", &tokens, &arena)?;
        call.bind_i32("batch", "positions", &positions, &arena)?;
        let out = call.run()?;
        let logits = to_vec_f32(&out[0])?; // (B, V)
        let v = rt.manifest.config.vocab;
        for row in 0..prompts.len() {
            let row_logits = &logits[row * v..(row + 1) * v];
            let mut best = 0usize;
            let mut best_val = f32::NEG_INFINITY;
            // never emit PAD
            for (tok, &val) in row_logits.iter().enumerate() {
                if tok as i32 != PAD && val > best_val {
                    best = tok;
                    best_val = val;
                }
            }
            if lens[row] < s {
                tokens[row * s + lens[row]] = best as i32;
                lens[row] += 1;
            }
        }
    }
    Ok((0..prompts.len())
        .map(|row| tokens[row * s..row * s + lens[row]].to_vec())
        .collect())
}
