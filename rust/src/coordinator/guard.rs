//! Divergence guard: detect a run going bad and decide to roll back.
//!
//! ZO training can destabilize in two visible ways: the loss goes
//! non-finite (NaN/Inf measurements, which the step path already skips in
//! lockstep) or it spikes far above its recent trend. The guard watches
//! the per-step loss stream and trips a rollback decision when either
//! signal crosses its configured threshold. The *mechanism* of rollback —
//! reload the last good checkpoint, truncate the journal tail, re-run —
//! lives in the trainer and fleet coordinator; this module is the pure
//! policy state machine, so its exact semantics are property-tested
//! without artifacts (`rust/tests/props_journal.rs`).
//!
//! Because a deterministic run reproduces the same losses after a pure
//! rollback, `skip_steps > 0` optionally suppresses the next N *updates*
//! after a rollback (measurements still run and are journaled as
//! `kappa = None`, exactly like a lockstep skip) — nudging the trajectory
//! off the divergent path while staying bitwise-replayable from the
//! journal. See docs/robustness.md for the full failure model.

use anyhow::{ensure, Result};

/// Guard thresholds. `Default` is fully disabled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GuardPolicy {
    /// trip after this many *consecutive* non-finite step losses
    /// (0 = non-finite detection off)
    pub nonfinite_streak: usize,
    /// trip when a finite loss exceeds `spike_factor * ewma`
    /// (0.0 = spike detection off; must be > 1.0 when on)
    pub spike_factor: f64,
    /// EWMA smoothing for the loss trend, in (0, 1]
    pub ewma_alpha: f64,
    /// finite losses folded into the EWMA before spike detection arms
    pub warmup: usize,
    /// rollbacks allowed before the guard gives up and errors the run
    pub max_rollbacks: usize,
    /// updates suppressed (journaled as skips) after each rollback
    pub skip_steps: usize,
}

impl Default for GuardPolicy {
    fn default() -> Self {
        GuardPolicy {
            nonfinite_streak: 0,
            spike_factor: 0.0,
            ewma_alpha: 0.1,
            warmup: 8,
            max_rollbacks: 3,
            skip_steps: 0,
        }
    }
}

impl GuardPolicy {
    /// Is any detector on?
    pub fn enabled(&self) -> bool {
        self.nonfinite_streak > 0 || self.spike_factor > 0.0
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled() {
            return Ok(());
        }
        ensure!(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0,
                "guard ewma alpha must be in (0, 1], got {}", self.ewma_alpha);
        ensure!(self.spike_factor == 0.0 || self.spike_factor > 1.0,
                "guard spike factor must be > 1 (or 0 to disable), got {}",
                self.spike_factor);
        ensure!(self.max_rollbacks > 0,
                "an enabled guard needs max_rollbacks > 0");
        Ok(())
    }
}

/// Why the guard tripped.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GuardReason {
    /// `streak` consecutive non-finite step losses
    NonFiniteStreak { streak: usize },
    /// a finite loss blew past the trend: `loss > factor * ewma`
    LossSpike { loss: f64, ewma: f64 },
}

impl std::fmt::Display for GuardReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GuardReason::NonFiniteStreak { streak } => {
                write!(f, "{streak} consecutive non-finite step losses")
            }
            GuardReason::LossSpike { loss, ewma } => {
                write!(f, "loss spike: {loss:.6} vs trend {ewma:.6}")
            }
        }
    }
}

/// The guard's observation state. Feed it every step loss; a `Some`
/// return is a rollback decision (the caller checks [`can_roll_back`]
/// and then reports the rollback via [`rolled_back`], which re-arms the
/// detectors from scratch).
///
/// [`can_roll_back`]: GuardState::can_roll_back
/// [`rolled_back`]: GuardState::rolled_back
#[derive(Clone, Debug)]
pub struct GuardState {
    policy: GuardPolicy,
    streak: usize,
    ewma: Option<f64>,
    seen: usize,
    rollbacks: usize,
}

impl GuardState {
    pub fn new(policy: GuardPolicy) -> Self {
        GuardState { policy, streak: 0, ewma: None, seen: 0, rollbacks: 0 }
    }

    pub fn policy(&self) -> &GuardPolicy {
        &self.policy
    }

    pub fn rollbacks(&self) -> usize {
        self.rollbacks
    }

    /// Is there rollback budget left?
    pub fn can_roll_back(&self) -> bool {
        self.rollbacks < self.policy.max_rollbacks
    }

    /// Record a rollback and reset the detectors (the run re-enters past
    /// territory; the streak, trend, and warmup must rebuild).
    pub fn rolled_back(&mut self) {
        self.rollbacks += 1;
        self.streak = 0;
        self.ewma = None;
        self.seen = 0;
    }

    /// Observe one step loss. `Some(reason)` means "roll back now".
    pub fn observe(&mut self, loss: f64) -> Option<GuardReason> {
        if !self.policy.enabled() {
            return None;
        }
        if !loss.is_finite() {
            self.streak += 1;
            if self.policy.nonfinite_streak > 0
                && self.streak >= self.policy.nonfinite_streak
            {
                return Some(GuardReason::NonFiniteStreak { streak: self.streak });
            }
            return None;
        }
        self.streak = 0;
        if self.policy.spike_factor > 0.0 {
            if let Some(ewma) = self.ewma {
                // a multiplicative threshold only means something on a
                // positive trend (losses here are MSE / cross-entropy)
                if self.seen >= self.policy.warmup
                    && ewma > 0.0
                    && loss > self.policy.spike_factor * ewma
                {
                    return Some(GuardReason::LossSpike { loss, ewma });
                }
            }
        }
        self.ewma = Some(match self.ewma {
            Some(e) => self.policy.ewma_alpha * loss
                + (1.0 - self.policy.ewma_alpha) * e,
            None => loss,
        });
        self.seen += 1;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nf_policy(streak: usize) -> GuardPolicy {
        GuardPolicy { nonfinite_streak: streak, ..GuardPolicy::default() }
    }

    #[test]
    fn disabled_guard_never_trips() {
        let mut g = GuardState::new(GuardPolicy::default());
        for _ in 0..100 {
            assert_eq!(g.observe(f64::NAN), None);
        }
    }

    #[test]
    fn nonfinite_streak_trips_exactly_at_threshold() {
        let mut g = GuardState::new(nf_policy(3));
        assert_eq!(g.observe(f64::NAN), None);
        assert_eq!(g.observe(f64::INFINITY), None);
        assert_eq!(g.observe(f64::NAN),
                   Some(GuardReason::NonFiniteStreak { streak: 3 }));
    }

    #[test]
    fn finite_loss_resets_the_streak() {
        let mut g = GuardState::new(nf_policy(2));
        assert_eq!(g.observe(f64::NAN), None);
        assert_eq!(g.observe(1.0), None);
        assert_eq!(g.observe(f64::NAN), None);
        assert!(g.observe(f64::NAN).is_some());
    }

    #[test]
    fn spike_respects_warmup_and_threshold() {
        let p = GuardPolicy { spike_factor: 2.0, ewma_alpha: 0.5, warmup: 3,
                              ..GuardPolicy::default() };
        let mut g = GuardState::new(p);
        // warmup: even a huge jump does not trip yet
        assert_eq!(g.observe(1.0), None);
        assert_eq!(g.observe(100.0), None);
        assert_eq!(g.observe(1.0), None);
        // trend is now well under 30; a 100x loss trips
        let r = g.observe(3000.0);
        assert!(matches!(r, Some(GuardReason::LossSpike { .. })), "{r:?}");
    }

    #[test]
    fn rollback_budget_and_reset() {
        let p = GuardPolicy { nonfinite_streak: 1, max_rollbacks: 2,
                              ..GuardPolicy::default() };
        let mut g = GuardState::new(p);
        assert!(g.observe(f64::NAN).is_some());
        assert!(g.can_roll_back());
        g.rolled_back();
        // detectors re-armed: one more NaN trips again
        assert!(g.observe(f64::NAN).is_some());
        g.rolled_back();
        assert!(!g.can_roll_back());
        assert_eq!(g.rollbacks(), 2);
    }

    #[test]
    fn validate_rejects_bad_thresholds() {
        assert!(GuardPolicy::default().validate().is_ok());
        let bad = GuardPolicy { spike_factor: 0.5, ..GuardPolicy::default() };
        assert!(bad.validate().is_err());
        let bad = GuardPolicy { nonfinite_streak: 1, ewma_alpha: 0.0,
                                ..GuardPolicy::default() };
        assert!(bad.validate().is_err());
    }
}
