//! ZO-gradient diagnostics through the live runtime.
//!
//! At fixed parameters and a fixed batch, resample the perturbation seed k
//! times and study the distribution of the projected gradient
//! ``kappa = (f+ - f-) / (2 rho)``:
//!
//! * `E[kappa^2]` estimates `E[<g, Z>^2] / ||...||` up to the estimator's
//!   variance constant — Theorem 1's delta shows up as the *ratio* of
//!   kappa-second-moments between estimators with different (m, n, r);
//! * the sign consistency of kappa across seeds measures how informative a
//!   single two-point probe is at the current point (the quantity that
//!   makes ZO fine-tuning work at all).
//!
//! `tezo probe-variance` exposes this per method; EXPERIMENTS.md E11 uses
//! it as the live-system complement to the Monte-Carlo Theorem-1 tests.

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;
use crate::coordinator::optimizer::{build_optimizer, ForwardOut, StepCtx};
use crate::coordinator::seeds::SeedSchedule;
use crate::data::Batch;
use crate::runtime::{ParamStore, Runtime};
use crate::tensor::stats;

/// Distribution summary of kappa over `k` independent seeds.
#[derive(Clone, Debug)]
pub struct KappaStats {
    pub method: Method,
    pub samples: usize,
    pub mean: f64,
    pub std: f64,
    pub second_moment: f64,
    /// fraction of draws agreeing with the majority sign
    pub sign_consistency: f64,
}

/// Probe the kappa distribution for `method` at the given parameters.
///
/// Uses sub-perturbation indices of step 0 so every draw is an independent
/// stream from the schedule without advancing training state. The update
/// phase never runs — parameters are untouched.
pub fn kappa_distribution(rt: &Runtime, params: &mut ParamStore, batch: &Batch,
                          method: Method, rho: f32, k: usize, seed: u64)
                          -> Result<KappaStats> {
    let cfg = TrainConfig { method, rho, seed, ..Default::default() };
    let seeds = SeedSchedule::new(seed);
    let mut driver = build_optimizer(rt, &cfg, &seeds)?;
    let mut timers = PhaseTimers::default();
    let mut counter = SampleCounter::default();
    let mut kappas = Vec::with_capacity(k);
    for i in 0..k {
        // walk the *step* index (sub is capped at 64 by the schedule); the
        // probe batch is fixed, so the content-addressed arena keeps
        // reusing one staged copy across all k forwards
        let arena = rt.step_arena(i as u64);
        let mut ctx = StepCtx {
            rt,
            params,
            batch,
            cfg: &cfg,
            seeds: &seeds,
            step: i as u64,
            sub: 0,
            lr: cfg.lr,
            form: cfg.forward_form.resolve_fallback(),
            timers: &mut timers,
            counter: &mut counter,
            arena: &arena,
        };
        match driver.forward(&mut ctx)? {
            ForwardOut::TwoPoint { f_plus, f_minus } => {
                kappas.push(((f_plus - f_minus) / (2.0 * rho)) as f64);
            }
            ForwardOut::Loss(_) => {
                anyhow::bail!("probe requires a ZO method");
            }
        }
    }
    let mean = stats::mean(&kappas);
    let std = stats::std_dev(&kappas);
    let m2 = kappas.iter().map(|k| k * k).sum::<f64>() / kappas.len() as f64;
    let pos = kappas.iter().filter(|&&k| k > 0.0).count();
    let sign = pos.max(kappas.len() - pos) as f64 / kappas.len() as f64;
    Ok(KappaStats {
        method,
        samples: k,
        mean,
        std,
        second_moment: m2,
        sign_consistency: sign,
    })
}

#[cfg(test)]
mod tests {
    // runtime-dependent tests live in rust/tests/integration_train.rs
}
