//! The training loop: two-point evaluation, projected gradient, update.
//!
//! Per step (paper Alg. 1):
//!   1. sample the batch (seeded from the `Data` stream — reproducible and
//!      decorrelated from the perturbation stream);
//!   2. `forward` — ONE artifact call computes both `f(W + rho Z)` and
//!      `f(W - rho Z)` (Z regenerated from the step seed / factor panels);
//!   3. `kappa = (f+ - f-) / (2 rho)` on host;
//!   4. `update` — the method's update artifact; parameter buffers swap in
//!      place, optimizer state evolves (O(r) on host for the TeZO family).
//!
//! Steps 2-4 live in [`StepEngine`] (shared with the data-parallel
//! [`crate::fleet`]); this type owns the run loop, data plumbing, eval
//! hooks, and metrics. Every phase is timed (Fig 3b), every random draw
//! counted (Table 2).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::eval;
use crate::coordinator::metrics::{Phase, TrainMetrics};
use crate::coordinator::optimizer::build_optimizer;
use crate::coordinator::seeds::SeedSchedule;
use crate::coordinator::step::StepEngine;
use crate::data::{Batch, BatchBuilder, Corpus};
use crate::jsonx::Value;
use crate::runtime::{ParamStore, Runtime};
use crate::telemetry::{Stopwatch, Telemetry};

/// Where training batches come from.
pub enum DataSource {
    /// few-shot classification task (Tables 3/4/5 protocol)
    Task(BatchBuilder),
    /// LM corpus (end-to-end driver)
    Corpus { corpus: Corpus, batch: usize },
}

impl DataSource {
    /// Build the batch for `step` from a `Stream::Data` seed (see
    /// [`SeedSchedule::data_seed`] / [`SeedSchedule::shard_data_seed`]).
    pub fn batch(&self, data_seed: u64, step: u64) -> Batch {
        match self {
            DataSource::Task(bb) => bb.train_batch(data_seed, step),
            DataSource::Corpus { corpus, batch } => {
                BatchBuilder::corpus_batch(corpus, *batch, data_seed, step)
            }
        }
    }
}

/// Result of one training run.
pub struct TrainOutcome {
    pub metrics: TrainMetrics,
    pub counter: SampleCounter,
    pub state_bytes: u64,
    /// non-finite loss steps that were skipped
    pub skipped: u64,
    /// host→device staging traffic over the run (uploads, reuses,
    /// residency) — see `runtime::stage`
    pub staging: crate::runtime::StageStats,
}

/// Drives one fine-tuning job.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub engine: StepEngine,
    pub data: DataSource,
    /// optional per-step observer (step, loss)
    pub on_step: Option<Box<dyn FnMut(u64, f64) + 'a>>,
    /// eval batches for the periodic accuracy hook
    pub eval_set: Option<(Vec<Batch>, Vec<i32>)>,
    /// tracer handle (disabled by default; `--telemetry-dir` enables it)
    pub telemetry: Telemetry,
    /// autotuner resolution record, forwarded into the outcome's
    /// `summary_json` as the `tuning` block
    pub tuning: Option<Value>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: TrainConfig, data: DataSource) -> Self {
        Self {
            rt,
            engine: StepEngine::new(cfg),
            data,
            on_step: None,
            eval_set: None,
            telemetry: Telemetry::off(),
            tuning: None,
        }
    }

    /// Attach a held-out eval set (batches + candidate label tokens).
    pub fn with_eval(mut self, batches: Vec<Batch>, label_tokens: Vec<i32>) -> Self {
        self.eval_set = Some((batches, label_tokens));
        self
    }

    /// Attach a tracer: phase spans, step spans, and loss/kappa counters
    /// land in its ring (observational only — never fed back into seeds).
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// Attach the autotuner's resolution record (see
    /// [`crate::runtime::tune::Resolution::summary_json`]).
    pub fn with_tuning(mut self, tuning: Value) -> Self {
        self.tuning = Some(tuning);
        self
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.engine.cfg
    }

    pub fn seeds(&self) -> &SeedSchedule {
        &self.engine.seeds
    }

    /// Run the configured number of steps.
    pub fn run(&mut self, params: &mut ParamStore) -> Result<TrainOutcome> {
        self.engine.cfg.validate()?;
        let engine = self.engine.clone();
        let steps = engine.cfg.steps as u64;
        let mut driver = build_optimizer(self.rt, &engine.cfg, &engine.seeds)?;
        let mut metrics = TrainMetrics::default();
        metrics.tuning = self.tuning.clone();
        let mut counter = SampleCounter::default();
        let mut skipped = 0u64;
        let staged0 = self.rt.stage().stats();
        metrics.timers.set_telemetry(self.telemetry.clone());
        let wall0 = Stopwatch::start();
        let run0 = self.telemetry.now_ns();

        for step in 0..steps {
            metrics.timers.set_span_step(step as i64);
            let step0 = self.telemetry.now_ns();
            let dseed = engine.seeds.data_seed(step);
            let batch = metrics
                .timers
                .time(Phase::Sampling, || self.data.batch(dseed, step));
            let loss = engine.step(self.rt, &mut *driver, params, &batch, step,
                                   &mut metrics.timers, &mut counter)?;
            self.telemetry.span_from("step", "step", step0, 0, step as i64);
            self.telemetry.counter("step", "loss", loss, step as i64);
            if loss.is_finite() {
                metrics.record_loss(loss);
            } else {
                skipped += 1;
                metrics.record_loss(f64::NAN);
            }
            if let Some(cb) = self.on_step.as_mut() {
                cb(step, loss);
            }
            if engine.cfg.eval_every > 0
                && (step + 1) % engine.cfg.eval_every as u64 == 0
            {
                if let Some((batches, labels)) = &self.eval_set {
                    let acc = eval::accuracy(self.rt, params, batches, labels)?;
                    metrics.evals.push((step + 1, acc));
                }
            }
        }
        // final eval, unless the periodic hook already scored the last step
        let evaled_at_end = engine.cfg.eval_every > 0
            && steps % engine.cfg.eval_every as u64 == 0;
        if !evaled_at_end {
            if let Some((batches, labels)) = &self.eval_set {
                let acc = eval::accuracy(self.rt, params, batches, labels)?;
                metrics.evals.push((steps, acc));
            }
        }
        metrics.timers.set_span_step(-1);
        self.telemetry.span_from("run", "train", run0, 0, -1);
        metrics.wall_seconds = wall0.elapsed_secs();
        Ok(TrainOutcome {
            metrics,
            counter,
            state_bytes: driver.state_bytes(),
            skipped,
            staging: self.rt.stage().stats().since(&staged0),
        })
    }
}
