//! The training loop: two-point evaluation, projected gradient, update.
//!
//! Per step (paper Alg. 1):
//!   1. sample the batch (seeded — reproducible);
//!   2. `forward` — ONE artifact call computes both `f(W + rho Z)` and
//!      `f(W - rho Z)` (Z regenerated from the step seed / factor panels);
//!   3. `kappa = (f+ - f-) / (2 rho)` on host;
//!   4. `update` — the method's update artifact; parameter buffers swap in
//!      place, optimizer state evolves (O(r) on host for the TeZO family).
//!
//! Every phase is timed (Fig 3b), every random draw counted (Table 2).

use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::eval;
use crate::coordinator::metrics::{Phase, TrainMetrics};
use crate::coordinator::optimizer::{build_optimizer, ForwardOut, StepCtx, ZoOptimizer};
use crate::coordinator::seeds::SeedSchedule;
use crate::data::{Batch, BatchBuilder, Corpus};
use crate::runtime::{ParamStore, Runtime};

/// Where training batches come from.
pub enum DataSource {
    /// few-shot classification task (Tables 3/4/5 protocol)
    Task(BatchBuilder),
    /// LM corpus (end-to-end driver)
    Corpus { corpus: Corpus, batch: usize },
}

impl DataSource {
    fn batch(&self, seed: u64, step: u64) -> Batch {
        match self {
            DataSource::Task(bb) => bb.train_batch(seed, step),
            DataSource::Corpus { corpus, batch } => {
                BatchBuilder::corpus_batch(corpus, *batch, seed, step)
            }
        }
    }
}

/// Result of one training run.
pub struct TrainOutcome {
    pub metrics: TrainMetrics,
    pub counter: SampleCounter,
    pub state_bytes: u64,
    /// non-finite loss steps that were skipped
    pub skipped: u64,
}

/// Drives one fine-tuning job.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub cfg: TrainConfig,
    pub data: DataSource,
    pub seeds: SeedSchedule,
    /// optional per-step observer (step, loss)
    pub on_step: Option<Box<dyn FnMut(u64, f64) + 'a>>,
    /// eval batches for the periodic accuracy hook
    pub eval_set: Option<(Vec<Batch>, Vec<i32>)>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: TrainConfig, data: DataSource) -> Self {
        let seeds = SeedSchedule::new(cfg.seed);
        Self { rt, cfg, data, seeds, on_step: None, eval_set: None }
    }

    /// Attach a held-out eval set (batches + candidate label tokens).
    pub fn with_eval(mut self, batches: Vec<Batch>, label_tokens: Vec<i32>) -> Self {
        self.eval_set = Some((batches, label_tokens));
        self
    }

    /// Run the configured number of steps.
    pub fn run(&mut self, params: &mut ParamStore) -> Result<TrainOutcome> {
        self.cfg.validate()?;
        let mut driver = build_optimizer(self.rt, &self.cfg, &self.seeds)?;
        let mut metrics = TrainMetrics::default();
        let mut counter = SampleCounter::default();
        let mut skipped = 0u64;
        let wall0 = Instant::now();

        for step in 0..self.cfg.steps as u64 {
            let batch = metrics
                .timers
                .time(Phase::Sampling, || self.data.batch(self.cfg.seed, step));
            let loss = self.step(&mut *driver, params, &batch, step,
                                  &mut metrics, &mut counter)?;
            if loss.is_finite() {
                metrics.record_loss(loss);
            } else {
                skipped += 1;
                metrics.record_loss(f64::NAN);
            }
            if let Some(cb) = self.on_step.as_mut() {
                cb(step, loss);
            }
            if self.cfg.eval_every > 0
                && (step + 1) % self.cfg.eval_every as u64 == 0
            {
                if let Some((batches, labels)) = &self.eval_set {
                    let acc = eval::accuracy(self.rt, params, batches, labels)?;
                    metrics.evals.push((step + 1, acc));
                }
            }
        }
        // final eval
        if let Some((batches, labels)) = &self.eval_set {
            let acc = eval::accuracy(self.rt, params, batches, labels)?;
            metrics.evals.push((self.cfg.steps as u64, acc));
        }
        metrics.wall_seconds = wall0.elapsed().as_secs_f64();
        Ok(TrainOutcome {
            metrics,
            counter,
            state_bytes: driver.state_bytes(),
            skipped,
        })
    }

    /// One optimization step; returns the (two-point mean) loss.
    ///
    /// With `n_perturb = q > 1` (q-SPSA), the step averages q independent
    /// perturbation directions: each sub-perturbation runs its own fused
    /// two-point forward and applies its update scaled by `kappa / q`
    /// (exactly the mean direction for the linear SGD-form updates —
    /// `TrainConfig::validate` rejects stateful methods).
    fn step(&self, driver: &mut dyn ZoOptimizer, params: &mut ParamStore,
            batch: &Batch, step: u64, metrics: &mut TrainMetrics,
            counter: &mut SampleCounter) -> Result<f64> {
        let q = self.cfg.n_perturb.max(1) as u32;
        let lr_eff = self.cfg.lr_schedule.at(self.cfg.lr, step, self.cfg.steps);
        let mut loss_acc = 0.0f64;
        for sub in 0..q {
            let mut ctx = StepCtx {
                rt: self.rt,
                params,
                batch,
                cfg: &self.cfg,
                seeds: &self.seeds,
                step,
                sub,
                lr: lr_eff / q as f32,
                timers: &mut metrics.timers,
                counter,
            };
            let fwd = driver.forward(&mut ctx)?;
            let (loss, kappa) = match fwd {
                ForwardOut::TwoPoint { f_plus, f_minus } => {
                    let kappa = (f_plus - f_minus) / (2.0 * self.cfg.rho);
                    (((f_plus + f_minus) * 0.5) as f64, kappa)
                }
                ForwardOut::Loss(l) => (l as f64, 0.0),
            };
            if !loss.is_finite() || !kappa.is_finite() {
                // skip the update; the run records the NaN and continues
                return Ok(loss);
            }
            let kappa = if self.cfg.kappa_clip > 0.0 {
                kappa.clamp(-self.cfg.kappa_clip, self.cfg.kappa_clip)
            } else {
                kappa
            };
            // FO driver ignores kappa and must see the full lr
            if matches!(driver.method(), crate::config::Method::FoAdam) {
                ctx.lr = lr_eff;
            }
            driver.update(&mut ctx, kappa)?;
            loss_acc += loss;
        }
        Ok(loss_acc / q as f64)
    }
}
