//! The training loop: two-point evaluation, projected gradient, update.
//!
//! Per step (paper Alg. 1):
//!   1. sample the batch (seeded from the `Data` stream — reproducible and
//!      decorrelated from the perturbation stream);
//!   2. `forward` — ONE artifact call computes both `f(W + rho Z)` and
//!      `f(W - rho Z)` (Z regenerated from the step seed / factor panels);
//!   3. `kappa = (f+ - f-) / (2 rho)` on host;
//!   4. `update` — the method's update artifact; parameter buffers swap in
//!      place, optimizer state evolves (O(r) on host for the TeZO family).
//!
//! Steps 2-4 live in [`StepEngine`] (shared with the data-parallel
//! [`crate::fleet`]); this type owns the run loop, data plumbing, eval
//! hooks, and metrics. Every phase is timed (Fig 3b), every random draw
//! counted (Table 2).
//!
//! ## Durability (PR 10)
//!
//! With [`with_checkpointing`](Trainer::with_checkpointing) the run keeps a
//! write-ahead `(step, sub, seed, kappa)` journal
//! ([`crate::runtime::journal`]) next to its retained, digest-verified
//! checkpoints: every update is journaled *before* it is applied, so
//! [`with_resume`](Trainer::with_resume) can reload the newest verifiable
//! checkpoint and replay the journal tail **update-only** (no forward
//! passes) to land bitwise on the uninterrupted trajectory. A
//! [`GuardPolicy`] additionally watches the loss stream and rolls a
//! diverging run back to the last good checkpoint. See docs/robustness.md.

use std::path::PathBuf;

use anyhow::{ensure, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::eval;
use crate::coordinator::guard::{GuardPolicy, GuardState};
use crate::coordinator::metrics::{Phase, TrainMetrics};
use crate::coordinator::optimizer::build_optimizer;
use crate::coordinator::seeds::SeedSchedule;
use crate::coordinator::step::StepEngine;
use crate::data::{Batch, BatchBuilder, Corpus};
use crate::jsonx::Value;
use crate::runtime::journal::plan_replay;
use crate::runtime::{checkpoint, Journal, JournalEntry, ParamStore, Runtime};
use crate::telemetry::{Stopwatch, Telemetry};

/// Where training batches come from.
pub enum DataSource {
    /// few-shot classification task (Tables 3/4/5 protocol)
    Task(BatchBuilder),
    /// LM corpus (end-to-end driver)
    Corpus { corpus: Corpus, batch: usize },
}

impl DataSource {
    /// Build the batch for `step` from a `Stream::Data` seed (see
    /// [`SeedSchedule::data_seed`] / [`SeedSchedule::shard_data_seed`]).
    pub fn batch(&self, data_seed: u64, step: u64) -> Batch {
        match self {
            DataSource::Task(bb) => bb.train_batch(data_seed, step),
            DataSource::Corpus { corpus, batch } => {
                BatchBuilder::corpus_batch(corpus, *batch, data_seed, step)
            }
        }
    }
}

/// Checkpoint cadence + retention for a durable run.
#[derive(Clone, Debug)]
pub struct CheckpointPlan {
    /// checkpoint directory (also holds `journal.bin`)
    pub dir: PathBuf,
    /// save every N completed steps (0 = only the guard's step-0 fallback)
    pub every: u64,
    /// retained checkpoints (see [`checkpoint::KEEP_DEFAULT`])
    pub keep: usize,
}

/// Result of one training run.
pub struct TrainOutcome {
    pub metrics: TrainMetrics,
    pub counter: SampleCounter,
    pub state_bytes: u64,
    /// non-finite loss steps that were skipped
    pub skipped: u64,
    /// host→device staging traffic over the run (uploads, reuses,
    /// residency) — see `runtime::stage`
    pub staging: crate::runtime::StageStats,
}

/// Drives one fine-tuning job.
pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub engine: StepEngine,
    pub data: DataSource,
    /// optional per-step observer (step, loss)
    pub on_step: Option<Box<dyn FnMut(u64, f64) + 'a>>,
    /// eval batches for the periodic accuracy hook
    pub eval_set: Option<(Vec<Batch>, Vec<i32>)>,
    /// tracer handle (disabled by default; `--telemetry-dir` enables it)
    pub telemetry: Telemetry,
    /// autotuner resolution record, forwarded into the outcome's
    /// `summary_json` as the `tuning` block
    pub tuning: Option<Value>,
    /// durable checkpoint + journal plan (`None` = in-memory run)
    pub checkpointing: Option<CheckpointPlan>,
    /// resume from the plan's directory instead of starting fresh
    pub resume: bool,
    /// divergence guard thresholds (`Default` = disabled)
    pub guard: GuardPolicy,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: TrainConfig, data: DataSource) -> Self {
        Self {
            rt,
            engine: StepEngine::new(cfg),
            data,
            on_step: None,
            eval_set: None,
            telemetry: Telemetry::off(),
            tuning: None,
            checkpointing: None,
            resume: false,
            guard: GuardPolicy::default(),
        }
    }

    /// Attach a held-out eval set (batches + candidate label tokens).
    pub fn with_eval(mut self, batches: Vec<Batch>, label_tokens: Vec<i32>) -> Self {
        self.eval_set = Some((batches, label_tokens));
        self
    }

    /// Attach a tracer: phase spans, step spans, and loss/kappa counters
    /// land in its ring (observational only — never fed back into seeds).
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// Attach the autotuner's resolution record (see
    /// [`crate::runtime::tune::Resolution::summary_json`]).
    pub fn with_tuning(mut self, tuning: Value) -> Self {
        self.tuning = Some(tuning);
        self
    }

    /// Checkpoint every `every` completed steps under `dir`, keeping the
    /// last `keep` checkpoints, and journal every update durably.
    pub fn with_checkpointing(mut self, dir: impl Into<PathBuf>, every: u64,
                              keep: usize) -> Self {
        self.checkpointing = Some(CheckpointPlan { dir: dir.into(), every, keep });
        self
    }

    /// Resume from the checkpoint directory: newest verifiable checkpoint,
    /// then update-only journal replay, then live training.
    pub fn with_resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Arm the divergence guard (requires a checkpoint plan to roll back to).
    pub fn with_guard(mut self, guard: GuardPolicy) -> Self {
        self.guard = guard;
        self
    }

    pub fn cfg(&self) -> &TrainConfig {
        &self.engine.cfg
    }

    pub fn seeds(&self) -> &SeedSchedule {
        &self.engine.seeds
    }

    /// Run the configured number of steps.
    pub fn run(&mut self, params: &mut ParamStore) -> Result<TrainOutcome> {
        self.engine.cfg.validate()?;
        self.guard.validate()?;
        let plan = self.checkpointing.clone();
        if self.resume {
            ensure!(plan.is_some(),
                    "--resume needs a checkpoint directory (with_checkpointing)");
        }
        if self.guard.enabled() {
            ensure!(plan.is_some(),
                    "the divergence guard needs a checkpoint directory to \
                     roll back to (with_checkpointing)");
        }
        if self.resume || self.guard.enabled() {
            ensure!(self.engine.cfg.method.statelessly_replayable(),
                    "method {:?} cannot replay updates from (seed, kappa) \
                     records; --resume and the divergence guard need a \
                     statelessly replayable method",
                    self.engine.cfg.method);
        }

        let engine = self.engine.clone();
        let steps = engine.cfg.steps as u64;
        let q = engine.n_sub();
        let mut driver = build_optimizer(self.rt, &engine.cfg, &engine.seeds)?;
        let mut metrics = TrainMetrics::default();
        metrics.tuning = self.tuning.clone();
        let mut counter = SampleCounter::default();
        let mut skipped = 0u64;
        let staged0 = self.rt.stage().stats();
        metrics.timers.set_telemetry(self.telemetry.clone());
        let wall0 = Stopwatch::start();
        let run0 = self.telemetry.now_ns();

        // durable journal: recovered entries drive resume; a fresh run must
        // not inherit a stale log from an earlier run in the same directory
        let mut journal: Option<Journal> = None;
        let mut recovered: Vec<JournalEntry> = Vec::new();
        if let Some(plan) = &plan {
            let (mut j, entries) =
                Journal::open(&plan.dir.join("journal.bin"), engine.cfg.seed)?;
            if self.resume {
                recovered = entries;
            } else if !j.is_empty() {
                j.truncate_from_step(0)?;
            }
            journal = Some(j);
        }

        // resume: newest verifiable checkpoint, then update-only replay of
        // the journal tail. A trailing step interrupted mid-write is
        // truncated and re-run live — its forwards are deterministic, so
        // the re-run is bitwise identical to what the crash cut short.
        let mut start_step = 0u64;
        if self.resume {
            if let Some(plan) = &plan {
                let mut ckpt_step = 0u64;
                if !checkpoint::candidates(&plan.dir).is_empty() {
                    let (store, step) = checkpoint::load_with_fallback(
                        &plan.dir, &self.rt.client, &self.rt.manifest)
                        .with_context(|| format!("resuming from {}",
                                                 plan.dir.display()))?;
                    *params = store;
                    ckpt_step = step;
                }
                let replay = plan_replay(&recovered, ckpt_step, q)?;
                if let Some(partial) = replay.partial {
                    if let Some(j) = journal.as_mut() {
                        j.truncate_from_step(partial)?;
                    }
                }
                let mut replayed = 0u64;
                for (step, group) in &replay.steps {
                    let dseed = engine.seeds.data_seed(*step);
                    let batch = metrics.timers.time(Phase::Sampling,
                                                    || self.data.batch(dseed, *step));
                    for e in group {
                        ensure!(e.perturb_seed
                                    == engine.seeds.perturb_seed(e.step, e.sub),
                                "journal step {} sub {} carries seed {:#010x} \
                                 but this run's schedule derives {:#010x} — \
                                 the journal belongs to a different run",
                                e.step, e.sub, e.perturb_seed,
                                engine.seeds.perturb_seed(e.step, e.sub));
                        if let Some(kappa) = e.kappa {
                            engine.update_sub(self.rt, &mut *driver, params,
                                              &batch, e.step, e.sub, kappa,
                                              &mut metrics.timers, &mut counter)?;
                        }
                        replayed += 1;
                    }
                }
                start_step = replay.partial
                    .or_else(|| replay.steps.last().map(|(s, _)| s + 1))
                    .unwrap_or(ckpt_step);
                metrics.resumed_from = Some(ckpt_step);
                self.telemetry.counter("resume", "replayed", replayed as f64,
                                       start_step as i64);
                self.telemetry.mark("resume", "resumed", 0, start_step as i64);
            }
        }

        // an armed guard always has somewhere to roll back to: publish the
        // initial params as a step-0 checkpoint when none exists yet
        let mut guard = GuardState::new(self.guard);
        let mut suppress = 0usize;
        if let Some(plan) = &plan {
            if self.guard.enabled() && checkpoint::candidates(&plan.dir).is_empty() {
                checkpoint::save_retained(&plan.dir, &self.rt.manifest, params,
                                          0, plan.keep)?;
            }
        }

        let mut step = start_step;
        while step < steps {
            metrics.timers.set_span_step(step as i64);
            let step0 = self.telemetry.now_ns();
            let dseed = engine.seeds.data_seed(step);
            let batch = metrics
                .timers
                .time(Phase::Sampling, || self.data.batch(dseed, step));
            let loss = if suppress > 0 {
                // post-rollback suppression: measure the loss but journal a
                // skip instead of updating — the same footprint as a
                // lockstep non-finite skip, so replay stays exact
                suppress -= 1;
                let fwd = engine.forward_sub(self.rt, &mut *driver, params,
                                             &batch, step, 0,
                                             &mut metrics.timers, &mut counter)?;
                let (loss, _) = engine.combine(&fwd);
                if let Some(j) = journal.as_mut() {
                    j.append(&JournalEntry {
                        step,
                        sub: 0,
                        perturb_seed: engine.seeds.perturb_seed(step, 0),
                        kappa: None,
                    })?;
                }
                self.telemetry.counter("guard", "suppressed", 1.0, step as i64);
                loss
            } else {
                engine.step_observed(
                    self.rt, &mut *driver, params, &batch, step,
                    &mut metrics.timers, &mut counter,
                    &mut |s, sub, seed, kappa| {
                        if let Some(j) = journal.as_mut() {
                            j.append(&JournalEntry {
                                step: s,
                                sub,
                                perturb_seed: seed,
                                kappa,
                            })?;
                        }
                        Ok(())
                    })?
            };
            self.telemetry.span_from("step", "step", step0, 0, step as i64);
            self.telemetry.counter("step", "loss", loss, step as i64);
            if loss.is_finite() {
                metrics.record_loss(loss);
            } else {
                skipped += 1;
                metrics.record_loss(f64::NAN);
            }
            if let Some(cb) = self.on_step.as_mut() {
                cb(step, loss);
            }

            if let Some(reason) = guard.observe(loss) {
                ensure!(guard.can_roll_back(),
                        "divergence guard tripped at step {step} ({reason}) \
                         with the rollback budget ({}) exhausted",
                        self.guard.max_rollbacks);
                if let Some(plan) = &plan {
                    self.telemetry.mark("guard", "rollback", 0, step as i64);
                    self.telemetry.counter("guard", "rollback", 1.0, step as i64);
                    let (store, good_step) = checkpoint::load_with_fallback(
                        &plan.dir, &self.rt.client, &self.rt.manifest)
                        .with_context(|| format!(
                            "guard rollback at step {step} ({reason})"))?;
                    *params = store;
                    if let Some(j) = journal.as_mut() {
                        j.truncate_from_step(good_step)?;
                    }
                    // stateless methods rebuild optimizer state from seeds
                    driver = build_optimizer(self.rt, &engine.cfg, &engine.seeds)?;
                    guard.rolled_back();
                    metrics.rollbacks += 1;
                    suppress = self.guard.skip_steps;
                    step = good_step;
                    continue;
                }
            }

            if engine.cfg.eval_every > 0
                && (step + 1) % engine.cfg.eval_every as u64 == 0
            {
                if let Some((batches, labels)) = &self.eval_set {
                    let acc = eval::accuracy(self.rt, params, batches, labels)?;
                    metrics.evals.push((step + 1, acc));
                }
            }

            if let Some(plan) = &plan {
                if plan.every > 0 && (step + 1) % plan.every == 0 {
                    checkpoint::save_retained(&plan.dir, &self.rt.manifest,
                                              params, step + 1, plan.keep)?;
                    // prune the journal to the *oldest retained* checkpoint,
                    // not the newest: if the newest descriptor is later found
                    // corrupt, the fallback checkpoint still needs its replay
                    // tail in the journal
                    if let Some(j) = journal.as_mut() {
                        let floor = checkpoint::list_retained(&plan.dir)
                            .last()
                            .map(|&(s, _)| s)
                            .unwrap_or(step + 1);
                        j.retain_from_step(floor)?;
                    }
                    self.telemetry.mark("checkpoint", "saved", 0,
                                        (step + 1) as i64);
                }
            }
            step += 1;
        }
        // final eval, unless the periodic hook already scored the last step
        let evaled_at_end = engine.cfg.eval_every > 0
            && steps % engine.cfg.eval_every as u64 == 0;
        if !evaled_at_end {
            if let Some((batches, labels)) = &self.eval_set {
                let acc = eval::accuracy(self.rt, params, batches, labels)?;
                metrics.evals.push((steps, acc));
            }
        }
        metrics.timers.set_span_step(-1);
        self.telemetry.span_from("run", "train", run0, 0, -1);
        metrics.wall_seconds = wall0.elapsed_secs();
        metrics.nonfinite_skips = skipped;
        Ok(TrainOutcome {
            metrics,
            counter,
            state_bytes: driver.state_bytes(),
            skipped,
            staging: self.rt.stage().stats().since(&staged0),
        })
    }
}
