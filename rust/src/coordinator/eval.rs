//! Evaluation: classification accuracy via verbalizer logits, and LM
//! perplexity for the end-to-end driver.

use anyhow::Result;

use crate::data::Batch;
use crate::runtime::exec::{scalar_f32, to_vec_f32};
use crate::runtime::{ArgValue, ParamStore, Runtime};

/// Accuracy over eval batches: for each row, read the logits at the SEP
/// position and argmax over the candidate `label_tokens` (the MeZO scoring
/// protocol).
pub fn accuracy(rt: &Runtime, params: &ParamStore, batches: &[Batch],
                label_tokens: &[i32]) -> Result<f64> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in batches {
        let out = rt
            .call("eval_logits")?
            .bufs(params.bufs())?
            .arg(ArgValue::I32(&b.tokens))?
            .arg(ArgValue::I32(&b.positions))?
            .run()?;
        let logits = to_vec_f32(&out[0])?; // (B, V)
        let vocab = logits.len() / b.batch;
        for row in 0..b.batch {
            let row_logits = &logits[row * vocab..(row + 1) * vocab];
            let pred = label_tokens
                .iter()
                .enumerate()
                .max_by(|(_, &a), (_, &c)| {
                    row_logits[a as usize]
                        .partial_cmp(&row_logits[c as usize])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(i, _)| i)
                .unwrap_or(0);
            if pred == b.labels[row] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Mean masked LM loss over batches (perplexity = exp(loss)).
pub fn lm_loss(rt: &Runtime, params: &ParamStore, batches: &[Batch]) -> Result<f64> {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for b in batches {
        let out = rt
            .call("fwd_loss")?
            .bufs(params.bufs())?
            .arg(ArgValue::I32(&b.tokens))?
            .arg(ArgValue::I32(&b.targets))?
            .arg(ArgValue::F32(&b.mask))?
            .run()?;
        acc += scalar_f32(&out[0])? as f64;
        n += 1;
    }
    Ok(acc / n.max(1) as f64)
}
