//! Evaluation: classification accuracy via verbalizer logits, and LM
//! perplexity for the end-to-end driver.
//!
//! Eval sets are fixed for the life of a run, so their tensors are staged
//! through a *persistent* arena: the first eval pass uploads them, every
//! later pass (and the final-accuracy hook) reuses the resident device
//! buffers — zero host→device traffic on repeat evals.

use anyhow::Result;

use crate::data::Batch;
use crate::runtime::exec::{scalar_f32, to_vec_f32};
use crate::runtime::{ParamStore, Runtime};

/// Accuracy over eval batches: for each row, read the logits at the SEP
/// position and argmax over the candidate `label_tokens` (the MeZO scoring
/// protocol).
pub fn accuracy(rt: &Runtime, params: &ParamStore, batches: &[Batch],
                label_tokens: &[i32]) -> Result<f64> {
    let arena = rt.persistent_arena();
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in batches {
        let mut call = rt.prepared("eval_logits")?;
        call.bind_bufs("param", params.bufs())?;
        call.bind_i32("batch", "tokens", &b.tokens, &arena)?;
        call.bind_i32("batch", "positions", &b.positions, &arena)?;
        let out = call.run()?;
        let logits = to_vec_f32(&out[0])?; // (B, V)
        let vocab = logits.len() / b.batch;
        for row in 0..b.batch {
            let row_logits = &logits[row * vocab..(row + 1) * vocab];
            let pred = label_tokens
                .iter()
                .enumerate()
                .max_by(|(_, &a), (_, &c)| {
                    row_logits[a as usize]
                        .partial_cmp(&row_logits[c as usize])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(i, _)| i)
                .unwrap_or(0);
            if pred == b.labels[row] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Mean masked LM loss over batches (perplexity = exp(loss)).
pub fn lm_loss(rt: &Runtime, params: &ParamStore, batches: &[Batch]) -> Result<f64> {
    let arena = rt.persistent_arena();
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for b in batches {
        let mut call = rt.prepared("fwd_loss")?;
        call.bind_bufs("param", params.bufs())?;
        call.bind_i32("batch", "tokens", &b.tokens, &arena)?;
        call.bind_i32("batch", "targets", &b.targets, &arena)?;
        call.bind_f32("batch", "mask", &b.mask, &arena)?;
        let out = call.run()?;
        acc += scalar_f32(&out[0])? as f64;
        n += 1;
    }
    Ok(acc / n.max(1) as f64)
}
