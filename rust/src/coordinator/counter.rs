//! Table-2 accounting: how many random elements each method samples.
//!
//! The paper's Table 2 counts the *total generated random elements* for one
//! m x n weight over T iterations:
//!
//! | method | total            |
//! |--------|------------------|
//! | MeZO   | m*n*T            |
//! | SubZO  | (m+n+r)*r*T (amortized lazy: (m+n)r per refresh + r^2 per step) |
//! | LOZO   | (m+n)*r*T  (U lazily, V per step)                               |
//! | TeZO   | (m+n+T)*r  (U,V once + tau per step)                            |
//!
//! Drivers increment these counters at the moment they actually draw (or
//! cause an artifact to draw) random values, so the closed forms can be
//! *asserted* against the implementation (tests + bench_table2_sampling).

/// Cumulative sampled-element counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SampleCounter {
    /// draws that scale with matrix sizes (the Table-2 quantity)
    pub matrix_elements: u64,
    /// draws for 1D parameters (outside the paper's 2D accounting)
    pub vector_elements: u64,
}

impl SampleCounter {
    pub fn add_matrix(&mut self, n: u64) {
        self.matrix_elements += n;
    }

    pub fn add_vector(&mut self, n: u64) {
        self.vector_elements += n;
    }

    pub fn total(&self) -> u64 {
        self.matrix_elements + self.vector_elements
    }
}

/// Closed forms of Table 2 for one (m, n) weight after T steps.
pub mod closed_form {
    /// MeZO: a dense Z every step.
    pub fn mezo(m: u64, n: u64, t: u64) -> u64 {
        m * n * t
    }

    /// LOZO with lazy interval nu: V (n x r) per step + U (m x r) per window.
    pub fn lozo(m: u64, n: u64, r: u64, t: u64, nu: u64) -> u64 {
        let windows = t.div_ceil(nu.max(1));
        n * r * t + m * r * windows
    }

    /// SubZO with lazy interval nu: Sigma (r x r) per step + U,V per window.
    pub fn subzo(m: u64, n: u64, r: u64, t: u64, nu: u64) -> u64 {
        let windows = t.div_ceil(nu.max(1));
        r * r * t + (m + n) * r * windows
    }

    /// TeZO: U,V once + tau (r) per step — the (m+n+T)r headline.
    pub fn tezo(m: u64, n: u64, r: u64, t: u64) -> u64 {
        (m + n) * r + r * t
    }
}

#[cfg(test)]
mod tests {
    use super::closed_form::*;

    #[test]
    fn tezo_asymptotics_beat_baselines() {
        // the Table-2 ordering at LLM-ish sizes
        let (m, n, r, t) = (4096, 4096, 64, 15_000);
        let mezo = mezo(m, n, t);
        let lozo = lozo(m, n, r, t, 50);
        let subzo = subzo(m, n, r, t, 500);
        let tezo = tezo(m, n, r, t);
        assert!(tezo < lozo && tezo < subzo && tezo < mezo);
        assert!(lozo < mezo && subzo < mezo);
        // TeZO is O(sqrt(d) + T) vs O(sqrt(d) * T): at least 100x less here
        assert!((lozo as f64) / (tezo as f64) > 100.0);
    }

    #[test]
    fn lazy_windows_amortize() {
        // halving the refresh rate halves the U-draws
        let a = lozo(1000, 1000, 8, 1000, 50);
        let b = lozo(1000, 1000, 8, 1000, 100);
        assert!(b < a);
        assert_eq!(a - b, 1000 * 8 * 10); // 20 vs 10 windows
    }
}
