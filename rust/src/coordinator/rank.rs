//! Eq.(7) layer-wise rank selection, re-derived in Rust.
//!
//! The AOT pipeline bakes the rank schedule into artifact shapes (ranks are
//! compile-time). This module recomputes the schedule from the *shipped
//! initial weights* with the in-tree SVD and cross-checks the manifest —
//! the `tezo rank-probe` command and an integration test both run it.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::{Manifest, ParamStore};
use crate::tensor::svd;

/// Block index of a parameter (mirrors configs.py `block_of`).
pub fn block_of(name: &str, n_layers: usize) -> usize {
    if let Some(rest) = name.strip_prefix("block") {
        if let Some(dot) = rest.find('.') {
            if let Ok(i) = rest[..dot].parse::<usize>() {
                return i;
            }
        }
    }
    if name.starts_with("embed") {
        0
    } else {
        n_layers.saturating_sub(1)
    }
}

/// Recompute the Eq.(7) schedule from the current parameter values.
/// Returns name -> rank for every 2D weight.
pub fn rank_schedule(manifest: &Manifest, params: &ParamStore)
                     -> Result<BTreeMap<String, usize>> {
    let threshold = manifest.config.rank_threshold;
    let r_max = manifest.config.r_max;
    let n_layers = manifest.config.n_layers;
    // per-block min of Rank(W)
    let mut block_rank: BTreeMap<usize, usize> = BTreeMap::new();
    for p in manifest.matrix_params() {
        let w = params.fetch_matrix(&p.name)?;
        let r = svd::rank_at_threshold(&w, threshold, r_max, 0xEC7)?;
        let b = block_of(&p.name, n_layers);
        block_rank
            .entry(b)
            .and_modify(|cur| *cur = (*cur).min(r))
            .or_insert(r);
    }
    let mut out = BTreeMap::new();
    for p in manifest.matrix_params() {
        let b = block_of(&p.name, n_layers);
        out.insert(p.name.clone(), block_rank[&b].min(r_max).max(1));
    }
    Ok(out)
}

/// Compare the recomputed schedule against the manifest's baked ranks.
/// Returns mismatches as (name, manifest_rank, recomputed_rank).
pub fn verify_against_manifest(manifest: &Manifest, params: &ParamStore)
                               -> Result<Vec<(String, usize, usize)>> {
    let ours = rank_schedule(manifest, params)?;
    let mut mismatches = Vec::new();
    for mr in &manifest.matrix_ranks {
        let got = ours.get(&mr.name).copied().unwrap_or(0);
        if got != mr.rank {
            mismatches.push((mr.name.clone(), mr.rank, got));
        }
    }
    Ok(mismatches)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_of_matches_python() {
        assert_eq!(block_of("embed.tok", 4), 0);
        assert_eq!(block_of("embed.pos", 4), 0);
        assert_eq!(block_of("block0.attn.wq", 4), 0);
        assert_eq!(block_of("block3.ffn.w2", 4), 3);
        assert_eq!(block_of("final_ln.g", 4), 3);
    }
}
