//! Optimizer drivers: one per method row of the paper's tables.
//!
//! A driver owns the method-specific state (factor panels, tau vectors,
//! full-size moment buffers, lazy windows) and knows how to call its two
//! artifacts:
//!
//! * `forward(ctx)` — the fused two-point loss (`(f+, f-)`), or loss +
//!   cached grads for the first-order reference;
//! * `update(ctx, kappa)` — the parameter update, swapping the new buffers
//!   into the [`ParamStore`].
//!
//! All randomness flows through the step seed (resampling technique) or
//! through host-generated factor/tau vectors counted by [`SampleCounter`].

mod fo_adam;
mod lozo;
mod mezo;
mod subzo;
mod tezo;
mod zo_adamu;

pub use fo_adam::FoAdam;
pub use lozo::{Lozo, LozoM};
pub use mezo::{Mezo, MezoAdam, MezoM};
pub use subzo::Subzo;
pub use tezo::{Tezo, TezoAdam, TezoM};
pub use zo_adamu::ZoAdamu;

use anyhow::Result;

use crate::config::{ForwardForm, Method, TrainConfig};
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;
use crate::coordinator::seeds::SeedSchedule;
use crate::data::Batch;
use crate::runtime::{ParamStore, PreparedCall, Runtime, StepArena};

/// Everything a driver sees during one step.
pub struct StepCtx<'a> {
    pub rt: &'a Runtime,
    pub params: &'a mut ParamStore,
    pub batch: &'a Batch,
    pub cfg: &'a TrainConfig,
    pub seeds: &'a SeedSchedule,
    pub step: u64,
    /// q-SPSA sub-perturbation index (0 when n_perturb == 1)
    pub sub: u32,
    /// schedule-effective learning rate for this step
    pub lr: f32,
    /// the concrete two-point forward form this run dispatches — resolved
    /// once by the autotuner (or pinned by the config) before the engine
    /// was built; drivers use this, never the config policy
    pub form: ForwardForm,
    pub timers: &'a mut PhaseTimers,
    pub counter: &'a mut SampleCounter,
    /// step-scoped staging arena: host tensors bound through it are
    /// uploaded at most once per step and shared across the q-SPSA
    /// sub-forwards and the paired update call
    pub arena: &'a StepArena<'a>,
}

impl<'a> StepCtx<'a> {
    /// The per-(step, sub) perturbation seed (shared by forward and update).
    pub fn step_seed(&self) -> u32 {
        self.seeds.perturb_seed(self.step, self.sub)
    }

    /// The tau/factor derivation index for this (step, sub).
    pub fn perturb_index(&self) -> u64 {
        SeedSchedule::perturb_index(self.step, self.sub)
    }
}

/// Bind the training-batch slots (`batch/tokens|targets|mask`) through the
/// step arena — one upload per step, every loss artifact shares it.
pub(crate) fn bind_batch(call: &mut PreparedCall, batch: &Batch,
                         arena: &StepArena) -> Result<()> {
    call.bind_i32("batch", "tokens", &batch.tokens, arena)?;
    call.bind_i32("batch", "targets", &batch.targets, arena)?;
    call.bind_f32("batch", "mask", &batch.mask, arena)?;
    Ok(())
}

/// The outcome of the forward phase.
pub enum ForwardOut {
    /// two-point losses (ZO methods)
    TwoPoint { f_plus: f32, f_minus: f32 },
    /// plain loss (FO reference; grads cached inside the driver)
    Loss(f32),
}

/// One optimizer driver.
pub trait ZoOptimizer {
    fn method(&self) -> Method;

    /// Run the forward phase for `ctx.step`.
    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut>;

    /// Apply the update. `kappa` is the projected gradient
    /// `(f+ - f-) / (2 rho)` (unused by the FO driver).
    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()>;

    /// Bytes of optimizer state this driver holds resident (device + host) —
    /// cross-checked against the analytic memory model.
    fn state_bytes(&self) -> u64;
}

/// Construct the driver for `cfg.method` against an opened runtime.
pub fn build_optimizer(rt: &Runtime, cfg: &TrainConfig,
                       seeds: &SeedSchedule) -> Result<Box<dyn ZoOptimizer>> {
    Ok(match cfg.method {
        Method::Mezo => Box::new(Mezo::new()),
        Method::MezoM => Box::new(MezoM::new(rt)?),
        Method::MezoAdam => Box::new(MezoAdam::new(rt)?),
        Method::Lozo => Box::new(Lozo::new(rt, cfg, seeds)?),
        Method::LozoM => Box::new(LozoM::new(rt, cfg, seeds)?),
        Method::Subzo => Box::new(Subzo::new(rt, cfg, seeds)?),
        Method::ZoAdamu => Box::new(ZoAdamu::new(rt)?),
        Method::Tezo => Box::new(Tezo::new(rt, seeds)?),
        Method::TezoM => Box::new(TezoM::new(rt, seeds)?),
        Method::TezoAdam => Box::new(TezoAdam::new(rt, seeds)?),
        Method::FoAdam => Box::new(FoAdam::new(rt)?),
    })
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

/// Upload a zero-filled buffer of `shape`.
pub(crate) fn zeros_buf(rt: &Runtime, shape: &[usize]) -> Result<xla::PjRtBuffer> {
    let n: usize = shape.iter().product();
    let host = vec![0.0f32; n];
    Ok(rt.client.buffer_from_host_buffer(&host, shape, None)?)
}

/// One zero buffer per parameter (full-size moment state).
pub(crate) fn zeros_like_params(rt: &Runtime) -> Result<Vec<xla::PjRtBuffer>> {
    rt.manifest
        .params
        .iter()
        .map(|p| zeros_buf(rt, &p.shape))
        .collect()
}

/// Total f32 elements of the full-size parameter set.
pub(crate) fn param_elems(rt: &Runtime) -> u64 {
    rt.manifest.params.iter().map(|p| p.numel() as u64).sum()
}

/// Sum over 1D params of numel (the dense-1D draw count per step).
pub(crate) fn vector_elems(rt: &Runtime) -> u64 {
    rt.manifest.vector_params().iter().map(|p| p.numel() as u64).sum()
}

/// Sum over 2D params of numel.
pub(crate) fn matrix_elems(rt: &Runtime) -> u64 {
    rt.manifest.matrix_params().iter().map(|p| p.numel() as u64).sum()
}
