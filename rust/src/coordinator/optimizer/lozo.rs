//! LOZO drivers (Chen et al. 2024): `Z = U V^T`, V resampled in-HLO per
//! step, U refreshed lazily every `lazy_interval` steps via the
//! `lozo_init_u` artifact. LOZO-m accumulates momentum in the V-factor
//! (`S` state, n x r per matrix) while the U subspace is frozen; `S` resets
//! at each window boundary.

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::metrics::Phase;
use crate::coordinator::seeds::SeedSchedule;
use crate::runtime::exec::scalar_pair;
use crate::runtime::{Runtime, StepArena};
use crate::telemetry::Stopwatch;

use super::{bind_batch, vector_elems, zeros_buf, ForwardOut, StepCtx, ZoOptimizer};

/// Lazily-refreshed U panels.
struct LazyU {
    us: Vec<xla::PjRtBuffer>,
    window: u64,
    rank: usize,
    /// sum of m over matrices (U refresh draw count = m_sum * r)
    m_sum: u64,
    /// sum of n over matrices (V per-step draw count = n_sum * r)
    n_sum: u64,
}

impl LazyU {
    fn init(rt: &Runtime, _cfg: &TrainConfig, _seeds: &SeedSchedule) -> Result<LazyU> {
        let rank = rt.manifest.lozo_rank;
        let mats = rt.manifest.matrix_params();
        debug_assert!(mats.iter().all(|p| p.shape.len() == 2));
        let m_sum: u64 = mats.iter().map(|p| p.shape[0] as u64).sum();
        let n_sum: u64 = mats.iter().map(|p| p.shape[1] as u64).sum();
        // the first maybe_refresh (step 0) performs the initial draw so the
        // Table-2 accounting sees it (window = MAX forces it)
        Ok(LazyU { us: Vec::new(), window: u64::MAX, rank, m_sum, n_sum })
    }

    fn refresh(&mut self, rt: &Runtime, arena: &StepArena, seed: u32,
               window: u64) -> Result<()> {
        let mut call = rt.prepared("lozo_init_u")?;
        call.bind_scalar_u32("seed", seed, arena)?;
        self.us = call.run()?;
        self.window = window;
        Ok(())
    }

    /// Refresh if `step` entered a new lazy window; returns draws made.
    fn maybe_refresh(&mut self, ctx: &mut StepCtx) -> Result<u64> {
        let interval = ctx.cfg.lazy_interval.max(1) as u64;
        let window = ctx.step / interval;
        if window != self.window {
            let seed = ctx.seeds.window_seed(ctx.step, ctx.cfg.lazy_interval);
            self.refresh(ctx.rt, ctx.arena, seed, window)?;
            return Ok(self.m_sum * self.rank as u64);
        }
        Ok(0)
    }
}

/// Two-point forward shared by LOZO / LOZO-m. `ctx.form` (the resolved
/// autotuner/pin decision) selects the artifact; both forms share one
/// calling convention — see tezo.rs.
fn lozo_forward(ctx: &mut StepCtx, lazy: &LazyU) -> Result<ForwardOut> {
    let seed = ctx.step_seed();
    // per-step V draws (in-HLO) + dense 1D
    ctx.counter.add_matrix(lazy.n_sum * lazy.rank as u64);
    ctx.counter.add_vector(vector_elems(ctx.rt));
    let t0 = Stopwatch::start();
    let artifact = ctx.rt.manifest.loss_artifact(ctx.cfg.method, ctx.form);
    let mut call = ctx.rt.prepared(artifact)?;
    call.bind_bufs("param", ctx.params.bufs())?;
    call.bind_bufs("factor_u", &lazy.us)?;
    bind_batch(&mut call, ctx.batch, ctx.arena)?;
    call.bind_scalar_u32("seed", seed, ctx.arena)?;
    call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
    ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
    let out = ctx.timers.time(Phase::Forward, || call.run())?;
    let (f_plus, f_minus) = scalar_pair(&out)?;
    Ok(ForwardOut::TwoPoint { f_plus, f_minus })
}

/// Plain LOZO.
pub struct Lozo {
    lazy: LazyU,
}

impl Lozo {
    pub fn new(rt: &Runtime, cfg: &TrainConfig, seeds: &SeedSchedule) -> Result<Self> {
        Ok(Self { lazy: LazyU::init(rt, cfg, seeds)? })
    }
}

impl ZoOptimizer for Lozo {
    fn method(&self) -> Method {
        Method::Lozo
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        let draws = self.lazy.maybe_refresh(ctx)?;
        ctx.counter.add_matrix(draws);
        lozo_forward(ctx, &self.lazy)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let seed = ctx.step_seed();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("lozo_update_sgd")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("factor_u", &self.lazy.us)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("coeff", ctx.lr * kappa, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Update, || call.run())?;
        ctx.params.replace_all(out)
    }

    fn state_bytes(&self) -> u64 {
        self.lazy.m_sum * self.lazy.rank as u64 * 4
    }
}

/// LOZO-m: V-factor momentum `S` (n x r per matrix).
pub struct LozoM {
    lazy: LazyU,
    s: Vec<xla::PjRtBuffer>,
    s_elems: u64,
}

impl LozoM {
    pub fn new(rt: &Runtime, cfg: &TrainConfig, seeds: &SeedSchedule) -> Result<Self> {
        let lazy = LazyU::init(rt, cfg, seeds)?;
        let (s, s_elems) = Self::zero_s(rt, lazy.rank)?;
        Ok(Self { lazy, s, s_elems })
    }

    fn zero_s(rt: &Runtime, rank: usize) -> Result<(Vec<xla::PjRtBuffer>, u64)> {
        let mut s = Vec::new();
        let mut elems = 0u64;
        for p in rt.manifest.matrix_params() {
            debug_assert!(p.shape.len() == 2);
            let n = p.shape[1];
            s.push(zeros_buf(rt, &[n, rank])?);
            elems += (n * rank) as u64;
        }
        Ok((s, elems))
    }
}

impl ZoOptimizer for LozoM {
    fn method(&self) -> Method {
        Method::LozoM
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        let draws = self.lazy.maybe_refresh(ctx)?;
        if draws > 0 && ctx.step > 0 {
            // subspace changed: reset the V-space momentum
            let (s, _) = Self::zero_s(ctx.rt, self.lazy.rank)?;
            self.s = s;
        }
        ctx.counter.add_matrix(draws);
        lozo_forward(ctx, &self.lazy)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let seed = ctx.step_seed();
        let n = ctx.params.len();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("lozo_update_m")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("factor_u", &self.lazy.us)?;
        call.bind_bufs("state_s", &self.s)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("kappa", kappa, ctx.arena)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("beta1", ctx.cfg.beta1, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Update, || call.run())?;
        let new_s = out.split_off(n);
        ctx.params.replace_all(out)?;
        self.s = new_s;
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        (self.lazy.m_sum * self.lazy.rank as u64 + self.s_elems) * 4
    }
}
