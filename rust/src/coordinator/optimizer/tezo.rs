//! TeZO family drivers (this paper, Alg. 1).
//!
//! The CPD factor panels `U_l (m x r_l)`, `V_l (n x r_l)` are drawn ONCE at
//! construction (host RNG, counted as (m+n)r samples) and live as device
//! buffers for the whole run. Each step draws only the temporal factors
//! `tau_l (r_l)` — the O(sqrt(d) + T) sampling story of Table 2 — and the
//! momentum/Adam state is the tau-sized host vectors `tau_M`, `tau_V`
//! (the O(r) optimizer state that makes TeZO-Adam cheaper than MeZO-SGD).

use anyhow::Result;

use crate::config::Method;
use crate::coordinator::metrics::Phase;
use crate::coordinator::seeds::{SeedSchedule, Stream};
use crate::rngx::{normal_rng, SplitMix64};
use crate::runtime::exec::scalar_pair;
use crate::runtime::Runtime;
use crate::telemetry::Stopwatch;

use super::{bind_batch, vector_elems, ForwardOut, StepCtx, ZoOptimizer};

/// Shared factor-panel state.
struct Factors {
    /// per-matrix ranks (manifest order of matrix params)
    ranks: Vec<usize>,
    us: Vec<xla::PjRtBuffer>,
    vs: Vec<xla::PjRtBuffer>,
    /// (m+n)*r elements drawn at init
    init_draws: u64,
    /// factor elements resident on device
    factor_elems: u64,
}

impl Factors {
    fn init(rt: &Runtime, seeds: &SeedSchedule) -> Result<Factors> {
        let mats = rt.manifest.matrix_params();
        let mut ranks = Vec::with_capacity(mats.len());
        let mut us = Vec::with_capacity(mats.len());
        let mut vs = Vec::with_capacity(mats.len());
        let mut init_draws = 0u64;
        let mut factor_elems = 0u64;
        for (idx, p) in mats.iter().enumerate() {
            let r = rt.manifest.rank_of(&p.name)?;
            let (m, n) = (p.shape[0], p.shape[1]);
            let seed = seeds.seed64(Stream::FactorInit, idx as u64);
            let mut gen = normal_rng(seed);
            let mut u_host = vec![0.0f32; m * r];
            for x in u_host.iter_mut() {
                *x = gen.next_f32();
            }
            let mut v_host = vec![0.0f32; n * r];
            for x in v_host.iter_mut() {
                *x = gen.next_f32();
            }
            us.push(rt.client.buffer_from_host_buffer(&u_host, &[m, r], None)?);
            vs.push(rt.client.buffer_from_host_buffer(&v_host, &[n, r], None)?);
            ranks.push(r);
            init_draws += ((m + n) * r) as u64;
            factor_elems += ((m + n) * r) as u64;
        }
        Ok(Factors { ranks, us, vs, init_draws, factor_elems })
    }

    /// One zeroed tau-shaped buffer set (r_l floats per matrix) — the
    /// drivers preallocate these once and refill them in place every
    /// sub-step instead of allocating fresh `Vec<Vec<f32>>`s in the hot
    /// loop.
    fn tau_scratch(&self) -> Vec<Vec<f32>> {
        self.ranks.iter().map(|&r| vec![0.0f32; r]).collect()
    }

    /// Draw the tau vectors for one (step, sub) perturbation into `out`
    /// (host; r_l per matrix; `out` must be `tau_scratch()`-shaped).
    fn draw_taus_into(&self, master: &SeedSchedule, perturb_index: u64,
                      out: &mut [Vec<f32>]) {
        let base = master.seed64(Stream::Perturb, perturb_index);
        for (i, tau) in out.iter_mut().enumerate() {
            let mut gen = normal_rng(SplitMix64::mix(base, 0x7A0 + i as u64));
            for x in tau.iter_mut() {
                *x = gen.next_f32();
            }
        }
    }

    fn tau_draw_count(&self) -> u64 {
        self.ranks.iter().map(|&r| r as u64).sum()
    }
}

/// Fused two-point forward shared by all TeZO variants.
///
/// `ctx.form` — resolved once by the autotuner (or pinned by the config)
/// before the engine was built — selects the artifact: the implicit
/// factor-form one folds the rank-r perturbation into the matmuls
/// sign-batched, the materialized one builds dense `W +/- rho Z` copies.
/// Both share one calling convention, so only the name differs here.
fn tezo_forward(ctx: &mut StepCtx, factors: &Factors, taus: &[Vec<f32>])
                -> Result<ForwardOut> {
    let seed = ctx.step_seed();
    ctx.counter.add_matrix(factors.tau_draw_count());
    ctx.counter.add_vector(vector_elems(ctx.rt));
    let t0 = Stopwatch::start();
    let artifact = ctx.rt.manifest.loss_artifact(ctx.cfg.method, ctx.form);
    let mut call = ctx.rt.prepared(artifact)?;
    call.bind_bufs("param", ctx.params.bufs())?;
    call.bind_bufs("factor_u", &factors.us)?;
    call.bind_bufs("factor_v", &factors.vs)?;
    for (i, tau) in taus.iter().enumerate() {
        call.bind_nth_f32("tau", i, tau, ctx.arena)?;
    }
    bind_batch(&mut call, ctx.batch, ctx.arena)?;
    call.bind_scalar_u32("seed", seed, ctx.arena)?;
    call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
    ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
    let out = ctx.timers.time(Phase::Forward, || call.run())?;
    let (f_plus, f_minus) = scalar_pair(&out)?;
    Ok(ForwardOut::TwoPoint { f_plus, f_minus })
}

/// Factor-form update: `W -= U diag(tau_eff) V^T` + dense 1D SGD.
fn tezo_update_factor(ctx: &mut StepCtx, factors: &Factors,
                      tau_effs: &[Vec<f32>], coeff1d: f32) -> Result<()> {
    let seed = ctx.step_seed();
    let t0 = Stopwatch::start();
    let mut call = ctx.rt.prepared("tezo_update_factor")?;
    call.bind_bufs("param", ctx.params.bufs())?;
    call.bind_bufs("factor_u", &factors.us)?;
    call.bind_bufs("factor_v", &factors.vs)?;
    for (i, t) in tau_effs.iter().enumerate() {
        call.bind_nth_f32("tau_eff", i, t, ctx.arena)?;
    }
    // the forward half of this (step, sub) already staged this seed —
    // the arena hands back the same device buffer
    call.bind_scalar_u32("seed", seed, ctx.arena)?;
    call.bind_scalar_f32("coeff1d", coeff1d, ctx.arena)?;
    ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
    let out = ctx.timers.time(Phase::Update, || call.run())?;
    ctx.params.replace_all(out)
}

// ---------------------------------------------------------------------------
// TeZO (plain ZO-SGD form)
// ---------------------------------------------------------------------------

pub struct Tezo {
    factors: Factors,
    /// taus drawn in forward, reused in update (must match exactly);
    /// preallocated once, refilled in place per sub-step
    pending_taus: Vec<Vec<f32>>,
    /// scratch for the update's scaled taus (same shape, same lifetime)
    tau_eff: Vec<Vec<f32>>,
    counted_init: bool,
}

impl Tezo {
    pub fn new(rt: &Runtime, seeds: &SeedSchedule) -> Result<Self> {
        let factors = Factors::init(rt, seeds)?;
        let pending_taus = factors.tau_scratch();
        let tau_eff = factors.tau_scratch();
        Ok(Self { factors, pending_taus, tau_eff, counted_init: false })
    }
}

impl ZoOptimizer for Tezo {
    fn method(&self) -> Method {
        Method::Tezo
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        if !self.counted_init {
            // the one-time U/V panel draws — Table 2's (m+n)r term
            ctx.counter.add_matrix(self.factors.init_draws);
            self.counted_init = true;
        }
        let idx = ctx.perturb_index();
        let seeds = ctx.seeds;
        let (factors, pending) = (&self.factors, &mut self.pending_taus);
        ctx.timers.time(Phase::Sampling, || {
            factors.draw_taus_into(seeds, idx, pending);
        });
        tezo_forward(ctx, &self.factors, &self.pending_taus)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        // Theorem 1: the unbiased estimator is (1/r) <g, Z> Z — the per-layer
        // 1/r_l keeps the SGD-form step scale comparable to MeZO's (without
        // it the effective lr is r_l times larger and the shared Table-6
        // presets diverge).
        for ((eff, tau), &r) in self.tau_eff.iter_mut()
            .zip(self.pending_taus.iter())
            .zip(self.factors.ranks.iter())
        {
            let scale = ctx.lr * kappa / r as f32;
            for (e, &t) in eff.iter_mut().zip(tau.iter()) {
                *e = scale * t;
            }
        }
        tezo_update_factor(ctx, &self.factors, &self.tau_eff, ctx.lr * kappa)
    }

    fn state_bytes(&self) -> u64 {
        self.factors.factor_elems * 4
    }
}

// ---------------------------------------------------------------------------
// TeZO-m: momentum in the temporal factor (Alg. 1, TeZO-m branch)
// ---------------------------------------------------------------------------

pub struct TezoM {
    factors: Factors,
    pending_taus: Vec<Vec<f32>>,
    /// tau_M per matrix — THE momentum state (r floats per layer)
    tau_m: Vec<Vec<f32>>,
    /// scratch for the update's lr-scaled momentum (refilled in place)
    tau_eff: Vec<Vec<f32>>,
    counted_init: bool,
}

impl TezoM {
    pub fn new(rt: &Runtime, seeds: &SeedSchedule) -> Result<Self> {
        let factors = Factors::init(rt, seeds)?;
        let pending_taus = factors.tau_scratch();
        let tau_m = factors.tau_scratch();
        let tau_eff = factors.tau_scratch();
        Ok(Self { factors, pending_taus, tau_m, tau_eff, counted_init: false })
    }
}

impl ZoOptimizer for TezoM {
    fn method(&self) -> Method {
        Method::TezoM
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        if !self.counted_init {
            // the one-time U/V panel draws — Table 2's (m+n)r term
            ctx.counter.add_matrix(self.factors.init_draws);
            self.counted_init = true;
        }
        let idx = ctx.perturb_index();
        let seeds = ctx.seeds;
        let (factors, pending) = (&self.factors, &mut self.pending_taus);
        ctx.timers.time(Phase::Sampling, || {
            factors.draw_taus_into(seeds, idx, pending);
        });
        tezo_forward(ctx, &self.factors, &self.pending_taus)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let b1 = ctx.cfg.beta1;
        // tau_M <- b1 tau_M + (1-b1) (kappa/r) tau   (O(r) host work; the
        // 1/r is the Theorem-1 unbiasedness factor, see Tezo::update)
        let (tau_m, pending, ranks) =
            (&mut self.tau_m, &self.pending_taus, &self.factors.ranks);
        ctx.timers.time(Phase::Host, || {
            for ((m, tau), &r) in tau_m.iter_mut()
                .zip(pending.iter())
                .zip(ranks.iter())
            {
                let kr = kappa / r as f32;
                for (mm, &t) in m.iter_mut().zip(tau.iter()) {
                    *mm = b1 * *mm + (1.0 - b1) * kr * t;
                }
            }
        });
        let lr = ctx.lr;
        for (eff, m) in self.tau_eff.iter_mut().zip(self.tau_m.iter()) {
            for (e, &t) in eff.iter_mut().zip(m.iter()) {
                *e = lr * t;
            }
        }
        tezo_update_factor(ctx, &self.factors, &self.tau_eff, lr * kappa)
    }

    fn state_bytes(&self) -> u64 {
        let tau: u64 = self.tau_m.iter().map(|v| v.len() as u64).sum();
        self.factors.factor_elems * 4 + tau * 4
    }
}

// ---------------------------------------------------------------------------
// TeZO-Adam: lightweight separable second moment (paper Eq. 8)
// ---------------------------------------------------------------------------

pub struct TezoAdam {
    factors: Factors,
    pending_taus: Vec<Vec<f32>>,
    tau_m: Vec<Vec<f32>>,
    tau_v: Vec<Vec<f32>>,
    /// bias-corrected views handed to the artifact — scratch, refilled in
    /// place each step (the moments above are the real state)
    tau_m_hat: Vec<Vec<f32>>,
    tau_v_hat: Vec<Vec<f32>>,
    t: u64,
    counted_init: bool,
}

impl TezoAdam {
    pub fn new(rt: &Runtime, seeds: &SeedSchedule) -> Result<Self> {
        let factors = Factors::init(rt, seeds)?;
        let pending_taus = factors.tau_scratch();
        let tau_m = factors.tau_scratch();
        let tau_v = factors.tau_scratch();
        let tau_m_hat = factors.tau_scratch();
        let tau_v_hat = factors.tau_scratch();
        Ok(Self { factors, pending_taus, tau_m, tau_v, tau_m_hat, tau_v_hat,
                  t: 0, counted_init: false })
    }
}

impl ZoOptimizer for TezoAdam {
    fn method(&self) -> Method {
        Method::TezoAdam
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        if !self.counted_init {
            // the one-time U/V panel draws — Table 2's (m+n)r term
            ctx.counter.add_matrix(self.factors.init_draws);
            self.counted_init = true;
        }
        let idx = ctx.perturb_index();
        let seeds = ctx.seeds;
        let (factors, pending) = (&self.factors, &mut self.pending_taus);
        ctx.timers.time(Phase::Sampling, || {
            factors.draw_taus_into(seeds, idx, pending);
        });
        tezo_forward(ctx, &self.factors, &self.pending_taus)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        self.t += 1;
        let (b1, b2) = (ctx.cfg.beta1, ctx.cfg.beta2);
        // O(r) host accumulation of both moments in tau space
        let (tau_m, tau_v, pending) =
            (&mut self.tau_m, &mut self.tau_v, &self.pending_taus);
        ctx.timers.time(Phase::Host, || {
            for ((m, v), tau) in tau_m.iter_mut().zip(tau_v.iter_mut())
                .zip(pending.iter())
            {
                for i in 0..tau.len() {
                    m[i] = b1 * m[i] + (1.0 - b1) * kappa * tau[i];
                    v[i] = b2 * v[i] + (1.0 - b2) * kappa * kappa * tau[i] * tau[i];
                }
            }
        });
        // bias correction commutes with the linear reconstruction, so the
        // corrected vectors are what the artifact receives (scratch buffers,
        // refilled in place — no hot-loop allocation)
        let (bc1, bc2) = if ctx.cfg.bias_correction {
            (1.0 - b1.powi(self.t as i32), 1.0 - b2.powi(self.t as i32))
        } else {
            (1.0, 1.0)
        };
        for (hat, m) in self.tau_m_hat.iter_mut().zip(self.tau_m.iter()) {
            for (h, &x) in hat.iter_mut().zip(m.iter()) {
                *h = x / bc1.max(1e-12);
            }
        }
        for (hat, v) in self.tau_v_hat.iter_mut().zip(self.tau_v.iter()) {
            for (h, &x) in hat.iter_mut().zip(v.iter()) {
                *h = (x / bc2.max(1e-12)).max(0.0);
            }
        }
        let (tau_m_hat, tau_v_hat) = (&self.tau_m_hat, &self.tau_v_hat);

        let seed = ctx.step_seed();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("tezo_update_adam")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("factor_u", &self.factors.us)?;
        call.bind_bufs("factor_v", &self.factors.vs)?;
        for (i, t) in tau_m_hat.iter().enumerate() {
            call.bind_nth_f32("tau_m", i, t, ctx.arena)?;
        }
        for (i, t) in tau_v_hat.iter().enumerate() {
            call.bind_nth_f32("tau_v", i, t, ctx.arena)?;
        }
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("eps", ctx.cfg.eps, ctx.arena)?;
        call.bind_scalar_f32("coeff1d", ctx.lr * kappa, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Update, || call.run())?;
        ctx.params.replace_all(out)
    }

    fn state_bytes(&self) -> u64 {
        let tau: u64 = self.tau_m.iter().map(|v| v.len() as u64).sum();
        self.factors.factor_elems * 4 + 2 * tau * 4
    }
}
