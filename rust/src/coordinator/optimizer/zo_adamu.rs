//! ZO-AdaMU driver (Jiang et al. 2024): the perturbation itself is adapted
//! by the momentum of past perturbation directions
//! (`z = sqrt(1-a) z_rand + sqrt(a) m_pert`), and updates are scaled by an
//! Adam-style second moment. Full-size `m_pert` and `v` states, so its
//! memory footprint is MeZO-Adam-like (paper Table 4 baseline).

use anyhow::Result;

use crate::config::Method;
use crate::coordinator::metrics::Phase;
use crate::runtime::exec::scalar_pair;
use crate::runtime::Runtime;
use crate::telemetry::Stopwatch;

use super::{bind_batch, matrix_elems, param_elems, vector_elems, zeros_like_params,
            ForwardOut, StepCtx, ZoOptimizer};

pub struct ZoAdamu {
    m_pert: Vec<xla::PjRtBuffer>,
    v: Vec<xla::PjRtBuffer>,
    elems: u64,
    t: u64,
}

impl ZoAdamu {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self {
            m_pert: zeros_like_params(rt)?,
            v: zeros_like_params(rt)?,
            elems: param_elems(rt),
            t: 0,
        })
    }
}

impl ZoOptimizer for ZoAdamu {
    fn method(&self) -> Method {
        Method::ZoAdamu
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        let seed = ctx.step_seed();
        ctx.counter.add_matrix(matrix_elems(ctx.rt));
        ctx.counter.add_vector(vector_elems(ctx.rt));
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("adamu_loss_pm")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("state_mpert", &self.m_pert)?;
        bind_batch(&mut call, ctx.batch, ctx.arena)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
        call.bind_scalar_f32("alpha", ctx.cfg.adamu_alpha, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Forward, || call.run())?;
        let (f_plus, f_minus) = scalar_pair(&out)?;
        Ok(ForwardOut::TwoPoint { f_plus, f_minus })
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        self.t += 1;
        let seed = ctx.step_seed();
        let n = ctx.params.len();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("adamu_update")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("state_mpert", &self.m_pert)?;
        call.bind_bufs("state_v", &self.v)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("kappa", kappa, ctx.arena)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("alpha", ctx.cfg.adamu_alpha, ctx.arena)?;
        call.bind_scalar_f32("beta1", ctx.cfg.beta1, ctx.arena)?;
        call.bind_scalar_f32("beta2", ctx.cfg.beta2, ctx.arena)?;
        call.bind_scalar_f32("eps", ctx.cfg.eps, ctx.arena)?;
        call.bind_scalar_f32("step_t", self.t as f32, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Update, || call.run())?;
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        ctx.params.replace_all(out)?;
        self.m_pert = new_m;
        self.v = new_v;
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        2 * self.elems * 4
    }
}
