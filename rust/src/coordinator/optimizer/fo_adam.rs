//! First-order Adam reference (the `FT` rows of Tables 3/4/5).
//!
//! Uses the `fo_valgrad` artifact (jax.grad lowered at build time) and a
//! full Adam state — deliberately the expensive baseline the memory tables
//! compare against.

use anyhow::{anyhow, Result};

use crate::config::Method;
use crate::coordinator::metrics::Phase;
use crate::runtime::exec::scalar_first;
use crate::runtime::Runtime;
use crate::telemetry::Stopwatch;

use super::{bind_batch, param_elems, zeros_like_params, ForwardOut, StepCtx,
            ZoOptimizer};

pub struct FoAdam {
    m: Vec<xla::PjRtBuffer>,
    v: Vec<xla::PjRtBuffer>,
    grads: Option<Vec<xla::PjRtBuffer>>,
    elems: u64,
    t: u64,
}

impl FoAdam {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self {
            m: zeros_like_params(rt)?,
            v: zeros_like_params(rt)?,
            grads: None,
            elems: param_elems(rt),
            t: 0,
        })
    }
}

impl ZoOptimizer for FoAdam {
    fn method(&self) -> Method {
        Method::FoAdam
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("fo_valgrad")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        bind_batch(&mut call, ctx.batch, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Forward, || call.run())?;
        let grads = out.split_off(1);
        let loss = scalar_first(&out)?;
        self.grads = Some(grads);
        Ok(ForwardOut::Loss(loss))
    }

    fn update(&mut self, ctx: &mut StepCtx, _kappa: f32) -> Result<()> {
        self.t += 1;
        let grads = self
            .grads
            .take()
            .ok_or_else(|| anyhow!("fo-adam update without forward"))?;
        let n = ctx.params.len();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("fo_adam_update")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("grad", &grads)?;
        call.bind_bufs("state_m", &self.m)?;
        call.bind_bufs("state_v", &self.v)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("beta1", ctx.cfg.beta1, ctx.arena)?;
        call.bind_scalar_f32("beta2", ctx.cfg.beta2, ctx.arena)?;
        call.bind_scalar_f32("eps", ctx.cfg.eps, ctx.arena)?;
        call.bind_scalar_f32("step_t", self.t as f32, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Update, || call.run())?;
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        ctx.params.replace_all(out)?;
        self.m = new_m;
        self.v = new_v;
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        // m + v (+ transient grads counted as one more copy)
        3 * self.elems * 4
    }
}
