//! SubZO driver (Yu et al. 2024): `Z = U Sigma V^T` with orthonormal U, V
//! refreshed lazily (QR in the `subzo_factors` artifact) and a Gaussian
//! r x r Sigma drawn in-HLO each step.

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::metrics::Phase;
use crate::coordinator::seeds::SeedSchedule;
use crate::runtime::exec::scalar_pair;
use crate::runtime::{Runtime, StepArena};
use crate::telemetry::Stopwatch;

use super::{bind_batch, vector_elems, ForwardOut, StepCtx, ZoOptimizer};

pub struct Subzo {
    us: Vec<xla::PjRtBuffer>,
    vs: Vec<xla::PjRtBuffer>,
    window: u64,
    rank: usize,
    n_mats: u64,
    uv_units: u64, // sum (m+n)
}

impl Subzo {
    pub fn new(rt: &Runtime, _cfg: &TrainConfig, _seeds: &SeedSchedule) -> Result<Self> {
        let rank = rt.manifest.subzo_rank;
        let mats = rt.manifest.matrix_params();
        let uv_units: u64 = mats.iter().map(|p| (p.shape[0] + p.shape[1]) as u64).sum();
        // first maybe_refresh (step 0) performs the initial draw so the
        // Table-2 accounting sees it
        Ok(Subzo {
            us: Vec::new(),
            vs: Vec::new(),
            window: u64::MAX,
            rank,
            n_mats: mats.len() as u64,
            uv_units,
        })
    }

    fn refresh(&mut self, rt: &Runtime, arena: &StepArena, seed: u32,
               window: u64) -> Result<()> {
        let mut call = rt.prepared("subzo_factors")?;
        call.bind_scalar_u32("seed", seed, arena)?;
        let out = call.run()?;
        // outputs interleave (U, V) per matrix
        let mut us = Vec::new();
        let mut vs = Vec::new();
        for (i, buf) in out.into_iter().enumerate() {
            if i % 2 == 0 {
                us.push(buf);
            } else {
                vs.push(buf);
            }
        }
        self.us = us;
        self.vs = vs;
        self.window = window;
        Ok(())
    }

    fn maybe_refresh(&mut self, ctx: &mut StepCtx) -> Result<u64> {
        let interval = ctx.cfg.lazy_interval.max(1) as u64;
        let window = ctx.step / interval;
        if window != self.window {
            let seed = ctx.seeds.window_seed(ctx.step, ctx.cfg.lazy_interval);
            self.refresh(ctx.rt, ctx.arena, seed, window)?;
            return Ok(self.uv_units * self.rank as u64);
        }
        Ok(0)
    }
}

impl ZoOptimizer for Subzo {
    fn method(&self) -> Method {
        Method::Subzo
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        let draws = self.maybe_refresh(ctx)?;
        ctx.counter.add_matrix(draws);
        // per-step Sigma draws (r x r per matrix) + dense 1D
        ctx.counter.add_matrix(self.n_mats * (self.rank * self.rank) as u64);
        ctx.counter.add_vector(vector_elems(ctx.rt));
        let seed = ctx.step_seed();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("subzo_loss_pm")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("factor_u", &self.us)?;
        call.bind_bufs("factor_v", &self.vs)?;
        bind_batch(&mut call, ctx.batch, ctx.arena)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Forward, || call.run())?;
        let (f_plus, f_minus) = scalar_pair(&out)?;
        Ok(ForwardOut::TwoPoint { f_plus, f_minus })
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let seed = ctx.step_seed();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("subzo_update")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("factor_u", &self.us)?;
        call.bind_bufs("factor_v", &self.vs)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("coeff", ctx.lr * kappa, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Update, || call.run())?;
        ctx.params.replace_all(out)
    }

    fn state_bytes(&self) -> u64 {
        self.uv_units * self.rank as u64 * 4
    }
}
