//! MeZO family drivers (Malladi et al. 2023).
//!
//! Dense Z regenerated in-HLO from the step seed. Plain MeZO holds *zero*
//! state (the resampling technique); -m and -Adam hold full-size moment
//! buffers — exactly the memory the paper's Fig 3(a) charges them for.

use anyhow::Result;

use crate::config::Method;
use crate::coordinator::metrics::Phase;
use crate::runtime::exec::scalar_pair;
use crate::runtime::Runtime;
use crate::telemetry::Stopwatch;

use super::{bind_batch, matrix_elems, param_elems, vector_elems, zeros_like_params,
            ForwardOut, StepCtx, ZoOptimizer};

/// Shared forward: `mezo_loss_pm(params, batch, seed, rho)`.
fn mezo_forward(ctx: &mut StepCtx) -> Result<ForwardOut> {
    let seed = ctx.step_seed();
    // the artifact draws a dense Z over every parameter
    ctx.counter.add_matrix(matrix_elems(ctx.rt));
    ctx.counter.add_vector(vector_elems(ctx.rt));
    let t0 = Stopwatch::start();
    let mut call = ctx.rt.prepared("mezo_loss_pm")?;
    call.bind_bufs("param", ctx.params.bufs())?;
    bind_batch(&mut call, ctx.batch, ctx.arena)?;
    call.bind_scalar_u32("seed", seed, ctx.arena)?;
    call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
    ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
    let out = ctx.timers.time(Phase::Forward, || call.run())?;
    let (f_plus, f_minus) = scalar_pair(&out)?;
    Ok(ForwardOut::TwoPoint { f_plus, f_minus })
}

/// Plain MeZO (ZO-SGD): no optimizer state at all.
pub struct Mezo;

impl Mezo {
    pub fn new() -> Self {
        Mezo
    }
}

impl Default for Mezo {
    fn default() -> Self {
        Self::new()
    }
}

impl ZoOptimizer for Mezo {
    fn method(&self) -> Method {
        Method::Mezo
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        mezo_forward(ctx)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let seed = ctx.step_seed();
        // update regenerates the SAME z from the same seed: counted once in
        // the paper's model (the draw is one logical sample per step), so no
        // second counter increment here.
        let coeff = ctx.lr * kappa;
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("mezo_update_sgd")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("coeff", coeff, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let out = ctx.timers.time(Phase::Update, || call.run())?;
        ctx.params.replace_all(out)
    }

    fn state_bytes(&self) -> u64 {
        4 // the stored seed
    }
}

/// MeZO-m: full-size momentum buffer.
pub struct MezoM {
    m: Vec<xla::PjRtBuffer>,
    elems: u64,
}

impl MezoM {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self { m: zeros_like_params(rt)?, elems: param_elems(rt) })
    }
}

impl ZoOptimizer for MezoM {
    fn method(&self) -> Method {
        Method::MezoM
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        mezo_forward(ctx)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        let seed = ctx.step_seed();
        let n = ctx.params.len();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("mezo_update_m")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("state_m", &self.m)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("kappa", kappa, ctx.arena)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("beta1", ctx.cfg.beta1, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Update, || call.run())?;
        let new_m = out.split_off(n);
        ctx.params.replace_all(out)?;
        self.m = new_m;
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        self.elems * 4
    }
}

/// MeZO-Adam: full-size first and second moments (the 3x memory row).
pub struct MezoAdam {
    m: Vec<xla::PjRtBuffer>,
    v: Vec<xla::PjRtBuffer>,
    elems: u64,
    t: u64,
}

impl MezoAdam {
    pub fn new(rt: &Runtime) -> Result<Self> {
        Ok(Self {
            m: zeros_like_params(rt)?,
            v: zeros_like_params(rt)?,
            elems: param_elems(rt),
            t: 0,
        })
    }
}

impl ZoOptimizer for MezoAdam {
    fn method(&self) -> Method {
        Method::MezoAdam
    }

    fn forward(&mut self, ctx: &mut StepCtx) -> Result<ForwardOut> {
        mezo_forward(ctx)
    }

    fn update(&mut self, ctx: &mut StepCtx, kappa: f32) -> Result<()> {
        self.t += 1;
        let seed = ctx.step_seed();
        let n = ctx.params.len();
        let t0 = Stopwatch::start();
        let mut call = ctx.rt.prepared("mezo_update_adam")?;
        call.bind_bufs("param", ctx.params.bufs())?;
        call.bind_bufs("state_m", &self.m)?;
        call.bind_bufs("state_v", &self.v)?;
        call.bind_scalar_u32("seed", seed, ctx.arena)?;
        call.bind_scalar_f32("kappa", kappa, ctx.arena)?;
        call.bind_scalar_f32("lr", ctx.lr, ctx.arena)?;
        call.bind_scalar_f32("beta1", ctx.cfg.beta1, ctx.arena)?;
        call.bind_scalar_f32("beta2", ctx.cfg.beta2, ctx.arena)?;
        call.bind_scalar_f32("eps", ctx.cfg.eps, ctx.arena)?;
        call.bind_scalar_f32("step_t", self.t as f32, ctx.arena)?;
        ctx.timers.add(Phase::Dispatch, t0.elapsed().as_secs_f64());
        let mut out = ctx.timers.time(Phase::Update, || call.run())?;
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        ctx.params.replace_all(out)?;
        self.m = new_m;
        self.v = new_v;
        Ok(())
    }

    fn state_bytes(&self) -> u64 {
        2 * self.elems * 4
    }
}
