//! L3 coordinator: the fine-tuning training system.
//!
//! [`trainer`] owns the run loop (data plumbing, eval hooks, metrics);
//! [`step`] is the single-step engine (two-point ZO evaluation, projected
//! gradient, update dispatch) shared with the data-parallel
//! [`crate::fleet`]; [`optimizer`] implements one driver per method
//! (MeZO/LOZO/SubZO/ZO-AdaMU baselines, the TeZO family, and the
//! first-order FT reference); [`seeds`] is the resampling-technique seed
//! schedule; [`autotune`] is the live probe behind the
//! [`crate::runtime::tune`] form autotuner; [`rank`] re-derives the Eq.(7) rank schedule in Rust and
//! cross-checks the manifest; [`eval`] scores classification accuracy via
//! verbalizer logits; [`counter`] does the Table-2 sampled-element
//! accounting; [`metrics`] records loss curves and phase breakdowns;
//! [`guard`] is the divergence-detection policy (non-finite streaks, EWMA
//! loss spikes) behind automatic rollback — see docs/robustness.md.

pub mod autotune;
pub mod counter;
pub mod eval;
pub mod generate;
pub mod guard;
pub mod metrics;
pub mod optimizer;
pub mod probe;
pub mod rank;
pub mod seeds;
pub mod step;
pub mod trainer;

pub use counter::SampleCounter;
pub use guard::{GuardPolicy, GuardReason, GuardState};
pub use metrics::{PhaseTimers, TrainMetrics};
pub use optimizer::{build_optimizer, StepCtx, ZoOptimizer};
pub use seeds::SeedSchedule;
pub use step::StepEngine;
pub use trainer::{CheckpointPlan, TrainOutcome, Trainer};
