//! The resampling-technique seed schedule.
//!
//! MeZO's memory trick (adopted by all ZO methods here): instead of storing
//! the perturbation, store the 4-byte step seed and regenerate identical
//! draws in the perturb and update phases. The schedule derives independent
//! u32 seeds per step (and per purpose) from one master seed via splitmix
//! mixing, so whole runs replay bit-identically from `TrainConfig::seed`.

use crate::rngx::SplitMix64;

/// Deterministic per-step seed derivation.
#[derive(Clone, Copy, Debug)]
pub struct SeedSchedule {
    master: u64,
}

/// Purpose tags keep independent streams from colliding.
#[derive(Clone, Copy, Debug)]
pub enum Stream {
    /// the ZO perturbation seed handed to loss_pm/update artifacts
    Perturb,
    /// factor initialization (TeZO u/v panels)
    FactorInit,
    /// lazy-window refresh (LOZO U, SubZO U/V)
    LazyRefresh,
    /// batch sampling
    Data,
}

impl Stream {
    fn salt(self) -> u64 {
        match self {
            Stream::Perturb => 0x5045_5254,
            Stream::FactorInit => 0x4641_4354,
            Stream::LazyRefresh => 0x4C41_5A59,
            Stream::Data => 0x4441_5441,
        }
    }
}

impl SeedSchedule {
    pub fn new(master: u64) -> Self {
        Self { master }
    }

    /// 64-bit seed for (stream, index).
    pub fn seed64(&self, stream: Stream, index: u64) -> u64 {
        SplitMix64::mix(self.master ^ stream.salt(), index)
    }

    /// u32 seed (what the artifacts take); never 0 so PRNGKey(0) — the
    /// jax default key — cannot collide with a scheduled step.
    pub fn seed32(&self, stream: Stream, index: u64) -> u32 {
        let s = (self.seed64(stream, index) >> 16) as u32;
        if s == 0 { 1 } else { s }
    }

    /// Index of sub-perturbation `sub` of `step` (q-SPSA; sub < 64).
    pub fn perturb_index(step: u64, sub: u32) -> u64 {
        debug_assert!(sub < 64);
        (step << 6) | sub as u64
    }

    /// The per-(step, sub) perturbation seed.
    pub fn perturb_seed(&self, step: u64, sub: u32) -> u32 {
        self.seed32(Stream::Perturb, Self::perturb_index(step, sub))
    }

    /// The per-step perturbation seed (sub = 0).
    pub fn step_seed(&self, step: u64) -> u32 {
        self.perturb_seed(step, 0)
    }

    /// Lazy-window seed for the window containing `step`.
    pub fn window_seed(&self, step: u64, interval: usize) -> u32 {
        let window = if interval == 0 { 0 } else { step / interval as u64 };
        self.seed32(Stream::LazyRefresh, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let s = SeedSchedule::new(42);
        assert_eq!(s.step_seed(5), s.step_seed(5));
        assert_ne!(s.step_seed(5), s.step_seed(6));
        assert_ne!(s.seed32(Stream::Perturb, 5), s.seed32(Stream::Data, 5));
    }

    #[test]
    fn window_seed_constant_within_window() {
        let s = SeedSchedule::new(7);
        assert_eq!(s.window_seed(0, 50), s.window_seed(49, 50));
        assert_ne!(s.window_seed(49, 50), s.window_seed(50, 50));
    }

    #[test]
    fn no_low_entropy_collisions() {
        let s = SeedSchedule::new(0);
        let mut seen = std::collections::HashSet::new();
        for step in 0..10_000u64 {
            seen.insert(s.step_seed(step));
        }
        assert!(seen.len() > 9_990, "too many collisions: {}", seen.len());
    }
}
