//! The resampling-technique seed schedule.
//!
//! MeZO's memory trick (adopted by all ZO methods here): instead of storing
//! the perturbation, store the 4-byte step seed and regenerate identical
//! draws in the perturb and update phases. The schedule derives independent
//! u32 seeds per step (and per purpose) from one master seed via splitmix
//! mixing, so whole runs replay bit-identically from `TrainConfig::seed`.

use crate::rngx::SplitMix64;

/// Deterministic per-step seed derivation.
#[derive(Clone, Copy, Debug)]
pub struct SeedSchedule {
    master: u64,
}

/// Purpose tags keep independent streams from colliding.
#[derive(Clone, Copy, Debug)]
pub enum Stream {
    /// the ZO perturbation seed handed to loss_pm/update artifacts
    Perturb,
    /// factor initialization (TeZO u/v panels)
    FactorInit,
    /// lazy-window refresh (LOZO U, SubZO U/V)
    LazyRefresh,
    /// batch sampling
    Data,
}

impl Stream {
    fn salt(self) -> u64 {
        match self {
            Stream::Perturb => 0x5045_5254,
            Stream::FactorInit => 0x4641_4354,
            Stream::LazyRefresh => 0x4C41_5A59,
            Stream::Data => 0x4441_5441,
        }
    }
}

impl SeedSchedule {
    pub fn new(master: u64) -> Self {
        Self { master }
    }

    /// 64-bit seed for (stream, index).
    pub fn seed64(&self, stream: Stream, index: u64) -> u64 {
        SplitMix64::mix(self.master ^ stream.salt(), index)
    }

    /// u32 seed (what the artifacts take); never 0 so PRNGKey(0) — the
    /// jax default key — cannot collide with a scheduled step.
    pub fn seed32(&self, stream: Stream, index: u64) -> u32 {
        let s = (self.seed64(stream, index) >> 16) as u32;
        if s == 0 { 1 } else { s }
    }

    /// Index of sub-perturbation `sub` of `step` (q-SPSA; sub < 64).
    pub fn perturb_index(step: u64, sub: u32) -> u64 {
        debug_assert!(sub < 64);
        (step << 6) | sub as u64
    }

    /// The per-(step, sub) perturbation seed.
    pub fn perturb_seed(&self, step: u64, sub: u32) -> u32 {
        self.seed32(Stream::Perturb, Self::perturb_index(step, sub))
    }

    /// The per-step perturbation seed (sub = 0).
    pub fn step_seed(&self, step: u64) -> u32 {
        self.perturb_seed(step, 0)
    }

    /// Lazy-window seed for the window containing `step`.
    pub fn window_seed(&self, step: u64, interval: usize) -> u32 {
        let window = if interval == 0 { 0 } else { step / interval as u64 };
        self.seed32(Stream::LazyRefresh, window)
    }

    /// Data-stream index of (step, shard) among `shards` disjoint shards.
    /// Shards interleave (`step * shards + shard`), so every (step, worker)
    /// pair draws from its own point of the stream and shard 0 of 1 is the
    /// plain single-process index — the fleet's 1-worker bit-parity hinges
    /// on that identity.
    pub fn data_index(step: u64, shard: u32, shards: u32) -> u64 {
        let n = shards.max(1) as u64;
        debug_assert!((shard as u64) < n);
        step * n + shard as u64
    }

    /// The per-step batch-sampling seed (single process = shard 0 of 1).
    pub fn data_seed(&self, step: u64) -> u64 {
        self.shard_data_seed(step, 0, 1)
    }

    /// The batch-sampling seed of data shard `shard` of `shards` at `step`
    /// (one shard per fleet worker).
    pub fn shard_data_seed(&self, step: u64, shard: u32, shards: u32) -> u64 {
        self.seed64(Stream::Data, Self::data_index(step, shard, shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let s = SeedSchedule::new(42);
        assert_eq!(s.step_seed(5), s.step_seed(5));
        assert_ne!(s.step_seed(5), s.step_seed(6));
        assert_ne!(s.seed32(Stream::Perturb, 5), s.seed32(Stream::Data, 5));
    }

    #[test]
    fn window_seed_constant_within_window() {
        let s = SeedSchedule::new(7);
        assert_eq!(s.window_seed(0, 50), s.window_seed(49, 50));
        assert_ne!(s.window_seed(49, 50), s.window_seed(50, 50));
    }

    #[test]
    fn no_low_entropy_collisions() {
        let s = SeedSchedule::new(0);
        let mut seen = std::collections::HashSet::new();
        for step in 0..10_000u64 {
            seen.insert(s.step_seed(step));
        }
        assert!(seen.len() > 9_990, "too many collisions: {}", seen.len());
    }

    const ALL_STREAMS: [Stream; 4] =
        [Stream::Perturb, Stream::FactorInit, Stream::LazyRefresh, Stream::Data];

    #[test]
    fn streams_are_pairwise_independent_at_equal_index() {
        // The four purpose streams must never hand the same 64-bit seed to
        // two different consumers at the same index (that would correlate
        // e.g. the perturbation draw with the batch order).
        for master in [0u64, 1, 42, 0xFFFF_FFFF_FFFF_FFFF] {
            let s = SeedSchedule::new(master);
            for idx in 0..10_000u64 {
                let seeds: Vec<u64> =
                    ALL_STREAMS.iter().map(|&st| s.seed64(st, idx)).collect();
                for i in 0..seeds.len() {
                    for j in i + 1..seeds.len() {
                        assert_ne!(seeds[i], seeds[j],
                                   "master {master}: streams {i}/{j} collide at {idx}");
                    }
                }
            }
        }
    }

    #[test]
    fn seed32_never_returns_zero() {
        for master in [0u64, 7, u64::MAX] {
            let s = SeedSchedule::new(master);
            for idx in 0..10_000u64 {
                for &st in &ALL_STREAMS {
                    assert_ne!(s.seed32(st, idx), 0, "master {master} idx {idx}");
                }
            }
        }
    }

    #[test]
    fn shard_data_seeds_are_disjoint_across_workers() {
        let s = SeedSchedule::new(9);
        let shards = 4u32;
        let mut seen = std::collections::HashSet::new();
        for step in 0..5_000u64 {
            for w in 0..shards {
                assert!(seen.insert(s.shard_data_seed(step, w, shards)),
                        "duplicate data seed at step {step} worker {w}");
            }
        }
        // shard 0 of 1 is the single-process data stream (fleet parity)
        assert_eq!(s.data_seed(17), s.shard_data_seed(17, 0, 1));
        assert_eq!(s.data_seed(17), s.seed64(Stream::Data, 17));
        // and differs from the same step's multi-worker shard 0
        assert_ne!(s.data_seed(17), s.shard_data_seed(17, 0, 4));
    }
}
