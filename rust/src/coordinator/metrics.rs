//! Training metrics: loss curves, phase timing, report emission.
//!
//! Since PR 8 the phase timers are a thin view over the telemetry layer:
//! the float `secs`/`counts` aggregates stay authoritative (they are the
//! fleet wire contract, see [`PhaseTimers::parts`]), while every timing
//! additionally lands in a per-phase [`LatencyHist`] and — when a tracer
//! is attached — in the span ring. Wall-clock access goes through
//! `telemetry::clock` only (TZ-OBS001).

use std::path::Path;

use anyhow::Result;

use crate::jsonx::Value;
use crate::telemetry::{secs_to_ns, LatencyHist, Stopwatch, Telemetry};
use crate::tensor::stats;

/// The per-step phases of a ZO iteration (paper Fig 3b breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// host-side random sampling (tau vectors, batches)
    Sampling,
    /// prepared-call dispatch: argument binding, validation, and
    /// host→device staging (see `runtime::plan` / `runtime::stage`)
    Dispatch,
    /// the fused two-point forward (or FO forward+backward)
    Forward,
    /// the parameter update artifact
    Update,
    /// host scalar work (kappa, moment accumulation)
    Host,
}

impl Phase {
    pub const ALL: [Phase; 5] =
        [Phase::Sampling, Phase::Dispatch, Phase::Forward, Phase::Update, Phase::Host];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Sampling => "sampling",
            Phase::Dispatch => "dispatch",
            Phase::Forward => "forward",
            Phase::Update => "update",
            Phase::Host => "host",
        }
    }
}

/// Accumulated wall-clock per phase, plus the host→device upload byte
/// counters of the staging pool (what the ≥2x TeZO upload-reduction claim
/// is measured with — see docs/runtime.md).
///
/// The histograms and tracer handle are in-process extensions: they do
/// not travel over the fleet wire ([`Self::parts`] is unchanged from the
/// PR 7 codec), so a report decoded from a TCP worker carries aggregates
/// only.
#[derive(Clone, Debug)]
pub struct PhaseTimers {
    secs: [f64; 5],
    counts: [u64; 5],
    upload_bytes: u64,
    upload_reused_bytes: u64,
    hists: [LatencyHist; 5],
    telemetry: Telemetry,
    span_step: i64,
}

impl Default for PhaseTimers {
    fn default() -> Self {
        Self {
            secs: [0.0; 5],
            counts: [0; 5],
            upload_bytes: 0,
            upload_reused_bytes: 0,
            hists: [
                LatencyHist::new(),
                LatencyHist::new(),
                LatencyHist::new(),
                LatencyHist::new(),
                LatencyHist::new(),
            ],
            telemetry: Telemetry::off(),
            span_step: -1,
        }
    }
}

impl PhaseTimers {
    fn slot(phase: Phase) -> usize {
        Phase::ALL
            .iter()
            .position(|p| *p == phase)
            .unwrap_or(Phase::ALL.len() - 1)
    }

    /// Attach a tracer: subsequent timings also emit phase spans.
    pub fn set_telemetry(&mut self, telemetry: Telemetry) {
        self.telemetry = telemetry;
    }

    /// Tracer handle shared with this timer set (disabled by default).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Tag subsequent phase spans with a training step (-1 clears).
    pub fn set_span_step(&mut self, step: i64) {
        self.span_step = step;
    }

    fn record_phase(&mut self, phase: Phase, secs: f64, dur_ns: u64, start_ns: Option<u64>) {
        let i = Self::slot(phase);
        self.secs[i] += secs;
        self.counts[i] += 1;
        self.hists[i].record_ns(dur_ns);
        if self.telemetry.enabled() {
            match start_ns {
                Some(t0) => {
                    self.telemetry.span_at("phase", phase.name(), t0, dur_ns, 0, self.span_step)
                }
                None => self.telemetry.span_dur("phase", phase.name(), dur_ns, 0, self.span_step),
            }
        }
    }

    /// Time a closure under `phase`. With a tracer attached the tracer's
    /// clock is used (so a deterministic test clock yields deterministic
    /// spans); otherwise a [`Stopwatch`] measures the duration.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        if self.telemetry.enabled() {
            let t0 = self.telemetry.now_ns();
            let out = f();
            let dur_ns = self.telemetry.now_ns().saturating_sub(t0);
            self.record_phase(phase, dur_ns as f64 / 1e9, dur_ns, Some(t0));
            out
        } else {
            let t0 = Stopwatch::start();
            let out = f();
            let dur_ns = t0.elapsed_ns();
            self.record_phase(phase, dur_ns as f64 / 1e9, dur_ns, None);
            out
        }
    }

    /// Record pre-measured seconds under `phase` (for work that cannot be
    /// wrapped in a closure without fighting the borrow checker).
    pub fn add(&mut self, phase: Phase, secs: f64) {
        self.record_phase(phase, secs, secs_to_ns(secs), None);
    }

    /// Record host→device staging traffic: bytes actually uploaded and
    /// bytes satisfied from the staging pool without an upload.
    pub fn add_upload_bytes(&mut self, fresh: u64, reused: u64) {
        self.upload_bytes += fresh;
        self.upload_reused_bytes += reused;
        if self.telemetry.enabled() {
            if fresh > 0 {
                self.telemetry
                    .counter("stage", "upload_fresh_bytes", fresh as f64, self.span_step);
            }
            if reused > 0 {
                self.telemetry
                    .counter("stage", "upload_reused_bytes", reused as f64, self.span_step);
            }
        }
    }

    /// Bytes moved host→device by artifact-argument staging.
    pub fn upload_bytes(&self) -> u64 {
        self.upload_bytes
    }

    /// Bytes the staging pool deduplicated (would have been re-uploaded by
    /// per-call staging).
    pub fn upload_reused_bytes(&self) -> u64 {
        self.upload_reused_bytes
    }

    /// Raw field tuple for serialization (the fleet wire codec ships the
    /// per-worker report over TCP): `(secs, counts, upload, reused)`.
    /// Histograms and tracer state deliberately stay host-local.
    pub fn parts(&self) -> ([f64; 5], [u64; 5], u64, u64) {
        (self.secs, self.counts, self.upload_bytes, self.upload_reused_bytes)
    }

    /// Rebuild from [`Self::parts`] output (wire decode). The rebuilt
    /// timers carry empty histograms and no tracer.
    pub fn from_parts(secs: [f64; 5], counts: [u64; 5], upload_bytes: u64,
                      upload_reused_bytes: u64) -> Self {
        Self { secs, counts, upload_bytes, upload_reused_bytes, ..Self::default() }
    }

    pub fn seconds(&self, phase: Phase) -> f64 {
        self.secs[Self::slot(phase)]
    }

    pub fn total_seconds(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Per-phase latency histogram (nanoseconds, this process only).
    pub fn hist(&self, phase: Phase) -> &LatencyHist {
        &self.hists[Self::slot(phase)]
    }

    /// (phase, seconds, fraction) rows. An empty run reports zero
    /// fractions rather than NaN/garbage ratios.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_seconds();
        Phase::ALL
            .iter()
            .map(|p| {
                let s = self.seconds(*p);
                let frac = if total > 0.0 { s / total } else { 0.0 };
                (p.name(), s, frac)
            })
            .collect()
    }

    /// Per-phase quantile summary (the `TrainOutcome` telemetry block).
    pub fn phase_quantiles_json(&self) -> Value {
        Value::arr(
            Phase::ALL
                .iter()
                .map(|p| {
                    let h = self.hist(*p);
                    Value::obj(vec![
                        ("phase", Value::str(p.name())),
                        ("count", Value::i(h.count() as i64)),
                        ("p50_ns", Value::i(h.p50_ns() as i64)),
                        ("p95_ns", Value::i(h.p95_ns() as i64)),
                        ("p99_ns", Value::i(h.p99_ns() as i64)),
                        ("max_ns", Value::i(h.max_ns() as i64)),
                    ])
                })
                .collect(),
        )
    }
}

/// Full training record for one run.
#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub losses: Vec<f64>,
    /// (step, accuracy)
    pub evals: Vec<(u64, f64)>,
    pub timers: PhaseTimers,
    pub steps: u64,
    pub wall_seconds: f64,
    /// the autotuner's resolution record (`Resolution::summary_json`),
    /// attached by the train entry points when the run went through the
    /// form resolver; `None` for embedders that pin the form themselves
    pub tuning: Option<Value>,
    /// steps whose update was skipped on a non-finite measurement — a
    /// silently-stalled run must be visible in the summary
    pub nonfinite_skips: u64,
    /// guard-triggered rollbacks taken during this run
    pub rollbacks: u64,
    /// the checkpoint step this run resumed from (`--resume`)
    pub resumed_from: Option<u64>,
}

impl TrainMetrics {
    pub fn record_loss(&mut self, loss: f64) {
        self.losses.push(loss);
        self.steps += 1;
    }

    pub fn final_loss_avg(&self, window: usize) -> f64 {
        if self.losses.is_empty() {
            return f64::NAN;
        }
        let k = window.min(self.losses.len());
        stats::mean(&self.losses[self.losses.len() - k..])
    }

    pub fn initial_loss_avg(&self, window: usize) -> f64 {
        if self.losses.is_empty() {
            return f64::NAN;
        }
        let k = window.min(self.losses.len());
        stats::mean(&self.losses[..k])
    }

    /// Mean wall seconds per step; 0.0 (not NaN) for an empty run.
    pub fn seconds_per_step(&self) -> f64 {
        if self.steps == 0 { 0.0 } else { self.wall_seconds / self.steps as f64 }
    }

    /// Smoothed loss curve (paper Fig 4 uses gaussian_filter1d; EMA with a
    /// matched bandwidth gives the same qualitative curve).
    pub fn smoothed_losses(&self, alpha: f64) -> Vec<f64> {
        stats::ema(&self.losses, alpha)
    }

    /// Write the loss curve as `step,loss,smoothed` CSV.
    pub fn write_loss_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let smooth = self.smoothed_losses(0.05);
        let mut out = String::from("step,loss,smoothed\n");
        for (i, (l, s)) in self.losses.iter().zip(smooth.iter()).enumerate() {
            out.push_str(&format!("{i},{l},{s}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// JSON summary (for EXPERIMENTS.md and the sweep driver). All PR 7
    /// keys are preserved; `phase_quantiles` is the additive PR 8
    /// telemetry block.
    pub fn summary_json(&self, label: &str) -> Value {
        let mut fields = vec![
            ("label", Value::str(label)),
            ("steps", Value::i(self.steps as i64)),
            ("initial_loss", Value::f(self.initial_loss_avg(20))),
            ("final_loss", Value::f(self.final_loss_avg(20))),
            ("wall_seconds", Value::f(self.wall_seconds)),
            ("sec_per_step", Value::f(self.seconds_per_step())),
            ("final_accuracy",
             Value::f(self.evals.last().map(|e| e.1).unwrap_or(f64::NAN))),
            ("upload_bytes", Value::i(self.timers.upload_bytes() as i64)),
            ("upload_reused_bytes",
             Value::i(self.timers.upload_reused_bytes() as i64)),
            ("phases", Value::arr(
                self.timers.breakdown().into_iter()
                    .map(|(n, s, f)| Value::obj(vec![
                        ("phase", Value::str(n)),
                        ("seconds", Value::f(s)),
                        ("fraction", Value::f(f)),
                    ]))
                    .collect())),
            ("phase_quantiles", self.timers.phase_quantiles_json()),
            ("nonfinite_skips", Value::i(self.nonfinite_skips as i64)),
            ("rollbacks", Value::i(self.rollbacks as i64)),
        ];
        if let Some(step) = self.resumed_from {
            fields.push(("resumed_from", Value::i(step as i64)));
        }
        if let Some(t) = &self.tuning {
            fields.push(("tuning", t.clone()));
        }
        Value::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{EventKind, TestClock};

    #[test]
    fn timers_accumulate() {
        let mut t = PhaseTimers::default();
        t.time(Phase::Forward, || std::thread::sleep(std::time::Duration::from_millis(5)));
        t.time(Phase::Update, || {});
        assert!(t.seconds(Phase::Forward) >= 0.004);
        let br = t.breakdown();
        assert_eq!(br.len(), 5);
        let frac_sum: f64 = br.iter().map(|(_, _, f)| f).sum();
        assert!((frac_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn upload_counters_accumulate() {
        let mut t = PhaseTimers::default();
        t.add(Phase::Dispatch, 0.25);
        t.add_upload_bytes(1024, 0);
        t.add_upload_bytes(512, 2048);
        assert!((t.seconds(Phase::Dispatch) - 0.25).abs() < 1e-12);
        assert_eq!(t.upload_bytes(), 1536);
        assert_eq!(t.upload_reused_bytes(), 2048);
    }

    #[test]
    fn loss_windows() {
        let mut m = TrainMetrics::default();
        for i in 0..100 {
            m.record_loss(10.0 - (i as f64) * 0.05);
        }
        assert!(m.final_loss_avg(10) < m.initial_loss_avg(10));
    }

    #[test]
    fn empty_breakdown_is_all_zeros() {
        let t = PhaseTimers::default();
        for (_, secs, frac) in t.breakdown() {
            assert_eq!(secs, 0.0);
            assert_eq!(frac, 0.0);
            assert!(frac.is_finite());
        }
    }

    #[test]
    fn empty_run_seconds_per_step_is_zero() {
        let m = TrainMetrics::default();
        assert_eq!(m.seconds_per_step(), 0.0);
        assert!(m.seconds_per_step().is_finite());
        // ... and the JSON summary stays renderable (no panics, fractions 0)
        let v = m.summary_json("empty");
        assert_eq!(v.get_f64("sec_per_step").unwrap(), 0.0);
    }

    #[test]
    fn timings_land_in_histograms() {
        let mut t = PhaseTimers::default();
        t.add(Phase::Forward, 0.001);
        t.add(Phase::Forward, 0.002);
        t.time(Phase::Host, || {});
        assert_eq!(t.hist(Phase::Forward).count(), 2);
        assert_eq!(t.hist(Phase::Host).count(), 1);
        assert!(t.hist(Phase::Forward).max_ns() >= 2_000_000);
        let (_, counts, _, _) = t.parts();
        assert_eq!(counts[PhaseTimers::slot(Phase::Forward)],
                   t.hist(Phase::Forward).count());
    }

    #[test]
    fn attached_tracer_sees_phase_spans() {
        let tel = Telemetry::with_clock(16, Box::new(TestClock::new(500)));
        let mut t = PhaseTimers::default();
        t.set_telemetry(tel.clone());
        t.set_span_step(7);
        t.time(Phase::Forward, || {});
        t.add(Phase::Dispatch, 0.001);
        t.add_upload_bytes(64, 0);
        let ev = tel.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, EventKind::Span);
        assert_eq!(ev[0].name, "forward");
        assert_eq!(ev[0].dur_ns, 500); // one TestClock tick
        assert_eq!(ev[0].step, 7);
        assert_eq!(ev[1].name, "dispatch");
        assert_eq!(ev[2].name, "upload_fresh_bytes");
        // the float aggregate and the histogram agree with the spans
        assert_eq!(t.hist(Phase::Forward).count(), 1);
        assert!((t.seconds(Phase::Forward) - 500e-9).abs() < 1e-15);
    }

    #[test]
    fn wire_parts_roundtrip_ignores_telemetry_state() {
        let mut t = PhaseTimers::default();
        t.set_telemetry(Telemetry::with_clock(8, Box::new(TestClock::new(1))));
        t.add(Phase::Forward, 0.5);
        let (secs, counts, up, reused) = t.parts();
        let back = PhaseTimers::from_parts(secs, counts, up, reused);
        assert_eq!(back.parts(), t.parts());
        assert!(!back.telemetry().enabled());
        assert!(back.hist(Phase::Forward).is_empty());
    }
}
