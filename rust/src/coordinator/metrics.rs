//! Training metrics: loss curves, phase timing, report emission.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::jsonx::Value;
use crate::tensor::stats;

/// The per-step phases of a ZO iteration (paper Fig 3b breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// host-side random sampling (tau vectors, batches)
    Sampling,
    /// prepared-call dispatch: argument binding, validation, and
    /// host→device staging (see `runtime::plan` / `runtime::stage`)
    Dispatch,
    /// the fused two-point forward (or FO forward+backward)
    Forward,
    /// the parameter update artifact
    Update,
    /// host scalar work (kappa, moment accumulation)
    Host,
}

impl Phase {
    pub const ALL: [Phase; 5] =
        [Phase::Sampling, Phase::Dispatch, Phase::Forward, Phase::Update, Phase::Host];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Sampling => "sampling",
            Phase::Dispatch => "dispatch",
            Phase::Forward => "forward",
            Phase::Update => "update",
            Phase::Host => "host",
        }
    }
}

/// Accumulated wall-clock per phase, plus the host→device upload byte
/// counters of the staging pool (what the ≥2x TeZO upload-reduction claim
/// is measured with — see docs/runtime.md).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimers {
    secs: [f64; 5],
    counts: [u64; 5],
    upload_bytes: u64,
    upload_reused_bytes: u64,
}

impl PhaseTimers {
    fn slot(phase: Phase) -> usize {
        Phase::ALL
            .iter()
            .position(|p| *p == phase)
            .unwrap_or(Phase::ALL.len() - 1)
    }

    /// Time a closure under `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let i = Self::slot(phase);
        self.secs[i] += t0.elapsed().as_secs_f64();
        self.counts[i] += 1;
        out
    }

    /// Record pre-measured seconds under `phase` (for work that cannot be
    /// wrapped in a closure without fighting the borrow checker).
    pub fn add(&mut self, phase: Phase, secs: f64) {
        let i = Self::slot(phase);
        self.secs[i] += secs;
        self.counts[i] += 1;
    }

    /// Record host→device staging traffic: bytes actually uploaded and
    /// bytes satisfied from the staging pool without an upload.
    pub fn add_upload_bytes(&mut self, fresh: u64, reused: u64) {
        self.upload_bytes += fresh;
        self.upload_reused_bytes += reused;
    }

    /// Bytes moved host→device by artifact-argument staging.
    pub fn upload_bytes(&self) -> u64 {
        self.upload_bytes
    }

    /// Bytes the staging pool deduplicated (would have been re-uploaded by
    /// per-call staging).
    pub fn upload_reused_bytes(&self) -> u64 {
        self.upload_reused_bytes
    }

    /// Raw field tuple for serialization (the fleet wire codec ships the
    /// per-worker report over TCP): `(secs, counts, upload, reused)`.
    pub fn parts(&self) -> ([f64; 5], [u64; 5], u64, u64) {
        (self.secs, self.counts, self.upload_bytes, self.upload_reused_bytes)
    }

    /// Rebuild from [`Self::parts`] output (wire decode).
    pub fn from_parts(secs: [f64; 5], counts: [u64; 5], upload_bytes: u64,
                      upload_reused_bytes: u64) -> Self {
        Self { secs, counts, upload_bytes, upload_reused_bytes }
    }

    pub fn seconds(&self, phase: Phase) -> f64 {
        self.secs[Self::slot(phase)]
    }

    pub fn total_seconds(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// (phase, seconds, fraction) rows.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_seconds().max(1e-12);
        Phase::ALL
            .iter()
            .map(|p| {
                let s = self.seconds(*p);
                (p.name(), s, s / total)
            })
            .collect()
    }
}

/// Full training record for one run.
#[derive(Clone, Debug, Default)]
pub struct TrainMetrics {
    pub losses: Vec<f64>,
    /// (step, accuracy)
    pub evals: Vec<(u64, f64)>,
    pub timers: PhaseTimers,
    pub steps: u64,
    pub wall_seconds: f64,
}

impl TrainMetrics {
    pub fn record_loss(&mut self, loss: f64) {
        self.losses.push(loss);
        self.steps += 1;
    }

    pub fn final_loss_avg(&self, window: usize) -> f64 {
        if self.losses.is_empty() {
            return f64::NAN;
        }
        let k = window.min(self.losses.len());
        stats::mean(&self.losses[self.losses.len() - k..])
    }

    pub fn initial_loss_avg(&self, window: usize) -> f64 {
        if self.losses.is_empty() {
            return f64::NAN;
        }
        let k = window.min(self.losses.len());
        stats::mean(&self.losses[..k])
    }

    pub fn seconds_per_step(&self) -> f64 {
        if self.steps == 0 { 0.0 } else { self.wall_seconds / self.steps as f64 }
    }

    /// Smoothed loss curve (paper Fig 4 uses gaussian_filter1d; EMA with a
    /// matched bandwidth gives the same qualitative curve).
    pub fn smoothed_losses(&self, alpha: f64) -> Vec<f64> {
        stats::ema(&self.losses, alpha)
    }

    /// Write the loss curve as `step,loss,smoothed` CSV.
    pub fn write_loss_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let smooth = self.smoothed_losses(0.05);
        let mut out = String::from("step,loss,smoothed\n");
        for (i, (l, s)) in self.losses.iter().zip(smooth.iter()).enumerate() {
            out.push_str(&format!("{i},{l},{s}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// JSON summary (for EXPERIMENTS.md and the sweep driver).
    pub fn summary_json(&self, label: &str) -> Value {
        Value::obj(vec![
            ("label", Value::str(label)),
            ("steps", Value::i(self.steps as i64)),
            ("initial_loss", Value::f(self.initial_loss_avg(20))),
            ("final_loss", Value::f(self.final_loss_avg(20))),
            ("wall_seconds", Value::f(self.wall_seconds)),
            ("sec_per_step", Value::f(self.seconds_per_step())),
            ("final_accuracy",
             Value::f(self.evals.last().map(|e| e.1).unwrap_or(f64::NAN))),
            ("upload_bytes", Value::i(self.timers.upload_bytes() as i64)),
            ("upload_reused_bytes",
             Value::i(self.timers.upload_reused_bytes() as i64)),
            ("phases", Value::arr(
                self.timers.breakdown().into_iter()
                    .map(|(n, s, f)| Value::obj(vec![
                        ("phase", Value::str(n)),
                        ("seconds", Value::f(s)),
                        ("fraction", Value::f(f)),
                    ]))
                    .collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut t = PhaseTimers::default();
        t.time(Phase::Forward, || std::thread::sleep(std::time::Duration::from_millis(5)));
        t.time(Phase::Update, || {});
        assert!(t.seconds(Phase::Forward) >= 0.004);
        let br = t.breakdown();
        assert_eq!(br.len(), 5);
        let frac_sum: f64 = br.iter().map(|(_, _, f)| f).sum();
        assert!((frac_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn upload_counters_accumulate() {
        let mut t = PhaseTimers::default();
        t.add(Phase::Dispatch, 0.25);
        t.add_upload_bytes(1024, 0);
        t.add_upload_bytes(512, 2048);
        assert!((t.seconds(Phase::Dispatch) - 0.25).abs() < 1e-12);
        assert_eq!(t.upload_bytes(), 1536);
        assert_eq!(t.upload_reused_bytes(), 2048);
    }

    #[test]
    fn loss_windows() {
        let mut m = TrainMetrics::default();
        for i in 0..100 {
            m.record_loss(10.0 - (i as f64) * 0.05);
        }
        assert!(m.final_loss_avg(10) < m.initial_loss_avg(10));
    }
}
