//! The single-step engine: one sub-perturbation's forward / kappa / update
//! arithmetic, factored out of [`crate::coordinator::trainer::Trainer`] so
//! that the data-parallel fleet ([`crate::fleet`]) can drive the *same*
//! code with an aggregation point spliced between the two phases.
//!
//! The contract that makes seed-synchronized data parallelism work:
//!
//! * `forward_sub` + `combine` + `clip_kappa` + `update_sub` executed back
//!   to back are bit-identical to the old in-trainer step;
//! * `combine` is a pure function of the (possibly shard-averaged) two
//!   losses, so a coordinator can aggregate `f+`/`f-` across replicas and
//!   every replica replays the identical update from `(step seed, kappa)`.

use anyhow::Result;

use crate::config::{ForwardForm, Method, TrainConfig};
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;
use crate::coordinator::optimizer::{ForwardOut, StepCtx, ZoOptimizer};
use crate::coordinator::seeds::SeedSchedule;
use crate::data::Batch;
use crate::runtime::{ParamStore, Runtime};

/// Training-step arithmetic shared by [`Trainer`] and `fleet::FleetTrainer`.
///
/// Owns the run configuration and the derived seed schedule; holds no
/// per-run mutable state, so one engine can be cloned into every fleet
/// worker and all replicas stay in lockstep.
///
/// [`Trainer`]: crate::coordinator::trainer::Trainer
#[derive(Clone, Debug)]
pub struct StepEngine {
    pub cfg: TrainConfig,
    pub seeds: SeedSchedule,
    /// the concrete forward form every sub-step dispatches. Resolved from
    /// `cfg.forward_form` at construction: the train/train-dp entry points
    /// pin the config (autotuner or explicit flag) *before* building the
    /// engine, so an `Auto` reaching here takes the documented fallback.
    form: ForwardForm,
}

impl StepEngine {
    pub fn new(cfg: TrainConfig) -> Self {
        let seeds = SeedSchedule::new(cfg.seed);
        let form = cfg.forward_form.resolve_fallback();
        Self { cfg, seeds, form }
    }

    /// The concrete two-point forward form this engine dispatches.
    pub fn form(&self) -> ForwardForm {
        self.form
    }

    /// q-SPSA sub-perturbation count (>= 1).
    pub fn n_sub(&self) -> u32 {
        self.cfg.n_perturb.max(1) as u32
    }

    /// Schedule-effective learning rate at `step`.
    pub fn lr_at(&self, step: u64) -> f32 {
        self.cfg.lr_schedule.at(self.cfg.lr, step, self.cfg.steps)
    }

    /// The lr handed to a sub-perturbation's ctx: `lr_eff / q` for the
    /// averaged-direction ZO updates; the FO reference ignores kappa and
    /// must see the full step lr.
    fn sub_lr(&self, step: u64, method: Method) -> f32 {
        let lr_eff = self.lr_at(step);
        if matches!(method, Method::FoAdam) {
            lr_eff
        } else {
            lr_eff / self.n_sub() as f32
        }
    }

    /// Run the forward phase of sub-perturbation `sub` of `step`.
    pub fn forward_sub(&self, rt: &Runtime, driver: &mut dyn ZoOptimizer,
                       params: &mut ParamStore, batch: &Batch, step: u64,
                       sub: u32, timers: &mut PhaseTimers,
                       counter: &mut SampleCounter) -> Result<ForwardOut> {
        let lr = self.sub_lr(step, driver.method());
        let arena = rt.step_arena(step);
        let staged0 = rt.stage().stats();
        let mut ctx = StepCtx {
            rt,
            params,
            batch,
            cfg: &self.cfg,
            seeds: &self.seeds,
            step,
            sub,
            lr,
            form: self.form,
            timers,
            counter,
            arena: &arena,
        };
        let out = driver.forward(&mut ctx);
        let d = rt.stage().stats().since(&staged0);
        timers.add_upload_bytes(d.upload_bytes, d.reused_bytes);
        out
    }

    /// Fold a forward outcome into `(mean loss, raw kappa)`:
    /// `kappa = (f+ - f-) / (2 rho)`, zero for the FO path.
    pub fn combine(&self, fwd: &ForwardOut) -> (f64, f32) {
        match *fwd {
            ForwardOut::TwoPoint { f_plus, f_minus } => {
                let kappa = (f_plus - f_minus) / (2.0 * self.cfg.rho);
                (((f_plus + f_minus) * 0.5) as f64, kappa)
            }
            ForwardOut::Loss(l) => (l as f64, 0.0),
        }
    }

    /// Clip |kappa| at `cfg.kappa_clip` (0 disables).
    pub fn clip_kappa(&self, kappa: f32) -> f32 {
        if self.cfg.kappa_clip > 0.0 {
            kappa.clamp(-self.cfg.kappa_clip, self.cfg.kappa_clip)
        } else {
            kappa
        }
    }

    /// Apply the update phase of sub `sub` with an already-clipped kappa.
    pub fn update_sub(&self, rt: &Runtime, driver: &mut dyn ZoOptimizer,
                      params: &mut ParamStore, batch: &Batch, step: u64,
                      sub: u32, kappa: f32, timers: &mut PhaseTimers,
                      counter: &mut SampleCounter) -> Result<()> {
        let lr = self.sub_lr(step, driver.method());
        // same step → same arena epoch: the update half shares the staged
        // buffers (seed scalar, factor vectors) the forward half uploaded
        let arena = rt.step_arena(step);
        let staged0 = rt.stage().stats();
        let mut ctx = StepCtx {
            rt,
            params,
            batch,
            cfg: &self.cfg,
            seeds: &self.seeds,
            step,
            sub,
            lr,
            form: self.form,
            timers,
            counter,
            arena: &arena,
        };
        let out = driver.update(&mut ctx, kappa);
        let d = rt.stage().stats().since(&staged0);
        timers.add_upload_bytes(d.upload_bytes, d.reused_bytes);
        out
    }

    /// One complete local step (all sub-perturbations, forward + update) —
    /// the single-process path. Returns the step's (two-point mean) loss;
    /// a non-finite measurement skips the update and aborts the remaining
    /// sub-perturbations, returning the offending loss (the run counts the
    /// skip, emits `step/nonfinite` telemetry, and continues).
    pub fn step(&self, rt: &Runtime, driver: &mut dyn ZoOptimizer,
                params: &mut ParamStore, batch: &Batch, step: u64,
                timers: &mut PhaseTimers, counter: &mut SampleCounter)
                -> Result<f64> {
        self.step_observed(rt, driver, params, batch, step, timers, counter,
                           &mut |_, _, _, _| Ok(()))
    }

    /// [`step`](Self::step) with a write-ahead observer: `observe(step,
    /// sub, perturb_seed, kappa)` fires after combine/clip and *before*
    /// the update is applied (`kappa = None` for a non-finite skip), which
    /// is exactly the ordering a durable journal needs — an observed
    /// record may be un-applied after a crash (replay re-applies it), but
    /// an applied update is always journaled. An observer error aborts
    /// the step before the update runs.
    #[allow(clippy::too_many_arguments)]
    pub fn step_observed(
        &self, rt: &Runtime, driver: &mut dyn ZoOptimizer,
        params: &mut ParamStore, batch: &Batch, step: u64,
        timers: &mut PhaseTimers, counter: &mut SampleCounter,
        observe: &mut dyn FnMut(u64, u32, u32, Option<f32>) -> Result<()>)
        -> Result<f64> {
        let q = self.n_sub();
        let mut loss_acc = 0.0f64;
        for sub in 0..q {
            let fwd = self.forward_sub(rt, driver, params, batch, step, sub,
                                       timers, counter)?;
            let (loss, kappa) = self.combine(&fwd);
            // observational only: the tracer reads kappa, never the reverse
            timers.telemetry().counter("step", "kappa", kappa as f64, step as i64);
            let seed = self.seeds.perturb_seed(step, sub);
            if !loss.is_finite() || !kappa.is_finite() {
                // surface the skipped update instead of stalling silently
                timers.telemetry().counter("step", "nonfinite", 1.0, step as i64);
                timers.telemetry().mark("step", "nonfinite", 0, step as i64);
                observe(step, sub, seed, None)?;
                return Ok(loss);
            }
            let kappa = self.clip_kappa(kappa);
            observe(step, sub, seed, Some(kappa))?;
            self.update_sub(rt, driver, params, batch, step, sub, kappa,
                            timers, counter)?;
            loss_acc += loss;
        }
        Ok(loss_acc / q as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(rho: f32, clip: f32) -> StepEngine {
        let mut cfg = TrainConfig::default();
        cfg.rho = rho;
        cfg.kappa_clip = clip;
        StepEngine::new(cfg)
    }

    #[test]
    fn combine_matches_two_point_formula() {
        let e = engine(1e-3, 0.0);
        let (loss, kappa) = e.combine(&ForwardOut::TwoPoint {
            f_plus: 2.5,
            f_minus: 2.3,
        });
        assert!((loss - 2.4).abs() < 1e-7);
        let expect = (2.5f32 - 2.3) / (2.0 * 1e-3);
        assert_eq!(kappa, expect);
        let (l, k) = e.combine(&ForwardOut::Loss(1.25));
        assert_eq!(l, 1.25);
        assert_eq!(k, 0.0);
    }

    #[test]
    fn clip_bounds_kappa() {
        let e = engine(1e-3, 2.0);
        assert_eq!(e.clip_kappa(5.0), 2.0);
        assert_eq!(e.clip_kappa(-5.0), -2.0);
        assert_eq!(e.clip_kappa(1.5), 1.5);
        let open = engine(1e-3, 0.0);
        assert_eq!(open.clip_kappa(5.0e6), 5.0e6);
    }

    #[test]
    fn engine_resolves_form_from_policy() {
        use crate::config::{FormPolicy, ForwardForm};
        let mut cfg = TrainConfig::default();
        cfg.forward_form = FormPolicy::Pinned(ForwardForm::Materialize);
        assert_eq!(StepEngine::new(cfg).form(), ForwardForm::Materialize);
        // an engine built straight from an Auto config (tests, embedders)
        // takes the documented fallback instead of erroring
        assert_eq!(StepEngine::new(TrainConfig::default()).form(),
                   ForwardForm::Implicit);
    }

    #[test]
    fn seeds_derive_from_cfg_master() {
        let mut cfg = TrainConfig::default();
        cfg.seed = 77;
        let e = StepEngine::new(cfg);
        assert_eq!(e.seeds.step_seed(3), SeedSchedule::new(77).step_seed(3));
    }
}
