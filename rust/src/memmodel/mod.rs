//! Analytic GPU-memory model (substitute for the paper's H100 measurements).
//!
//! Memory tables in the paper are determined by tensor shapes and optimizer
//! state policy, both of which we model exactly over the *real* OPT / LLaMA
//! parameter layouts ([`layout`]). [`usage`] accounts params, activations,
//! optimizer state, and per-method ZO factor state; [`tables`] renders the
//! Table 7 / Table 9 / Fig 1(c) / Fig 3(a) reproductions; [`comm`] models
//! the data-parallel communication cost (the fleet's O(1) scalar sync vs
//! gradient all-reduce).
//!
//! Calibration choices (documented, not fitted per-row): fp16 weights,
//! fp32 factor vectors and optimizer moments kept in the precision each
//! method's reference implementation uses, inference activation workspace
//! proportional to batch x seq x d x layers.

pub mod comm;
pub mod layout;
pub mod tables;
pub mod usage;

pub use layout::{llama, opt, ModelLayout};
pub use usage::{durability_footprint_bytes, forward_transient_bytes,
                memory_usage, memory_usage_form, memory_usage_policy,
                resolve_form_policy, MemoryBreakdown};
