//! Renderers for the paper's memory tables/figures.
//!
//! * [`table7`] — GPU memory across OPT-{125M..30B} / LLaMA-{7B..30B} for
//!   every method row of the paper's Table 7 (also Fig 3a at 13B/7B).
//! * [`table9`] — FO ft / ft-LoRA / ft-prefix vs ZO rows (OPT-6.7B/13B).
//! * [`fig1c`] — the Fig 1(c) bar data (OPT-13B, method x {params, state}).
//! * [`forward_forms`] — materialize vs implicit two-point transients per
//!   low-rank method (the PR5 `forward_form` knob).

use crate::benchkit::Report;
use crate::config::{FormPolicy, ForwardForm, Method};

use super::layout::{llama, opt};
use super::usage::{self, memory_usage, memory_usage_form,
                   memory_usage_policy, zero_shot};

const T7_METHODS: [Method; 9] = [
    Method::Mezo, Method::Subzo, Method::Lozo, Method::Tezo,
    Method::MezoM, Method::LozoM, Method::TezoM,
    Method::MezoAdam, Method::TezoAdam,
];

fn gib(bytes: u64) -> String {
    format!("{:.2} G", bytes as f64 / (1u64 << 30) as f64)
}

/// Table 7: memory per (method, model size).
pub fn table7() -> Report {
    let opts = ["125m", "1.3b", "2.7b", "6.7b", "13b", "30b"];
    let llamas = ["7b", "13b", "30b"];
    let mut header: Vec<String> = opts.iter().map(|s| format!("OPT-{s}")).collect();
    header.extend(llamas.iter().map(|s| format!("LLaMA-{s}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rep = Report::new("Table 7 — GPU memory (analytic model, GiB)", &header_refs);

    let layouts: Vec<_> = opts.iter().map(|s| opt(s))
        .chain(llamas.iter().map(|s| llama(s)))
        .collect();

    let zs_row: Vec<String> = layouts.iter().map(|l| gib(zero_shot(l).total())).collect();
    rep.add_row("Zero-Shot", zs_row);
    for m in T7_METHODS {
        let row: Vec<String> = layouts.iter()
            .map(|l| gib(memory_usage(l, m).total()))
            .collect();
        rep.add_row(m.name(), row);
    }
    rep
}

/// Table 9: FO (ft / LoRA / prefix) vs ZO memory with ratios vs zero-shot.
pub fn table9() -> Report {
    let sizes = ["6.7b", "13b"];
    let mut header = Vec::new();
    for s in &sizes {
        header.push(format!("OPT-{s} mem"));
        header.push(format!("OPT-{s} ratio"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut rep = Report::new("Table 9 — FO vs ZO memory (analytic model)", &header_refs);

    let layouts: Vec<_> = sizes.iter().map(|s| opt(s)).collect();
    let zs: Vec<u64> = layouts.iter().map(|l| zero_shot(l).total()).collect();

    let mut add = |label: &str, bytes: Vec<u64>| {
        let mut cells = Vec::new();
        for (b, z) in bytes.iter().zip(zs.iter()) {
            cells.push(gib(*b));
            cells.push(format!("{:.2}x", *b as f64 / *z as f64));
        }
        rep.add_row(label, cells);
    };

    add("ft", layouts.iter().map(|l| memory_usage(l, Method::FoAdam).total()).collect());
    add("ft-LoRA", layouts.iter().map(|l| usage::fo_peft(l, 0.023).total()).collect());
    add("ft-prefix", layouts.iter().map(|l| usage::fo_peft(l, 0.023).total()).collect());
    add("MeZO", layouts.iter().map(|l| memory_usage(l, Method::Mezo).total()).collect());
    add("MeZO-LoRA", layouts.iter().map(|l| usage::zo_peft(l).total()).collect());
    add("MeZO-prefix", layouts.iter().map(|l| usage::zo_peft(l).total()).collect());
    add("MeZO-Adam", layouts.iter().map(|l| memory_usage(l, Method::MezoAdam).total()).collect());
    add("TeZO-Adam", layouts.iter().map(|l| memory_usage(l, Method::TezoAdam).total()).collect());
    add("Zero-Shot", zs.clone());
    rep
}

/// Fig 1(c): OPT-13B memory decomposition per method.
pub fn fig1c() -> Report {
    let l = opt("13b");
    let mut rep = Report::new(
        "Fig 1(c) — OPT-13B memory decomposition (GiB)",
        &["params", "activations", "opt state", "zo factors", "total"],
    );
    let methods = [Method::Mezo, Method::MezoM, Method::MezoAdam,
                   Method::Tezo, Method::TezoM, Method::TezoAdam];
    for m in methods {
        let u = memory_usage(&l, m);
        rep.add_row(m.name(), vec![
            gib(u.params), gib(u.activations), gib(u.optimizer_state),
            gib(u.zo_state), gib(u.total()),
        ]);
    }
    rep
}

/// Forward-form comparison: the transient perturbed-weight copies the
/// materialized two-point loss allocates vs the implicit factor-form one,
/// per low-rank method, at the Fig 1(c) scales.
pub fn forward_forms() -> Report {
    let mut rep = Report::new(
        "Forward forms — two-point transients (materialize vs implicit)",
        &["transient (mat)", "transient (impl)", "total (mat)",
          "total (impl)", "saved", "auto picks"],
    );
    // only the methods whose implicit artifact actually exists — SubZO is
    // low-rank too but always runs its materialized loss (no implicit
    // artifact; `loss_artifact` falls back), so a row here would advertise
    // savings no knob can deliver
    let methods = [Method::Tezo, Method::TezoM, Method::TezoAdam,
                   Method::Lozo, Method::LozoM];
    for l in [opt("13b"), llama("7b")] {
        for m in methods {
            let mat = memory_usage_form(&l, m, 16, ForwardForm::Materialize);
            let imp = memory_usage_form(&l, m, 16, ForwardForm::Implicit);
            let saved = mat.total().saturating_sub(imp.total());
            // the analytic stand-in for the runtime tuner's decision: the
            // form the byte model would pin under `--forward-form auto`
            // (the live tuner optimizes time and records its winner in
            // `tuning.json`; see docs/runtime.md "Autotuning")
            let (tuned, _) = memory_usage_policy(&l, m, 16, FormPolicy::Auto);
            rep.add_row(&format!("{} {}", l.name, m.name()), vec![
                gib(mat.transient), gib(imp.transient),
                gib(mat.total()), gib(imp.total()), gib(saved),
                tuned.name().to_string(),
            ]);
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_without_panicking() {
        let _ = table7();
        let _ = table9();
        let _ = fig1c();
        let _ = forward_forms();
    }

    #[test]
    fn table7_ordering_matches_paper_shape() {
        // Spot-check the paper's ordering claims at OPT-13B:
        // mezo < mezo_m < mezo_adam; tezo_adam ~ mezo; all low-rank ~ mezo
        let l = opt("13b");
        let mezo = memory_usage(&l, Method::Mezo).total();
        let mezo_m = memory_usage(&l, Method::MezoM).total();
        let mezo_adam = memory_usage(&l, Method::MezoAdam).total();
        let tezo_adam = memory_usage(&l, Method::TezoAdam).total();
        assert!(mezo < mezo_m && mezo_m < mezo_adam);
        assert!((tezo_adam as f64) < 1.05 * mezo as f64);
        // paper: TeZO-Adam ~ 34.6% of MeZO-Adam at 13B
        let ratio = tezo_adam as f64 / mezo_adam as f64;
        assert!(ratio > 0.25 && ratio < 0.45, "ratio {ratio}");
    }
}
