//! Analytic communication-cost model for data-parallel fine-tuning.
//!
//! The resampling trick makes a ZO step fully described by a 4-byte seed
//! plus one scalar `kappa = (f+ - f-)/(2 rho)`, so the seed-synchronized
//! fleet ([`crate::fleet`]) moves O(1) bytes per worker per step. This
//! module pins down the logical wire sizes (the fleet's [`CommStats`]
//! counts with these constants) and the gradient all-reduce volume a
//! first-order — or parameter-averaging — data-parallel scheme would move
//! instead, so the "scalars vs gradients" headline is a computed table, not
//! prose.
//!
//! [`CommStats`]: crate::fleet::CommStats

/// Logical bytes of one work ticket (step u64 + sub u32 + perturb seed u32).
pub const TICKET_BYTES: u64 = 16;
/// Logical bytes of one worker's two-point result (f+ and f- as f32).
pub const TWO_POINT_BYTES: u64 = 8;
/// Logical bytes of one aggregated-kappa broadcast (f32, padded ticket echo
/// included for the replica-consistency check).
pub const KAPPA_BYTES: u64 = 4 + TICKET_BYTES;

/// Per-frame overhead of the binary TCP codec (`fleet::wire`): a 4-byte
/// little-endian length prefix plus a 1-byte message tag.
pub const FRAME_HEADER_BYTES: u64 = 5;
/// Result-path metadata the framed protocol carries beyond the logical
/// two-point payload: worker id (u32) + step (u64) + sub (u32) + forward
/// wall seconds (f64) for straggler accounting.
pub const RESULT_META_BYTES: u64 = 4 + 8 + 4 + 8;

/// Total logical wire bytes one training step moves for the fleet protocol:
/// per sub-perturbation, a ticket down to every worker, a two-point result
/// up from every worker, and the aggregated kappa broadcast back down.
pub fn zo_scalar_step_bytes(workers: u64, n_perturb: u64) -> u64 {
    let q = n_perturb.max(1);
    q * workers * (TICKET_BYTES + TWO_POINT_BYTES + KAPPA_BYTES)
}

/// Framed bytes the same step puts on a real wire: each logical message
/// plus its frame header, and the result frame's metadata fields. This is
/// what `fleet::wire` actually encodes — pinned against the codec by
/// `tests/props_wire.rs`, so model and implementation cannot drift.
pub fn zo_scalar_step_wire_bytes(workers: u64, n_perturb: u64) -> u64 {
    let q = n_perturb.max(1);
    let ticket = FRAME_HEADER_BYTES + TICKET_BYTES;
    let result = FRAME_HEADER_BYTES + RESULT_META_BYTES + TWO_POINT_BYTES;
    let kappa = FRAME_HEADER_BYTES + KAPPA_BYTES;
    q * workers * (ticket + result + kappa)
}

/// Total wire bytes of one ring all-reduce over an fp32 gradient of
/// `n_params` elements: each of the `workers` ranks transmits
/// `2 (W-1)/W * 4 * n_params` bytes (reduce-scatter + all-gather).
pub fn gradient_allreduce_step_bytes(n_params: u64, workers: u64) -> u64 {
    if workers <= 1 {
        return 0;
    }
    // summed over ranks: W * 2*(W-1)/W * 4 * n = 2*(W-1)*4*n
    2 * (workers - 1) * 4 * n_params
}

/// How many times less traffic the scalar-sync fleet moves than a gradient
/// all-reduce at the same worker count (per step).
pub fn reduction_factor(n_params: u64, workers: u64, n_perturb: u64) -> f64 {
    let scalar = zo_scalar_step_bytes(workers, n_perturb).max(1);
    gradient_allreduce_step_bytes(n_params, workers) as f64 / scalar as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::layout::opt;

    #[test]
    fn scalar_sync_is_constant_in_model_size() {
        let w = zo_scalar_step_bytes(8, 1);
        assert!(w < 1024, "per-step fleet traffic must be O(workers): {w}");
        assert_eq!(zo_scalar_step_bytes(8, 1), zo_scalar_step_bytes(8, 1));
        // q-SPSA scales linearly
        assert_eq!(zo_scalar_step_bytes(8, 4), 4 * zo_scalar_step_bytes(8, 1));
    }

    #[test]
    fn framing_overhead_is_bounded_and_scales_like_the_logical_model() {
        // framed > logical, but by a constant per message — the O(workers)
        // scaling the paper's systems claim rests on is unchanged
        for (w, q) in [(1u64, 1u64), (4, 1), (8, 2), (64, 4)] {
            let logical = zo_scalar_step_bytes(w, q);
            let framed = zo_scalar_step_wire_bytes(w, q);
            assert!(framed > logical);
            assert_eq!(
                framed - logical,
                q * w * (3 * FRAME_HEADER_BYTES + RESULT_META_BYTES),
                "overhead must be exactly 3 headers + result metadata per \
                 (worker, sub)"
            );
        }
        // q-SPSA scales linearly in the framed model too
        assert_eq!(zo_scalar_step_wire_bytes(8, 4),
                   4 * zo_scalar_step_wire_bytes(8, 1));
    }

    #[test]
    fn allreduce_is_gradient_sized() {
        let n = 13_000_000_000u64; // OPT-13B-ish
        let b = gradient_allreduce_step_bytes(n, 8);
        assert!(b > n * 4, "all-reduce moves more than one gradient copy");
        assert_eq!(gradient_allreduce_step_bytes(n, 1), 0);
    }

    #[test]
    fn fleet_beats_allreduce_by_many_orders_of_magnitude() {
        let l = opt("13b");
        let n = l.n_params() as u64;
        let f = reduction_factor(n, 8, 1);
        assert!(f > 1e8, "13B @ 8 workers: reduction factor {f:.1}");
        // even a tiny model at 2 workers wins by >1000x
        let f_small = reduction_factor(1_000_000, 2, 1);
        assert!(f_small > 1e3, "1M @ 2 workers: {f_small:.1}");
    }
}
