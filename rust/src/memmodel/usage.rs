//! Per-method memory accounting (Fig 1c, Fig 3a, Tables 7 & 9).

use crate::config::{FormPolicy, ForwardForm, Method};

use super::layout::ModelLayout;

/// Byte-level breakdown of one (model, method) cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryBreakdown {
    /// model weights (fp16)
    pub params: u64,
    /// inference activations + runtime workspace
    pub activations: u64,
    /// full-size optimizer state (momentum / Adam moments / gradients)
    pub optimizer_state: u64,
    /// low-rank ZO factor state (U/V panels, tau vectors, lazy factors)
    pub zo_state: u64,
    /// FO-only: backprop activation storage
    pub backprop: u64,
    /// prepared-call staging-pool residency: batch tensors, tau/scalar
    /// stagings, kept one extra step for cross-step reuse (runtime::stage)
    pub staging: u64,
    /// transient perturbed-weight copies of the two-point forward: the
    /// materialized loss form allocates dense `W +/- rho Z` per matrix per
    /// call, the implicit (factor-form) one only its (2, r) tau stacks.
    /// Zero in the paper-table entry points (the paper's measured rows are
    /// materialized baselines whose transients the calibrated terms above
    /// already absorb) — populated by [`memory_usage_form`].
    pub transient: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.params + self.activations + self.optimizer_state + self.zo_state
            + self.backprop + self.staging + self.transient
    }

    pub fn total_gib(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

/// Weight precision (paper runs fp16 on GPU).
pub const WEIGHT_BYTES: u64 = 2;
/// Optimizer moments in the reference implementations are fp16 tensors
/// shadowing the weights (MeZO codebase keeps states in model dtype).
pub const STATE_BYTES: u64 = 2;
/// Factor panels / tau states live in model dtype (fp16 on GPU).
pub const FACTOR_BYTES: u64 = 2;

/// Inference activation + workspace bytes: residual stream + attention
/// workspace for one forward, batch 16 x seq (the paper's fine-tuning
/// batch), fp16. One constant recipe for every method/model — the *shape*
/// of the tables comes from the state policy, not from this term.
fn activation_bytes(l: &ModelLayout, batch: u64) -> u64 {
    let s = 512u64.min(l.seq_len as u64); // fine-tuning prompts, not full ctx
    let d = l.d_model as u64;
    let layers = l.n_layers as u64;
    // residual + qkv + ffn intermediate live tensors (~6d per token) plus a
    // couple of attention score tiles
    let per_token = 6 * d + 2 * s;
    batch * s * per_token * WEIGHT_BYTES * (layers / 8 + 1)
}

/// Backprop activation storage for FO fine-tuning (no checkpointing, as in
/// the paper's `ft` rows): every layer keeps its inputs.
fn backprop_bytes(l: &ModelLayout, batch: u64) -> u64 {
    let s = 512u64.min(l.seq_len as u64);
    let d = l.d_model as u64;
    let layers = l.n_layers as u64;
    batch * s * d * layers * 8 * WEIGHT_BYTES
}

/// Staging-pool residency for one training step at batch size `batch`:
/// the three batch tensors (tokens/targets i32 + mask f32, 4 B each on the
/// wire) held for the current step plus the one-step reuse window, the
/// per-matrix tau-group vectors of the low-rank methods, and the scalar
/// knobs. The batch term dominates; the rest is here so the model's
/// residency matches what `DeviceStage::stats()` reports.
fn staging_bytes(l: &ModelLayout, batch: u64, method: Method) -> u64 {
    let s = 512u64.min(l.seq_len as u64);
    let batch_resident = 3 * batch * s * 4 * 2; // x2: one-step reuse window
    let nmat = l.n_matrices() as u64;
    // tau groups staged per step (raw + update-side effective/moment forms)
    let tau_groups = match method {
        Method::Tezo | Method::TezoM => 2,
        Method::TezoAdam => 3,
        _ => 0,
    };
    let tau_resident = tau_groups * nmat * TEZO_RANK * 4 * 2;
    let scalars = 16 * 4; // seeds + knobs, generously
    batch_resident + tau_resident + scalars
}

/// TeZO rank used for memory accounting (the r_max cap of Table 6).
pub const TEZO_RANK: u64 = 64;
/// LOZO rank (paper Table 6: r = 8).
pub const LOZO_RANK: u64 = 8;
/// SubZO rank (paper Table 6: r in {32,64,128}).
pub const SUBZO_RANK: u64 = 64;

/// Memory usage of fine-tuning `layout` with `method` at batch size 16.
pub fn memory_usage(l: &ModelLayout, method: Method) -> MemoryBreakdown {
    memory_usage_batch(l, method, 16)
}

pub fn memory_usage_batch(l: &ModelLayout, method: Method, batch: u64) -> MemoryBreakdown {
    let p = l.n_params() as u64;
    let fu = l.factor_units() as u64; // sum (m+n)*count
    let nmat = l.n_matrices() as u64;
    let mut b = MemoryBreakdown {
        params: p * WEIGHT_BYTES,
        activations: activation_bytes(l, batch),
        staging: staging_bytes(l, batch, method),
        ..Default::default()
    };
    b.optimizer_state = method.full_size_state_copies() as u64 * p * STATE_BYTES;
    // dense-Z methods hold transient per-parameter normal draws during the
    // perturb/restore passes; with allocator caching the peak is ~two
    // largest-parameter buffers (this is why the paper's measured MeZO rows
    // sit ~1 GiB above the low-rank rows at 13B — Fig 1c / Table 7)
    let largest = l.matrices.iter().map(|m| (m.m * m.n) as u64).max().unwrap_or(0);
    match method {
        Method::Mezo | Method::MezoM | Method::MezoAdam | Method::ZoAdamu => {
            b.zo_state = 2 * largest * WEIGHT_BYTES;
        }
        Method::Lozo | Method::LozoM => {
            // U lazy (m x r) + per-step V (n x r); -m adds S (n x r)
            let copies = if method == Method::LozoM { 3 } else { 2 };
            b.zo_state = fu / 2 * LOZO_RANK * FACTOR_BYTES * copies / 1;
        }
        Method::Subzo => {
            // orthonormal U (m x r) + V (n x r) + Sigma (r x r)
            b.zo_state = (fu * SUBZO_RANK + nmat * SUBZO_RANK * SUBZO_RANK) * FACTOR_BYTES;
        }
        Method::Tezo => {
            // U + V panels once for the whole run + per-layer tau
            b.zo_state = (fu * TEZO_RANK + nmat * TEZO_RANK) * FACTOR_BYTES;
        }
        Method::TezoM => {
            b.zo_state = (fu * TEZO_RANK + 2 * nmat * TEZO_RANK) * FACTOR_BYTES;
        }
        Method::TezoAdam => {
            b.zo_state = (fu * TEZO_RANK + 3 * nmat * TEZO_RANK) * FACTOR_BYTES;
        }
        Method::FoAdam => {
            b.backprop = backprop_bytes(l, batch);
            // grads already counted in full_size_state_copies (3 copies)
        }
    }
    b
}

/// Transient perturbed-weight bytes of one two-point forward under
/// `form` — the term the implicit (factor-form) loss artifacts exist to
/// drop (see `python/compile/model.py` and `hlo_stats`'s param-shaped
/// metrics, which measure the same quantity statically per artifact).
///
/// * Methods with an implicit artifact (TeZO family, LOZO family),
///   `Materialize`: two dense perturbed copies of every matrix weight per
///   call (`W + rho Z` for f+, `W - rho Z` for f-).
/// * Same methods, `Implicit`: the (2, r) sign-batched tau stacks per
///   matrix — O(r), negligible.
/// * Everything else — dense-Z methods, SubZO (low-rank but with no
///   implicit artifact: `Manifest::loss_artifact` always falls back to its
///   materialized loss), and the FO reference — reports 0 regardless of
///   `form`: their transients are already absorbed in the calibrated
///   `zo_state` term, and no knob setting can change what they run.
pub fn forward_transient_bytes(l: &ModelLayout, method: Method,
                               form: ForwardForm) -> u64 {
    let has_implicit = matches!(method,
        Method::Tezo | Method::TezoM | Method::TezoAdam
        | Method::Lozo | Method::LozoM);
    if !has_implicit {
        return 0;
    }
    let rank = match method {
        Method::Lozo | Method::LozoM => LOZO_RANK,
        _ => TEZO_RANK,
    };
    match form {
        ForwardForm::Materialize => {
            let mat_elems: u64 = l.matrices.iter()
                .map(|m| (m.m * m.n * m.count) as u64)
                .sum();
            2 * mat_elems * WEIGHT_BYTES
        }
        ForwardForm::Implicit => {
            2 * l.n_matrices() as u64 * rank * FACTOR_BYTES
        }
    }
}

/// Memory usage with the forward-form transient term populated — the
/// `memory-report --table forms` view. The paper-table entry points
/// ([`memory_usage`] / [`memory_usage_batch`]) stay transient-free so the
/// calibrated Table 7 / 9 / Fig 1(c) reproductions are untouched.
pub fn memory_usage_form(l: &ModelLayout, method: Method, batch: u64,
                         form: ForwardForm) -> MemoryBreakdown {
    let mut b = memory_usage_batch(l, method, batch);
    b.transient = forward_transient_bytes(l, method, form);
    b
}

/// Analytic resolution of a form *policy*: a pinned policy is itself;
/// `auto` picks the form with the smaller modeled total, ties to the
/// implicit form — the same tie-break the runtime tuner uses. This is the
/// memory model's stand-in for `runtime::tune` (which optimizes time, not
/// bytes, and can disagree on small shapes where the materialized forward
/// is faster); the `memory-report --table forms` view shows both so that
/// disagreement is visible, not hidden.
pub fn resolve_form_policy(l: &ModelLayout, method: Method, batch: u64,
                           policy: FormPolicy) -> ForwardForm {
    match policy.pinned() {
        Some(form) => form,
        None => {
            let mat = memory_usage_form(l, method, batch,
                                        ForwardForm::Materialize).total();
            let imp = memory_usage_form(l, method, batch,
                                        ForwardForm::Implicit).total();
            if mat < imp { ForwardForm::Materialize } else { ForwardForm::Implicit }
        }
    }
}

/// [`memory_usage_form`] for a policy: resolves `auto` analytically first
/// and reports which concrete form the numbers describe.
pub fn memory_usage_policy(l: &ModelLayout, method: Method, batch: u64,
                           policy: FormPolicy)
                           -> (ForwardForm, MemoryBreakdown) {
    let form = resolve_form_policy(l, method, batch, policy);
    (form, memory_usage_form(l, method, batch, form))
}

/// Host-side disk footprint of the durability machinery (docs/robustness.md):
/// `keep` retained fp32 checkpoints plus the journal's retention window —
/// between prunes at most `checkpoint_every` steps of `q`-sub frames
/// survive (`retain_from_step` trims the rest at each save). Disk, not
/// device memory — sized with the same layout arithmetic as the tables but
/// never folded into the calibrated Table 7/9 totals.
pub fn durability_footprint_bytes(l: &ModelLayout, q: u64,
                                  checkpoint_every: u64, keep: u64) -> u64 {
    let ckpt = keep * l.n_params() as u64 * 4; // checkpoint bins are fp32 LE
    let window = checkpoint_every.max(1) * q;
    ckpt + crate::runtime::journal::journal_bytes(window)
}

/// Zero-shot (inference-only) baseline.
pub fn zero_shot(l: &ModelLayout) -> MemoryBreakdown {
    MemoryBreakdown {
        params: l.n_params() as u64 * WEIGHT_BYTES,
        activations: activation_bytes(l, 16),
        ..Default::default()
    }
}

/// PEFT variants for Table 9: only `trainable_frac` of the params get
/// optimizer state; FO backprop activations still required.
pub fn fo_peft(l: &ModelLayout, trainable_frac: f64) -> MemoryBreakdown {
    let p = l.n_params() as u64;
    let trainable = (p as f64 * trainable_frac) as u64;
    MemoryBreakdown {
        params: p * WEIGHT_BYTES,
        activations: activation_bytes(l, 16),
        optimizer_state: trainable * (STATE_BYTES + 4 + 4 + 4), // grad + fp32 m,v,master
        zo_state: 0,
        backprop: backprop_bytes(l, 16),
    }
}

/// ZO + PEFT (MeZO-LoRA / MeZO-prefix rows of Table 9).
pub fn zo_peft(l: &ModelLayout) -> MemoryBreakdown {
    MemoryBreakdown {
        params: l.n_params() as u64 * WEIGHT_BYTES,
        activations: activation_bytes(l, 16),
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::layout::{llama, opt};

    #[test]
    fn tezo_adam_below_mezo_sgd() {
        // The paper's headline memory claim (Fig 1c): TeZO-Adam needs less
        // memory than MeZO-SGD... is approximately equal; and far below
        // MeZO-Adam (~35%).
        for l in [opt("13b"), llama("7b")] {
            let mezo = memory_usage(&l, Method::Mezo).total();
            let tezo_adam = memory_usage(&l, Method::TezoAdam).total();
            let mezo_adam = memory_usage(&l, Method::MezoAdam).total();
            assert!(tezo_adam as f64 <= mezo as f64 * 1.02,
                    "{}: tezo-adam {} vs mezo {}", l.name, tezo_adam, mezo);
            let ratio = tezo_adam as f64 / mezo_adam as f64;
            assert!(ratio < 0.45, "{}: ratio {ratio}", l.name);
        }
    }

    #[test]
    fn mezo_m_roughly_doubles_state() {
        let l = opt("13b");
        let mezo = memory_usage(&l, Method::Mezo);
        let mezo_m = memory_usage(&l, Method::MezoM);
        let delta = mezo_m.total() - mezo.total();
        let p16 = l.n_params() as u64 * 2;
        assert!((delta as f64 - p16 as f64).abs() / (p16 as f64) < 0.05);
    }

    #[test]
    fn fo_ft_is_many_times_zero_shot() {
        // Table 9: ft ~ 8-10x zero-shot
        let l = opt("13b");
        let zs = zero_shot(&l).total() as f64;
        let ft = memory_usage(&l, Method::FoAdam).total() as f64;
        let ratio = ft / zs;
        assert!(ratio > 4.0, "ft/zs ratio {ratio}");
    }

    #[test]
    fn staging_residency_is_negligible_and_method_ordered() {
        // the pool holds batch tensors + tau/scalar stagings: well under a
        // tenth of a percent of the weights at LLM scale, and the tau terms
        // only appear for the TeZO family
        let l = llama("7b");
        for m in [Method::Mezo, Method::Tezo, Method::TezoAdam, Method::FoAdam] {
            let u = memory_usage(&l, m);
            assert!(u.staging > 0);
            assert!((u.staging as f64) < 1e-3 * u.params as f64,
                    "{:?}: staging {} params {}", m, u.staging, u.params);
        }
        let mezo = memory_usage(&l, Method::Mezo).staging;
        let tezo = memory_usage(&l, Method::Tezo).staging;
        let tezo_adam = memory_usage(&l, Method::TezoAdam).staging;
        assert!(mezo < tezo && tezo < tezo_adam,
                "tau staging should grow with the tau-group count");
    }

    #[test]
    fn implicit_form_drops_the_perturbed_weight_transients() {
        let l = llama("7b");
        for m in [Method::Tezo, Method::TezoAdam, Method::Lozo, Method::LozoM] {
            let mat = memory_usage_form(&l, m, 16, ForwardForm::Materialize);
            let imp = memory_usage_form(&l, m, 16, ForwardForm::Implicit);
            let mat_elems: u64 = l.matrices.iter()
                .map(|s| (s.m * s.n * s.count) as u64)
                .sum();
            assert_eq!(mat.transient, 2 * mat_elems * WEIGHT_BYTES, "{m:?}");
            // implicit keeps only the (2, r) tau stacks — under 0.1% of the
            // materialized copies at 7B scale
            assert!(imp.transient < mat.transient / 1000,
                    "{m:?}: imp {} vs mat {}", imp.transient, mat.transient);
            assert!(imp.total() < mat.total());
        }
        // dense-Z methods, SubZO (no implicit artifact), and FO: form inert
        for m in [Method::Mezo, Method::MezoAdam, Method::ZoAdamu,
                  Method::Subzo, Method::FoAdam] {
            let mat = memory_usage_form(&l, m, 16, ForwardForm::Materialize);
            let imp = memory_usage_form(&l, m, 16, ForwardForm::Implicit);
            assert_eq!(mat.transient, 0);
            assert_eq!(imp.total(), mat.total());
        }
        // the paper-table entry points stay transient-free (calibration)
        assert_eq!(memory_usage(&l, Method::Tezo).transient, 0);
    }

    #[test]
    fn auto_policy_resolves_analytically() {
        let l = llama("7b");
        // pinned policies are themselves
        assert_eq!(resolve_form_policy(&l, Method::Tezo, 16,
                       FormPolicy::Pinned(ForwardForm::Materialize)),
                   ForwardForm::Materialize);
        // auto: implicit drops the dense transients, so it wins the
        // byte-model for every tunable method; inert methods tie and take
        // the same tie-break as the runtime tuner
        for m in [Method::Tezo, Method::TezoAdam, Method::Lozo,
                  Method::Mezo, Method::Subzo, Method::FoAdam] {
            assert_eq!(resolve_form_policy(&l, m, 16, FormPolicy::Auto),
                       ForwardForm::Implicit, "{m:?}");
        }
        let (form, b) = memory_usage_policy(&l, Method::Tezo, 16,
                                            FormPolicy::Auto);
        assert_eq!(form, ForwardForm::Implicit);
        assert_eq!(b.total(),
                   memory_usage_form(&l, Method::Tezo, 16,
                                     ForwardForm::Implicit).total());
    }

    #[test]
    fn durability_footprint_is_checkpoint_dominated() {
        let l = llama("7b");
        // two retained fp32 checkpoints = 8 bytes/param; the journal window
        // (100 steps x 1 sub, 33 B/frame + 20 B header) is noise next to it
        let bytes = durability_footprint_bytes(&l, 1, 100, 2);
        let ckpt = 2 * l.n_params() as u64 * 4;
        assert!(bytes > ckpt);
        assert!((bytes - ckpt) < 4 * 1024, "journal window {}", bytes - ckpt);
        // the journal term scales with q and the prune cadence
        let wider = durability_footprint_bytes(&l, 4, 100, 2);
        assert_eq!(wider - ckpt, (bytes - ckpt - 20) * 4 + 20);
    }

    #[test]
    fn low_rank_state_is_sub_percent_of_params() {
        let l = llama("7b");
        for m in [Method::Tezo, Method::TezoAdam, Method::Lozo, Method::Subzo] {
            let u = memory_usage(&l, m);
            assert!((u.zo_state as f64) < 0.05 * u.params as f64,
                    "{:?}: zo_state {} params {}", m, u.zo_state, u.params);
        }
    }
}
