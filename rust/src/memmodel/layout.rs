//! Parameter layouts of the paper's model families (OPT, LLaMA) and of our
//! OPTLite substitute configs.

/// A 2D weight in the model, with multiplicity (how many identical layers).
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub name: &'static str,
    pub m: usize,
    pub n: usize,
    pub count: usize,
}

/// Parameter layout of one model.
#[derive(Clone, Debug)]
pub struct ModelLayout {
    pub name: String,
    pub family: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub matrices: Vec<MatrixSpec>,
    /// 1D parameters (layernorms, biases), total element count
    pub vector_elems: usize,
}

impl ModelLayout {
    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.matrices.iter().map(|m| m.m * m.n * m.count).sum::<usize>() + self.vector_elems
    }

    /// Sum over matrices of (m + n) * count — the size driver of rank-r
    /// factor state (multiply by r for elements).
    pub fn factor_units(&self) -> usize {
        self.matrices.iter().map(|m| (m.m + m.n) * m.count).sum()
    }

    /// Number of 2D matrices (counting multiplicity).
    pub fn n_matrices(&self) -> usize {
        self.matrices.iter().map(|m| m.count).sum()
    }
}

/// OPT family (Zhang et al. 2022): pre-LN decoder, ffn = 4*d, learned
/// positional embeddings, vocab 50272, seq 2048.
pub fn opt(size: &str) -> ModelLayout {
    let (d, l): (usize, usize) = match size {
        "125m" => (768, 12),
        "350m" => (1024, 24),
        "1.3b" => (2048, 24),
        "2.7b" => (2560, 32),
        "6.7b" => (4096, 32),
        "13b" => (5120, 40),
        "30b" => (7168, 48),
        other => panic!("unknown OPT size {other}"),
    };
    let v = 50272;
    let s = 2048;
    let ff = 4 * d;
    let matrices = vec![
        MatrixSpec { name: "embed.tok", m: v, n: d, count: 1 },
        MatrixSpec { name: "embed.pos", m: s + 2, n: d, count: 1 },
        MatrixSpec { name: "attn.qkvo", m: d, n: d, count: 4 * l },
        MatrixSpec { name: "ffn.fc1", m: d, n: ff, count: l },
        MatrixSpec { name: "ffn.fc2", m: ff, n: d, count: l },
    ];
    // biases (qkvo + fc1 + fc2) + 2 layernorms per block + final LN
    let vector_elems = l * (4 * d + ff + d + 4 * d) + 2 * d;
    ModelLayout {
        name: format!("opt-{size}"),
        family: "opt",
        d_model: d,
        n_layers: l,
        d_ff: ff,
        vocab: v,
        seq_len: s,
        matrices,
        vector_elems,
    }
}

/// LLaMA family (Touvron et al. 2023): RMSNorm (no biases), SwiGLU FFN,
/// vocab 32000, seq 2048, untied output head.
pub fn llama(size: &str) -> ModelLayout {
    let (d, l, ff): (usize, usize, usize) = match size {
        "7b" => (4096, 32, 11008),
        "13b" => (5120, 40, 13824),
        "30b" => (6656, 60, 17920),
        other => panic!("unknown LLaMA size {other}"),
    };
    let v = 32000;
    let matrices = vec![
        MatrixSpec { name: "embed.tok", m: v, n: d, count: 1 },
        MatrixSpec { name: "lm_head", m: d, n: v, count: 1 },
        MatrixSpec { name: "attn.qkvo", m: d, n: d, count: 4 * l },
        MatrixSpec { name: "ffn.gate_up", m: d, n: ff, count: 2 * l },
        MatrixSpec { name: "ffn.down", m: ff, n: d, count: l },
    ];
    let vector_elems = l * 2 * d + d; // RMSNorm scales
    ModelLayout {
        name: format!("llama-{size}"),
        family: "llama",
        d_model: d,
        n_layers: l,
        d_ff: ff,
        vocab: v,
        seq_len: 2048,
        matrices,
        vector_elems,
    }
}

/// Our OPTLite substitute configs (mirrors python/compile/configs.py) —
/// used to cross-check the analytic model against measured RSS.
pub fn optlite(name: &str) -> ModelLayout {
    let (d, l, ff, v, s): (usize, usize, usize, usize, usize) = match name {
        "tiny" => (64, 2, 256, 256, 64),
        "small" => (256, 4, 1024, 2048, 128),
        "medium" => (512, 8, 2048, 8192, 128),
        "e2e" => (768, 12, 3072, 8192, 128),
        other => panic!("unknown OPTLite config {other}"),
    };
    let matrices = vec![
        MatrixSpec { name: "embed.tok", m: v, n: d, count: 1 },
        MatrixSpec { name: "embed.pos", m: s, n: d, count: 1 },
        MatrixSpec { name: "attn.qkvo", m: d, n: d, count: 4 * l },
        MatrixSpec { name: "ffn.w1", m: d, n: ff, count: l },
        MatrixSpec { name: "ffn.w2", m: ff, n: d, count: l },
    ];
    let vector_elems = l * 4 * d + 2 * d;
    ModelLayout {
        name: format!("optlite-{name}"),
        family: "optlite",
        d_model: d,
        n_layers: l,
        d_ff: ff,
        vocab: v,
        seq_len: s,
        matrices,
        vector_elems,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_param_counts_are_close_to_nominal() {
        // within 10% of the headline sizes
        for (size, nominal) in [("125m", 125e6), ("1.3b", 1.3e9), ("2.7b", 2.7e9),
                                ("6.7b", 6.7e9), ("13b", 13e9), ("30b", 30e9)] {
            let n = opt(size).n_params() as f64;
            assert!((n - nominal).abs() / nominal < 0.10, "{size}: {n} vs {nominal}");
        }
    }

    #[test]
    fn llama_param_counts_are_close_to_nominal() {
        for (size, nominal) in [("7b", 6.7e9), ("13b", 13e9), ("30b", 32.5e9)] {
            let n = llama(size).n_params() as f64;
            assert!((n - nominal).abs() / nominal < 0.10, "{size}: {n} vs {nominal}");
        }
    }

    #[test]
    fn factor_units_scale_like_sqrt_d() {
        // factor state grows ~sqrt(params): ratio (units / sqrt(params))
        // should stay within one order of magnitude across sizes
        let small = opt("125m");
        let big = opt("13b");
        let r_small = small.factor_units() as f64 / (small.n_params() as f64).sqrt();
        let r_big = big.factor_units() as f64 / (big.n_params() as f64).sqrt();
        assert!(r_big / r_small < 10.0 && r_small / r_big < 10.0);
    }

    #[test]
    fn optlite_matches_python_configs() {
        // python tiny config reports 297_472 params (embed 256*64 + pos
        // 64*64 + 2 blocks + lns) — keep in sync with configs.py
        let t = optlite("tiny");
        assert_eq!(t.n_params(), 256 * 64 + 64 * 64
            + 2 * (4 * 64 * 64 + 64 * 256 + 256 * 64) + 2 * 4 * 64 + 2 * 64);
    }
}
