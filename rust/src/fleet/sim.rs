//! Artifact-free simulation replica for transport and fault-tolerance
//! tests.
//!
//! A deterministic toy model stands in for the PJRT runtime: parameters
//! are a small vector initialized from the fleet's own seed schedule, each
//! worker's "data shard" is a per-(step, shard) target vector, the loss is
//! the mean squared distance to that target, and the ZO update is
//! `p -= lr * kappa * z` with `z` regenerated from the ticket's
//! perturbation seed — the same resampling contract the real engine obeys.
//! Everything (losses, kappas, updates) is a pure function of the seed
//! schedule, so [`run_oracle`] can replay the exact single-process
//! trajectory the fleet must reproduce *bitwise*, which is what the chaos
//! and loopback-vs-TCP parity tests assert.
//!
//! The measurement/update arithmetic lives in free functions shared by
//! [`SimReplica`] and [`run_oracle`]; bitwise agreement is by construction,
//! not by accident of two parallel implementations.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::optimizer::ForwardOut;
use crate::coordinator::seeds::Stream;
use crate::coordinator::step::StepEngine;
use crate::rngx;

use super::protocol::{aggregate_two_point, LogEntry};
use super::worker::{Replica, ReplicaReport};

/// Initial parameters: derived from the `FactorInit` stream so two fleets
/// with the same master seed start bit-identical.
pub fn init_params(engine: &StepEngine, dim: usize) -> Vec<f32> {
    rngx::normal_vec(engine.seeds.seed64(Stream::FactorInit, 0), dim)
}

/// The ticket's perturbation direction, regenerated from its seed.
fn sim_z(engine: &StepEngine, step: u64, sub: u32, dim: usize) -> Vec<f32> {
    rngx::normal_vec(engine.seeds.perturb_seed(step, sub) as u64, dim)
}

/// Worker `shard`'s target vector for `step` (its "data batch").
fn shard_target(engine: &StepEngine, step: u64, shard: u32, shards: u32,
                dim: usize) -> Vec<f32> {
    rngx::normal_vec(engine.seeds.shard_data_seed(step, shard, shards), dim)
}

/// Mean squared distance of `params ± rho z` to `target`, f64-accumulated
/// exactly once per sign — the sim's fused two-point forward.
fn two_point(params: &[f32], z: &[f32], target: &[f32], rho: f32)
             -> (f32, f32) {
    let n = params.len().max(1) as f64;
    let mut plus = 0.0f64;
    let mut minus = 0.0f64;
    for ((&p, &zi), &t) in params.iter().zip(z.iter()).zip(target.iter()) {
        let dp = (p + rho * zi) - t;
        let dm = (p - rho * zi) - t;
        plus += (dp as f64) * (dp as f64);
        minus += (dm as f64) * (dm as f64);
    }
    ((plus / n) as f32, (minus / n) as f32)
}

/// The replayable ZO update: `p -= lr * kappa * z`, elementwise in f32.
fn apply_update(params: &mut [f32], z: &[f32], lr: f32, kappa: f32) {
    for (p, &zi) in params.iter_mut().zip(z.iter()) {
        *p -= lr * kappa * zi;
    }
}

/// Per-sub learning rate (mirrors `StepEngine::sub_lr` for ZO methods).
fn sub_lr(engine: &StepEngine, step: u64) -> f32 {
    engine.lr_at(step) / engine.n_sub() as f32
}

// ---------------------------------------------------------------------------
// checkpoint file format (step u64 LE + params f32 LE)
// ---------------------------------------------------------------------------

fn params_bytes(step: u64, params: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(8 + params.len() * 4);
    bytes.extend_from_slice(&step.to_le_bytes());
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    bytes
}

/// Read a sim checkpoint / final-params file: `(step, params)`.
pub fn read_sim_params(path: &Path) -> Result<(u64, Vec<f32>)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let Some(head) = bytes.get(..8) else {
        bail!("{}: shorter than the step header", path.display());
    };
    let mut b = [0u8; 8];
    b.copy_from_slice(head);
    let step = u64::from_le_bytes(b);
    let body = bytes.get(8..).unwrap_or(&[]);
    ensure!(body.len() % 4 == 0, "{}: truncated f32 payload", path.display());
    let params = body
        .chunks_exact(4)
        .map(|c| {
            let mut f = [0u8; 4];
            f.copy_from_slice(c);
            f32::from_le_bytes(f)
        })
        .collect();
    Ok((step, params))
}

fn write_sim_params(path: &Path, step: u64, params: &[f32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    // temp + rename + fsync via the durable seam: a reader (rejoining
    // worker) never sees a half write, and a published checkpoint a
    // rollback may land on survives a crash
    crate::runtime::durable::write_atomic(path, &params_bytes(step, params))
        .with_context(|| format!("committing {}", path.display()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// the replica
// ---------------------------------------------------------------------------

/// Deterministic toy replica. Drop-in for [`EngineReplica`] in the serve
/// loop; needs no artifacts, runs a step in microseconds, and can inject
/// crashes at chosen (step, sub) boundaries.
///
/// [`EngineReplica`]: super::worker::EngineReplica
pub struct SimReplica {
    worker: usize,
    workers: u32,
    dim: usize,
    engine: StepEngine,
    params: Vec<f32>,
    checkpoint_path: Option<PathBuf>,
    save_to: Option<PathBuf>,
    /// fail the forward of these (step, sub) tickets — a protocol-level
    /// crash the coordinator's fault handling must absorb
    die_at: Vec<(u64, u32)>,
    /// answer these (step, sub) forwards with NaN exactly once — a
    /// transient numeric fault the divergence guard must absorb; consumed
    /// on first hit so the post-rollback re-run measures clean
    nan_once_at: Vec<(u64, u32)>,
}

impl SimReplica {
    pub fn new(worker: usize, workers: u32, cfg: &TrainConfig, dim: usize)
               -> Self {
        let engine = StepEngine::new(cfg.clone());
        let params = init_params(&engine, dim);
        Self {
            worker,
            workers,
            dim,
            engine,
            params,
            checkpoint_path: None,
            save_to: None,
            die_at: Vec::new(),
            nan_once_at: Vec::new(),
        }
    }

    /// File step checkpoints are published to / loaded from.
    pub fn with_checkpoint_path(mut self, path: PathBuf) -> Self {
        self.checkpoint_path = Some(path);
        self
    }

    /// Write final parameters here on Stop (any worker — the parity tests
    /// compare per-worker finals across transports).
    pub fn with_save_to(mut self, path: PathBuf) -> Self {
        self.save_to = Some(path);
        self
    }

    /// Inject crashes: the forward of each listed (step, sub) fails.
    pub fn with_die_at(mut self, plan: Vec<(u64, u32)>) -> Self {
        self.die_at = plan;
        self
    }

    /// Inject transient NaNs: the forward of each listed (step, sub)
    /// measures (NaN, NaN) once, then the entry is spent.
    pub fn with_nan_once_at(mut self, plan: Vec<(u64, u32)>) -> Self {
        self.nan_once_at = plan;
        self
    }
}

impl Replica for SimReplica {
    fn forward(&mut self, step: u64, sub: u32) -> Result<(f32, f32)> {
        if self.die_at.contains(&(step, sub)) {
            bail!("sim worker {}: injected crash at step {step} sub {sub}",
                  self.worker);
        }
        if let Some(pos) =
            self.nan_once_at.iter().position(|&(s, u)| s == step && u == sub)
        {
            self.nan_once_at.remove(pos);
            return Ok((f32::NAN, f32::NAN));
        }
        let z = sim_z(&self.engine, step, sub, self.dim);
        let target = shard_target(&self.engine, step, self.worker as u32,
                                  self.workers, self.dim);
        Ok(two_point(&self.params, &z, &target, self.engine.cfg.rho))
    }

    fn apply(&mut self, step: u64, sub: u32, kappa: f32) -> Result<()> {
        let z = sim_z(&self.engine, step, sub, self.dim);
        apply_update(&mut self.params, &z, sub_lr(&self.engine, step), kappa);
        Ok(())
    }

    fn eval(&mut self) -> Result<f64> {
        Ok(f64::NAN)
    }

    fn save_checkpoint(&mut self, step: u64) -> Result<()> {
        let Some(path) = &self.checkpoint_path else {
            bail!("sim worker {}: Checkpoint command but no checkpoint path",
                  self.worker);
        };
        write_sim_params(path, step, &self.params)
    }

    fn load_checkpoint(&mut self, expect_step: u64) -> Result<()> {
        let Some(path) = &self.checkpoint_path else {
            bail!("sim worker {}: CatchUp names a checkpoint but no \
                   checkpoint path", self.worker);
        };
        let (step, params) = read_sim_params(path)?;
        ensure!(step == expect_step,
                "sim checkpoint {} is for step {step}, coordinator expected \
                 {expect_step}", path.display());
        ensure!(params.len() == self.dim,
                "sim checkpoint {} holds {} params, replica has {}",
                path.display(), params.len(), self.dim);
        self.params = params;
        Ok(())
    }

    fn finish(&mut self) -> Result<ReplicaReport> {
        if let Some(path) = &self.save_to {
            write_sim_params(path, self.engine.cfg.steps as u64, &self.params)?;
        }
        Ok(ReplicaReport::default())
    }
}

// ---------------------------------------------------------------------------
// the oracle
// ---------------------------------------------------------------------------

/// What the uninterrupted single-process run of the sim model produces.
pub struct OracleOut {
    pub params: Vec<f32>,
    /// the (seed, kappa) trace — the fleet's log must match it bitwise
    pub trace: Vec<LogEntry>,
    pub losses: Vec<f64>,
}

/// Replay the exact trajectory a fault-free fleet of `workers` sim
/// replicas follows: per (step, sub), every shard's two-point measurement,
/// the slotted aggregation, combine/clip through the *same* [`StepEngine`]
/// the coordinator uses, then the shared update. The chaos tests compare
/// interrupted fleet runs against this bitwise.
pub fn run_oracle(cfg: &TrainConfig, workers: u32, dim: usize) -> OracleOut {
    let engine = StepEngine::new(cfg.clone());
    let mut params = init_params(&engine, dim);
    let mut trace = Vec::new();
    let mut losses = Vec::new();
    let q = engine.n_sub();
    for step in 0..cfg.steps as u64 {
        let mut loss_acc = 0.0f64;
        let mut early: Option<f64> = None;
        for sub in 0..q {
            let seed = engine.seeds.perturb_seed(step, sub);
            let z = sim_z(&engine, step, sub, dim);
            let pairs: Vec<(f32, f32)> = (0..workers)
                .map(|w| {
                    let target = shard_target(&engine, step, w, workers, dim);
                    two_point(&params, &z, &target, engine.cfg.rho)
                })
                .collect();
            let (f_plus, f_minus) = aggregate_two_point(&pairs);
            let (loss, kappa_raw) =
                engine.combine(&ForwardOut::TwoPoint { f_plus, f_minus });
            if !loss.is_finite() || !kappa_raw.is_finite() {
                trace.push(LogEntry { step, sub, perturb_seed: seed, kappa: None });
                early = Some(loss);
                break;
            }
            let kappa = engine.clip_kappa(kappa_raw);
            apply_update(&mut params, &z, sub_lr(&engine, step), kappa);
            trace.push(LogEntry { step, sub, perturb_seed: seed, kappa: Some(kappa) });
            loss_acc += loss;
        }
        losses.push(match early {
            Some(l) => l,
            None => loss_acc / q as f64,
        });
    }
    OracleOut { params, trace, losses }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_deterministic_and_seed_sensitive() {
        let cfg = TrainConfig { steps: 5, lr: 0.05, seed: 11,
                                ..TrainConfig::default() };
        let a = run_oracle(&cfg, 2, 16);
        let b = run_oracle(&cfg, 2, 16);
        assert_eq!(a.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
                   b.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>());
        assert_eq!(a.trace, b.trace);
        let other = TrainConfig { seed: 12, ..cfg };
        let c = run_oracle(&other, 2, 16);
        assert_ne!(a.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
                   c.params.iter().map(|p| p.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn oracle_actually_trains() {
        let cfg = TrainConfig { steps: 40, lr: 0.1, seed: 3,
                                ..TrainConfig::default() };
        let out = run_oracle(&cfg, 1, 16);
        assert_eq!(out.losses.len(), 40);
        assert_eq!(out.trace.len(), 40);
        let first = out.losses.first().copied().unwrap_or(f64::NAN);
        let last = out.losses.last().copied().unwrap_or(f64::NAN);
        assert!(last < first,
                "sim loss should fall: first {first:.4}, last {last:.4}");
    }

    #[test]
    fn sim_checkpoint_round_trips() {
        let dir = std::env::temp_dir().join("tezo_sim_ckpt_test");
        let path = dir.join("sim.ckpt");
        let params = vec![1.0f32, -2.5, f32::MIN_POSITIVE];
        write_sim_params(&path, 7, &params).unwrap();
        let (step, back) = read_sim_params(&path).unwrap();
        assert_eq!(step, 7);
        assert_eq!(back.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
                   params.iter().map(|p| p.to_bits()).collect::<Vec<_>>());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
