//! Transport abstraction for the fleet: how commands and events cross the
//! coordinator/worker boundary.
//!
//! Two implementations exist:
//! * [`LoopbackHub`]/[`LoopbackLink`] — the original in-process `mpsc`
//!   channels, now speaking the same membership protocol (join/leave
//!   events) as a real network transport;
//! * `TcpHub`/`TcpLink` ([`super::tcp`]) — the length-prefixed binary
//!   codec of [`super::wire`] over TCP sockets.
//!
//! The coordinator drives a [`Hub`]: a multiplexed event source that
//! reports worker joins, departures, and protocol events, plus per-slot
//! command sends. Workers drive a [`Link`]: a single duplex connection.
//! Both transports tally *framed* wire bytes (what the codec would put on
//! a socket — loopback counts the identical encoding without copying it),
//! so `CommStats`' logical payload accounting can be compared against real
//! framing overhead in benches and tests.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::protocol::{Command, Event};
use super::wire;

/// Framed traffic counters (wire bytes include the frame header; compare
/// with the logical payload counters in `CommStats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub frames_down: u64,
    pub bytes_down: u64,
    pub frames_up: u64,
    pub bytes_up: u64,
}

/// Multiplexed coordinator-side endpoint over all worker slots.
pub enum HubEvent {
    /// a worker claimed slot `w` (initial staffing or a rejoin)
    Joined(usize),
    /// slot `w`'s worker is gone (thread exit, connection loss, or kick)
    Left(usize),
    /// a protocol event from slot `w`
    Msg(usize, Event),
}

pub trait Hub {
    fn workers(&self) -> usize;

    /// Wait up to `timeout` for the next membership change or event.
    /// `Ok(None)` is a timeout; transport-fatal conditions are `Err`.
    fn poll(&mut self, timeout: Duration) -> Result<Option<HubEvent>>;

    /// Send a command to one slot. An `Err` means that link is down *now*
    /// (the matching [`HubEvent::Left`] may still be in flight).
    fn send(&mut self, worker: usize, cmd: &Command) -> Result<()>;

    /// Forcibly disconnect a slot (straggler drop). The departure is
    /// reported through the normal [`HubEvent::Left`] path.
    fn kick(&mut self, worker: usize);

    /// Framed byte tallies so far.
    fn wire(&self) -> WireStats;
}

/// Worker-side duplex connection to the coordinator.
pub trait Link {
    /// Next command; `Ok(None)` means the coordinator closed the link.
    fn recv(&mut self) -> Result<Option<Command>>;
    fn send(&mut self, ev: &Event) -> Result<()>;
}

// ---------------------------------------------------------------------------
// loopback (in-process channels)
// ---------------------------------------------------------------------------

/// What loopback workers push into the hub's shared queue.
pub enum LoopMsg {
    /// worker claims a slot and hands over its command channel
    Hello(usize, Sender<Command>),
    /// protocol event
    Ev(usize, Event),
    /// worker thread is exiting (sent from a drop guard, so it fires on
    /// panic unwinding too — the hub never waits on a dead thread)
    Bye(usize),
}

/// In-process hub: one shared event queue, one command channel per slot.
pub struct LoopbackHub {
    rx: Receiver<LoopMsg>,
    links: Vec<Option<Sender<Command>>>,
    wire: WireStats,
}

impl LoopbackHub {
    /// Returns the hub plus the sender side workers join through.
    pub fn new(workers: usize) -> (Self, Sender<LoopMsg>) {
        let (tx, rx) = mpsc::channel();
        let hub = Self { rx, links: vec![None; workers], wire: WireStats::default() };
        (hub, tx)
    }
}

impl Hub for LoopbackHub {
    fn workers(&self) -> usize {
        self.links.len()
    }

    fn poll(&mut self, timeout: Duration) -> Result<Option<HubEvent>> {
        match self.rx.recv_timeout(timeout) {
            Ok(LoopMsg::Hello(w, tx)) => {
                let slot = self
                    .links
                    .get_mut(w)
                    .ok_or_else(|| anyhow!("join for unknown slot {w}"))?;
                *slot = Some(tx);
                Ok(Some(HubEvent::Joined(w)))
            }
            Ok(LoopMsg::Ev(w, ev)) => {
                self.wire.frames_up += 1;
                self.wire.bytes_up += wire::event_frame_len(&ev);
                Ok(Some(HubEvent::Msg(w, ev)))
            }
            Ok(LoopMsg::Bye(w)) => {
                if let Some(slot) = self.links.get_mut(w) {
                    *slot = None;
                }
                Ok(Some(HubEvent::Left(w)))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("every worker (and the spawner) disconnected from the hub")
            }
        }
    }

    fn send(&mut self, worker: usize, cmd: &Command) -> Result<()> {
        let n = wire::command_frame_len(cmd);
        let Some(slot) = self.links.get_mut(worker) else {
            bail!("no such worker slot {worker}");
        };
        let Some(tx) = slot.as_ref() else {
            bail!("worker {worker} is not connected");
        };
        if tx.send(cmd.clone()).is_err() {
            *slot = None;
            bail!("worker {worker} hung up");
        }
        self.wire.frames_down += 1;
        self.wire.bytes_down += n;
        Ok(())
    }

    fn kick(&mut self, worker: usize) {
        // dropping the sole command sender closes the worker's receiver;
        // its serve loop exits cleanly and the Bye guard reports Left
        if let Some(slot) = self.links.get_mut(worker) {
            *slot = None;
        }
    }

    fn wire(&self) -> WireStats {
        self.wire
    }
}

/// Worker side of a loopback connection.
pub struct LoopbackLink {
    worker: usize,
    rx: Receiver<Command>,
    tx: Sender<LoopMsg>,
}

/// Join the loopback hub on `worker`'s slot: create the command channel
/// and announce it. Called from inside the worker thread.
pub fn loopback_join(worker: usize, hub_tx: &Sender<LoopMsg>) -> Result<LoopbackLink> {
    let (ctx, crx) = mpsc::channel();
    hub_tx
        .send(LoopMsg::Hello(worker, ctx))
        .map_err(|_| anyhow!("coordinator hub is gone"))?;
    Ok(LoopbackLink { worker, rx: crx, tx: hub_tx.clone() })
}

impl Link for LoopbackLink {
    fn recv(&mut self) -> Result<Option<Command>> {
        // a closed channel means the coordinator is gone or kicked us;
        // either way it is not this worker's error
        Ok(self.rx.recv().ok())
    }

    fn send(&mut self, ev: &Event) -> Result<()> {
        self.tx
            .send(LoopMsg::Ev(self.worker, ev.clone()))
            .map_err(|_| anyhow!("coordinator hub is gone"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::protocol::Ticket;

    fn ticket() -> Ticket {
        Ticket { step: 0, sub: 0, perturb_seed: 1 }
    }

    #[test]
    fn loopback_membership_and_traffic() {
        let (mut hub, tx) = LoopbackHub::new(2);
        let mut link = loopback_join(1, &tx).unwrap();
        match hub.poll(Duration::from_secs(1)).unwrap() {
            Some(HubEvent::Joined(1)) => {}
            other => panic!("expected Joined(1), got {:?}", other.is_some()),
        }
        hub.send(1, &Command::Forward(ticket())).unwrap();
        assert!(hub.send(0, &Command::Stop).is_err(), "slot 0 never joined");
        assert_eq!(link.recv().unwrap(), Some(Command::Forward(ticket())));
        link.send(&Event::Applied { worker: 1, step: 0, sub: 0, update_secs: 0.0 })
            .unwrap();
        match hub.poll(Duration::from_secs(1)).unwrap() {
            Some(HubEvent::Msg(1, Event::Applied { .. })) => {}
            _ => panic!("expected the Applied event"),
        }
        // tallies count the framed encoding both ways
        let ws = hub.wire();
        assert_eq!(ws.frames_down, 1);
        assert_eq!(ws.frames_up, 1);
        assert_eq!(ws.bytes_down, wire::command_frame_len(&Command::Forward(ticket())));
        assert!(ws.bytes_up > 0);
        // kick closes the worker's command stream
        hub.kick(1);
        assert_eq!(link.recv().unwrap(), None);
    }

    #[test]
    fn poll_times_out_quietly() {
        let (mut hub, _tx) = LoopbackHub::new(1);
        assert!(hub.poll(Duration::from_millis(5)).unwrap().is_none());
    }
}
