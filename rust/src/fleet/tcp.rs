//! TCP transport: the fleet ticket protocol over real sockets.
//!
//! Coordinator side ([`TcpHub`]): a listener thread admits workers via the
//! Hello/HelloAck handshake of [`super::wire`], assigns slots, and spawns
//! one reader thread per connection; departures (EOF, decode failure,
//! straggler kick) surface through the same membership events the loopback
//! transport emits, so the coordinator's fault handling is
//! transport-agnostic. Worker side ([`dial`]/[`TcpLink`]): a dialer with
//! bounded exponential backoff and read timeouts, returning the
//! [`JoinInfo`] (slot, fleet width, full [`TrainConfig`], job spec) the
//! coordinator shipped in the handshake — a TCP worker needs no local
//! configuration beyond the address and the artifact directory.
//!
//! Ordering guarantees the fault tolerance leans on: the HelloAck is the
//! first frame on every connection (written before the write half is
//! published to the coordinator), and a slot's `Left` event is queued
//! under the connection table lock *before* the slot becomes claimable —
//! so the coordinator can never observe a rejoin before the departure.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::TrainConfig;
use crate::telemetry::Stopwatch;

use super::protocol::{Command, Event};
use super::transport::{Hub, HubEvent, Link, WireStats};
use super::wire::{self, Hello, HelloAck, JobSpec, SLOT_REJECTED};

/// Read-timeout quantum for non-blocking polls (worker links, handshakes).
const POLL_QUANTUM: Duration = Duration::from_millis(250);
/// Once a frame has started, it must finish within this budget — a
/// mid-frame stall desynchronizes the stream and cannot be resumed.
const STALL_BUDGET: Duration = Duration::from_secs(30);
/// A connection must complete its handshake within this budget.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

enum FrameRead {
    Frame(Vec<u8>),
    Eof,
    /// read timed out before the first header byte (stream still in sync)
    Idle,
}

/// Finish reading `buf`; read timeouts are retried under [`STALL_BUDGET`].
fn read_exact_stalling(stream: &mut TcpStream, buf: &mut [u8]) -> Result<()> {
    let start = Stopwatch::start();
    let mut got = 0usize;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => bail!("connection closed mid-frame ({got}/{} bytes)", buf.len()),
            Ok(n) => got += n,
            Err(e) if is_timeout(&e) => {
                if start.elapsed() > STALL_BUDGET {
                    bail!("mid-frame stall exceeded {STALL_BUDGET:?}");
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("reading frame"),
        }
    }
    Ok(())
}

/// Read one full frame (length prefix included, as the codec expects).
/// With a read timeout configured on `stream`, an idle boundary returns
/// [`FrameRead::Idle`]; without one, the call blocks until data or EOF.
fn read_frame_step(stream: &mut TcpStream) -> Result<FrameRead> {
    let mut head = [0u8; 4];
    let got = match stream.read(&mut head) {
        Ok(0) => return Ok(FrameRead::Eof),
        Ok(n) => n,
        Err(e) if is_timeout(&e) => return Ok(FrameRead::Idle),
        Err(e) if e.kind() == ErrorKind::Interrupted => return Ok(FrameRead::Idle),
        Err(e) => return Err(e).context("reading frame header"),
    };
    read_exact_stalling(stream, &mut head[got..])?;
    let len = u32::from_le_bytes(head) as usize;
    if len > wire::MAX_FRAME {
        bail!(wire::WireError::Oversize { len: len as u64 });
    }
    let mut frame = vec![0u8; 4 + len];
    frame[..4].copy_from_slice(&head);
    read_exact_stalling(stream, &mut frame[4..])?;
    Ok(FrameRead::Frame(frame))
}

/// Read one frame within `deadline`, treating idle polls as waiting.
fn read_frame_deadline(stream: &mut TcpStream, deadline: Duration) -> Result<Vec<u8>> {
    let start = Stopwatch::start();
    loop {
        match read_frame_step(stream)? {
            FrameRead::Frame(f) => return Ok(f),
            FrameRead::Eof => bail!("connection closed during handshake"),
            FrameRead::Idle => {
                if start.elapsed() > deadline {
                    bail!("handshake timed out after {deadline:?}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// coordinator side
// ---------------------------------------------------------------------------

/// What the coordinator ships to every admitted worker in the HelloAck.
#[derive(Clone)]
pub struct AckInfo {
    pub cfg: TrainConfig,
    pub job: JobSpec,
}

struct Conns {
    /// write halves, by slot (the reader thread owns the read half)
    write: Vec<Option<TcpStream>>,
    /// slot claims; a claim outlives the write half until the reader
    /// thread finishes tearing the connection down
    claimed: Vec<bool>,
}

impl Conns {
    fn claim(&mut self, requested: u32) -> Option<usize> {
        if requested != u32::MAX {
            let w = requested as usize;
            return match self.claimed.get_mut(w) {
                Some(c) if !*c => {
                    *c = true;
                    Some(w)
                }
                _ => None,
            };
        }
        for (w, c) in self.claimed.iter_mut().enumerate() {
            if !*c {
                *c = true;
                return Some(w);
            }
        }
        None
    }

    fn release(&mut self, slot: usize) {
        if let Some(c) = self.claimed.get_mut(slot) {
            *c = false;
        }
    }
}

struct HubShared {
    conns: Mutex<Conns>,
    shutdown: AtomicBool,
    frames_down: AtomicU64,
    bytes_down: AtomicU64,
    frames_up: AtomicU64,
    bytes_up: AtomicU64,
}

impl HubShared {
    fn lock(&self) -> Result<MutexGuard<'_, Conns>> {
        self.conns.lock().map_err(|_| anyhow!("connection table poisoned"))
    }

    fn count_down(&self, bytes: u64) {
        self.frames_down.fetch_add(1, Ordering::Relaxed);
        self.bytes_down.fetch_add(bytes, Ordering::Relaxed);
    }

    fn count_up(&self, bytes: u64) {
        self.frames_up.fetch_add(1, Ordering::Relaxed);
        self.bytes_up.fetch_add(bytes, Ordering::Relaxed);
    }
}

/// Coordinator-side TCP endpoint: listener + per-connection readers.
pub struct TcpHub {
    shared: Arc<HubShared>,
    rx: Receiver<HubEvent>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl TcpHub {
    /// Bind `addr` and start admitting workers. `ack` is shipped to every
    /// admitted worker; slots are assigned first-free (or as requested).
    pub fn listen(addr: &str, workers: usize, ack: AckInfo) -> Result<TcpHub> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(HubShared {
            conns: Mutex::new(Conns {
                write: (0..workers).map(|_| None).collect(),
                claimed: vec![false; workers],
            }),
            shutdown: AtomicBool::new(false),
            frames_down: AtomicU64::new(0),
            bytes_down: AtomicU64::new(0),
            frames_up: AtomicU64::new(0),
            bytes_up: AtomicU64::new(0),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared, tx, ack, workers))
        };
        Ok(TcpHub { shared, rx, accept: Some(accept), workers })
    }

    /// The local address the listener bound (for `--listen 127.0.0.1:0`).
    pub fn local_addr_of(listener: &TcpListener) -> Result<String> {
        Ok(listener.local_addr()?.to_string())
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<HubShared>, tx: Sender<HubEvent>,
               ack: AckInfo, workers: usize) {
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // handshake failures only cost this one connection
                let _ = admit(stream, &shared, &tx, &ack, workers);
            }
            Err(ref e) if is_timeout(e) => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn admit(mut stream: TcpStream, shared: &Arc<HubShared>, tx: &Sender<HubEvent>,
         ack: &AckInfo, workers: usize) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(POLL_QUANTUM)).ok();
    let frame = read_frame_deadline(&mut stream, HANDSHAKE_TIMEOUT)?;
    shared.count_up(frame.len() as u64);
    let hello: Hello = wire::decode_hello(&frame)?;

    let slot = shared.lock()?.claim(hello.requested_slot);
    let Some(slot) = slot else {
        // fleet full (or the requested slot is taken): reject politely
        let rej = wire::encode_hello_ack(&HelloAck {
            slot: SLOT_REJECTED,
            workers: workers as u32,
            cfg: ack.cfg.clone(),
            job: ack.job.clone(),
        });
        let _ = stream.write_all(&rej);
        return Ok(());
    };

    // the ack must be the first frame on the stream: write it *before*
    // publishing the write half, or a coordinator command could interleave
    let ackf = wire::encode_hello_ack(&HelloAck {
        slot: slot as u32,
        workers: workers as u32,
        cfg: ack.cfg.clone(),
        job: ack.job.clone(),
    });
    if stream.write_all(&ackf).is_err() {
        shared.lock()?.release(slot);
        return Ok(());
    }
    shared.count_down(ackf.len() as u64);

    let read_half = stream.try_clone().context("cloning connection")?;
    read_half.set_read_timeout(None).ok(); // readers block; EOF/shutdown unblocks
    {
        let mut c = shared.lock()?;
        if let Some(w) = c.write.get_mut(slot) {
            *w = Some(stream);
        }
        let _ = tx.send(HubEvent::Joined(slot));
    }
    let shared = shared.clone();
    let tx = tx.clone();
    std::thread::spawn(move || reader_loop(read_half, slot, shared, tx));
    Ok(())
}

fn reader_loop(mut stream: TcpStream, slot: usize, shared: Arc<HubShared>,
               tx: Sender<HubEvent>) {
    loop {
        match read_frame_step(&mut stream) {
            Ok(FrameRead::Frame(f)) => match wire::decode_event(&f) {
                Ok(ev) => {
                    shared.count_up(f.len() as u64);
                    if tx.send(HubEvent::Msg(slot, ev)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    // a corrupt stream cannot be resumed: surface it, then
                    // tear the connection down
                    let _ = tx.send(HubEvent::Msg(slot, Event::Failed {
                        worker: slot,
                        error: format!("wire decode: {e}"),
                    }));
                    break;
                }
            },
            Ok(FrameRead::Idle) => {} // blocking mode: spurious wakeup
            Ok(FrameRead::Eof) | Err(_) => break,
        }
    }
    // teardown under the lock: the Left event is queued before the slot
    // becomes claimable, so a rejoin can never be observed first
    if let Ok(mut c) = shared.conns.lock() {
        if let Some(w) = c.write.get_mut(slot) {
            if let Some(s) = w.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        c.release(slot);
        let _ = tx.send(HubEvent::Left(slot));
    }
}

impl Hub for TcpHub {
    fn workers(&self) -> usize {
        self.workers
    }

    fn poll(&mut self, timeout: Duration) -> Result<Option<HubEvent>> {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => Ok(Some(ev)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("tcp hub acceptor thread died")
            }
        }
    }

    fn send(&mut self, worker: usize, cmd: &Command) -> Result<()> {
        let frame = wire::encode_command(cmd);
        let mut c = self.shared.lock()?;
        let Some(slot) = c.write.get_mut(worker) else {
            bail!("no such worker slot {worker}");
        };
        let Some(stream) = slot.as_mut() else {
            bail!("worker {worker} is not connected");
        };
        if stream.write_all(&frame).is_err() {
            // leave teardown (Left event, claim release) to the reader
            let _ = stream.shutdown(Shutdown::Both);
            bail!("worker {worker}: connection lost mid-send");
        }
        drop(c);
        self.shared.count_down(frame.len() as u64);
        Ok(())
    }

    fn kick(&mut self, worker: usize) {
        if let Ok(c) = self.shared.conns.lock() {
            if let Some(Some(s)) = c.write.get(worker) {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    fn wire(&self) -> WireStats {
        WireStats {
            frames_down: self.shared.frames_down.load(Ordering::Relaxed),
            bytes_down: self.shared.bytes_down.load(Ordering::Relaxed),
            frames_up: self.shared.frames_up.load(Ordering::Relaxed),
            bytes_up: self.shared.bytes_up.load(Ordering::Relaxed),
        }
    }
}

impl Drop for TcpHub {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Ok(mut c) = self.shared.conns.lock() {
            for w in c.write.iter_mut() {
                if let Some(s) = w.take() {
                    let _ = s.shutdown(Shutdown::Both);
                }
            }
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// Bounded reconnect policy for a TCP worker.
#[derive(Clone, Copy, Debug)]
pub struct Reconnect {
    /// connection attempts per dial (exponential backoff between them)
    pub attempts: u32,
    pub base_delay: Duration,
    pub max_delay: Duration,
}

impl Default for Reconnect {
    fn default() -> Self {
        Self {
            attempts: 10,
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_secs(5),
        }
    }
}

fn backoff_delay(rc: Reconnect, attempt: u32) -> Duration {
    let shift = attempt.saturating_sub(1).min(16);
    let ms = (rc.base_delay.as_millis() as u64).saturating_mul(1u64 << shift);
    Duration::from_millis(ms).min(rc.max_delay)
}

/// Everything the handshake told this worker about its place in the fleet.
#[derive(Clone, Debug)]
pub struct JoinInfo {
    pub slot: usize,
    pub workers: u32,
    pub cfg: TrainConfig,
    pub job: JobSpec,
}

/// Worker side of one TCP connection.
pub struct TcpLink {
    stream: TcpStream,
    /// how long `recv` tolerates an idle (but open) link before failing
    pub idle_timeout: Duration,
}

impl Link for TcpLink {
    fn recv(&mut self) -> Result<Option<Command>> {
        let idle0 = Stopwatch::start();
        loop {
            match read_frame_step(&mut self.stream)? {
                FrameRead::Frame(f) => return Ok(Some(wire::decode_command(&f)?)),
                FrameRead::Eof => return Ok(None),
                FrameRead::Idle => {
                    if idle0.elapsed() > self.idle_timeout {
                        bail!("coordinator link idle for {:?}", self.idle_timeout);
                    }
                }
            }
        }
    }

    fn send(&mut self, ev: &Event) -> Result<()> {
        let frame = wire::encode_event(ev);
        self.stream
            .write_all(&frame)
            .context("sending event to the coordinator")
    }
}

fn try_dial(addr: &str, requested_slot: Option<usize>) -> Result<(TcpLink, JoinInfo)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(POLL_QUANTUM)).context("read timeout")?;
    let hello = Hello {
        requested_slot: match requested_slot {
            Some(w) => w as u32,
            None => u32::MAX,
        },
    };
    stream.write_all(&wire::encode_hello(&hello)).context("sending hello")?;
    let frame = read_frame_deadline(&mut stream, HANDSHAKE_TIMEOUT)?;
    let ack = wire::decode_hello_ack(&frame)?;
    if ack.slot == SLOT_REJECTED {
        bail!("coordinator rejected the join (fleet full or slot taken)");
    }
    Ok((
        TcpLink { stream, idle_timeout: Duration::from_secs(600) },
        JoinInfo {
            slot: ack.slot as usize,
            workers: ack.workers,
            cfg: ack.cfg,
            job: ack.job,
        },
    ))
}

/// Dial the coordinator with bounded exponential backoff. Retries cover
/// both refused connections (coordinator not up yet) and rejected joins
/// (our old slot's Left event still in flight after a crash).
pub fn dial(addr: &str, requested_slot: Option<usize>, rc: Reconnect)
            -> Result<(TcpLink, JoinInfo)> {
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..rc.attempts.max(1) {
        if attempt > 0 {
            std::thread::sleep(backoff_delay(rc, attempt));
        }
        match try_dial(addr, requested_slot) {
            Ok(ok) => return Ok(ok),
            Err(e) => last = Some(e),
        }
    }
    let err = last.unwrap_or_else(|| anyhow!("no connection attempts made"));
    Err(err.context(format!("dialing {addr} ({} attempts)", rc.attempts.max(1))))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_and_monotone() {
        let rc = Reconnect::default();
        assert_eq!(backoff_delay(rc, 1), Duration::from_millis(100));
        assert_eq!(backoff_delay(rc, 2), Duration::from_millis(200));
        assert!(backoff_delay(rc, 3) >= backoff_delay(rc, 2));
        // saturates at max_delay, never overflows
        assert_eq!(backoff_delay(rc, 60), rc.max_delay);
    }

    #[test]
    fn dial_fails_cleanly_with_no_listener() {
        // port 1 is essentially never listening; bounded attempts must
        // return an error (not hang) even with nothing on the other side
        let rc = Reconnect {
            attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
        };
        assert!(dial("127.0.0.1:1", None, rc).is_err());
    }
}
