//! Coordinator <-> worker message protocol and logical wire accounting.
//!
//! Everything that crosses the coordinator/worker boundary is scalar-sized:
//! a [`Ticket`] (step id + perturbation seed) down, a two-point loss pair
//! up, one aggregated kappa back down. Parameters, gradients, and optimizer
//! state never move — every replica regenerates them from the shared seed
//! schedule. [`CommStats`] counts the logical payload bytes (what a network
//! transport would carry), using the authoritative wire sizes from
//! [`crate::memmodel::comm`] so the analytic model and the runtime counter
//! can be cross-checked.

use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;
use crate::memmodel::comm::{KAPPA_BYTES, TICKET_BYTES, TWO_POINT_BYTES};

/// Per-(step, sub-perturbation) work ticket broadcast by the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket {
    pub step: u64,
    /// q-SPSA sub-perturbation index
    pub sub: u32,
    /// the perturbation seed every replica must use for this ticket;
    /// workers cross-check it against their own schedule, so a diverged
    /// replica fails loudly instead of silently drifting
    pub perturb_seed: u32,
}

/// Coordinator -> worker commands.
///
/// `PartialEq` (and the loss of `Copy` to the catch-up log) because the
/// wire codec's round-trip tests compare decoded commands structurally.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// run the fused two-point forward for this ticket
    Forward(Ticket),
    /// apply the globally aggregated (already clipped) kappa
    Apply { ticket: Ticket, kappa: f32 },
    /// skip this ticket's update (non-finite global measurement); every
    /// replica skips together, so parameters stay bit-identical
    Skip { ticket: Ticket },
    /// run the held-out eval hook (sent to one worker only)
    Eval { step: u64 },
    /// finish: send the final [`WorkerReport`] and exit
    Stop,
    /// publish a step checkpoint for step `step` (sent to one worker; the
    /// coordinator prunes its catch-up log on the CheckpointDone reply)
    Checkpoint { step: u64 },
    /// first command to a (re)joining worker: replay history and converge
    /// on the fleet's current parameters before taking tickets
    CatchUp(CatchUp),
}

/// Deterministic catch-up instructions for a (re)joining worker: load the
/// published checkpoint (if any), then replay the logged tail of updates.
/// Replay is exact because an update is fully determined by
/// (perturb_seed, kappa) — the replica regenerates z from the seed, just
/// like live steps do.
#[derive(Clone, Debug, PartialEq)]
pub struct CatchUp {
    /// completed-step count of the checkpoint to load (`None`: fresh start
    /// from the artifact's initial parameters)
    pub checkpoint_step: Option<u64>,
    /// update log from that point to now, in step order
    pub entries: Vec<LogEntry>,
}

/// One logged (step, sub) outcome: the seed that generated the
/// perturbation and the aggregated kappa that was applied (`None` = the
/// round was skipped in lockstep).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LogEntry {
    pub step: u64,
    pub sub: u32,
    pub perturb_seed: u32,
    pub kappa: Option<f32>,
}

/// Worker -> coordinator events.
#[derive(Clone, Debug)]
pub enum Event {
    /// two-point measurement for a ticket
    TwoPoint {
        worker: usize,
        step: u64,
        sub: u32,
        f_plus: f32,
        f_minus: f32,
        /// wall seconds of the forward call (straggler accounting)
        forward_secs: f64,
    },
    /// update applied (or skipped) for a ticket
    Applied {
        worker: usize,
        step: u64,
        sub: u32,
        update_secs: f64,
    },
    /// eval accuracy (NaN when the worker carries no eval set)
    EvalDone { worker: usize, step: u64, accuracy: f64 },
    /// terminal worker failure; the coordinator aborts the fleet (or, with
    /// a restart budget, counts it against the budget)
    Failed { worker: usize, error: String },
    /// final per-worker report (response to [`Command::Stop`])
    Report(Box<WorkerReport>),
    /// checkpoint published (response to [`Command::Checkpoint`])
    CheckpointDone { worker: usize, step: u64 },
}

/// End-of-run report from one worker replica.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub timers: PhaseTimers,
    pub counter: SampleCounter,
    pub state_bytes: u64,
}

/// Mean two-point losses over workers.
///
/// Reduces in worker-index order with an f64 accumulator, so the result is
/// invariant to result *arrival* order (thread scheduling) and, for a
/// single worker, bit-identical to that worker's own measurement.
pub fn aggregate_two_point(results: &[(f32, f32)]) -> (f32, f32) {
    let w = results.len().max(1) as f64;
    let mut sum_plus = 0.0f64;
    let mut sum_minus = 0.0f64;
    for &(f_plus, f_minus) in results {
        sum_plus += f_plus as f64;
        sum_minus += f_minus as f64;
    }
    ((sum_plus / w) as f32, (sum_minus / w) as f32)
}

/// Logical communication counters for one fleet run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// tickets broadcast (counted once per worker)
    pub tickets: u64,
    /// two-point results received
    pub results: u64,
    /// kappa/skip broadcasts (counted once per worker)
    pub broadcasts: u64,
    /// coordinator -> workers payload bytes
    pub bytes_down: u64,
    /// workers -> coordinator payload bytes
    pub bytes_up: u64,
    /// framed coordinator -> worker bytes actually put on the wire (frame
    /// headers + handshakes + catch-up traffic included); loopback runs
    /// tally the identical encoding without copying it
    pub wire_down: u64,
    /// framed worker -> coordinator bytes
    pub wire_up: u64,
    /// frames sent coordinator -> workers
    pub frames_down: u64,
    /// frames received from workers
    pub frames_up: u64,
}

impl CommStats {
    pub fn on_tickets(&mut self, workers: u64) {
        self.tickets += workers;
        self.bytes_down += workers * TICKET_BYTES;
    }

    pub fn on_results(&mut self, workers: u64) {
        self.results += workers;
        self.bytes_up += workers * TWO_POINT_BYTES;
    }

    pub fn on_broadcasts(&mut self, workers: u64) {
        self.broadcasts += workers;
        self.bytes_down += workers * KAPPA_BYTES;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_down + self.bytes_up
    }

    /// Framed bytes actually moved (0 until a transport reports in).
    pub fn total_wire_bytes(&self) -> u64 {
        self.wire_down + self.wire_up
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::comm::zo_scalar_step_bytes;

    #[test]
    fn slotted_aggregation_is_invariant_to_arrival_order() {
        // the coordinator slots results by worker index before reducing, so
        // any arrival permutation yields a bitwise-identical global mean
        let by_worker = [(1.25f32, 1.5f32), (0.75, 2.0), (3.5, 0.125), (2.0, 2.25)];
        let arrivals: [[usize; 4]; 3] =
            [[0, 1, 2, 3], [3, 1, 0, 2], [2, 3, 1, 0]];
        let mut outs = Vec::new();
        for order in arrivals {
            let mut slots = [(0.0f32, 0.0f32); 4];
            for worker in order {
                slots[worker] = by_worker[worker]; // slotting: arrival order irrelevant
            }
            outs.push(aggregate_two_point(&slots));
        }
        for w in &outs[1..] {
            assert_eq!(outs[0].0.to_bits(), w.0.to_bits());
            assert_eq!(outs[0].1.to_bits(), w.1.to_bits());
        }
    }

    #[test]
    fn single_worker_aggregation_is_identity() {
        for (fp, fm) in [(0.1f32, 0.2f32), (123.456, -7.5), (1e-30, 1e30)] {
            let (p, m) = aggregate_two_point(&[(fp, fm)]);
            assert_eq!(p.to_bits(), fp.to_bits());
            assert_eq!(m.to_bits(), fm.to_bits());
        }
    }

    #[test]
    fn aggregation_propagates_non_finite_shards() {
        let (p, _) = aggregate_two_point(&[(1.0, 1.0), (f32::NAN, 1.0)]);
        assert!(p.is_nan(), "a poisoned shard must poison the global mean");
        let (p, _) = aggregate_two_point(&[(f32::INFINITY, 1.0), (1.0, 1.0)]);
        assert!(!p.is_finite());
    }

    #[test]
    fn comm_stats_match_analytic_model() {
        // one step, q=1, 4 workers: ticket + result + broadcast per worker
        let mut c = CommStats::default();
        c.on_tickets(4);
        c.on_results(4);
        c.on_broadcasts(4);
        assert_eq!(c.total_bytes(), zo_scalar_step_bytes(4, 1));
        assert_eq!(c.tickets, 4);
        assert_eq!(c.results, 4);
        assert_eq!(c.broadcasts, 4);
    }
}
