//! One fleet worker: a private runtime + parameter replica driven by
//! coordinator tickets.
//!
//! The worker never sees another replica's parameters. It samples its own
//! data shard (`Stream::Data`, shard = worker index), runs the fused
//! two-point forward for each ticket, reports the scalar loss pair, and
//! replays the coordinator's aggregated kappa through the *same*
//! [`StepEngine`] update path the single-process trainer uses — which is
//! exactly why all replicas stay bit-identical with zero parameter traffic.

use std::path::Path;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::eval;
use crate::coordinator::metrics::{Phase, PhaseTimers};
use crate::coordinator::optimizer::{build_optimizer, ForwardOut};
use crate::coordinator::step::StepEngine;
use crate::coordinator::trainer::DataSource;
use crate::data::Batch;
use crate::runtime::{checkpoint, Manifest, ParamStore, Runtime};

use super::protocol::{Command, Event, Ticket, WorkerReport};

/// Everything one worker needs beyond the shared [`TrainConfig`]: its data
/// shard source, and (worker 0 only) the eval set and checkpoint target.
pub struct WorkerJob {
    pub data: DataSource,
    /// held-out eval batches + candidate label tokens (worker 0 carries the
    /// fleet's eval responsibility; other workers leave this `None`)
    pub eval: Option<(Vec<Batch>, Vec<i32>)>,
    /// write a final checkpoint here on Stop (worker 0)
    pub save_to: Option<std::path::PathBuf>,
}

/// Builds a [`WorkerJob`] from the worker index and the opened manifest.
/// Shared by reference across worker threads, hence `Sync`; `Send` so the
/// owning fleet trainer itself can cross threads.
pub type JobFactory = dyn Fn(usize, &Manifest) -> Result<WorkerJob> + Send + Sync;

/// The standard few-shot-classification job factory (shared by the
/// `train-dp` CLI, the example, the benches, and the determinism tests):
/// every worker builds the same task pool — the *seeds* shard the data —
/// and worker 0 carries the eval set (`eval_n > 0`) and the optional
/// checkpoint target.
pub fn task_job_factory(task_name: String, seed: u64, k_shot: usize,
                        eval_n: usize,
                        save_to: Option<std::path::PathBuf>)
                        -> Box<JobFactory> {
    Box::new(move |worker: usize, manifest: &Manifest|
                   -> Result<WorkerJob> {
        let spec = crate::data::tasks::spec_by_name(&task_name)
            .ok_or_else(|| anyhow!("unknown task {task_name:?}"))?;
        let tok = crate::data::Tokenizer::new(manifest.config.vocab);
        let task = crate::data::Task::new(spec, tok, manifest.config.seq_len,
                                          seed);
        let label_tokens = task.label_tokens();
        let builder =
            crate::data::BatchBuilder::new(task, manifest.config.batch, k_shot);
        let eval = (worker == 0 && eval_n > 0)
            .then(|| (builder.eval_batches(eval_n), label_tokens));
        Ok(WorkerJob {
            data: DataSource::Task(builder),
            eval,
            save_to: if worker == 0 { save_to.clone() } else { None },
        })
    })
}

/// Thread entry point: run the ticket loop, convert any error into a
/// [`Event::Failed`] so the coordinator aborts cleanly instead of hanging.
/// A *panic* (as opposed to an `Err`) is also reported via a drop guard —
/// otherwise the coordinator would block forever on a round the dead
/// worker never answers; the panic itself still propagates through the
/// scoped join.
pub(crate) fn run_worker(worker: usize, workers: u32, artifact_dir: &Path,
                         cfg: &TrainConfig, factory: &JobFactory,
                         rx: Receiver<Command>, tx: Sender<Event>) {
    struct PanicGuard {
        worker: usize,
        tx: Sender<Event>,
    }
    impl Drop for PanicGuard {
        fn drop(&mut self) {
            if std::thread::panicking() {
                let _ = self.tx.send(Event::Failed {
                    worker: self.worker,
                    error: "worker thread panicked".to_string(),
                });
            }
        }
    }
    let _guard = PanicGuard { worker, tx: tx.clone() };
    if let Err(e) = worker_loop(worker, workers, artifact_dir, cfg, factory,
                                &rx, &tx) {
        let _ = tx.send(Event::Failed { worker, error: format!("{e:#}") });
    }
}

fn send(tx: &Sender<Event>, ev: Event) -> Result<()> {
    tx.send(ev).map_err(|_| anyhow!("coordinator channel closed"))
}

fn worker_loop(worker: usize, workers: u32, artifact_dir: &Path,
               cfg: &TrainConfig, factory: &JobFactory,
               rx: &Receiver<Command>, tx: &Sender<Event>) -> Result<()> {
    let rt = Runtime::open(artifact_dir)
        .with_context(|| format!("worker {worker}: opening runtime"))?;
    let engine = StepEngine::new(cfg.clone());
    let mut driver = build_optimizer(&rt, &engine.cfg, &engine.seeds)?;
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let job = factory(worker, &rt.manifest)
        .with_context(|| format!("worker {worker}: building job"))?;
    // precompile exactly this method's artifact set (plus the eval head on
    // the worker that carries it) so the first ticket is pure execution and
    // round-0 straggling doesn't depend on compile order
    rt.warmup_method(cfg.method, cfg.forward_form)
        .with_context(|| format!("worker {worker}: warmup"))?;
    if job.eval.is_some() {
        rt.warmup(&["eval_logits"])
            .with_context(|| format!("worker {worker}: eval warmup"))?;
    }
    let mut timers = PhaseTimers::default();
    let mut counter = SampleCounter::default();
    // the current step's batch; sub-perturbations and the update phase
    // reuse it, exactly like the single-process trainer
    let mut current: Option<(u64, Batch)> = None;

    loop {
        // a closed command channel means the coordinator is gone (it
        // aborted); exit quietly — it is not this worker's error
        let Ok(cmd) = rx.recv() else { return Ok(()) };
        match cmd {
            Command::Forward(t) => {
                check_ticket(&engine, worker, &t)?;
                if current.as_ref().map(|(s, _)| *s) != Some(t.step) {
                    let dseed = engine.seeds
                        .shard_data_seed(t.step, worker as u32, workers);
                    let b = timers.time(Phase::Sampling,
                                        || job.data.batch(dseed, t.step));
                    current = Some((t.step, b));
                }
                let Some((_, batch)) = current.as_ref() else {
                    bail!("worker {worker}: no batch staged for step {}", t.step);
                };
                let t0 = Instant::now();
                let fwd = engine.forward_sub(&rt, &mut *driver, &mut params,
                                             batch, t.step, t.sub,
                                             &mut timers, &mut counter)?;
                let forward_secs = t0.elapsed().as_secs_f64();
                let ForwardOut::TwoPoint { f_plus, f_minus } = fwd else {
                    bail!("worker {worker}: fleet requires a two-point ZO \
                           forward (got a first-order loss)");
                };
                send(tx, Event::TwoPoint {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    f_plus,
                    f_minus,
                    forward_secs,
                })?;
            }
            Command::Apply { ticket: t, kappa } => {
                check_ticket(&engine, worker, &t)?;
                let Some((step, batch)) = current.as_ref() else {
                    bail!("worker {worker}: Apply before any Forward");
                };
                ensure!(*step == t.step,
                        "worker {worker}: Apply for step {} but batch is for \
                         step {step}", t.step);
                let t0 = Instant::now();
                engine.update_sub(&rt, &mut *driver, &mut params, batch,
                                  t.step, t.sub, kappa, &mut timers,
                                  &mut counter)?;
                send(tx, Event::Applied {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    update_secs: t0.elapsed().as_secs_f64(),
                })?;
            }
            Command::Skip { ticket: t } => {
                send(tx, Event::Applied {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    update_secs: 0.0,
                })?;
            }
            Command::Eval { step } => {
                let accuracy = match &job.eval {
                    Some((batches, labels)) => {
                        eval::accuracy(&rt, &params, batches, labels)?
                    }
                    None => f64::NAN,
                };
                send(tx, Event::EvalDone { worker, step, accuracy })?;
            }
            Command::Stop => {
                if let Some(dir) = &job.save_to {
                    checkpoint::save(dir, &rt.manifest, &params,
                                     engine.cfg.steps as u64)?;
                }
                send(tx, Event::Report(Box::new(WorkerReport {
                    worker,
                    timers,
                    counter,
                    state_bytes: driver.state_bytes(),
                })))?;
                return Ok(());
            }
        }
    }
}

/// Replica-consistency check: the broadcast perturbation seed must match
/// this worker's locally derived schedule.
fn check_ticket(engine: &StepEngine, worker: usize, t: &Ticket) -> Result<()> {
    let local = engine.seeds.perturb_seed(t.step, t.sub);
    ensure!(local == t.perturb_seed,
            "worker {worker}: seed schedule diverged at step {} sub {} \
             (coordinator {:#x}, local {local:#x})",
            t.step, t.sub, t.perturb_seed);
    Ok(())
}
