//! One fleet worker: a parameter replica driven by coordinator commands.
//!
//! The worker never sees another replica's parameters. It samples its own
//! data shard (`Stream::Data`, shard = worker slot), runs the fused
//! two-point forward for each ticket, reports the scalar loss pair, and
//! replays the coordinator's aggregated kappa through the *same*
//! [`StepEngine`] update path the single-process trainer uses — which is
//! exactly why all replicas stay bit-identical with zero parameter traffic.
//!
//! The protocol loop ([`serve`]) is written against the [`Replica`] trait
//! and the transport [`Link`] trait, so the same loop runs the real
//! PJRT-backed [`EngineReplica`] over in-process channels or TCP, and the
//! artifact-free simulation replica (`fleet::sim`) in the chaos tests.
//! Catch-up is part of the loop: a (re)joining worker receives the last
//! published checkpoint plus the (seed, kappa) log and replays it — an
//! update is fully determined by those scalars, so replay is exact.

use std::path::{Path, PathBuf};
use std::sync::mpsc::Sender;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::eval;
use crate::coordinator::metrics::{Phase, PhaseTimers};
use crate::coordinator::optimizer::{build_optimizer, ForwardOut, ZoOptimizer};
use crate::coordinator::seeds::SeedSchedule;
use crate::coordinator::step::StepEngine;
use crate::coordinator::trainer::DataSource;
use crate::data::Batch;
use crate::runtime::{checkpoint, Manifest, ParamStore, Runtime};
use crate::telemetry::Stopwatch;

use super::protocol::{Command, Event, Ticket, WorkerReport};
use super::tcp::{self, JoinInfo, Reconnect};
use super::transport::{loopback_join, Link, LoopMsg};

/// Everything one worker needs beyond the shared [`TrainConfig`]: its data
/// shard source, and (worker 0 only) the eval set and checkpoint target.
pub struct WorkerJob {
    pub data: DataSource,
    /// held-out eval batches + candidate label tokens (worker 0 carries the
    /// fleet's eval responsibility; other workers leave this `None`)
    pub eval: Option<(Vec<Batch>, Vec<i32>)>,
    /// write a final checkpoint here on Stop (worker 0)
    pub save_to: Option<std::path::PathBuf>,
}

/// Builds a [`WorkerJob`] from the worker index and the opened manifest.
/// Shared by reference across worker threads, hence `Sync`; `Send` so the
/// owning fleet trainer itself can cross threads.
pub type JobFactory = dyn Fn(usize, &Manifest) -> Result<WorkerJob> + Send + Sync;

/// The standard few-shot-classification job factory (shared by the
/// `train-dp` CLI, the example, the benches, and the determinism tests):
/// every worker builds the same task pool — the *seeds* shard the data —
/// and worker 0 carries the eval set (`eval_n > 0`) and the optional
/// checkpoint target.
pub fn task_job_factory(task_name: String, seed: u64, k_shot: usize,
                        eval_n: usize,
                        save_to: Option<std::path::PathBuf>)
                        -> Box<JobFactory> {
    Box::new(move |worker: usize, manifest: &Manifest|
                   -> Result<WorkerJob> {
        let spec = crate::data::tasks::spec_by_name(&task_name)
            .ok_or_else(|| anyhow!("unknown task {task_name:?}"))?;
        let tok = crate::data::Tokenizer::new(manifest.config.vocab);
        let task = crate::data::Task::new(spec, tok, manifest.config.seq_len,
                                          seed);
        let label_tokens = task.label_tokens();
        let builder =
            crate::data::BatchBuilder::new(task, manifest.config.batch, k_shot);
        let eval = (worker == 0 && eval_n > 0)
            .then(|| (builder.eval_batches(eval_n), label_tokens));
        Ok(WorkerJob {
            data: DataSource::Task(builder),
            eval,
            save_to: if worker == 0 { save_to.clone() } else { None },
        })
    })
}

// ---------------------------------------------------------------------------
// the replica abstraction
// ---------------------------------------------------------------------------

/// End-of-run accounting one replica hands back on Stop.
#[derive(Clone, Debug, Default)]
pub struct ReplicaReport {
    pub timers: PhaseTimers,
    pub counter: SampleCounter,
    pub state_bytes: u64,
}

/// One parameter replica, as the protocol loop sees it. Implementations:
/// [`EngineReplica`] (the real runtime) and `fleet::sim::SimReplica`
/// (deterministic toy model for transport/fault tests).
pub trait Replica {
    /// Two-point forward for (step, sub) on this replica's data shard.
    fn forward(&mut self, step: u64, sub: u32) -> Result<(f32, f32)>;
    /// Apply the aggregated (already clipped) kappa for (step, sub). Also
    /// the catch-up replay path, so it must not assume a prior `forward`
    /// for the same step.
    fn apply(&mut self, step: u64, sub: u32, kappa: f32) -> Result<()>;
    /// Lockstep skip for (step, sub) — default no-op.
    fn skip(&mut self, _step: u64, _sub: u32) -> Result<()> {
        Ok(())
    }
    /// Held-out eval; NaN when this replica carries no eval set.
    fn eval(&mut self) -> Result<f64>;
    /// Publish a step checkpoint (`step` = completed-step count).
    fn save_checkpoint(&mut self, step: u64) -> Result<()>;
    /// Load the published checkpoint; must be for exactly `expect_step`.
    fn load_checkpoint(&mut self, expect_step: u64) -> Result<()>;
    /// Final bookkeeping (write the end-of-run checkpoint, report stats).
    fn finish(&mut self) -> Result<ReplicaReport>;
}

/// How one [`serve`] session ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeEnd {
    /// clean protocol shutdown (Stop received, report sent)
    Stopped,
    /// the coordinator closed the link (kick, or coordinator gone); a TCP
    /// worker reconnects with a fresh replica, a loopback worker exits
    LinkClosed,
}

/// Replica-consistency check: the broadcast perturbation seed must match
/// this worker's locally derived schedule.
fn check_ticket(seeds: &SeedSchedule, worker: usize, t: &Ticket) -> Result<()> {
    let local = seeds.perturb_seed(t.step, t.sub);
    ensure!(local == t.perturb_seed,
            "worker {worker}: seed schedule diverged at step {} sub {} \
             (coordinator {:#x}, local {local:#x})",
            t.step, t.sub, t.perturb_seed);
    Ok(())
}

/// The protocol loop: execute commands against `replica` until the
/// coordinator stops us or the link dies. Transport- and replica-agnostic.
pub fn serve(link: &mut dyn Link, worker: usize, seeds: &SeedSchedule,
             replica: &mut dyn Replica) -> Result<ServeEnd> {
    loop {
        let Some(cmd) = link.recv()? else { return Ok(ServeEnd::LinkClosed) };
        match cmd {
            Command::Forward(t) => {
                check_ticket(seeds, worker, &t)?;
                let t0 = Stopwatch::start();
                let (f_plus, f_minus) = replica.forward(t.step, t.sub)?;
                let forward_secs = t0.elapsed().as_secs_f64();
                link.send(&Event::TwoPoint {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    f_plus,
                    f_minus,
                    forward_secs,
                })?;
            }
            Command::Apply { ticket: t, kappa } => {
                check_ticket(seeds, worker, &t)?;
                let t0 = Stopwatch::start();
                replica.apply(t.step, t.sub, kappa)?;
                link.send(&Event::Applied {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    update_secs: t0.elapsed().as_secs_f64(),
                })?;
            }
            Command::Skip { ticket: t } => {
                check_ticket(seeds, worker, &t)?;
                replica.skip(t.step, t.sub)?;
                link.send(&Event::Applied {
                    worker,
                    step: t.step,
                    sub: t.sub,
                    update_secs: 0.0,
                })?;
            }
            Command::Eval { step } => {
                let accuracy = replica.eval()?;
                link.send(&Event::EvalDone { worker, step, accuracy })?;
            }
            Command::Checkpoint { step } => {
                replica.save_checkpoint(step)?;
                link.send(&Event::CheckpointDone { worker, step })?;
            }
            Command::CatchUp(c) => {
                // converge on the fleet's current parameters: load the
                // checkpoint (if any), then replay the logged tail; each
                // entry is cross-checked against the local seed schedule
                if let Some(cs) = c.checkpoint_step {
                    replica.load_checkpoint(cs)?;
                }
                for e in &c.entries {
                    let local = seeds.perturb_seed(e.step, e.sub);
                    ensure!(local == e.perturb_seed,
                            "worker {worker}: catch-up log diverged from the \
                             seed schedule at step {} sub {}", e.step, e.sub);
                    match e.kappa {
                        Some(k) => replica.apply(e.step, e.sub, k)?,
                        None => replica.skip(e.step, e.sub)?,
                    }
                }
                // no reply: the coordinator's next command (a Forward for
                // the in-flight round) is the acknowledgement path
            }
            Command::Stop => {
                let r = replica.finish()?;
                link.send(&Event::Report(Box::new(WorkerReport {
                    worker,
                    timers: r.timers,
                    counter: r.counter,
                    state_bytes: r.state_bytes,
                })))?;
                return Ok(ServeEnd::Stopped);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the real (PJRT runtime) replica
// ---------------------------------------------------------------------------

/// The production replica: private [`Runtime`] + [`ParamStore`] + optimizer
/// driver, stepping through the same [`StepEngine`] as the single-process
/// trainer.
pub struct EngineReplica {
    worker: usize,
    workers: u32,
    rt: Runtime,
    engine: StepEngine,
    driver: Box<dyn ZoOptimizer>,
    params: ParamStore,
    job: WorkerJob,
    timers: PhaseTimers,
    counter: SampleCounter,
    /// the current step's batch; sub-perturbations and the update phase
    /// reuse it, exactly like the single-process trainer
    current: Option<(u64, Batch)>,
    /// where fleet step checkpoints are published / loaded from
    checkpoint_dir: Option<PathBuf>,
}

impl EngineReplica {
    pub fn build(worker: usize, workers: u32, artifact_dir: &Path,
                 cfg: &TrainConfig, factory: &JobFactory,
                 checkpoint_dir: Option<PathBuf>) -> Result<Self> {
        let rt = Runtime::open(artifact_dir)
            .with_context(|| format!("worker {worker}: opening runtime"))?;
        let engine = StepEngine::new(cfg.clone());
        let driver = build_optimizer(&rt, &engine.cfg, &engine.seeds)?;
        let params = ParamStore::load(&rt.client, &rt.manifest)?;
        let job = factory(worker, &rt.manifest)
            .with_context(|| format!("worker {worker}: building job"))?;
        // precompile exactly this method's artifact set (plus the eval head
        // on the worker that carries it) so the first ticket is pure
        // execution and round-0 straggling doesn't depend on compile order
        // the coordinator resolved the form policy before spawning us (it
        // rides the handshake), so a pinned policy compiles exactly one
        // loss lowering; a raw Auto (direct embedder) takes the fallback
        rt.warmup_method(cfg.method, cfg.forward_form.resolve_fallback())
            .with_context(|| format!("worker {worker}: warmup"))?;
        if job.eval.is_some() {
            rt.warmup(&["eval_logits"])
                .with_context(|| format!("worker {worker}: eval warmup"))?;
        }
        Ok(Self {
            worker,
            workers,
            rt,
            engine,
            driver,
            params,
            job,
            timers: PhaseTimers::default(),
            counter: SampleCounter::default(),
            current: None,
            checkpoint_dir,
        })
    }

    /// Sample this worker's shard batch for `step` unless already staged.
    /// Both `forward` and `apply` stage — the apply side matters on the
    /// catch-up replay path, where no forward precedes the update.
    fn stage_batch(&mut self, step: u64) {
        if self.current.as_ref().map(|(s, _)| *s) == Some(step) {
            return;
        }
        let dseed = self.engine.seeds
            .shard_data_seed(step, self.worker as u32, self.workers);
        let Self { timers, job, .. } = self;
        let b = timers.time(Phase::Sampling, || job.data.batch(dseed, step));
        self.current = Some((step, b));
    }
}

impl Replica for EngineReplica {
    fn forward(&mut self, step: u64, sub: u32) -> Result<(f32, f32)> {
        self.stage_batch(step);
        let Self { worker, rt, engine, driver, params, timers, counter,
                   current, .. } = self;
        let Some((_, batch)) = current.as_ref() else {
            bail!("worker {worker}: no batch staged for step {step}");
        };
        let fwd = engine.forward_sub(rt, &mut **driver, params, batch, step,
                                     sub, timers, counter)?;
        let ForwardOut::TwoPoint { f_plus, f_minus } = fwd else {
            bail!("worker {worker}: fleet requires a two-point ZO forward \
                   (got a first-order loss)");
        };
        Ok((f_plus, f_minus))
    }

    fn apply(&mut self, step: u64, sub: u32, kappa: f32) -> Result<()> {
        self.stage_batch(step);
        let Self { worker, rt, engine, driver, params, timers, counter,
                   current, .. } = self;
        let Some((s, batch)) = current.as_ref() else {
            bail!("worker {worker}: no batch staged for step {step}");
        };
        ensure!(*s == step,
                "worker {worker}: Apply for step {step} but batch is for \
                 step {s}");
        engine.update_sub(rt, &mut **driver, params, batch, step, sub, kappa,
                          timers, counter)
    }

    fn eval(&mut self) -> Result<f64> {
        match &self.job.eval {
            Some((batches, labels)) => {
                eval::accuracy(&self.rt, &self.params, batches, labels)
            }
            None => Ok(f64::NAN),
        }
    }

    fn save_checkpoint(&mut self, step: u64) -> Result<()> {
        let Some(dir) = &self.checkpoint_dir else {
            bail!("worker {}: Checkpoint command but no --checkpoint-dir",
                  self.worker);
        };
        checkpoint::save(dir, &self.rt.manifest, &self.params, step)
    }

    fn load_checkpoint(&mut self, expect_step: u64) -> Result<()> {
        let Some(dir) = &self.checkpoint_dir else {
            bail!("worker {}: CatchUp names a checkpoint but no \
                   --checkpoint-dir", self.worker);
        };
        let (store, step) = checkpoint::load(dir, &self.rt.client,
                                             &self.rt.manifest)?;
        ensure!(step == expect_step,
                "checkpoint in {} is for step {step}, coordinator expected \
                 {expect_step}", dir.display());
        self.params = store;
        self.current = None;
        Ok(())
    }

    fn finish(&mut self) -> Result<ReplicaReport> {
        if let Some(dir) = &self.job.save_to {
            checkpoint::save(dir, &self.rt.manifest, &self.params,
                             self.engine.cfg.steps as u64)?;
        }
        Ok(ReplicaReport {
            timers: self.timers.clone(),
            counter: self.counter.clone(),
            state_bytes: self.driver.state_bytes(),
        })
    }
}

// ---------------------------------------------------------------------------
// thread / process entry points
// ---------------------------------------------------------------------------

/// Builds a custom replica for (worker slot, fleet width) — the test
/// injection point the chaos suite uses to run artifact-free fleets.
pub type ReplicaFactory =
    dyn Fn(usize, u32) -> Result<Box<dyn Replica>> + Send + Sync;

/// Departure announcement on every exit path, *including panic unwinding*
/// — the coordinator must never wait on a dead thread. Declared first in
/// each entry point so it drops last (after any Failed event is sent).
struct ByeGuard {
    worker: usize,
    tx: Sender<LoopMsg>,
}

impl Drop for ByeGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(LoopMsg::Bye(self.worker));
    }
}

/// Loopback thread entry for the production replica. Joins first so the
/// coordinator learns membership while the (slow) runtime build and warmup
/// proceed; commands queue in the channel meanwhile.
pub(crate) fn run_worker_loopback(worker: usize, workers: u32,
                                  artifact_dir: &Path, cfg: &TrainConfig,
                                  factory: &JobFactory,
                                  hub_tx: Sender<LoopMsg>,
                                  checkpoint_dir: Option<PathBuf>) {
    let _bye = ByeGuard { worker, tx: hub_tx.clone() };
    let Ok(mut link) = loopback_join(worker, &hub_tx) else { return };
    let seeds = SeedSchedule::new(cfg.seed);
    let fail = |e: &anyhow::Error| {
        let _ = hub_tx.send(LoopMsg::Ev(worker, Event::Failed {
            worker,
            error: format!("{e:#}"),
        }));
    };
    match EngineReplica::build(worker, workers, artifact_dir, cfg, factory,
                               checkpoint_dir) {
        Ok(mut replica) => {
            if let Err(e) = serve(&mut link, worker, &seeds, &mut replica) {
                fail(&e);
            }
        }
        Err(e) => fail(&e),
    }
}

/// Loopback thread entry for an injected replica (chaos / sim tests).
pub(crate) fn run_custom_loopback(worker: usize, workers: u32, seed: u64,
                                  make: &ReplicaFactory,
                                  hub_tx: Sender<LoopMsg>) {
    let _bye = ByeGuard { worker, tx: hub_tx.clone() };
    let Ok(mut link) = loopback_join(worker, &hub_tx) else { return };
    let seeds = SeedSchedule::new(seed);
    let fail = |e: &anyhow::Error| {
        let _ = hub_tx.send(LoopMsg::Ev(worker, Event::Failed {
            worker,
            error: format!("{e:#}"),
        }));
    };
    match make(worker, workers) {
        Ok(mut replica) => {
            if let Err(e) = serve(&mut link, worker, &seeds, &mut *replica) {
                fail(&e);
            }
        }
        Err(e) => fail(&e),
    }
}

/// TCP worker loop with an injected replica builder: dial, serve, and on a
/// closed link (kick, coordinator restart window) reconnect with a *fresh*
/// replica — the catch-up protocol converges it, so a reconnect is
/// indistinguishable from a crash-restart.
pub fn serve_tcp(addr: &str, rc: Reconnect,
                 make: &mut dyn FnMut(&JoinInfo) -> Result<Box<dyn Replica>>)
                 -> Result<()> {
    loop {
        let (mut link, info) = tcp::dial(addr, None, rc)?;
        let seeds = SeedSchedule::new(info.cfg.seed);
        let mut replica = make(&info)?;
        match serve(&mut link, info.slot, &seeds, &mut *replica) {
            Ok(ServeEnd::Stopped) => return Ok(()),
            Ok(ServeEnd::LinkClosed) => continue,
            Err(e) => {
                let _ = link.send(&Event::Failed {
                    worker: info.slot,
                    error: format!("{e:#}"),
                });
                return Err(e);
            }
        }
    }
}

/// Process entry for `tezo train-dp --connect <addr>`: a remote worker that
/// learns everything (slot, fleet width, config, job) from the handshake.
pub fn run_tcp_worker(addr: &str, artifact_dir: &Path,
                      save_to: Option<PathBuf>,
                      checkpoint_dir: Option<PathBuf>, rc: Reconnect)
                      -> Result<()> {
    let artifact_dir = artifact_dir.to_path_buf();
    serve_tcp(addr, rc, &mut |info: &JoinInfo| {
        let factory = task_job_factory(info.job.task.clone(), info.cfg.seed,
                                       info.job.k_shot as usize,
                                       info.job.eval_n as usize,
                                       save_to.clone());
        let replica = EngineReplica::build(info.slot, info.workers,
                                           &artifact_dir, &info.cfg, &*factory,
                                           checkpoint_dir.clone())?;
        Ok(Box::new(replica) as Box<dyn Replica>)
    })
}
