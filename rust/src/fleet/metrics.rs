//! Fleet-level metrics: per-worker phase accounting, straggler statistics,
//! and the communication counters (see [`crate::memmodel::comm`] for the
//! analytic side).
//!
//! The coordinator's synchronous rounds make straggling directly
//! measurable: each round waits for every worker's two-point result, so the
//! gap between the slowest worker and the mean is pure idle time on the
//! fast replicas. `critical_path_secs` (sum of per-round maxima) over
//! `mean_path_secs` (sum of per-round means) is the fleet's load-imbalance
//! factor — 1.0 means perfectly balanced shards.

use crate::fleet::protocol::CommStats;

/// Aggregated fleet statistics for one run.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// accumulated forward wall seconds per worker
    pub forward_secs: Vec<f64>,
    /// accumulated update wall seconds per worker
    pub update_secs: Vec<f64>,
    /// synchronous forward rounds driven (steps x sub-perturbations)
    pub rounds: u64,
    /// sum over rounds of the slowest worker's forward time
    pub critical_path_secs: f64,
    /// sum over rounds of the mean worker forward time
    pub mean_path_secs: f64,
    /// sum over rounds of (max - min) forward time
    pub spread_secs: f64,
    pub comm: CommStats,
    /// workers that (re)joined after the initial staffing — crash restarts
    /// and elastic rejoins both land here; each one replayed the catch-up
    /// log before taking tickets
    pub rejoins: u64,
    /// stragglers kicked by [`StragglerPolicy::DropSkip`]
    ///
    /// [`StragglerPolicy::DropSkip`]: crate::config::StragglerPolicy
    pub drops: u64,
    /// rounds abandoned by the straggler policy (skipped in lockstep, loss
    /// recorded as NaN — these are the rounds that break oracle bitwise
    /// parity, which is why the default policy is Wait)
    pub degraded_rounds: u64,
    /// late events from departed workers, discarded (buffered results that
    /// arrived after the round moved on)
    pub stale_events: u64,
    /// step checkpoints published for catch-up
    pub checkpoints: u64,
}

impl FleetMetrics {
    pub fn new(workers: usize) -> Self {
        Self {
            forward_secs: vec![0.0; workers],
            update_secs: vec![0.0; workers],
            ..Self::default()
        }
    }

    pub fn workers(&self) -> usize {
        self.forward_secs.len()
    }

    /// Record one synchronous forward round's per-worker wall times.
    pub fn record_forward_round(&mut self, times: &[f64]) {
        debug_assert_eq!(times.len(), self.forward_secs.len());
        let mut max = 0.0f64;
        let mut min = f64::INFINITY;
        let mut sum = 0.0f64;
        for (acc, &t) in self.forward_secs.iter_mut().zip(times) {
            *acc += t;
            max = max.max(t);
            min = min.min(t);
            sum += t;
        }
        self.rounds += 1;
        self.critical_path_secs += max;
        self.mean_path_secs += sum / times.len().max(1) as f64;
        self.spread_secs += max - min.min(max);
    }

    /// Record one update round's per-worker wall times.
    pub fn record_update_round(&mut self, times: &[f64]) {
        debug_assert_eq!(times.len(), self.update_secs.len());
        for (acc, &t) in self.update_secs.iter_mut().zip(times) {
            *acc += t;
        }
    }

    /// Load-imbalance factor: critical path over balanced path (>= 1.0).
    pub fn straggler_factor(&self) -> f64 {
        if self.mean_path_secs <= 0.0 {
            1.0
        } else {
            self.critical_path_secs / self.mean_path_secs
        }
    }

    /// Idle seconds the fast replicas spent waiting for the slowest one.
    pub fn straggler_wait_secs(&self) -> f64 {
        (self.critical_path_secs - self.mean_path_secs).max(0.0)
    }

    /// (worker, forward secs, update secs) rows for reporting.
    pub fn per_worker(&self) -> Vec<(usize, f64, f64)> {
        self.forward_secs
            .iter()
            .zip(&self.update_secs)
            .enumerate()
            .map(|(w, (&f, &u))| (w, f, u))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_accounting_tracks_the_slowest_worker() {
        let mut m = FleetMetrics::new(4);
        m.record_forward_round(&[1.0, 1.0, 1.0, 3.0]);
        m.record_forward_round(&[2.0, 1.0, 1.0, 1.0]);
        assert_eq!(m.rounds, 2);
        assert!((m.critical_path_secs - 5.0).abs() < 1e-12);
        assert!((m.mean_path_secs - 2.75).abs() < 1e-12); // 1.5 + 1.25
        assert!((m.spread_secs - 3.0).abs() < 1e-12); // 2.0 + 1.0
        assert!(m.straggler_factor() > 1.0);
        assert!((m.straggler_wait_secs() - 2.25).abs() < 1e-12);
        assert_eq!(m.forward_secs, vec![3.0, 2.0, 2.0, 4.0]);
    }

    #[test]
    fn balanced_fleet_has_unit_straggler_factor() {
        let mut m = FleetMetrics::new(2);
        m.record_forward_round(&[1.0, 1.0]);
        assert!((m.straggler_factor() - 1.0).abs() < 1e-12);
        assert_eq!(m.straggler_wait_secs(), 0.0);
        // empty metrics are well-defined too
        assert_eq!(FleetMetrics::new(2).straggler_factor(), 1.0);
    }
}
