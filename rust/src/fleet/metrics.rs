//! Fleet-level metrics: per-worker phase accounting, straggler statistics,
//! and the communication counters (see [`crate::memmodel::comm`] for the
//! analytic side).
//!
//! The coordinator's synchronous rounds make straggling directly
//! measurable: each round waits for every worker's two-point result, so the
//! gap between the slowest worker and the mean is pure idle time on the
//! fast replicas. `critical_path_secs` (sum of per-round maxima) over
//! `mean_path_secs` (sum of per-round means) is the fleet's load-imbalance
//! factor — 1.0 means perfectly balanced shards.
//!
//! Since PR 8 the per-worker running sums are backed by full round-RTT
//! histograms ([`LatencyHist`], telemetry layer): the sums stay (they are
//! what `train-dp` prints and what the tests pin), but p50/p95/p99 per
//! worker and the per-round straggler-factor series are now part of the
//! fleet summary JSON.

use crate::fleet::protocol::CommStats;
use crate::jsonx::Value;
use crate::telemetry::{secs_to_ns, LatencyHist};

/// Aggregated fleet statistics for one run.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// accumulated forward wall seconds per worker
    pub forward_secs: Vec<f64>,
    /// accumulated update wall seconds per worker
    pub update_secs: Vec<f64>,
    /// per-worker forward round-time histograms (ns)
    pub forward_hist: Vec<LatencyHist>,
    /// per-worker update round-time histograms (ns)
    pub update_hist: Vec<LatencyHist>,
    /// per-round straggler factor (slowest / mean forward time), one entry
    /// per forward round — the closed-loop signal the final
    /// [`Self::straggler_factor`] aggregate hides
    pub round_factors: Vec<f64>,
    /// synchronous forward rounds driven (steps x sub-perturbations)
    pub rounds: u64,
    /// sum over rounds of the slowest worker's forward time
    pub critical_path_secs: f64,
    /// sum over rounds of the mean worker forward time
    pub mean_path_secs: f64,
    /// sum over rounds of (max - min) forward time
    pub spread_secs: f64,
    pub comm: CommStats,
    /// workers that (re)joined after the initial staffing — crash restarts
    /// and elastic rejoins both land here; each one replayed the catch-up
    /// log before taking tickets
    pub rejoins: u64,
    /// stragglers kicked by [`StragglerPolicy::DropSkip`]
    ///
    /// [`StragglerPolicy::DropSkip`]: crate::config::StragglerPolicy
    pub drops: u64,
    /// rounds abandoned by the straggler policy (skipped in lockstep, loss
    /// recorded as NaN — these are the rounds that break oracle bitwise
    /// parity, which is why the default policy is Wait)
    pub degraded_rounds: u64,
    /// late events from departed workers, discarded (buffered results that
    /// arrived after the round moved on)
    pub stale_events: u64,
    /// step checkpoints published for catch-up
    pub checkpoints: u64,
}

impl FleetMetrics {
    pub fn new(workers: usize) -> Self {
        Self {
            forward_secs: vec![0.0; workers],
            update_secs: vec![0.0; workers],
            forward_hist: vec![LatencyHist::new(); workers],
            update_hist: vec![LatencyHist::new(); workers],
            ..Self::default()
        }
    }

    pub fn workers(&self) -> usize {
        self.forward_secs.len()
    }

    /// Record one synchronous forward round's per-worker wall times.
    pub fn record_forward_round(&mut self, times: &[f64]) {
        debug_assert_eq!(times.len(), self.forward_secs.len());
        let mut max = 0.0f64;
        let mut min = f64::INFINITY;
        let mut sum = 0.0f64;
        for (acc, &t) in self.forward_secs.iter_mut().zip(times) {
            *acc += t;
            max = max.max(t);
            min = min.min(t);
            sum += t;
        }
        for (h, &t) in self.forward_hist.iter_mut().zip(times) {
            h.record_ns(secs_to_ns(t));
        }
        let mean = sum / times.len().max(1) as f64;
        self.rounds += 1;
        self.critical_path_secs += max;
        self.mean_path_secs += mean;
        self.spread_secs += max - min.min(max);
        self.round_factors.push(if mean > 0.0 { max / mean } else { 1.0 });
    }

    /// Record one update round's per-worker wall times.
    pub fn record_update_round(&mut self, times: &[f64]) {
        debug_assert_eq!(times.len(), self.update_secs.len());
        for (acc, &t) in self.update_secs.iter_mut().zip(times) {
            *acc += t;
        }
        for (h, &t) in self.update_hist.iter_mut().zip(times) {
            h.record_ns(secs_to_ns(t));
        }
    }

    /// Load-imbalance factor: critical path over balanced path (>= 1.0).
    pub fn straggler_factor(&self) -> f64 {
        if self.mean_path_secs <= 0.0 {
            1.0
        } else {
            self.critical_path_secs / self.mean_path_secs
        }
    }

    /// Idle seconds the fast replicas spent waiting for the slowest one.
    pub fn straggler_wait_secs(&self) -> f64 {
        (self.critical_path_secs - self.mean_path_secs).max(0.0)
    }

    /// (worker, forward secs, update secs) rows for reporting.
    pub fn per_worker(&self) -> Vec<(usize, f64, f64)> {
        self.forward_secs
            .iter()
            .zip(&self.update_secs)
            .enumerate()
            .map(|(w, (&f, &u))| (w, f, u))
            .collect()
    }

    fn hist_json(h: &LatencyHist) -> Value {
        Value::obj(vec![
            ("count", Value::i(h.count() as i64)),
            ("p50_ns", Value::i(h.p50_ns() as i64)),
            ("p95_ns", Value::i(h.p95_ns() as i64)),
            ("p99_ns", Value::i(h.p99_ns() as i64)),
            ("max_ns", Value::i(h.max_ns() as i64)),
        ])
    }

    /// Fleet summary (written next to the trace by `--telemetry-dir`):
    /// aggregate straggler stats, the full per-round factor series, and
    /// per-worker forward/update quantiles.
    pub fn summary_json(&self) -> Value {
        Value::obj(vec![
            ("workers", Value::i(self.workers() as i64)),
            ("rounds", Value::i(self.rounds as i64)),
            ("straggler_factor", Value::f(self.straggler_factor())),
            ("straggler_wait_secs", Value::f(self.straggler_wait_secs())),
            ("round_straggler_factors",
             Value::arr(self.round_factors.iter().map(|&f| Value::f(f)).collect())),
            ("rejoins", Value::i(self.rejoins as i64)),
            ("drops", Value::i(self.drops as i64)),
            ("degraded_rounds", Value::i(self.degraded_rounds as i64)),
            ("checkpoints", Value::i(self.checkpoints as i64)),
            ("per_worker", Value::arr(
                self.forward_hist
                    .iter()
                    .zip(&self.update_hist)
                    .enumerate()
                    .map(|(w, (fh, uh))| Value::obj(vec![
                        ("worker", Value::i(w as i64)),
                        ("forward", Self::hist_json(fh)),
                        ("update", Self::hist_json(uh)),
                    ]))
                    .collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_accounting_tracks_the_slowest_worker() {
        let mut m = FleetMetrics::new(4);
        m.record_forward_round(&[1.0, 1.0, 1.0, 3.0]);
        m.record_forward_round(&[2.0, 1.0, 1.0, 1.0]);
        assert_eq!(m.rounds, 2);
        assert!((m.critical_path_secs - 5.0).abs() < 1e-12);
        assert!((m.mean_path_secs - 2.75).abs() < 1e-12); // 1.5 + 1.25
        assert!((m.spread_secs - 3.0).abs() < 1e-12); // 2.0 + 1.0
        assert!(m.straggler_factor() > 1.0);
        assert!((m.straggler_wait_secs() - 2.25).abs() < 1e-12);
        assert_eq!(m.forward_secs, vec![3.0, 2.0, 2.0, 4.0]);
    }

    #[test]
    fn balanced_fleet_has_unit_straggler_factor() {
        let mut m = FleetMetrics::new(2);
        m.record_forward_round(&[1.0, 1.0]);
        assert!((m.straggler_factor() - 1.0).abs() < 1e-12);
        assert_eq!(m.straggler_wait_secs(), 0.0);
        // empty metrics are well-defined too
        assert_eq!(FleetMetrics::new(2).straggler_factor(), 1.0);
    }

    #[test]
    fn per_round_factors_keep_what_the_aggregate_hides() {
        let mut m = FleetMetrics::new(2);
        m.record_forward_round(&[1.0, 1.0]); // balanced round
        m.record_forward_round(&[1.0, 3.0]); // skewed round
        assert_eq!(m.round_factors.len(), 2);
        assert!((m.round_factors[0] - 1.0).abs() < 1e-12);
        assert!((m.round_factors[1] - 1.5).abs() < 1e-12);
        // the aggregate factor sits between the two rounds
        let agg = m.straggler_factor();
        assert!(agg > m.round_factors[0] && agg < m.round_factors[1]);
    }

    #[test]
    fn round_times_land_in_per_worker_histograms() {
        let mut m = FleetMetrics::new(2);
        m.record_forward_round(&[0.001, 0.002]);
        m.record_forward_round(&[0.001, 0.004]);
        m.record_update_round(&[0.0005, 0.0005]);
        assert_eq!(m.forward_hist[0].count(), 2);
        assert_eq!(m.forward_hist[1].count(), 2);
        assert_eq!(m.update_hist[0].count(), 1);
        assert!(m.forward_hist[1].max_ns() >= 4_000_000);
        // running sums and histogram sums agree (to ns rounding)
        assert!((m.forward_secs[1] - m.forward_hist[1].sum_ns() as f64 / 1e9).abs() < 1e-9);
    }

    #[test]
    fn summary_json_has_per_round_and_per_worker_blocks() {
        let mut m = FleetMetrics::new(2);
        m.record_forward_round(&[1.0, 2.0]);
        m.record_update_round(&[0.5, 0.5]);
        let v = m.summary_json();
        assert_eq!(v.get("round_straggler_factors").unwrap().as_array().unwrap().len(), 1);
        let pw = v.get("per_worker").unwrap().as_array().unwrap();
        assert_eq!(pw.len(), 2);
        assert_eq!(pw[1].get("forward").unwrap().get("count").unwrap().as_i64().unwrap(), 1);
    }
}
