//! Seed-synchronized data-parallel ZO training (the fleet).
//!
//! The resampling trick (MeZO, adopted by every ZO method here) makes one
//! training step fully described by a 4-byte perturbation seed plus one
//! scalar `kappa = (f+ - f-) / (2 rho)`. Data parallelism therefore needs
//! no gradient all-reduce: N replicas share the seed schedule, each
//! measures the two-point loss on its own data shard, the coordinator
//! averages the scalars, and every replica replays the identical update —
//! O(N) bytes per step, independent of model size (see
//! [`crate::memmodel::comm`] for the analytic comparison and docs/fleet.md
//! for the design).
//!
//! Layout:
//! * [`protocol`] — ticket/result/ack message types, scalar aggregation,
//!   the catch-up log, logical wire accounting;
//! * [`wire`] — the length-prefixed binary codec (explicit tags, LE
//!   fields, bit-exact floats) every message crosses a real wire in;
//! * [`transport`] — the [`Hub`]/[`Link`] abstraction plus the in-process
//!   loopback transport;
//! * [`tcp`] — the TCP transport: listener/dialer, read timeouts, bounded
//!   reconnect with exponential backoff;
//! * [`worker`] — one replica: the transport-agnostic serve loop, the
//!   PJRT-backed [`EngineReplica`], catch-up replay;
//! * [`coordinator`] — [`FleetTrainer`]: broadcast, aggregate, lockstep,
//!   and the fault-tolerant membership machinery;
//! * [`sim`] — artifact-free deterministic replica + single-process oracle
//!   for the chaos/parity test battery;
//! * [`metrics`] — per-worker phase totals, straggler stats, comm bytes,
//!   fault counters.
//!
//! The single-step arithmetic is *not* re-implemented: workers call the
//! same [`StepEngine`](crate::coordinator::step::StepEngine) the plain
//! [`Trainer`](crate::coordinator::trainer::Trainer) uses, which is what
//! makes a 1-worker fleet bit-identical to single-process training (the
//! `integration_fleet` tests assert this).
//!
//! [`Hub`]: transport::Hub
//! [`Link`]: transport::Link
//! [`EngineReplica`]: worker::EngineReplica

pub mod coordinator;
pub mod metrics;
pub mod protocol;
pub mod sim;
pub mod tcp;
pub mod transport;
pub mod wire;
pub mod worker;

pub use coordinator::{FleetOutcome, FleetTrainer, KillPlan, Transport};
pub use metrics::FleetMetrics;
pub use protocol::{CatchUp, CommStats, LogEntry, WorkerReport};
pub use transport::{Hub, HubEvent, Link, WireStats};
pub use wire::JobSpec;
pub use worker::{task_job_factory, JobFactory, Replica, ReplicaFactory,
                 ServeEnd, WorkerJob};
