//! Seed-synchronized data-parallel ZO training (the fleet).
//!
//! The resampling trick (MeZO, adopted by every ZO method here) makes one
//! training step fully described by a 4-byte perturbation seed plus one
//! scalar `kappa = (f+ - f-) / (2 rho)`. Data parallelism therefore needs
//! no gradient all-reduce: N replicas share the seed schedule, each
//! measures the two-point loss on its own data shard, the coordinator
//! averages the scalars, and every replica replays the identical update —
//! O(N) bytes per step, independent of model size (see
//! [`crate::memmodel::comm`] for the analytic comparison and docs/fleet.md
//! for the design).
//!
//! Layout:
//! * [`protocol`] — ticket/result/ack message types, scalar aggregation,
//!   logical wire accounting;
//! * [`worker`] — one replica: private runtime + params, ticket loop;
//! * [`coordinator`] — [`FleetTrainer`]: broadcast, aggregate, lockstep;
//! * [`metrics`] — per-worker phase totals, straggler stats, comm bytes.
//!
//! The single-step arithmetic is *not* re-implemented: workers call the
//! same [`StepEngine`](crate::coordinator::step::StepEngine) the plain
//! [`Trainer`](crate::coordinator::trainer::Trainer) uses, which is what
//! makes a 1-worker fleet bit-identical to single-process training (the
//! `integration_fleet` tests assert this).

pub mod coordinator;
pub mod metrics;
pub mod protocol;
pub mod worker;

pub use coordinator::{FleetOutcome, FleetTrainer};
pub use metrics::FleetMetrics;
pub use protocol::{CommStats, WorkerReport};
pub use worker::{task_job_factory, JobFactory, WorkerJob};
