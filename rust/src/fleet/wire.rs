//! Length-prefixed binary wire codec for the fleet ticket protocol.
//!
//! Every message is one frame: a little-endian `u32` payload length, then
//! the payload — a one-byte tag followed by fixed-layout little-endian
//! fields (the same byte order as the `f32_le_bytes` parameter codecs).
//! The framing exists so the analytic model in [`crate::memmodel::comm`]
//! can be cross-checked against *actual* encoded sizes: a `Forward` frame
//! is exactly `FRAME_HEADER_BYTES + TICKET_BYTES`, an `Apply` frame
//! `FRAME_HEADER_BYTES + KAPPA_BYTES`, and so on (pinned by unit tests
//! here and by `tests/props_wire.rs`).
//!
//! Float policy: two-point losses (`f+`, `f-`) and eval accuracy are
//! carried *bit-exactly* — NaN is meaningful there (loss poisoning drives
//! the lockstep skip; NaN accuracy means "no eval set"). Control-plane
//! floats (kappa, wall seconds, config hyperparameters) must be finite and
//! decode to a typed [`WireError::NonFinite`] otherwise. Malformed input
//! (truncation, unknown tags, oversized length prefixes, bogus counts)
//! never panics: every decode path returns `Result<_, WireError>` and all
//! buffer access is bounds-checked via `get`.

use crate::config::{FormPolicy, ForwardForm, LrSchedule, Method, TrainConfig};
use crate::coordinator::counter::SampleCounter;
use crate::coordinator::metrics::PhaseTimers;

use super::protocol::{CatchUp, Command, Event, LogEntry, Ticket, WorkerReport};

/// Per-frame overhead: 4-byte length prefix + 1-byte message tag.
pub const FRAME_HEADER_BYTES: u64 = 5;

/// Hard ceiling on one frame's payload. Large enough for any catch-up log
/// the coordinator can produce (entries are pruned at checkpoints), small
/// enough that a corrupt length prefix cannot drive an allocation bomb.
pub const MAX_FRAME: usize = 1 << 22;

// Command tags (coordinator -> worker).
const TAG_FORWARD: u8 = 0x01;
const TAG_APPLY: u8 = 0x02;
const TAG_SKIP: u8 = 0x03;
const TAG_EVAL: u8 = 0x04;
const TAG_STOP: u8 = 0x05;
const TAG_CHECKPOINT: u8 = 0x06;
const TAG_CATCH_UP: u8 = 0x07;

// Event tags (worker -> coordinator).
const TAG_TWO_POINT: u8 = 0x41;
const TAG_APPLIED: u8 = 0x42;
const TAG_EVAL_DONE: u8 = 0x43;
const TAG_FAILED: u8 = 0x44;
const TAG_REPORT: u8 = 0x45;
const TAG_CHECKPOINT_DONE: u8 = 0x46;

// Handshake tags (transport-level, not part of Command/Event).
const TAG_HELLO: u8 = 0x21;
const TAG_HELLO_ACK: u8 = 0x22;

/// Typed decode failure. Every malformed input maps to one of these —
/// the codec never panics on untrusted bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// frame or field ends before its declared length
    Truncated { need: usize, have: usize },
    /// length prefix exceeds [`MAX_FRAME`]
    Oversize { len: u64 },
    /// unknown message tag for this decode direction
    UnknownTag { tag: u8 },
    /// a control-plane float field decoded to NaN/inf
    NonFinite { field: &'static str },
    /// payload longer than its message's layout
    Trailing { extra: usize },
    /// a declared element count cannot fit in the remaining payload
    BadCount { field: &'static str, count: u64 },
    /// a string field is not valid UTF-8
    BadUtf8 { field: &'static str },
    /// an enum-like field holds no known value
    BadEnum { field: &'static str },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Oversize { len } => {
                write!(f, "length prefix {len} exceeds MAX_FRAME {MAX_FRAME}")
            }
            WireError::UnknownTag { tag } => write!(f, "unknown message tag {tag:#04x}"),
            WireError::NonFinite { field } => {
                write!(f, "non-finite value in field `{field}`")
            }
            WireError::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after message payload")
            }
            WireError::BadCount { field, count } => {
                write!(f, "count {count} in `{field}` exceeds the payload")
            }
            WireError::BadUtf8 { field } => write!(f, "invalid UTF-8 in `{field}`"),
            WireError::BadEnum { field } => write!(f, "invalid enum value in `{field}`"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// cursor helpers (all bounds-checked; no indexing, no panics)
// ---------------------------------------------------------------------------

struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.pos)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated {
            need: n,
            have: self.remaining(),
        })?;
        let s = self.buf.get(self.pos..end).ok_or(WireError::Truncated {
            need: n,
            have: self.remaining(),
        })?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?.first().copied().unwrap_or(0))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        let mut b = [0u8; 4];
        b.copy_from_slice(s);
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    /// f32 carried bit-exactly (NaN payloads preserved).
    fn f32_bits(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// f32 that must be finite (control-plane values).
    fn f32_finite(&mut self, field: &'static str) -> Result<f32, WireError> {
        let v = self.f32_bits()?;
        if v.is_finite() {
            Ok(v)
        } else {
            Err(WireError::NonFinite { field })
        }
    }

    /// f64 carried bit-exactly (NaN legal — eval accuracy).
    fn f64_bits(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// f64 that must be finite (wall seconds and friends).
    fn f64_finite(&mut self, field: &'static str) -> Result<f64, WireError> {
        let v = self.f64_bits()?;
        if v.is_finite() {
            Ok(v)
        } else {
            Err(WireError::NonFinite { field })
        }
    }

    fn string(&mut self, field: &'static str) -> Result<String, WireError> {
        let n = self.u32()? as u64;
        if n > self.remaining() as u64 {
            return Err(WireError::BadCount { field, count: n });
        }
        let bytes = self.take(n as usize)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8 { field })
    }

    fn done(&self) -> Result<(), WireError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(WireError::Trailing { extra: self.remaining() })
        }
    }
}

struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    /// Start a frame: length prefix placeholder + tag.
    fn frame(tag: u8) -> Self {
        let mut buf = Vec::with_capacity(64);
        buf.extend_from_slice(&[0u8; 4]);
        buf.push(tag);
        Self { buf }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32_bits(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    fn f64_bits(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn string(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Finish: backfill the length prefix over the payload.
    fn finish(mut self) -> Vec<u8> {
        let payload = (self.buf.len() - 4) as u32;
        if let Some(head) = self.buf.get_mut(..4) {
            head.copy_from_slice(&payload.to_le_bytes());
        }
        self.buf
    }
}

/// Split a full frame into its payload, validating the length prefix.
fn frame_payload(frame: &[u8]) -> Result<&[u8], WireError> {
    let head = frame.get(..4).ok_or(WireError::Truncated {
        need: 4,
        have: frame.len(),
    })?;
    let mut b = [0u8; 4];
    b.copy_from_slice(head);
    let len = u32::from_le_bytes(b) as u64;
    if len > MAX_FRAME as u64 {
        return Err(WireError::Oversize { len });
    }
    let body = frame.get(4..).unwrap_or(&[]);
    if (body.len() as u64) < len {
        return Err(WireError::Truncated {
            need: len as usize,
            have: body.len(),
        });
    }
    if (body.len() as u64) > len {
        return Err(WireError::Trailing {
            extra: body.len() - len as usize,
        });
    }
    Ok(body)
}

// ---------------------------------------------------------------------------
// tickets / log entries
// ---------------------------------------------------------------------------

fn put_ticket(w: &mut Wr, t: &Ticket) {
    w.u64(t.step);
    w.u32(t.sub);
    w.u32(t.perturb_seed);
}

fn get_ticket(r: &mut Rd) -> Result<Ticket, WireError> {
    Ok(Ticket {
        step: r.u64()?,
        sub: r.u32()?,
        perturb_seed: r.u32()?,
    })
}

/// Smallest serialized catch-up entry (step + sub + seed + applied flag).
const LOG_ENTRY_MIN_BYTES: u64 = 17;

fn put_entry(w: &mut Wr, e: &LogEntry) {
    w.u64(e.step);
    w.u32(e.sub);
    w.u32(e.perturb_seed);
    match e.kappa {
        Some(k) => {
            w.u8(1);
            w.f32_bits(k);
        }
        None => w.u8(0),
    }
}

fn get_entry(r: &mut Rd) -> Result<LogEntry, WireError> {
    let step = r.u64()?;
    let sub = r.u32()?;
    let perturb_seed = r.u32()?;
    let kappa = match r.u8()? {
        0 => None,
        1 => Some(r.f32_finite("log_entry.kappa")?),
        _ => return Err(WireError::BadEnum { field: "log_entry.applied" }),
    };
    Ok(LogEntry { step, sub, perturb_seed, kappa })
}

// ---------------------------------------------------------------------------
// commands
// ---------------------------------------------------------------------------

/// Encode a command as a full frame (length prefix included).
pub fn encode_command(cmd: &Command) -> Vec<u8> {
    match cmd {
        Command::Forward(t) => {
            let mut w = Wr::frame(TAG_FORWARD);
            put_ticket(&mut w, t);
            w.finish()
        }
        Command::Apply { ticket, kappa } => {
            let mut w = Wr::frame(TAG_APPLY);
            put_ticket(&mut w, ticket);
            w.f32_bits(*kappa);
            w.finish()
        }
        Command::Skip { ticket } => {
            let mut w = Wr::frame(TAG_SKIP);
            put_ticket(&mut w, ticket);
            w.finish()
        }
        Command::Eval { step } => {
            let mut w = Wr::frame(TAG_EVAL);
            w.u64(*step);
            w.finish()
        }
        Command::Stop => Wr::frame(TAG_STOP).finish(),
        Command::Checkpoint { step } => {
            let mut w = Wr::frame(TAG_CHECKPOINT);
            w.u64(*step);
            w.finish()
        }
        Command::CatchUp(c) => {
            let mut w = Wr::frame(TAG_CATCH_UP);
            w.u64(c.checkpoint_step.unwrap_or(u64::MAX));
            w.u32(c.entries.len() as u32);
            for e in &c.entries {
                put_entry(&mut w, e);
            }
            w.finish()
        }
    }
}

/// Decode a full command frame.
pub fn decode_command(frame: &[u8]) -> Result<Command, WireError> {
    let mut r = Rd::new(frame_payload(frame)?);
    let cmd = match r.u8()? {
        TAG_FORWARD => Command::Forward(get_ticket(&mut r)?),
        TAG_APPLY => Command::Apply {
            ticket: get_ticket(&mut r)?,
            kappa: r.f32_finite("apply.kappa")?,
        },
        TAG_SKIP => Command::Skip { ticket: get_ticket(&mut r)? },
        TAG_EVAL => Command::Eval { step: r.u64()? },
        TAG_STOP => Command::Stop,
        TAG_CHECKPOINT => Command::Checkpoint { step: r.u64()? },
        TAG_CATCH_UP => {
            let raw = r.u64()?;
            let checkpoint_step = if raw == u64::MAX { None } else { Some(raw) };
            let count = r.u32()? as u64;
            if count * LOG_ENTRY_MIN_BYTES > r.remaining() as u64 {
                return Err(WireError::BadCount {
                    field: "catch_up.entries",
                    count,
                });
            }
            let mut entries = Vec::with_capacity(count as usize);
            for _ in 0..count {
                entries.push(get_entry(&mut r)?);
            }
            Command::CatchUp(CatchUp { checkpoint_step, entries })
        }
        tag => return Err(WireError::UnknownTag { tag }),
    };
    r.done()?;
    Ok(cmd)
}

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

/// Encode an event as a full frame (length prefix included).
pub fn encode_event(ev: &Event) -> Vec<u8> {
    match ev {
        Event::TwoPoint { worker, step, sub, f_plus, f_minus, forward_secs } => {
            let mut w = Wr::frame(TAG_TWO_POINT);
            w.u32(*worker as u32);
            w.u64(*step);
            w.u32(*sub);
            w.f32_bits(*f_plus);
            w.f32_bits(*f_minus);
            w.f64_bits(*forward_secs);
            w.finish()
        }
        Event::Applied { worker, step, sub, update_secs } => {
            let mut w = Wr::frame(TAG_APPLIED);
            w.u32(*worker as u32);
            w.u64(*step);
            w.u32(*sub);
            w.f64_bits(*update_secs);
            w.finish()
        }
        Event::EvalDone { worker, step, accuracy } => {
            let mut w = Wr::frame(TAG_EVAL_DONE);
            w.u32(*worker as u32);
            w.u64(*step);
            w.f64_bits(*accuracy);
            w.finish()
        }
        Event::Failed { worker, error } => {
            let mut w = Wr::frame(TAG_FAILED);
            w.u32(*worker as u32);
            w.string(error);
            w.finish()
        }
        Event::Report(r) => {
            let mut w = Wr::frame(TAG_REPORT);
            w.u32(r.worker as u32);
            w.u64(r.state_bytes);
            w.u64(r.counter.matrix_elements);
            w.u64(r.counter.vector_elements);
            let (secs, counts, up, reused) = r.timers.parts();
            for s in secs {
                w.f64_bits(s);
            }
            for c in counts {
                w.u64(c);
            }
            w.u64(up);
            w.u64(reused);
            w.finish()
        }
        Event::CheckpointDone { worker, step } => {
            let mut w = Wr::frame(TAG_CHECKPOINT_DONE);
            w.u32(*worker as u32);
            w.u64(*step);
            w.finish()
        }
    }
}

/// Decode a full event frame.
pub fn decode_event(frame: &[u8]) -> Result<Event, WireError> {
    let mut r = Rd::new(frame_payload(frame)?);
    let ev = match r.u8()? {
        TAG_TWO_POINT => Event::TwoPoint {
            worker: r.u32()? as usize,
            step: r.u64()?,
            sub: r.u32()?,
            // loss pair is bit-exact: NaN/inf here *is* the poisoning signal
            f_plus: r.f32_bits()?,
            f_minus: r.f32_bits()?,
            forward_secs: r.f64_finite("two_point.forward_secs")?,
        },
        TAG_APPLIED => Event::Applied {
            worker: r.u32()? as usize,
            step: r.u64()?,
            sub: r.u32()?,
            update_secs: r.f64_finite("applied.update_secs")?,
        },
        TAG_EVAL_DONE => Event::EvalDone {
            worker: r.u32()? as usize,
            step: r.u64()?,
            // NaN accuracy = "no eval set on this worker", carried bit-exact
            accuracy: r.f64_bits()?,
        },
        TAG_FAILED => Event::Failed {
            worker: r.u32()? as usize,
            error: r.string("failed.error")?,
        },
        TAG_REPORT => {
            let worker = r.u32()? as usize;
            let state_bytes = r.u64()?;
            let counter = SampleCounter {
                matrix_elements: r.u64()?,
                vector_elements: r.u64()?,
            };
            let mut secs = [0.0f64; 5];
            for s in secs.iter_mut() {
                *s = r.f64_finite("report.phase_secs")?;
            }
            let mut counts = [0u64; 5];
            for c in counts.iter_mut() {
                *c = r.u64()?;
            }
            let up = r.u64()?;
            let reused = r.u64()?;
            Event::Report(Box::new(WorkerReport {
                worker,
                timers: PhaseTimers::from_parts(secs, counts, up, reused),
                counter,
                state_bytes,
            }))
        }
        TAG_CHECKPOINT_DONE => Event::CheckpointDone {
            worker: r.u32()? as usize,
            step: r.u64()?,
        },
        tag => return Err(WireError::UnknownTag { tag }),
    };
    r.done()?;
    Ok(ev)
}

// ---------------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------------

/// Worker -> coordinator: claim a slot (`u32::MAX` = any free slot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub requested_slot: u32,
}

/// Slot value in a [`HelloAck`] meaning "no slot for you" (fleet full).
pub const SLOT_REJECTED: u32 = u32::MAX;

/// Everything a TCP worker needs to build its replica: its slot, the fleet
/// width, the full training config, and the data-job description.
#[derive(Clone, Debug, PartialEq)]
pub struct HelloAck {
    /// assigned worker slot, or [`SLOT_REJECTED`]
    pub slot: u32,
    pub workers: u32,
    pub cfg: TrainConfig,
    pub job: JobSpec,
}

/// Wire form of the standard task job (see `worker::task_job_factory`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobSpec {
    pub task: String,
    pub k_shot: u32,
    pub eval_n: u32,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self { task: "sst2".to_string(), k_shot: 16, eval_n: 0 }
    }
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut w = Wr::frame(TAG_HELLO);
    w.u32(h.requested_slot);
    w.finish()
}

pub fn decode_hello(frame: &[u8]) -> Result<Hello, WireError> {
    let mut r = Rd::new(frame_payload(frame)?);
    match r.u8()? {
        TAG_HELLO => {
            let h = Hello { requested_slot: r.u32()? };
            r.done()?;
            Ok(h)
        }
        tag => Err(WireError::UnknownTag { tag }),
    }
}

fn put_cfg(w: &mut Wr, cfg: &TrainConfig) {
    w.string(cfg.method.name());
    w.u64(cfg.steps as u64);
    w.f32_bits(cfg.lr);
    w.f32_bits(cfg.rho);
    w.f32_bits(cfg.beta1);
    w.f32_bits(cfg.beta2);
    w.f32_bits(cfg.eps);
    w.f32_bits(cfg.adamu_alpha);
    w.u64(cfg.lazy_interval as u64);
    w.u64(cfg.seed);
    w.u64(cfg.eval_every as u64);
    w.u8(cfg.bias_correction as u8);
    let (sched, frac) = match cfg.lr_schedule {
        LrSchedule::Constant => (0u8, 0.0f32),
        LrSchedule::Linear { final_frac } => (1, final_frac),
        LrSchedule::Cosine { final_frac } => (2, final_frac),
    };
    w.u8(sched);
    w.f32_bits(frac);
    w.f32_bits(cfg.kappa_clip);
    w.u32(cfg.n_perturb as u32);
    w.u8(match cfg.forward_form {
        FormPolicy::Pinned(ForwardForm::Materialize) => 0,
        FormPolicy::Pinned(ForwardForm::Implicit) => 1,
        FormPolicy::Auto => 2,
    });
}

fn get_cfg(r: &mut Rd) -> Result<TrainConfig, WireError> {
    let method_name = r.string("cfg.method")?;
    let method =
        Method::parse(&method_name).map_err(|_| WireError::BadEnum { field: "cfg.method" })?;
    let steps = r.u64()? as usize;
    let lr = r.f32_finite("cfg.lr")?;
    let rho = r.f32_finite("cfg.rho")?;
    let beta1 = r.f32_finite("cfg.beta1")?;
    let beta2 = r.f32_finite("cfg.beta2")?;
    let eps = r.f32_finite("cfg.eps")?;
    let adamu_alpha = r.f32_finite("cfg.adamu_alpha")?;
    let lazy_interval = r.u64()? as usize;
    let seed = r.u64()?;
    let eval_every = r.u64()? as usize;
    let bias_correction = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(WireError::BadEnum { field: "cfg.bias_correction" }),
    };
    let sched = r.u8()?;
    let frac = r.f32_finite("cfg.lr_schedule.final_frac")?;
    let lr_schedule = match sched {
        0 => LrSchedule::Constant,
        1 => LrSchedule::Linear { final_frac: frac },
        2 => LrSchedule::Cosine { final_frac: frac },
        _ => return Err(WireError::BadEnum { field: "cfg.lr_schedule" }),
    };
    let kappa_clip = r.f32_finite("cfg.kappa_clip")?;
    let n_perturb = r.u32()? as usize;
    let forward_form = match r.u8()? {
        0 => FormPolicy::Pinned(ForwardForm::Materialize),
        1 => FormPolicy::Pinned(ForwardForm::Implicit),
        2 => FormPolicy::Auto,
        _ => return Err(WireError::BadEnum { field: "cfg.forward_form" }),
    };
    Ok(TrainConfig {
        method,
        steps,
        lr,
        rho,
        beta1,
        beta2,
        eps,
        adamu_alpha,
        lazy_interval,
        seed,
        eval_every,
        bias_correction,
        lr_schedule,
        kappa_clip,
        n_perturb,
        forward_form,
    })
}

pub fn encode_hello_ack(a: &HelloAck) -> Vec<u8> {
    let mut w = Wr::frame(TAG_HELLO_ACK);
    w.u32(a.slot);
    w.u32(a.workers);
    put_cfg(&mut w, &a.cfg);
    w.string(&a.job.task);
    w.u32(a.job.k_shot);
    w.u32(a.job.eval_n);
    w.finish()
}

pub fn decode_hello_ack(frame: &[u8]) -> Result<HelloAck, WireError> {
    let mut r = Rd::new(frame_payload(frame)?);
    match r.u8()? {
        TAG_HELLO_ACK => {
            let slot = r.u32()?;
            let workers = r.u32()?;
            let cfg = get_cfg(&mut r)?;
            let job = JobSpec {
                task: r.string("job.task")?,
                k_shot: r.u32()?,
                eval_n: r.u32()?,
            };
            r.done()?;
            Ok(HelloAck { slot, workers, cfg, job })
        }
        tag => Err(WireError::UnknownTag { tag }),
    }
}

/// Framed size of a command on the wire (what a TCP transport writes).
pub fn command_frame_len(cmd: &Command) -> u64 {
    encode_command(cmd).len() as u64
}

/// Framed size of an event on the wire.
pub fn event_frame_len(ev: &Event) -> u64 {
    encode_event(ev).len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::comm::{KAPPA_BYTES, TICKET_BYTES, TWO_POINT_BYTES};

    fn ticket() -> Ticket {
        Ticket { step: 7, sub: 3, perturb_seed: 0xDEAD_BEEF }
    }

    #[test]
    fn command_round_trips() {
        let cmds = vec![
            Command::Forward(ticket()),
            Command::Apply { ticket: ticket(), kappa: -1.5 },
            Command::Skip { ticket: ticket() },
            Command::Eval { step: 42 },
            Command::Stop,
            Command::Checkpoint { step: 10 },
            Command::CatchUp(CatchUp {
                checkpoint_step: Some(4),
                entries: vec![
                    LogEntry { step: 4, sub: 0, perturb_seed: 9, kappa: Some(0.25) },
                    LogEntry { step: 5, sub: 0, perturb_seed: 10, kappa: None },
                ],
            }),
            Command::CatchUp(CatchUp { checkpoint_step: None, entries: vec![] }),
        ];
        for cmd in &cmds {
            let frame = encode_command(cmd);
            let back = decode_command(&frame).unwrap();
            assert_eq!(*cmd, back, "command round trip");
            // re-encoding the decoded message is bit-identical
            assert_eq!(frame, encode_command(&back));
        }
    }

    #[test]
    fn event_round_trips_bit_exactly() {
        let mut timers = PhaseTimers::default();
        timers.add(crate::coordinator::metrics::Phase::Forward, 1.25);
        timers.add_upload_bytes(100, 7);
        let evs = vec![
            Event::TwoPoint {
                worker: 2,
                step: 9,
                sub: 1,
                f_plus: f32::NAN, // poisoning must survive the wire
                f_minus: -0.0,
                forward_secs: 0.125,
            },
            Event::Applied { worker: 0, step: 1, sub: 0, update_secs: 0.5 },
            Event::EvalDone { worker: 0, step: 8, accuracy: f64::NAN },
            Event::Failed { worker: 3, error: "boom: bad artifact".to_string() },
            Event::Report(Box::new(WorkerReport {
                worker: 1,
                timers,
                counter: SampleCounter { matrix_elements: 5, vector_elements: 6 },
                state_bytes: 1234,
            })),
            Event::CheckpointDone { worker: 0, step: 4 },
        ];
        for ev in &evs {
            let frame = encode_event(ev);
            let back = decode_event(&frame).unwrap();
            assert_eq!(frame, encode_event(&back), "event {ev:?} not bit-stable");
        }
        // NaN loss bits survive exactly
        let frame = encode_event(&evs[0]);
        match decode_event(&frame).unwrap() {
            Event::TwoPoint { f_plus, f_minus, .. } => {
                assert_eq!(f_plus.to_bits(), f32::NAN.to_bits());
                assert_eq!(f_minus.to_bits(), (-0.0f32).to_bits());
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn frame_sizes_match_the_analytic_model() {
        // the memmodel constants are the *logical* payload; the frame adds
        // exactly FRAME_HEADER_BYTES (+ metadata on the result path)
        let fwd = encode_command(&Command::Forward(ticket()));
        assert_eq!(fwd.len() as u64, FRAME_HEADER_BYTES + TICKET_BYTES);
        let apply = encode_command(&Command::Apply { ticket: ticket(), kappa: 1.0 });
        assert_eq!(apply.len() as u64, FRAME_HEADER_BYTES + KAPPA_BYTES);
        let skip = encode_command(&Command::Skip { ticket: ticket() });
        assert_eq!(skip.len() as u64, FRAME_HEADER_BYTES + TICKET_BYTES);
        let tp = encode_event(&Event::TwoPoint {
            worker: 0,
            step: 0,
            sub: 0,
            f_plus: 0.0,
            f_minus: 0.0,
            forward_secs: 0.0,
        });
        assert_eq!(
            tp.len() as u64,
            FRAME_HEADER_BYTES + TWO_POINT_BYTES + crate::memmodel::comm::RESULT_META_BYTES
        );
    }

    #[test]
    fn malformed_frames_yield_typed_errors() {
        // truncation at every prefix of a valid frame
        let frame = encode_command(&Command::Apply { ticket: ticket(), kappa: 2.0 });
        for cut in 0..frame.len() {
            let err = decode_command(&frame[..cut]).unwrap_err();
            assert!(
                matches!(err, WireError::Truncated { .. }),
                "cut at {cut}: {err:?}"
            );
        }
        // unknown tag
        let mut bogus = encode_command(&Command::Stop);
        bogus[4] = 0xEE;
        assert_eq!(decode_command(&bogus), Err(WireError::UnknownTag { tag: 0xEE }));
        // oversized length prefix
        let mut huge = vec![0u8; 8];
        huge[..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            decode_command(&huge),
            Err(WireError::Oversize { .. })
        ));
        // trailing garbage
        let mut long = encode_command(&Command::Stop);
        long.push(0);
        assert!(matches!(decode_command(&long), Err(WireError::Trailing { .. })));
        // non-finite kappa is a wire error (the lockstep-skip path never
        // broadcasts one; a frame carrying it is corrupt by definition)
        let mut w = Wr::frame(TAG_APPLY);
        put_ticket(&mut w, &ticket());
        w.f32_bits(f32::INFINITY);
        assert_eq!(
            decode_command(&w.finish()),
            Err(WireError::NonFinite { field: "apply.kappa" })
        );
        // catch-up count larger than the payload can hold
        let mut w = Wr::frame(TAG_CATCH_UP);
        w.u64(u64::MAX);
        w.u32(1_000_000);
        assert!(matches!(
            decode_command(&w.finish()),
            Err(WireError::BadCount { .. })
        ));
    }

    #[test]
    fn handshake_round_trips() {
        let hello = Hello { requested_slot: 3 };
        assert_eq!(decode_hello(&encode_hello(&hello)).unwrap(), hello);

        let mut cfg = TrainConfig::default();
        cfg.steps = 17;
        cfg.seed = 99;
        cfg.lr_schedule = LrSchedule::Cosine { final_frac: 0.25 };
        let ack = HelloAck {
            slot: 1,
            workers: 4,
            cfg,
            job: JobSpec { task: "agnews".to_string(), k_shot: 8, eval_n: 32 },
        };
        let frame = encode_hello_ack(&ack);
        let back = decode_hello_ack(&frame).unwrap();
        assert_eq!(ack, back);
        assert_eq!(frame, encode_hello_ack(&back));
        // a command decoder must not accept a handshake frame
        assert!(matches!(
            decode_command(&frame),
            Err(WireError::UnknownTag { .. })
        ));
    }
}
