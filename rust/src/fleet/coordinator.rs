//! The fleet coordinator: broadcasts per-step tickets, aggregates two-point
//! losses into one global kappa, and keeps every replica in lockstep.
//!
//! Communication per (step, sub-perturbation): one [`Ticket`] down to each
//! of N workers, one `(f+, f-)` pair up from each, one aggregated kappa
//! back down — O(N) scalars, independent of model size. The global
//! estimate is exact data parallelism: with per-worker shard losses
//! `f±_w`, `kappa = (mean_w f+_w - mean_w f-_w) / (2 rho)` equals the
//! two-point estimate on the union batch, and every worker replays it
//! locally through [`StepEngine::update_sub`], so parameter replicas never
//! diverge (checked by the workers' seed cross-check and by the
//! fleet determinism tests).
//!
//! [`StepEngine::update_sub`]: crate::coordinator::step::StepEngine::update_sub
//!
//! # Fault tolerance
//!
//! The drive loop speaks to an abstract [`Hub`] (in-process loopback or
//! TCP) and treats membership as dynamic. The invariant that buys bitwise
//! reproducibility: **a round never aggregates over fewer than N shards**.
//! If a worker dies mid-round the round *stalls* — the departure is charged
//! to the restart budget, a replacement (re)joins, converges via the
//! catch-up protocol (last published checkpoint + the (seed, kappa) log),
//! and answers the re-sent ticket — so the N-slot aggregation, and
//! therefore the whole trajectory, is bit-identical to an uninterrupted
//! run (asserted by `tests/chaos_fleet.rs`). The only deliberately
//! non-bitwise path is [`StragglerPolicy::DropSkip`], which abandons a
//! round in lockstep instead of waiting for it.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::config::{FleetConfig, FormPolicy, StragglerPolicy, TrainConfig};
use crate::coordinator::autotune;
use crate::coordinator::guard::{GuardPolicy, GuardState};
use crate::coordinator::metrics::TrainMetrics;
use crate::coordinator::optimizer::ForwardOut;
use crate::coordinator::step::StepEngine;
use crate::runtime::journal::{self, Journal, JournalEntry};
use crate::runtime::checkpoint;
use crate::telemetry::{secs_to_ns, Stopwatch, Telemetry};

use super::metrics::FleetMetrics;
use super::protocol::{aggregate_two_point, CatchUp, Command, Event, LogEntry,
                      Ticket, WorkerReport};
use super::tcp::{AckInfo, TcpHub};
use super::transport::{Hub, HubEvent, LoopbackHub};
use super::wire::JobSpec;
use super::worker::{self, JobFactory, ReplicaFactory};

/// How often gather loops wake up to re-check round state and deadlines.
const POLL_QUANTUM: Duration = Duration::from_millis(200);
/// With zero live workers mid-run, how long to wait for a (re)join before
/// declaring the fleet dead.
const DEAD_FLEET_STALL: Duration = Duration::from_secs(60);

/// Which wire the fleet runs on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Transport {
    /// in-process worker threads over channels (the default; bit-identical
    /// to TCP by the parity tests)
    Loopback,
    /// bind this address and wait for `workers` remote `tezo train-dp
    /// --connect` processes to dial in
    TcpListen(String),
}

/// Coordinator-side chaos hook: called at each step boundary with the step
/// about to run; every returned slot is forcibly disconnected first. The
/// departures are charged to the restart budget like real crashes.
pub type KillPlan = Box<dyn FnMut(u64) -> Vec<usize> + Send>;

/// Result of one fleet run.
pub struct FleetOutcome {
    /// global loss curve / evals / wall time (same shape as a single-process
    /// [`TrainOutcome`](crate::coordinator::trainer::TrainOutcome))
    pub metrics: TrainMetrics,
    /// fleet-only accounting: per-worker phases, stragglers, comm bytes,
    /// fault-tolerance counters
    pub fleet: FleetMetrics,
    /// end-of-run per-worker reports (worker order; a worker that died
    /// without reporting gets a default-valued report)
    pub workers: Vec<WorkerReport>,
    /// non-finite steps skipped in lockstep
    pub skipped: u64,
    /// optimizer state bytes of one replica
    pub state_bytes: u64,
    /// the full (seed, kappa) update trace — what a rejoiner would replay;
    /// the chaos tests compare it bitwise against the single-process oracle
    pub trace: Vec<LogEntry>,
}

/// Seed-synchronized data-parallel trainer: N worker replicas, each with a
/// private runtime + parameter replica and a disjoint data shard, driven by
/// scalar tickets from this coordinator over loopback channels or TCP.
pub struct FleetTrainer {
    pub fleet: FleetConfig,
    pub cfg: TrainConfig,
    /// artifact directory every worker opens its own [`Runtime`] from
    ///
    /// [`Runtime`]: crate::runtime::Runtime
    pub artifact_dir: PathBuf,
    /// per-worker job builder (data shard source, eval set, checkpoint)
    pub job_factory: Box<JobFactory>,
    /// optional per-step observer (step, global loss)
    pub on_step: Option<Box<dyn FnMut(u64, f64) + Send>>,
    /// which wire the fleet runs on (default: loopback threads)
    pub transport: Transport,
    /// job description shipped to TCP workers in the handshake
    pub job_spec: JobSpec,
    /// where step checkpoints are published (loopback workers; TCP workers
    /// pass their own `--checkpoint-dir`)
    pub checkpoint_dir: Option<PathBuf>,
    /// chaos hook: slots to kill at each step boundary
    pub kill_plan: Option<KillPlan>,
    /// test injection: replace the PJRT-backed replica with a custom one
    /// (loopback only; see `fleet::sim`)
    pub replica_factory: Option<Box<ReplicaFactory>>,
    /// tracer handle (disabled by default; `--telemetry-dir` enables it).
    /// Spans and marks are recorded from values the drive loop already
    /// holds — the tracer never sits on a gather's wait path.
    pub telemetry: Telemetry,
    /// restart from the coordinator journal (+ newest verifiable
    /// checkpoint) in `checkpoint_dir` instead of starting fresh
    pub resume: bool,
    /// divergence guard thresholds (`Default` = disabled)
    pub guard: GuardPolicy,
}

impl FleetTrainer {
    pub fn new(fleet: FleetConfig, cfg: TrainConfig, artifact_dir: PathBuf,
               job_factory: Box<JobFactory>) -> Self {
        Self {
            fleet,
            cfg,
            artifact_dir,
            job_factory,
            on_step: None,
            transport: Transport::Loopback,
            job_spec: JobSpec::default(),
            checkpoint_dir: None,
            kill_plan: None,
            replica_factory: None,
            telemetry: Telemetry::off(),
            resume: false,
            guard: GuardPolicy::default(),
        }
    }

    /// Restart from the coordinator journal in `checkpoint_dir`: the
    /// staffed workers receive a catch-up (newest verifiable checkpoint +
    /// the journaled tail) before the first ticket.
    pub fn with_resume(mut self, resume: bool) -> Self {
        self.resume = resume;
        self
    }

    /// Arm the divergence guard (needs a published checkpoint to roll
    /// back to — `--checkpoint-dir` for real workers).
    pub fn with_guard(mut self, guard: GuardPolicy) -> Self {
        self.guard = guard;
        self
    }

    pub fn with_transport(mut self, transport: Transport) -> Self {
        self.transport = transport;
        self
    }

    pub fn with_job_spec(mut self, job_spec: JobSpec) -> Self {
        self.job_spec = job_spec;
        self
    }

    pub fn with_checkpoint_dir(mut self, dir: PathBuf) -> Self {
        self.checkpoint_dir = Some(dir);
        self
    }

    pub fn with_kill_plan(mut self, plan: KillPlan) -> Self {
        self.kill_plan = Some(plan);
        self
    }

    pub fn with_replica_factory(mut self, make: Box<ReplicaFactory>) -> Self {
        self.replica_factory = Some(make);
        self
    }

    /// Attach a tracer: per-worker round spans, rejoin/drop/checkpoint
    /// marks, and loss/kappa counters land in its ring.
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// Run the configured number of steps across the fleet.
    pub fn run(&mut self) -> Result<FleetOutcome> {
        self.cfg.validate()?;
        self.fleet.validate(&self.cfg)?;
        self.guard.validate()?;
        if self.guard.enabled() {
            ensure!(self.checkpoint_dir.is_some()
                        || self.replica_factory.is_some(),
                    "the fleet divergence guard needs --checkpoint-dir: a \
                     published checkpoint is the rollback target");
        }
        if self.resume {
            ensure!(self.checkpoint_dir.is_some(),
                    "fleet resume needs --checkpoint-dir (the coordinator \
                     journal lives there)");
        }
        // resolve the form policy once for the whole fleet, before the
        // engine or any worker exists: the pinned decision rides the
        // handshake (loopback cfg clones / TCP AckInfo), so every replica
        // dispatches the identical artifact and the bitwise-reproducibility
        // invariant extends to the tuned form. Sim fleets (custom replicas)
        // have no real artifact dir to probe and take the documented
        // fallback instead.
        let real_artifacts = self.replica_factory.is_none()
            && self.artifact_dir.join("manifest.json").exists();
        let tuning = match self.cfg.forward_form.pinned() {
            Some(_) => None,
            None if !real_artifacts => {
                // sim fleets (custom replicas) and fake artifact dirs have
                // nothing to probe; pin the documented fallback so the
                // handshake still ships a concrete form
                self.cfg.forward_form = FormPolicy::Pinned(
                    self.cfg.forward_form.resolve_fallback());
                None
            }
            None => {
                let r = autotune::resolve_for_dir(&self.artifact_dir,
                                                  &self.cfg,
                                                  &self.telemetry)?;
                self.cfg.forward_form = FormPolicy::Pinned(r.form);
                Some(r.summary_json())
            }
        };
        let workers = self.fleet.workers;
        let engine = StepEngine::new(self.cfg.clone());
        let fleet_cfg = self.fleet;
        let mut on_step = self.on_step.take();
        let mut kill_plan = self.kill_plan.take();
        let factory: &JobFactory = &*self.job_factory;
        let custom: Option<&ReplicaFactory> = self.replica_factory.as_deref();
        let dir = self.artifact_dir.clone();
        let cfg = self.cfg.clone();
        let seed = cfg.seed;
        let checkpoint_dir = self.checkpoint_dir.clone();
        let telemetry = self.telemetry.clone();

        // durable coordinator state: open (or create) the journal next to
        // the published checkpoints, and on resume turn the recovered
        // records into a prefilled catch-up log the staffed workers replay
        let q = engine.n_sub();
        let mut dur = Durability {
            journal: None,
            start_step: 0,
            log: Vec::new(),
            last_checkpoint: None,
            announce: false,
            resumed_from: None,
            guard: self.guard,
        };
        if let Some(ckpt_dir) = &checkpoint_dir {
            let (mut j, recovered) =
                Journal::open(&ckpt_dir.join("journal.bin"), seed)?;
            if self.resume {
                let ckpt = checkpoint::latest_verified(ckpt_dir)
                    .ok()
                    .map(|r| r.step);
                let floor = ckpt.unwrap_or(0);
                let replay = journal::plan_replay(&recovered, floor, q)?;
                if let Some(partial) = replay.partial {
                    // a step interrupted mid-journal is re-run live; its
                    // rounds are deterministic, so the re-run is bitwise
                    // identical to what the crash cut short
                    j.truncate_from_step(partial)?;
                }
                for (_, group) in &replay.steps {
                    for e in group {
                        ensure!(e.perturb_seed
                                    == engine.seeds.perturb_seed(e.step, e.sub),
                                "journal step {} sub {} carries seed {:#010x} \
                                 but this run's schedule derives {:#010x} — \
                                 the journal belongs to a different run",
                                e.step, e.sub, e.perturb_seed,
                                engine.seeds.perturb_seed(e.step, e.sub));
                        dur.log.push(LogEntry {
                            step: e.step,
                            sub: e.sub,
                            perturb_seed: e.perturb_seed,
                            kappa: e.kappa,
                        });
                    }
                }
                dur.start_step = replay.partial
                    .or_else(|| replay.steps.last().map(|(s, _)| s + 1))
                    .unwrap_or(floor);
                dur.last_checkpoint = ckpt;
                dur.announce = ckpt.is_some() || !dur.log.is_empty();
                dur.resumed_from = Some(floor);
            } else if !j.is_empty() {
                // a fresh run must not inherit a stale log
                j.truncate_from_step(0)?;
            }
            dur.journal = Some(j);
        }

        let mut outcome = match self.transport.clone() {
            Transport::Loopback => std::thread::scope(|scope| {
                let (mut hub, hub_tx) = LoopbackHub::new(workers);
                // spawner doubles as the crash-restart path: every `Left`
                // within the restart budget respawns the slot's thread,
                // which rejoins and catches up before taking tickets
                let mut spawn_worker = |w: usize| {
                    let hub_tx = hub_tx.clone();
                    match custom {
                        Some(make) => {
                            scope.spawn(move || {
                                worker::run_custom_loopback(
                                    w, workers as u32, seed, make, hub_tx);
                            });
                        }
                        None => {
                            let dir = dir.clone();
                            let cfg = cfg.clone();
                            let ckpt = checkpoint_dir.clone();
                            scope.spawn(move || {
                                worker::run_worker_loopback(
                                    w, workers as u32, &dir, &cfg, factory,
                                    hub_tx, ckpt);
                            });
                        }
                    }
                };
                for w in 0..workers {
                    spawn_worker(w);
                }
                let out = drive(&engine, &fleet_cfg, &mut hub, &mut on_step,
                                &mut spawn_worker, &mut kill_plan, &telemetry,
                                &mut dur);
                // dropping the hub drops every command sender: workers
                // unblock, see a closed link, and exit so the scope can
                // join instead of hanging on error paths
                drop(hub);
                out
            }),
            Transport::TcpListen(addr) => {
                let ack = AckInfo { cfg: cfg.clone(),
                                    job: self.job_spec.clone() };
                let mut hub = TcpHub::listen(&addr, workers, ack)?;
                // TCP workers own their reconnect loop; a departed slot is
                // refilled by the worker process dialing back in
                let mut no_respawn = |_w: usize| {};
                drive(&engine, &fleet_cfg, &mut hub, &mut on_step,
                      &mut no_respawn, &mut kill_plan, &telemetry, &mut dur)
            }
        }?;
        outcome.metrics.tuning = tuning;
        Ok(outcome)
    }
}

/// Coordinator-side durability state prepared by [`FleetTrainer::run`]
/// before the drive loop starts: the open journal, and (on resume) the
/// prefilled catch-up log plus where live training picks up.
struct Durability {
    journal: Option<Journal>,
    start_step: u64,
    log: Vec<LogEntry>,
    last_checkpoint: Option<u64>,
    /// broadcast a catch-up to the freshly staffed fleet (resume path)
    announce: bool,
    resumed_from: Option<u64>,
    guard: GuardPolicy,
}

/// Drive-loop state: membership, the catch-up log, and fleet accounting.
struct Drive<'a> {
    fc: &'a FleetConfig,
    hub: &'a mut dyn Hub,
    /// loopback crash-restart hook (no-op for TCP)
    respawn: &'a mut dyn FnMut(usize),
    alive: Vec<bool>,
    /// initial staffing complete; joins after this are rejoins and get the
    /// catch-up protocol
    staffed: bool,
    /// departures charged to the restart budget
    deaths: usize,
    /// departures we caused via straggler kicks (not charged)
    pending_drops: usize,
    last_failure: Option<String>,
    last_event: Stopwatch,
    /// prunable catch-up log (entries since the last published checkpoint)
    log: Vec<LogEntry>,
    /// full run trace (never pruned; returned in [`FleetOutcome`])
    trace: Vec<LogEntry>,
    last_checkpoint: Option<u64>,
    /// durable write-ahead journal mirroring `log` (None = in-memory run)
    journal: Option<Journal>,
    fleet: FleetMetrics,
    /// tracer handle (off by default; observational only)
    tel: Telemetry,
}

impl Drive<'_> {
    fn workers(&self) -> usize {
        self.alive.len()
    }

    /// Once any departure or drop has happened, late events from the old
    /// incarnation of a slot are legitimate and get discarded; in a
    /// fault-free run they indicate a protocol bug and abort.
    fn lenient(&self) -> bool {
        self.deaths > 0 || self.fleet.drops > 0
    }

    fn stale(&mut self, ev: Event, ctx: &str) -> Result<()> {
        if self.lenient() {
            self.fleet.stale_events += 1;
            Ok(())
        } else {
            bail!("unexpected event during {ctx}: {ev:?}")
        }
    }

    /// Send, treating a down link as a pending departure: the authoritative
    /// [`HubEvent::Left`] is still in flight and does the budget/respawn
    /// accounting; marking the slot dead here just stops resend spinning.
    fn try_send(&mut self, w: usize, cmd: &Command) -> bool {
        match self.hub.send(w, cmd) {
            Ok(()) => true,
            Err(_) => {
                if let Some(a) = self.alive.get_mut(w) {
                    *a = false;
                }
                false
            }
        }
    }

    fn poll_next(&mut self) -> Result<Option<HubEvent>> {
        let ev = self.hub.poll(POLL_QUANTUM)?;
        if ev.is_some() {
            self.last_event = Stopwatch::start();
        } else if self.staffed && !self.alive.iter().any(|&a| a) {
            // dead-fleet wait: one mark per poll quantum (bounded by the
            // stall budget, so this cannot flood the ring)
            self.tel.mark("fleet", "dead_wait", 0, -1);
            if self.last_event.elapsed() > DEAD_FLEET_STALL {
                match &self.last_failure {
                    Some(e) => bail!("every worker is gone and none rejoined \
                                      within {}s (last failure: {e})",
                                     DEAD_FLEET_STALL.as_secs()),
                    None => bail!("every worker is gone and none rejoined \
                                   within {}s", DEAD_FLEET_STALL.as_secs()),
                }
            }
        }
        Ok(ev)
    }

    /// Per-worker round spans (lane = worker slot) from the wall times the
    /// workers reported — recorded after the gather completes, never on its
    /// critical path.
    fn emit_round_spans(&self, name: &'static str, times: &[f64], step: u64) {
        if !self.tel.enabled() {
            return;
        }
        for (w, &t) in times.iter().enumerate() {
            self.tel
                .span_dur("round", name, secs_to_ns(t), w as u32, step as i64);
        }
    }

    fn on_joined(&mut self, w: usize) -> Result<()> {
        ensure!(w < self.alive.len(), "join for unknown slot {w}");
        self.alive[w] = true;
        if self.staffed {
            // a rejoin: converge the fresh replica on the fleet's current
            // parameters before it sees any ticket (per-link ordering
            // guarantees the CatchUp precedes the next Forward)
            self.fleet.rejoins += 1;
            self.tel.mark("fleet", "rejoin", w as u32, -1);
            self.tel
                .counter("fleet", "catchup_entries", self.log.len() as f64, -1);
            let cmd = Command::CatchUp(CatchUp {
                checkpoint_step: self.last_checkpoint,
                entries: self.log.clone(),
            });
            self.try_send(w, &cmd);
        }
        Ok(())
    }

    fn on_left(&mut self, w: usize) -> Result<()> {
        ensure!(w < self.alive.len(), "departure of unknown slot {w}");
        self.alive[w] = false;
        self.tel.mark("fleet", "left", w as u32, -1);
        if self.pending_drops > 0 {
            // a deliberate straggler kick, already counted in fleet.drops —
            // it does not consume the crash-restart budget
            self.pending_drops -= 1;
        } else {
            self.deaths += 1;
            self.tel
                .counter("fleet", "restart_budget_used", self.deaths as f64, -1);
            if self.deaths > self.fc.max_restarts {
                match &self.last_failure {
                    Some(e) => bail!("worker {w} failed: {e}"),
                    None => bail!("worker {w} left the fleet and the restart \
                                   budget ({}) is exhausted",
                                  self.fc.max_restarts),
                }
            }
        }
        (self.respawn)(w);
        Ok(())
    }

    fn on_failed(&mut self, w: usize, error: String) -> Result<()> {
        if self.fc.max_restarts == 0 {
            // the original fail-fast semantics
            bail!("worker {w} failed: {error}");
        }
        // tolerate it: the matching Left does the accounting, and the error
        // text is kept for the eventual budget-exhausted report
        self.last_failure = Some(error);
        Ok(())
    }

    /// Wait for every slot to be claimed (loopback threads were just
    /// spawned; TCP workers dial in on their own schedule).
    fn staff(&mut self) -> Result<()> {
        while !self.alive.iter().all(|&a| a) {
            match self.poll_next()? {
                None => {}
                Some(HubEvent::Joined(w)) => self.on_joined(w)?,
                Some(HubEvent::Left(w)) => self.on_left(w)?,
                Some(HubEvent::Msg(_, Event::Failed { worker, error })) => {
                    self.on_failed(worker, error)?;
                }
                Some(HubEvent::Msg(_, ev)) => self.stale(ev, "staffing")?,
            }
        }
        self.staffed = true;
        Ok(())
    }

    /// One forward round: a full N-slot gather of two-point results for
    /// `ticket`. Stalls through departures (the rejoin + catch-up + resend
    /// path refills the missing slot), so `Some` always carries exactly N
    /// measurements — the bitwise-identity invariant. `None` means the
    /// DropSkip straggler policy abandoned the round.
    fn forward_round(&mut self, ticket: Ticket)
                     -> Result<Option<(Vec<(f32, f32)>, Vec<f64>)>> {
        let n = self.workers();
        let mut slots: Vec<Option<(f32, f32)>> = vec![None; n];
        let mut sent = vec![false; n];
        let mut times = vec![0.0f64; n];
        let t0 = Stopwatch::start();
        loop {
            // (re)send to every live worker that has neither an outstanding
            // ticket nor a result — a rejoiner gets exactly one resend, so a
            // duplicate result below is a hard protocol violation
            for w in 0..n {
                if self.alive[w] && !sent[w] && slots[w].is_none()
                    && self.try_send(w, &Command::Forward(ticket))
                {
                    sent[w] = true;
                    self.fleet.comm.on_tickets(1);
                }
            }
            if slots.iter().all(|s| s.is_some()) {
                let pairs = slots.iter().filter_map(|s| *s).collect();
                return Ok(Some((pairs, times)));
            }
            match self.poll_next()? {
                None => {
                    if let StragglerPolicy::DropSkip { timeout_ms } =
                        self.fc.straggler
                    {
                        // only a *relative* straggler is dropped: if nobody
                        // answered, the fleet is uniformly slow and we wait
                        let some_answered = slots.iter().any(|s| s.is_some());
                        if some_answered
                            && t0.elapsed() >= Duration::from_millis(timeout_ms)
                        {
                            for w in 0..n {
                                if slots[w].is_none() && self.alive[w] {
                                    self.hub.kick(w);
                                    self.alive[w] = false;
                                    self.fleet.drops += 1;
                                    self.pending_drops += 1;
                                    self.tel.mark("fleet", "drop", w as u32,
                                                  ticket.step as i64);
                                }
                            }
                            return Ok(None);
                        }
                    }
                }
                Some(HubEvent::Joined(w)) => self.on_joined(w)?,
                Some(HubEvent::Left(w)) => {
                    self.on_left(w)?;
                    // the replacement needs its own ticket
                    if let Some(s) = sent.get_mut(w) {
                        *s = false;
                    }
                }
                Some(HubEvent::Msg(from, ev)) => match ev {
                    Event::TwoPoint { worker, step, sub, f_plus, f_minus,
                                      forward_secs }
                        if worker == from && step == ticket.step
                            && sub == ticket.sub =>
                    {
                        ensure!(worker < n,
                                "result from unknown worker {worker}");
                        ensure!(slots[worker].is_none(),
                                "duplicate result from worker {worker}");
                        slots[worker] = Some((f_plus, f_minus));
                        times[worker] = forward_secs;
                        self.fleet.comm.on_results(1);
                    }
                    Event::Failed { worker, error } => {
                        self.on_failed(worker, error)?;
                    }
                    other => self.stale(other, "forward wait")?,
                },
            }
        }
    }

    /// Broadcast the round's outcome (Apply with the aggregated kappa, or a
    /// lockstep Skip) and gather acks from the workers it reached. The log
    /// entry is appended *before* the gather, so a worker joining mid-wait
    /// receives a catch-up log that already covers this round.
    fn ack_round(&mut self, ticket: Ticket, kappa: Option<f32>)
                 -> Result<Vec<f64>> {
        let entry = LogEntry {
            step: ticket.step,
            sub: ticket.sub,
            perturb_seed: ticket.perturb_seed,
            kappa,
        };
        // WAL ordering: the record is durable before any worker is told to
        // apply it — a coordinator restart can always re-drive whatever the
        // fleet may have applied
        if let Some(j) = self.journal.as_mut() {
            j.append(&JournalEntry {
                step: entry.step,
                sub: entry.sub,
                perturb_seed: entry.perturb_seed,
                kappa: entry.kappa,
            })?;
        }
        self.log.push(entry);
        self.trace.push(entry);
        let n = self.workers();
        let cmd = match kappa {
            Some(k) => Command::Apply { ticket, kappa: k },
            None => Command::Skip { ticket },
        };
        let mut expect = vec![false; n];
        for w in 0..n {
            if self.alive[w] && self.try_send(w, &cmd) {
                expect[w] = true;
                self.fleet.comm.on_broadcasts(1);
            }
        }
        let mut got = vec![false; n];
        let mut times = vec![0.0f64; n];
        while expect.iter().zip(got.iter()).any(|(&e, &g)| e && !g) {
            match self.poll_next()? {
                None => {}
                // not added to the ack set: its catch-up replay (which
                // includes this entry) is the acknowledgement
                Some(HubEvent::Joined(w)) => self.on_joined(w)?,
                Some(HubEvent::Left(w)) => {
                    self.on_left(w)?;
                    if let Some(e) = expect.get_mut(w) {
                        *e = false;
                    }
                }
                Some(HubEvent::Msg(from, ev)) => match ev {
                    Event::Applied { worker, step, sub, update_secs } => {
                        if worker == from && worker < n
                            && step == ticket.step && sub == ticket.sub
                            && expect[worker] && !got[worker]
                        {
                            got[worker] = true;
                            times[worker] = update_secs;
                        } else {
                            self.stale(Event::Applied { worker, step, sub,
                                                        update_secs },
                                       "ack wait")?;
                        }
                    }
                    Event::Failed { worker, error } => {
                        self.on_failed(worker, error)?;
                    }
                    other => self.stale(other, "ack wait")?,
                },
            }
        }
        Ok(times)
    }

    /// Publish a step checkpoint (`step_done` = completed-step count) to
    /// the lowest live slot, retargeting on departure. On success the
    /// catch-up log is pruned to entries the checkpoint does not cover.
    fn checkpoint_round(&mut self, step_done: u64) -> Result<()> {
        'retry: loop {
            let Some(target) = self.alive.iter().position(|&a| a) else {
                self.pump_membership("checkpoint")?;
                continue;
            };
            if !self.try_send(target, &Command::Checkpoint { step: step_done })
            {
                continue;
            }
            loop {
                match self.poll_next()? {
                    None => {}
                    Some(HubEvent::Joined(w)) => self.on_joined(w)?,
                    Some(HubEvent::Left(w)) => {
                        self.on_left(w)?;
                        if w == target {
                            continue 'retry;
                        }
                    }
                    Some(HubEvent::Msg(from, ev)) => match ev {
                        Event::CheckpointDone { worker, step }
                            if worker == from && worker == target
                                && step == step_done =>
                        {
                            // prune the journal only to the *previous*
                            // checkpoint: if the new one is later found
                            // corrupt, resume falls back to the previous one
                            // and still needs its replay tail durably
                            let prev = self.last_checkpoint.unwrap_or(0);
                            self.last_checkpoint = Some(step_done);
                            self.log.retain(|e| e.step >= step_done);
                            if let Some(j) = self.journal.as_mut() {
                                j.retain_from_step(prev.min(step_done))?;
                            }
                            self.fleet.checkpoints += 1;
                            self.tel.mark("fleet", "checkpoint", 0,
                                          step_done as i64);
                            return Ok(());
                        }
                        Event::Failed { worker, error } => {
                            self.on_failed(worker, error)?;
                        }
                        other => self.stale(other, "checkpoint wait")?,
                    },
                }
            }
        }
    }

    /// Held-out eval on the lowest live slot (worker 0 in a healthy fleet —
    /// it is the one carrying the eval set), retargeting on departure.
    /// `None` when the answering replica has no eval set.
    fn eval_round(&mut self, step: u64) -> Result<Option<f64>> {
        'retry: loop {
            let Some(target) = self.alive.iter().position(|&a| a) else {
                self.pump_membership("eval")?;
                continue;
            };
            if !self.try_send(target, &Command::Eval { step }) {
                continue;
            }
            loop {
                match self.poll_next()? {
                    None => {}
                    Some(HubEvent::Joined(w)) => self.on_joined(w)?,
                    Some(HubEvent::Left(w)) => {
                        self.on_left(w)?;
                        if w == target {
                            continue 'retry;
                        }
                    }
                    Some(HubEvent::Msg(from, ev)) => match ev {
                        Event::EvalDone { worker, step: s, accuracy }
                            if worker == from && worker == target
                                && s == step =>
                        {
                            return Ok(if accuracy.is_nan() {
                                None
                            } else {
                                Some(accuracy)
                            });
                        }
                        Event::Failed { worker, error } => {
                            self.on_failed(worker, error)?;
                        }
                        other => self.stale(other, "eval wait")?,
                    },
                }
            }
        }
    }

    /// One poll iteration processing only membership/failure events — used
    /// while waiting for *any* live worker to appear.
    fn pump_membership(&mut self, ctx: &str) -> Result<()> {
        match self.poll_next()? {
            None => Ok(()),
            Some(HubEvent::Joined(w)) => self.on_joined(w),
            Some(HubEvent::Left(w)) => self.on_left(w),
            Some(HubEvent::Msg(_, Event::Failed { worker, error })) => {
                self.on_failed(worker, error)
            }
            Some(HubEvent::Msg(_, ev)) => self.stale(ev, ctx),
        }
    }

    /// Stop the fleet and gather final reports, tolerating deaths: a worker
    /// that exits cleanly reports first and its departure is expected; one
    /// that dies before reporting gets a default report synthesized.
    fn shutdown(&mut self) -> Result<Vec<WorkerReport>> {
        let n = self.workers();
        let mut expect = vec![false; n];
        for w in 0..n {
            if self.alive[w] && self.try_send(w, &Command::Stop) {
                expect[w] = true;
            }
        }
        let mut reports: Vec<Option<WorkerReport>> =
            (0..n).map(|_| None).collect();
        while expect
            .iter()
            .zip(reports.iter())
            .any(|(&e, r)| e && r.is_none())
        {
            match self.hub.poll(POLL_QUANTUM)? {
                None => {}
                Some(HubEvent::Joined(w)) => {
                    // too late to put it to work
                    self.hub.kick(w);
                }
                Some(HubEvent::Left(w)) => {
                    // expected for clean exits (the report precedes the
                    // departure); for a pre-report death, give up on the
                    // report. Never charged to the restart budget.
                    if let Some(a) = self.alive.get_mut(w) {
                        *a = false;
                    }
                    let reported =
                        matches!(reports.get(w), Some(Some(_)));
                    if !reported {
                        if let Some(e) = expect.get_mut(w) {
                            *e = false;
                        }
                    }
                }
                Some(HubEvent::Msg(from, ev)) => match ev {
                    Event::Report(r) => {
                        let w = r.worker;
                        ensure!(w == from && w < n,
                                "report from unknown worker {w}");
                        ensure!(reports[w].is_none(),
                                "duplicate report from {w}");
                        reports[w] = Some(*r);
                    }
                    Event::Failed { worker, error } => {
                        if self.fc.max_restarts == 0 {
                            bail!("worker {worker} failed during shutdown: \
                                   {error}");
                        }
                        self.last_failure = Some(error);
                    }
                    other => self.stale(other, "shutdown")?,
                },
            }
        }
        Ok(reports
            .into_iter()
            .enumerate()
            .map(|(w, r)| {
                r.unwrap_or_else(|| WorkerReport {
                    worker: w,
                    timers: Default::default(),
                    counter: Default::default(),
                    state_bytes: 0,
                })
            })
            .collect())
    }
}

/// The synchronous drive loop (runs on the coordinator thread).
fn drive(engine: &StepEngine, fc: &FleetConfig, hub: &mut dyn Hub,
         on_step: &mut Option<Box<dyn FnMut(u64, f64) + Send>>,
         respawn: &mut dyn FnMut(usize),
         kill_plan: &mut Option<KillPlan>, tel: &Telemetry,
         dur: &mut Durability)
         -> Result<FleetOutcome> {
    let workers = fc.workers;
    let steps = engine.cfg.steps as u64;
    let q = engine.n_sub();
    // on resume the catch-up log is prefilled from the journal so freshly
    // staffed workers replay it; the trace starts from the same prefix so a
    // resumed run's trace is bitwise-identical to an uninterrupted one
    let prefilled = std::mem::take(&mut dur.log);
    let mut d = Drive {
        fc,
        hub,
        respawn,
        alive: vec![false; workers],
        staffed: false,
        deaths: 0,
        pending_drops: 0,
        last_failure: None,
        last_event: Stopwatch::start(),
        log: prefilled.clone(),
        trace: prefilled,
        last_checkpoint: dur.last_checkpoint,
        journal: dur.journal.take(),
        fleet: FleetMetrics::new(workers),
        tel: tel.clone(),
    };
    let mut metrics = TrainMetrics::default();
    metrics.resumed_from = dur.resumed_from;
    let mut skipped = 0u64;
    let wall0 = Stopwatch::start();
    let run0 = tel.now_ns();
    d.staff()?;
    if dur.announce {
        // drive the staffed fleet from init up to where the journal left
        // off: load the last verified checkpoint (if any) and replay the
        // durable (seed, kappa) tail
        let cmd = Command::CatchUp(CatchUp {
            checkpoint_step: d.last_checkpoint,
            entries: d.log.clone(),
        });
        for w in 0..workers {
            if d.alive.get(w).copied().unwrap_or(false) {
                d.try_send(w, &cmd);
            }
        }
        d.tel.mark("fleet", "resume", 0, dur.start_step as i64);
        d.tel.counter("resume", "replayed", d.log.len() as f64,
                      dur.start_step as i64);
    }
    // an armed guard always has somewhere to roll back to: publish the
    // fleet's current params as a checkpoint when none exists yet (per-link
    // ordering guarantees the catch-up replay lands before the save)
    let mut guard = GuardState::new(dur.guard);
    let mut suppress = 0usize;
    if dur.guard.enabled() && d.last_checkpoint.is_none() {
        d.checkpoint_round(dur.start_step)?;
    }

    let mut step = dur.start_step;
    while step < steps {
        if let Some(kill) = kill_plan.as_mut() {
            for w in kill(step) {
                // chaos injection: the Left arrives through the normal poll
                // path and is charged to the restart budget like a crash
                if d.alive.get(w).copied().unwrap_or(false) {
                    d.hub.kick(w);
                }
            }
        }
        let step0 = tel.now_ns();
        let loss = if suppress > 0 {
            // post-rollback suppression: measure the loss but broadcast a
            // lockstep skip instead of an update — the same journal and
            // trace footprint as a non-finite skip, so replay stays exact
            suppress -= 1;
            let ticket = Ticket {
                step,
                sub: 0,
                perturb_seed: engine.seeds.perturb_seed(step, 0),
            };
            let measured = match d.forward_round(ticket)? {
                Some((pairs, fwd_times)) => {
                    d.fleet.record_forward_round(&fwd_times);
                    d.emit_round_spans("forward", &fwd_times, step);
                    let (f_plus, f_minus) = aggregate_two_point(&pairs);
                    engine.combine(&ForwardOut::TwoPoint { f_plus, f_minus }).0
                }
                None => {
                    d.fleet.degraded_rounds += 1;
                    f64::NAN
                }
            };
            d.ack_round(ticket, None)?;
            d.tel.counter("guard", "suppressed", 1.0, step as i64);
            measured
        } else {
        let mut loss_acc = 0.0f64;
        let mut early: Option<f64> = None;
        for sub in 0..q {
            let ticket = Ticket {
                step,
                sub,
                perturb_seed: engine.seeds.perturb_seed(step, sub),
            };
            let Some((pairs, fwd_times)) = d.forward_round(ticket)? else {
                // the straggler policy abandoned the round: the surviving
                // workers skip in lockstep and the step records NaN
                d.fleet.degraded_rounds += 1;
                d.ack_round(ticket, None)?;
                early = Some(f64::NAN);
                break;
            };
            d.fleet.record_forward_round(&fwd_times);
            d.emit_round_spans("forward", &fwd_times, step);
            if let Some(&f) = d.fleet.round_factors.last() {
                d.tel.counter("round", "straggler_factor", f, step as i64);
            }
            let (f_plus, f_minus) = aggregate_two_point(&pairs);
            let (loss, kappa_raw) =
                engine.combine(&ForwardOut::TwoPoint { f_plus, f_minus });
            if !loss.is_finite() || !kappa_raw.is_finite() {
                // lockstep skip: every replica must skip together or the
                // parameter replicas diverge
                d.ack_round(ticket, None)?;
                early = Some(loss);
                break;
            }
            let kappa = engine.clip_kappa(kappa_raw);
            // observational only: the tracer reads kappa, never the reverse
            d.tel.counter("round", "kappa", kappa as f64, step as i64);
            let upd_times = d.ack_round(ticket, Some(kappa))?;
            d.fleet.record_update_round(&upd_times);
            d.emit_round_spans("update", &upd_times, step);
            loss_acc += loss;
        }
        // same semantics as the single-process engine: a non-finite
        // measurement aborts the remaining sub-perturbations and the run
        // records that loss as-is
        match early {
            Some(l) => l,
            None => loss_acc / q as f64,
        }
        };
        tel.span_from("step", "step", step0, 0, step as i64);
        tel.counter("step", "loss", loss, step as i64);
        if loss.is_finite() {
            metrics.record_loss(loss);
        } else {
            skipped += 1;
            metrics.record_loss(f64::NAN);
        }
        if let Some(cb) = on_step.as_mut() {
            cb(step, loss);
        }

        if let Some(reason) = guard.observe(loss) {
            ensure!(guard.can_roll_back(),
                    "divergence guard tripped at step {step} ({reason}) \
                     with the rollback budget ({}) exhausted",
                    dur.guard.max_rollbacks);
            let Some(good) = d.last_checkpoint else {
                bail!("divergence guard tripped at step {step} ({reason}) \
                       but no checkpoint has been published to roll back to")
            };
            d.tel.mark("guard", "rollback", 0, step as i64);
            d.tel.counter("guard", "rollback", 1.0, step as i64);
            // rewind the durable record first, then converge every live
            // replica on (checkpoint, replayed tail) — the same state the
            // coordinator resumes from
            if let Some(j) = d.journal.as_mut() {
                j.truncate_from_step(good)?;
            }
            d.log.retain(|e| e.step < good);
            d.trace.retain(|e| e.step < good);
            let cmd = Command::CatchUp(CatchUp {
                checkpoint_step: Some(good),
                entries: d.log.clone(),
            });
            for w in 0..workers {
                if d.alive.get(w).copied().unwrap_or(false) {
                    d.try_send(w, &cmd);
                }
            }
            guard.rolled_back();
            metrics.rollbacks += 1;
            suppress = dur.guard.skip_steps;
            step = good;
            continue;
        }

        if fc.checkpoint_every > 0
            && (step + 1) % fc.checkpoint_every as u64 == 0
        {
            d.checkpoint_round(step + 1)?;
        }
        if engine.cfg.eval_every > 0
            && (step + 1) % engine.cfg.eval_every as u64 == 0
        {
            if let Some(acc) = d.eval_round(step + 1)? {
                metrics.evals.push((step + 1, acc));
            }
        }
        step += 1;
    }
    // final eval, unless the periodic hook already scored the last step
    // (the answering replica returns NaN when it carries no eval set, which
    // matches a Trainer without `with_eval`)
    let evaled_at_end = engine.cfg.eval_every > 0
        && steps % engine.cfg.eval_every as u64 == 0;
    if !evaled_at_end {
        if let Some(acc) = d.eval_round(steps)? {
            metrics.evals.push((steps, acc));
        }
    }

    let workers_out = d.shutdown()?;
    let ws = d.hub.wire();
    d.fleet.comm.wire_down = ws.bytes_down;
    d.fleet.comm.wire_up = ws.bytes_up;
    d.fleet.comm.frames_down = ws.frames_down;
    d.fleet.comm.frames_up = ws.frames_up;
    tel.span_from("run", "train-dp", run0, 0, -1);
    metrics.wall_seconds = wall0.elapsed_secs();
    metrics.nonfinite_skips = skipped;
    let state_bytes = workers_out.first().map(|r| r.state_bytes).unwrap_or(0);
    Ok(FleetOutcome {
        metrics,
        fleet: d.fleet,
        workers: workers_out,
        skipped,
        state_bytes,
        trace: d.trace,
    })
}
