//! The fleet coordinator: broadcasts per-step tickets, aggregates two-point
//! losses into one global kappa, and keeps every replica in lockstep.
//!
//! Communication per (step, sub-perturbation): one [`Ticket`] down to each
//! of N workers, one `(f+, f-)` pair up from each, one aggregated kappa
//! back down — O(N) scalars, independent of model size. The global
//! estimate is exact data parallelism: with per-worker shard losses
//! `f±_w`, `kappa = (mean_w f+_w - mean_w f-_w) / (2 rho)` equals the
//! two-point estimate on the union batch, and every worker replays it
//! locally through [`StepEngine::update_sub`], so parameter replicas never
//! diverge (checked by the workers' seed cross-check and by the
//! fleet determinism tests).

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{FleetConfig, TrainConfig};
use crate::coordinator::metrics::TrainMetrics;
use crate::coordinator::optimizer::ForwardOut;
use crate::coordinator::step::StepEngine;

use super::metrics::FleetMetrics;
use super::protocol::{aggregate_two_point, Command, Event, Ticket, WorkerReport};
use super::worker::{self, JobFactory};

/// Result of one fleet run.
pub struct FleetOutcome {
    /// global loss curve / evals / wall time (same shape as a single-process
    /// [`TrainOutcome`](crate::coordinator::trainer::TrainOutcome))
    pub metrics: TrainMetrics,
    /// fleet-only accounting: per-worker phases, stragglers, comm bytes
    pub fleet: FleetMetrics,
    /// end-of-run per-worker reports (worker order)
    pub workers: Vec<WorkerReport>,
    /// non-finite steps skipped in lockstep
    pub skipped: u64,
    /// optimizer state bytes of one replica
    pub state_bytes: u64,
}

/// Seed-synchronized data-parallel trainer: N worker threads, each with a
/// private runtime + parameter replica and a disjoint data shard, driven by
/// scalar tickets from this coordinator.
pub struct FleetTrainer {
    pub fleet: FleetConfig,
    pub cfg: TrainConfig,
    /// artifact directory every worker opens its own [`Runtime`] from
    ///
    /// [`Runtime`]: crate::runtime::Runtime
    pub artifact_dir: PathBuf,
    /// per-worker job builder (data shard source, eval set, checkpoint)
    pub job_factory: Box<JobFactory>,
    /// optional per-step observer (step, global loss)
    pub on_step: Option<Box<dyn FnMut(u64, f64) + Send>>,
}

impl FleetTrainer {
    pub fn new(fleet: FleetConfig, cfg: TrainConfig, artifact_dir: PathBuf,
               job_factory: Box<JobFactory>) -> Self {
        Self { fleet, cfg, artifact_dir, job_factory, on_step: None }
    }

    /// Run the configured number of steps across the fleet.
    pub fn run(&mut self) -> Result<FleetOutcome> {
        self.cfg.validate()?;
        self.fleet.validate(&self.cfg)?;
        let workers = self.fleet.workers;
        let engine = StepEngine::new(self.cfg.clone());
        let mut on_step = self.on_step.take();
        let factory: &JobFactory = &*self.job_factory;
        let dir = self.artifact_dir.clone();
        let cfg = self.cfg.clone();

        std::thread::scope(|scope| {
            let (etx, erx) = mpsc::channel::<Event>();
            let mut cmd_txs: Vec<Sender<Command>> = Vec::with_capacity(workers);
            for w in 0..workers {
                let (ctx, crx) = mpsc::channel::<Command>();
                cmd_txs.push(ctx);
                let etx = etx.clone();
                let dir = dir.clone();
                let cfg = cfg.clone();
                scope.spawn(move || {
                    worker::run_worker(w, workers as u32, &dir, &cfg, factory,
                                       crx, etx)
                });
            }
            drop(etx); // the coordinator only receives
            let out = drive(&engine, workers, &cmd_txs, &erx, &mut on_step);
            // on error, dropping the command channels unblocks every worker
            // so the scope can join instead of hanging
            drop(cmd_txs);
            out
        })
    }
}

/// Broadcast a command to every worker.
fn broadcast(cmd_txs: &[Sender<Command>], cmd: Command) -> Result<()> {
    for tx in cmd_txs {
        tx.send(cmd).map_err(|_| anyhow!("a worker exited early"))?;
    }
    Ok(())
}

fn recv(erx: &Receiver<Event>) -> Result<Event> {
    erx.recv().map_err(|_| anyhow!("all workers exited before reporting"))
}

/// Collect one `Applied` ack per worker for (step, sub).
fn collect_acks(erx: &Receiver<Event>, workers: usize, step: u64, sub: u32)
                -> Result<Vec<f64>> {
    let mut times = vec![0.0f64; workers];
    let mut seen = vec![false; workers];
    for _ in 0..workers {
        match recv(erx)? {
            Event::Applied { worker, step: s, sub: sb, update_secs } => {
                ensure!(s == step && sb == sub,
                        "ack for ({s},{sb}) during ({step},{sub})");
                ensure!(!seen[worker], "duplicate ack from worker {worker}");
                seen[worker] = true;
                times[worker] = update_secs;
            }
            Event::Failed { worker, error } => {
                bail!("worker {worker} failed: {error}")
            }
            other => bail!("unexpected event during ack wait: {other:?}"),
        }
    }
    Ok(times)
}

/// The synchronous drive loop (runs on the coordinator thread).
fn drive(engine: &StepEngine, workers: usize, cmd_txs: &[Sender<Command>],
         erx: &Receiver<Event>,
         on_step: &mut Option<Box<dyn FnMut(u64, f64) + Send>>)
         -> Result<FleetOutcome> {
    let steps = engine.cfg.steps as u64;
    let q = engine.n_sub();
    let mut metrics = TrainMetrics::default();
    let mut fleet = FleetMetrics::new(workers);
    let mut skipped = 0u64;
    let wall0 = Instant::now();

    for step in 0..steps {
        let mut loss_acc = 0.0f64;
        let mut early: Option<f64> = None;
        for sub in 0..q {
            let ticket = Ticket {
                step,
                sub,
                perturb_seed: engine.seeds.perturb_seed(step, sub),
            };
            broadcast(cmd_txs, Command::Forward(ticket))?;
            fleet.comm.on_tickets(workers as u64);

            // slot results by worker index: aggregation order is fixed no
            // matter which replica answers first
            let mut slots: Vec<Option<(f32, f32)>> = vec![None; workers];
            let mut fwd_times = vec![0.0f64; workers];
            for _ in 0..workers {
                match recv(erx)? {
                    Event::TwoPoint { worker, step: s, sub: sb, f_plus,
                                      f_minus, forward_secs } => {
                        ensure!(s == step && sb == sub,
                                "result for ({s},{sb}) during ({step},{sub})");
                        ensure!(slots[worker].is_none(),
                                "duplicate result from worker {worker}");
                        slots[worker] = Some((f_plus, f_minus));
                        fwd_times[worker] = forward_secs;
                    }
                    Event::Failed { worker, error } => {
                        bail!("worker {worker} failed: {error}")
                    }
                    other => bail!("unexpected event during forward wait: \
                                    {other:?}"),
                }
            }
            fleet.comm.on_results(workers as u64);
            fleet.record_forward_round(&fwd_times);

            let pairs: Vec<(f32, f32)> = slots
                .into_iter()
                .enumerate()
                .map(|(w, s)| s.ok_or_else(|| anyhow::anyhow!("no result slot for worker {w}")))
                .collect::<Result<_>>()?;
            let (f_plus, f_minus) = aggregate_two_point(&pairs);
            let (loss, kappa_raw) =
                engine.combine(&ForwardOut::TwoPoint { f_plus, f_minus });
            if !loss.is_finite() || !kappa_raw.is_finite() {
                // lockstep skip: every replica must skip together or the
                // parameter replicas diverge
                broadcast(cmd_txs, Command::Skip { ticket })?;
                fleet.comm.on_broadcasts(workers as u64);
                collect_acks(erx, workers, step, sub)?;
                early = Some(loss);
                break;
            }
            let kappa = engine.clip_kappa(kappa_raw);
            broadcast(cmd_txs, Command::Apply { ticket, kappa })?;
            fleet.comm.on_broadcasts(workers as u64);
            let upd_times = collect_acks(erx, workers, step, sub)?;
            fleet.record_update_round(&upd_times);
            loss_acc += loss;
        }
        // same semantics as the single-process engine: a non-finite
        // measurement aborts the remaining sub-perturbations and the run
        // records that loss as-is
        let loss = match early {
            Some(l) => l,
            None => loss_acc / q as f64,
        };
        if loss.is_finite() {
            metrics.record_loss(loss);
        } else {
            skipped += 1;
            metrics.record_loss(f64::NAN);
        }
        if let Some(cb) = on_step.as_mut() {
            cb(step, loss);
        }
        if engine.cfg.eval_every > 0
            && (step + 1) % engine.cfg.eval_every as u64 == 0
        {
            if let Some(acc) = run_eval(cmd_txs, erx, step + 1)? {
                metrics.evals.push((step + 1, acc));
            }
        }
    }
    // final eval, unless the periodic hook already scored the last step
    // (worker 0 answers NaN when it carries no eval set, which matches a
    // Trainer without `with_eval`)
    let evaled_at_end = engine.cfg.eval_every > 0
        && steps % engine.cfg.eval_every as u64 == 0;
    if !evaled_at_end {
        if let Some(acc) = run_eval(cmd_txs, erx, steps)? {
            metrics.evals.push((steps, acc));
        }
    }

    broadcast(cmd_txs, Command::Stop)?;
    let mut reports: Vec<Option<WorkerReport>> = (0..workers).map(|_| None).collect();
    for _ in 0..workers {
        match recv(erx)? {
            Event::Report(r) => {
                let w = r.worker;
                ensure!(reports[w].is_none(), "duplicate report from {w}");
                reports[w] = Some(*r);
            }
            Event::Failed { worker, error } => {
                bail!("worker {worker} failed during shutdown: {error}")
            }
            other => bail!("unexpected event during shutdown: {other:?}"),
        }
    }
    let workers_out: Vec<WorkerReport> = reports
        .into_iter()
        .enumerate()
        .map(|(w, r)| r.ok_or_else(|| anyhow::anyhow!("no shutdown report from worker {w}")))
        .collect::<Result<_>>()?;
    metrics.wall_seconds = wall0.elapsed().as_secs_f64();
    let state_bytes = workers_out.first().map(|r| r.state_bytes).unwrap_or(0);
    Ok(FleetOutcome {
        metrics,
        fleet,
        workers: workers_out,
        skipped,
        state_bytes,
    })
}

/// Ask worker 0 for a held-out eval; `None` when it has no eval set.
fn run_eval(cmd_txs: &[Sender<Command>], erx: &Receiver<Event>, step: u64)
            -> Result<Option<f64>> {
    cmd_txs[0]
        .send(Command::Eval { step })
        .map_err(|_| anyhow!("worker 0 exited early"))?;
    match recv(erx)? {
        Event::EvalDone { step: s, accuracy, .. } => {
            ensure!(s == step, "eval for step {s} during step {step}");
            Ok(if accuracy.is_nan() { None } else { Some(accuracy) })
        }
        Event::Failed { worker, error } => {
            bail!("worker {worker} failed during eval: {error}")
        }
        other => bail!("unexpected event during eval: {other:?}"),
    }
}
