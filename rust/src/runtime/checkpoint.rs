//! Parameter checkpointing: save/restore the device-resident parameter set
//! as raw `.bin` files + a JSON descriptor, compatible with the AOT param
//! format (so a checkpoint can also seed a fresh run or be inspected with
//! the same tools as the shipped init).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::jsonx::{self, Value};

use super::manifest::Manifest;
use super::params::{f32_le_bytes, read_f32_bin, ParamStore};

/// Save `params` under `dir` (created if needed) with run metadata.
///
/// Crash-safe, including when overwriting an existing checkpoint: the
/// `.bin` files are *step-qualified* (a crashed save can never alias the
/// files a previous `checkpoint.json` references), every file is written
/// to a sibling temp path, fsynced, and atomically renamed, and
/// `checkpoint.json` is renamed *last* — the single commit point. A crash
/// mid-save leaves the previous checkpoint fully intact (plus orphaned
/// files from the aborted save, which the next successful save garbage-
/// collects).
pub fn save(dir: &Path, manifest: &Manifest, params: &ParamStore, step: u64)
            -> Result<()> {
    std::fs::create_dir_all(dir.join("params"))
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut entries = Vec::new();
    let mut kept = Vec::new();
    for (i, e) in params.entries.iter().enumerate() {
        let host = params.fetch(i)?;
        let base = format!("s{step:010}_{i:03}_{}.bin", e.name.replace('.', "_"));
        write_atomic(&dir.join("params").join(&base), &f32_le_bytes(&host))?;
        let fname = format!("params/{base}");
        kept.push(base);
        entries.push(Value::obj(vec![
            ("name", Value::str(&e.name)),
            ("shape", Value::arr(e.shape.iter().map(|&s| Value::i(s as i64)).collect())),
            ("bin", Value::str(&fname)),
        ]));
    }
    // persist all bin renames with one directory fsync before the json
    // commit point (write_atomic already fsyncs each file's contents)
    sync_dir(&dir.join("params"));
    let doc = Value::obj(vec![
        ("format", Value::str("tezo-checkpoint-v1")),
        ("config", Value::str(&manifest.config.name)),
        ("n_params", Value::i(manifest.config.n_params as i64)),
        ("step", Value::i(step as i64)),
        ("params", Value::arr(entries)),
    ]);
    write_atomic(&dir.join("checkpoint.json"),
                 jsonx::to_string_pretty(&doc).as_bytes())?;
    sync_dir(dir);
    // the new json is committed: drop bins of older/aborted saves
    gc_params_dir(&dir.join("params"), &kept);
    Ok(())
}

/// Write `bytes` to `path` via a same-directory temp file + fsync + rename
/// (rename within one directory is atomic on POSIX filesystems).
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("no file name in {}", path.display()))?
        .to_os_string();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// Best-effort directory fsync, persisting the renames committed inside it
/// (unix-specific; a no-op where directories cannot be opened).
fn sync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Remove `.bin`/`.tmp` files the just-committed checkpoint does not
/// reference (leftovers of older or crashed saves). Best effort: a failed
/// removal only wastes disk, never correctness.
fn gc_params_dir(params_dir: &Path, kept: &[String]) {
    let Ok(rd) = std::fs::read_dir(params_dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !kept.iter().any(|k| k == name) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// Restore parameters from a checkpoint into fresh device buffers.
/// The checkpoint must match the manifest's config (name + param table).
pub fn load(dir: &Path, client: &xla::PjRtClient, manifest: &Manifest)
            -> Result<(ParamStore, u64)> {
    let text = std::fs::read_to_string(dir.join("checkpoint.json"))
        .with_context(|| format!("reading {}/checkpoint.json", dir.display()))?;
    let doc = jsonx::parse(&text)?;
    if doc.get_str("format")? != "tezo-checkpoint-v1" {
        bail!("unknown checkpoint format");
    }
    ensure!(doc.get_str("config")? == manifest.config.name,
            "checkpoint is for config {:?}, runtime is {:?}",
            doc.get_str("config")?, manifest.config.name);
    let step = u64::try_from(doc.get("step")?.as_i64()?)
        .map_err(|_| anyhow::anyhow!("checkpoint step is negative"))?;
    let entries = doc.get("params")?.as_array()?;
    ensure!(entries.len() == manifest.params.len(),
            "checkpoint has {} params, manifest {}", entries.len(),
            manifest.params.len());

    let mut store = ParamStore::load(client, manifest)?; // shapes/entries
    let mut bufs = Vec::with_capacity(entries.len());
    for (e, p) in entries.iter().zip(&manifest.params) {
        ensure!(e.get_str("name")? == p.name,
                "param order mismatch: {} vs {}", e.get_str("name")?, p.name);
        let host = read_f32_bin(&dir.join(e.get_str("bin")?), p.numel())?;
        bufs.push(client.buffer_from_host_buffer(&host, &p.shape, None)?);
    }
    store.replace_all(bufs)?;
    Ok((store, step))
}
