//! Parameter checkpointing: save/restore the device-resident parameter set
//! as raw `.bin` files + a JSON descriptor, compatible with the AOT param
//! format (so a checkpoint can also seed a fresh run or be inspected with
//! the same tools as the shipped init).
//!
//! Since PR 10 every checkpoint is *integrity-checked and retained*:
//!
//! * each `.bin` records its byte length and FNV-1a-64 digest in the
//!   descriptor, and every load re-verifies both — a truncated, flipped,
//!   or swapped bin is a typed error, never wrong params;
//! * each save also commits a step-qualified descriptor
//!   (`checkpoint_sNNNNNNNNNN.json`) and keeps the last K of them
//!   (default [`KEEP_DEFAULT`]), so the divergence guard and `--resume`
//!   always have an older checkpoint to fall back to;
//! * garbage collection is retention-aware: only bins referenced by *no*
//!   retained descriptor are collected.
//!
//! All writes go through [`super::durable`] (lint rule `TZ-IO001`); the
//! failure model is documented in docs/robustness.md.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::jsonx::{self, Value};

use super::durable;
use super::journal::fnv1a64;
use super::manifest::Manifest;
use super::params::{f32_from_le_bytes, f32_le_bytes, ParamStore};

/// Checkpoints retained per directory by default (current + one to roll
/// back to).
pub const KEEP_DEFAULT: usize = 2;

/// Save `params` under `dir` (created if needed), retaining the last
/// [`KEEP_DEFAULT`] checkpoints.
pub fn save(dir: &Path, manifest: &Manifest, params: &ParamStore, step: u64)
            -> Result<()> {
    save_retained(dir, manifest, params, step, KEEP_DEFAULT)
}

/// Save `params` under `dir` with an explicit retention depth.
///
/// Crash-safe, including when overwriting an existing checkpoint: the
/// `.bin` files are *step-qualified* (a crashed save can never alias the
/// files a previous descriptor references), every file is written to a
/// sibling temp path, fsynced, and atomically renamed, and the
/// descriptors are renamed *last* — a crash mid-save leaves the previous
/// checkpoint fully intact (plus orphaned files from the aborted save,
/// which the next successful save garbage-collects).
pub fn save_retained(dir: &Path, manifest: &Manifest, params: &ParamStore,
                     step: u64, keep: usize) -> Result<()> {
    let keep = keep.max(1);
    std::fs::create_dir_all(dir.join("params"))
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut entries = Vec::new();
    for (i, e) in params.entries.iter().enumerate() {
        let host = params.fetch(i)?;
        let bytes = f32_le_bytes(&host);
        let base = format!("s{step:010}_{i:03}_{}.bin", e.name.replace('.', "_"));
        durable::write_atomic(&dir.join("params").join(&base), &bytes)?;
        entries.push(Value::obj(vec![
            ("name", Value::str(&e.name)),
            ("shape", Value::arr(e.shape.iter().map(|&s| Value::i(s as i64)).collect())),
            ("bin", Value::str(format!("params/{base}"))),
            ("bytes", Value::i(bytes.len() as i64)),
            ("digest", Value::str(format!("{:016x}", fnv1a64(&bytes)))),
        ]));
    }
    // persist all bin renames with one directory fsync before the json
    // commit point (write_atomic already fsyncs each file's contents)
    durable::sync_dir(&dir.join("params"));
    let doc = Value::obj(vec![
        ("format", Value::str("tezo-checkpoint-v1")),
        ("config", Value::str(&manifest.config.name)),
        ("n_params", Value::i(manifest.config.n_params as i64)),
        ("step", Value::i(step as i64)),
        ("params", Value::arr(entries)),
    ]);
    let text = jsonx::to_string_pretty(&doc);
    // the retained step-qualified descriptor first, then the `current`
    // pointer — both atomic, so any crash point leaves a loadable state
    durable::write_atomic(&dir.join(retained_name(step)), text.as_bytes())?;
    durable::write_atomic(&dir.join("checkpoint.json"), text.as_bytes())?;
    durable::sync_dir(dir);
    // the new descriptors are committed: enforce retention and drop bins
    // no retained descriptor references (older or aborted saves)
    gc_retained(dir, keep);
    Ok(())
}

fn retained_name(step: u64) -> String {
    format!("checkpoint_s{step:010}.json")
}

/// Step-qualified descriptors under `dir`, newest first.
pub fn list_retained(dir: &Path) -> Vec<(u64, String)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else { return out };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(step) = name
            .strip_prefix("checkpoint_s")
            .and_then(|s| s.strip_suffix(".json"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        out.push((step, name.to_string()));
    }
    out.sort_by(|a, b| b.0.cmp(&a.0));
    out
}

/// Descriptor names to try when loading, newest first — the retained
/// step-qualified descriptors, then the legacy/current `checkpoint.json`.
pub fn candidates(dir: &Path) -> Vec<String> {
    let mut out: Vec<String> = list_retained(dir).into_iter().map(|(_, n)| n).collect();
    if dir.join("checkpoint.json").is_file() {
        out.push("checkpoint.json".to_string());
    }
    out
}

/// Retention + GC: keep the newest `keep` retained descriptors, remove
/// the rest, then remove `params/` files referenced by no surviving
/// descriptor. Best effort: a failed removal only wastes disk, never
/// correctness.
fn gc_retained(dir: &Path, keep: usize) {
    let retained = list_retained(dir);
    for (_, name) in retained.iter().skip(keep) {
        let _ = std::fs::remove_file(dir.join(name));
    }
    // union of bins referenced by every surviving descriptor (including
    // the current pointer, which may predate retention)
    let mut kept: Vec<String> = Vec::new();
    let mut survivors: Vec<String> =
        retained.iter().take(keep).map(|(_, n)| n.clone()).collect();
    survivors.push("checkpoint.json".to_string());
    for name in &survivors {
        let Ok(text) = std::fs::read_to_string(dir.join(name)) else { continue };
        let Ok(doc) = jsonx::parse(&text) else { continue };
        let Ok(entries) = doc.get("params").and_then(|p| p.as_array()) else { continue };
        for e in entries {
            if let Ok(bin) = e.get_str("bin") {
                if let Some(base) = bin.strip_prefix("params/") {
                    kept.push(base.to_string());
                }
            }
        }
    }
    let Ok(rd) = std::fs::read_dir(dir.join("params")) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !kept.iter().any(|k| k == name) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// One parameter record of a parsed descriptor.
struct BinEntry {
    name: String,
    shape: Vec<usize>,
    bin: String,
    /// byte length + FNV-1a digest (absent in pre-PR-10 checkpoints)
    bytes: Option<u64>,
    digest: Option<String>,
}

impl BinEntry {
    fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

struct CheckpointDoc {
    config: String,
    step: u64,
    entries: Vec<BinEntry>,
}

fn parse_doc(dir: &Path, json_name: &str) -> Result<CheckpointDoc> {
    let path = dir.join(json_name);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = jsonx::parse(&text)
        .with_context(|| format!("parsing {}", path.display()))?;
    if doc.get_str("format")? != "tezo-checkpoint-v1" {
        bail!("{}: unknown checkpoint format", path.display());
    }
    let config = doc.get_str("config")?.to_string();
    let step = u64::try_from(doc.get("step")?.as_i64()?)
        .map_err(|_| anyhow::anyhow!("{}: checkpoint step is negative", path.display()))?;
    let mut entries = Vec::new();
    for e in doc.get("params")?.as_array()? {
        let mut shape = Vec::new();
        for s in e.get("shape")?.as_array()? {
            shape.push(usize::try_from(s.as_i64()?)
                .map_err(|_| anyhow::anyhow!("negative shape dim"))?);
        }
        entries.push(BinEntry {
            name: e.get_str("name")?.to_string(),
            shape,
            bin: e.get_str("bin")?.to_string(),
            bytes: e.get("bytes").ok().and_then(|v| v.as_i64().ok())
                .and_then(|v| u64::try_from(v).ok()),
            digest: e.get("digest").ok().and_then(|v| v.as_str().ok())
                .map(|s| s.to_string()),
        });
    }
    Ok(CheckpointDoc { config, step, entries })
}

/// Read one bin and verify it against its descriptor record: the file
/// must exist, match the shape's byte count, match the recorded length,
/// and hash to the recorded digest. Every mismatch is a typed contextual
/// error naming the bin.
fn read_verified_bin(dir: &Path, e: &BinEntry) -> Result<Vec<u8>> {
    let path = dir.join(&e.bin);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading checkpoint bin {} ({})",
                                 path.display(), e.name))?;
    let want_shape = e.numel() * 4;
    ensure!(bytes.len() == want_shape,
            "{}: {} bytes on disk, shape {:?} needs {}",
            path.display(), bytes.len(), e.shape, want_shape);
    if let Some(want) = e.bytes {
        ensure!(bytes.len() as u64 == want,
                "{}: {} bytes on disk, descriptor recorded {}",
                path.display(), bytes.len(), want);
    }
    if let Some(want) = &e.digest {
        let got = format!("{:016x}", fnv1a64(&bytes));
        ensure!(&got == want,
                "{}: digest {} does not match descriptor {} — bin corrupted \
                 or swapped", path.display(), got, want);
    }
    Ok(bytes)
}

/// A verified checkpoint summary (pure file inspection, no PJRT).
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub json: String,
    pub config: String,
    pub step: u64,
    pub n_bins: usize,
    pub total_bytes: u64,
    /// bins carrying a digest (0 for pre-PR-10 checkpoints: length-only)
    pub digested: usize,
}

/// Verify one descriptor and every bin it references, without touching
/// the device runtime — the `checkpoint-verify` CLI path.
pub fn verify_doc(dir: &Path, json_name: &str) -> Result<VerifyReport> {
    let doc = parse_doc(dir, json_name)?;
    let mut total = 0u64;
    let mut digested = 0usize;
    for e in &doc.entries {
        let bytes = read_verified_bin(dir, e)
            .with_context(|| format!("verifying {json_name}"))?;
        total += bytes.len() as u64;
        if e.digest.is_some() {
            digested += 1;
        }
    }
    Ok(VerifyReport {
        json: json_name.to_string(),
        config: doc.config,
        step: doc.step,
        n_bins: doc.entries.len(),
        total_bytes: total,
        digested,
    })
}

/// Verify the current checkpoint (`checkpoint.json`).
pub fn verify(dir: &Path) -> Result<VerifyReport> {
    verify_doc(dir, "checkpoint.json")
}

/// Newest descriptor under `dir` that passes full verification, or an
/// error describing why every candidate failed.
pub fn latest_verified(dir: &Path) -> Result<VerifyReport> {
    let cands = candidates(dir);
    ensure!(!cands.is_empty(), "{}: no checkpoint descriptors found", dir.display());
    let mut failures = Vec::new();
    for name in &cands {
        match verify_doc(dir, name) {
            Ok(rep) => return Ok(rep),
            Err(e) => failures.push(format!("  {name}: {e:#}")),
        }
    }
    bail!("{}: no verifiable checkpoint among {} candidate(s):\n{}",
          dir.display(), cands.len(), failures.join("\n"));
}

fn load_from_doc(dir: &Path, json_name: &str, client: &xla::PjRtClient,
                 manifest: &Manifest) -> Result<(ParamStore, u64)> {
    let doc = parse_doc(dir, json_name)?;
    ensure!(doc.config == manifest.config.name,
            "checkpoint is for config {:?}, runtime is {:?}",
            doc.config, manifest.config.name);
    ensure!(doc.entries.len() == manifest.params.len(),
            "checkpoint has {} params, manifest {}", doc.entries.len(),
            manifest.params.len());
    let mut store = ParamStore::load(client, manifest)?; // shapes/entries
    let mut bufs = Vec::with_capacity(doc.entries.len());
    for (e, p) in doc.entries.iter().zip(&manifest.params) {
        ensure!(e.name == p.name,
                "param order mismatch: {} vs {}", e.name, p.name);
        ensure!(e.numel() == p.numel(),
                "{}: checkpoint shape {:?} vs manifest numel {}",
                e.name, e.shape, p.numel());
        let bytes = read_verified_bin(dir, e)?;
        let host = f32_from_le_bytes(&bytes);
        bufs.push(client.buffer_from_host_buffer(&host, &p.shape, None)?);
    }
    store.replace_all(bufs)?;
    Ok((store, doc.step))
}

/// Restore parameters from the current checkpoint into fresh device
/// buffers, verifying length + digest of every bin. The checkpoint must
/// match the manifest's config (name + param table).
pub fn load(dir: &Path, client: &xla::PjRtClient, manifest: &Manifest)
            -> Result<(ParamStore, u64)> {
    load_from_doc(dir, "checkpoint.json", client, manifest)
}

/// Restore from the newest loadable checkpoint, falling back through the
/// retained descriptors when the current one is corrupt — the recovery
/// path behind `--resume` and guard rollback.
pub fn load_with_fallback(dir: &Path, client: &xla::PjRtClient, manifest: &Manifest)
                          -> Result<(ParamStore, u64)> {
    let cands = candidates(dir);
    ensure!(!cands.is_empty(), "{}: no checkpoint descriptors found", dir.display());
    let mut failures = Vec::new();
    for name in &cands {
        match load_from_doc(dir, name, client, manifest) {
            Ok(out) => return Ok(out),
            Err(e) => failures.push(format!("  {name}: {e:#}")),
        }
    }
    bail!("{}: every checkpoint candidate failed to load:\n{}",
          dir.display(), failures.join("\n"));
}
