//! Parameter checkpointing: save/restore the device-resident parameter set
//! as raw `.bin` files + a JSON descriptor, compatible with the AOT param
//! format (so a checkpoint can also seed a fresh run or be inspected with
//! the same tools as the shipped init).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::jsonx::{self, Value};

use super::manifest::Manifest;
use super::params::{read_f32_bin, ParamStore};

/// Save `params` under `dir` (created if needed) with run metadata.
pub fn save(dir: &Path, manifest: &Manifest, params: &ParamStore, step: u64)
            -> Result<()> {
    std::fs::create_dir_all(dir.join("params"))
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut entries = Vec::new();
    for (i, e) in params.entries.iter().enumerate() {
        let host = params.fetch(i)?;
        let fname = format!("params/{i:03}_{}.bin", e.name.replace('.', "_"));
        let mut bytes = Vec::with_capacity(host.len() * 4);
        for x in &host {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(dir.join(&fname), bytes)?;
        entries.push(Value::obj(vec![
            ("name", Value::str(&e.name)),
            ("shape", Value::arr(e.shape.iter().map(|&s| Value::i(s as i64)).collect())),
            ("bin", Value::str(&fname)),
        ]));
    }
    let doc = Value::obj(vec![
        ("format", Value::str("tezo-checkpoint-v1")),
        ("config", Value::str(&manifest.config.name)),
        ("n_params", Value::i(manifest.config.n_params as i64)),
        ("step", Value::i(step as i64)),
        ("params", Value::arr(entries)),
    ]);
    std::fs::write(dir.join("checkpoint.json"), jsonx::to_string_pretty(&doc))?;
    Ok(())
}

/// Restore parameters from a checkpoint into fresh device buffers.
/// The checkpoint must match the manifest's config (name + param table).
pub fn load(dir: &Path, client: &xla::PjRtClient, manifest: &Manifest)
            -> Result<(ParamStore, u64)> {
    let text = std::fs::read_to_string(dir.join("checkpoint.json"))
        .with_context(|| format!("reading {}/checkpoint.json", dir.display()))?;
    let doc = jsonx::parse(&text)?;
    if doc.get_str("format")? != "tezo-checkpoint-v1" {
        bail!("unknown checkpoint format");
    }
    ensure!(doc.get_str("config")? == manifest.config.name,
            "checkpoint is for config {:?}, runtime is {:?}",
            doc.get_str("config")?, manifest.config.name);
    let step = doc.get("step")?.as_i64()? as u64;
    let entries = doc.get("params")?.as_array()?;
    ensure!(entries.len() == manifest.params.len(),
            "checkpoint has {} params, manifest {}", entries.len(),
            manifest.params.len());

    let mut store = ParamStore::load(client, manifest)?; // shapes/entries
    let mut bufs = Vec::with_capacity(entries.len());
    for (e, p) in entries.iter().zip(&manifest.params) {
        ensure!(e.get_str("name")? == p.name,
                "param order mismatch: {} vs {}", e.get_str("name")?, p.name);
        let host = read_f32_bin(&dir.join(e.get_str("bin")?), p.numel())?;
        bufs.push(client.buffer_from_host_buffer(&host, &p.shape, None)?);
    }
    store.replace_all(bufs)?;
    Ok((store, step))
}
