//! Persistent device staging: the host→device upload pool.
//!
//! Every host tensor an artifact call consumes (batch tensors, tau
//! vectors, scalar knobs) passes through the [`DeviceStage`]: a pool of
//! device buffers keyed by `(slot class, content fingerprint, length)`.
//! Staging the same content twice returns the SAME pooled buffer, so
//!
//! * the q-SPSA sub-forwards of one step share a single batch upload;
//! * the paired forward/update calls of a step share the staged step seed;
//! * run-constant scalars (rho) are uploaded exactly once per run;
//! * the periodic eval set is uploaded once and reused by every eval pass.
//!
//! Lifetimes are explicit: a [`StepArena`] scopes its entries to one
//! training step (entries survive one extra step so an identical re-stage
//! — the probe loop, a repeated batch — still hits, then get evicted),
//! while `persistent` arenas pin entries for the life of the runtime (the
//! eval set). [`StageStats`] counts every byte uploaded, reused, and
//! resident, which is what the per-step upload counters in
//! [`PhaseTimers`](crate::coordinator::metrics::PhaseTimers) and the bench
//! reports read.
//!
//! Reuse is sound because PJRT execution never donates input buffers in
//! this runtime (see docs/runtime.md, "buffer donation"): a staged buffer
//! stays valid until the pool drops its last `Rc`.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

/// How long a staged entry lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Epoch {
    /// pinned for the life of the pool (eval batches)
    Persistent,
    /// scoped to training step `s` (+1 step of grace, see `advance_to`)
    Step(u64),
}

/// Identity of one staged host tensor: slot class + content fingerprint
/// (seeded with the dtype tag and the shape dims, so equal-numel tensors
/// of different shape or dtype can never alias one device buffer). The
/// fingerprint is only the index — every pool hit is confirmed by a full
/// content comparison in [`DeviceStage::stage_words`], so reuse is exact,
/// not probabilistic.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct StageKey {
    class: String,
    fp: u64,
    len: usize,
}

struct StagedEntry {
    buf: Rc<xla::PjRtBuffer>,
    epoch: Cell<Epoch>,
    bytes: u64,
    /// the staged content (4-byte words, dtype-tagged bit patterns): pool
    /// hits byte-compare against this, so a fingerprint collision can
    /// never substitute one tensor for another — it falls back to an
    /// unpooled upload instead (bit-identity is load-bearing here)
    words: Vec<u32>,
}

/// Cumulative staging counters (all monotone except `resident_bytes`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageStats {
    /// host→device uploads performed
    pub uploads: u64,
    /// bytes actually moved host→device
    pub upload_bytes: u64,
    /// stagings satisfied from the pool without an upload
    pub reuses: u64,
    /// bytes those reuses would have moved
    pub reused_bytes: u64,
    /// bytes currently resident in the pool
    pub resident_bytes: u64,
    /// entries dropped by step advancement
    pub evictions: u64,
}

impl StageStats {
    /// Counter deltas since `earlier` (`resident_bytes` stays absolute).
    pub fn since(&self, earlier: &StageStats) -> StageStats {
        StageStats {
            uploads: self.uploads - earlier.uploads,
            upload_bytes: self.upload_bytes - earlier.upload_bytes,
            reuses: self.reuses - earlier.reuses,
            reused_bytes: self.reused_bytes - earlier.reused_bytes,
            resident_bytes: self.resident_bytes,
            evictions: self.evictions - earlier.evictions,
        }
    }
}

/// The per-runtime staging pool. Interior-mutable so staging composes with
/// the shared `&Runtime` the whole coordinator passes around; the PJRT
/// client stays owned by the runtime and is borrowed per arena.
#[derive(Default)]
pub struct DeviceStage {
    entries: RefCell<HashMap<StageKey, StagedEntry>>,
    current_step: Cell<Option<u64>>,
    stats: RefCell<StageStats>,
}

impl DeviceStage {
    pub(crate) fn new() -> DeviceStage {
        DeviceStage::default()
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> StageStats {
        *self.stats.borrow()
    }

    /// Count a host→device upload performed OUTSIDE the pool (the legacy
    /// positional builder's one-off stagings) so upload accounting covers
    /// every dispatch path.
    pub(crate) fn note_upload(&self, bytes: u64) {
        let mut s = self.stats.borrow_mut();
        s.uploads += 1;
        s.upload_bytes += bytes;
    }

    /// Begin (or continue) step `step`: entries staged before `step - 1`
    /// are evicted. The one-step grace window is what lets content that
    /// repeats across consecutive steps (a fixed probe batch) keep hitting
    /// the pool. A backward jump starts a new run: all step-scoped entries
    /// drop, persistent ones stay.
    fn advance_to(&self, step: u64) {
        let cur = self.current_step.get();
        if cur == Some(step) {
            return;
        }
        let new_run = matches!(cur, Some(c) if step < c);
        let mut entries = self.entries.borrow_mut();
        let mut stats = self.stats.borrow_mut();
        entries.retain(|_, e| {
            let keep = match e.epoch.get() {
                Epoch::Persistent => true,
                Epoch::Step(s) => !new_run && s + 1 >= step,
            };
            if !keep {
                stats.resident_bytes -= e.bytes;
                stats.evictions += 1;
            }
            keep
        });
        self.current_step.set(Some(step));
    }

    fn stage_words(&self, client: &xla::PjRtClient, epoch: Epoch,
                   class: String, fp: u64,
                   words: impl Iterator<Item = u32> + Clone, len: usize,
                   upload: impl FnOnce(&xla::PjRtClient) -> Result<xla::PjRtBuffer>)
                   -> Result<Rc<xla::PjRtBuffer>> {
        let bytes = (len * 4) as u64;
        let key = StageKey { class, fp, len };
        if let Some(e) = self.entries.borrow().get(&key) {
            // fingerprint hit: confirm the content really matches before
            // reusing (a collision must degrade to an extra upload, never
            // to training on the wrong tensor)
            if e.words.iter().copied().eq(words.clone()) {
                // touch: reuse extends the entry to the requesting lifetime
                match (e.epoch.get(), epoch) {
                    (Epoch::Persistent, _) => {}
                    (_, Epoch::Persistent) => e.epoch.set(Epoch::Persistent),
                    (Epoch::Step(old), Epoch::Step(new)) if new > old => {
                        e.epoch.set(Epoch::Step(new))
                    }
                    _ => {}
                }
                let mut s = self.stats.borrow_mut();
                s.reuses += 1;
                s.reused_bytes += e.bytes;
                return Ok(e.buf.clone());
            }
            // genuine 64-bit collision: bypass the pool for this staging
            let buf = Rc::new(upload(client)?);
            self.note_upload(bytes);
            return Ok(buf);
        }
        // upload outside any RefCell borrow (PJRT may re-enter the pool in
        // future backends)
        let buf = Rc::new(upload(client)?);
        let entry = StagedEntry {
            buf: buf.clone(),
            epoch: Cell::new(epoch),
            bytes,
            words: words.collect(),
        };
        {
            let mut s = self.stats.borrow_mut();
            s.uploads += 1;
            s.upload_bytes += bytes;
            s.resident_bytes += bytes;
        }
        self.entries.borrow_mut().insert(key, entry);
        Ok(buf)
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over the staging identity: a dtype tag, the shape dims, then
/// the content as 32-bit words (every staged dtype is 4 bytes). Seeding
/// with dtype + shape keeps equal-numel tensors of different geometry
/// from ever sharing a pooled buffer.
fn fingerprint(dtype: u8, shape: &[usize],
               words: impl Iterator<Item = u32>) -> u64 {
    let mut h = FNV_OFFSET;
    h ^= dtype as u64;
    h = h.wrapping_mul(FNV_PRIME);
    for &d in shape {
        h ^= d as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    for w in words {
        h ^= w as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A staging handle with a fixed lifetime: step-scoped (one per training
/// step / sub-phase) or persistent (eval sets). Cheap to construct; all
/// state lives in the shared [`DeviceStage`] pool.
pub struct StepArena<'s> {
    stage: &'s DeviceStage,
    client: &'s xla::PjRtClient,
    epoch: Epoch,
}

impl DeviceStage {
    /// Arena for step `step`; advances the pool's eviction horizon.
    pub fn step_arena<'s>(&'s self, client: &'s xla::PjRtClient,
                          step: u64) -> StepArena<'s> {
        self.advance_to(step);
        StepArena { stage: self, client, epoch: Epoch::Step(step) }
    }

    /// Arena whose entries are pinned for the life of the runtime.
    pub fn persistent_arena<'s>(&'s self, client: &'s xla::PjRtClient)
                                -> StepArena<'s> {
        StepArena { stage: self, client, epoch: Epoch::Persistent }
    }
}

impl StepArena<'_> {
    /// Stage an f32 tensor under `role/name`, reusing an identical staging
    /// if the pool already holds one.
    pub fn stage_f32(&self, role: &str, name: &str, data: &[f32],
                     shape: &[usize]) -> Result<Rc<xla::PjRtBuffer>> {
        let words = data.iter().map(|x| x.to_bits());
        let fp = fingerprint(b'f', shape, words.clone());
        self.stage.stage_words(
            self.client, self.epoch, format!("{role}.{name}"), fp, words,
            data.len(),
            |client| Ok(client.buffer_from_host_buffer(data, shape, None)?),
        )
    }

    /// Stage an i32 tensor under `role/name`.
    pub fn stage_i32(&self, role: &str, name: &str, data: &[i32],
                     shape: &[usize]) -> Result<Rc<xla::PjRtBuffer>> {
        let words = data.iter().map(|x| *x as u32);
        let fp = fingerprint(b'i', shape, words.clone());
        self.stage.stage_words(
            self.client, self.epoch, format!("{role}.{name}"), fp, words,
            data.len(),
            |client| Ok(client.buffer_from_host_buffer(data, shape, None)?),
        )
    }

    /// Stage an f32 scalar keyed by its exact bit pattern — a run-constant
    /// knob is uploaded once and reused every step thereafter.
    pub fn stage_scalar_f32(&self, name: &str, value: f32)
                            -> Result<Rc<xla::PjRtBuffer>> {
        let words = std::iter::once(value.to_bits());
        let fp = fingerprint(b'f', &[], words.clone());
        self.stage.stage_words(
            self.client, self.epoch, format!("scalar.{name}"), fp, words, 1,
            |client| Ok(client.buffer_from_host_buffer(&[value], &[], None)?),
        )
    }

    /// Stage a u32 scalar (seeds) keyed by value.
    pub fn stage_scalar_u32(&self, name: &str, value: u32)
                            -> Result<Rc<xla::PjRtBuffer>> {
        let words = std::iter::once(value);
        let fp = fingerprint(b'u', &[], words.clone());
        self.stage.stage_words(
            self.client, self.epoch, format!("scalar.{name}"), fp, words, 1,
            |client| Ok(client.buffer_from_host_buffer(&[value], &[], None)?),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_separates_content_shape_and_dtype() {
        let a = fingerprint(b'f', &[3], [1u32, 2, 3].into_iter());
        let b = fingerprint(b'f', &[3], [1u32, 2, 4].into_iter());
        let c = fingerprint(b'f', &[3], [1u32, 2, 3].into_iter());
        assert_eq!(a, c);
        assert_ne!(a, b);
        // order matters
        assert_ne!(fingerprint(b'f', &[2], [1u32, 2].into_iter()),
                   fingerprint(b'f', &[2], [2u32, 1].into_iter()));
        // equal numel, different geometry: must never alias (a [256,1024]
        // and a [512,512] staging of identical bytes are distinct buffers)
        assert_ne!(fingerprint(b'f', &[256, 1024], (0..4u32).cycle().take(64)),
                   fingerprint(b'f', &[512, 512], (0..4u32).cycle().take(64)));
        // same bits, different dtype tag: distinct
        assert_ne!(fingerprint(b'f', &[2], [7u32, 8].into_iter()),
                   fingerprint(b'i', &[2], [7u32, 8].into_iter()));
    }

    #[test]
    fn stats_delta_is_componentwise() {
        let early = StageStats { uploads: 2, upload_bytes: 100, reuses: 1,
                                 reused_bytes: 50, resident_bytes: 100,
                                 evictions: 0 };
        let late = StageStats { uploads: 5, upload_bytes: 300, reuses: 4,
                                reused_bytes: 250, resident_bytes: 120,
                                evictions: 2 };
        let d = late.since(&early);
        assert_eq!(d.uploads, 3);
        assert_eq!(d.upload_bytes, 200);
        assert_eq!(d.reuses, 3);
        assert_eq!(d.reused_bytes, 200);
        assert_eq!(d.resident_bytes, 120, "resident is absolute");
        assert_eq!(d.evictions, 2);
    }
}
