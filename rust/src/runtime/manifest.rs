//! Typed view of `artifacts/<config>/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{ForwardForm, Method};
use crate::jsonx::{self, Value};

/// Model geometry baked by the AOT pipeline.
#[derive(Clone, Debug)]
pub struct ConfigMeta {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub r_max: usize,
    pub rank_threshold: f64,
    pub use_pallas: bool,
    pub n_params: usize,
    pub init_seed: i64,
}

/// One parameter tensor: name, shape, and its raw f32 init file.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub bin: String,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_matrix(&self) -> bool {
        self.shape.len() == 2
    }
}

/// Eq.(7) rank schedule entry for one 2D weight.
#[derive(Clone, Debug)]
pub struct MatrixRank {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub rank: usize,
}

/// One artifact input/output slot.
#[derive(Clone, Debug)]
pub struct IoDesc {
    pub role: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One HLO artifact + its calling convention.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
    /// `"materialize"` / `"implicit"` for two-point loss artifacts (which
    /// compiled forward form this file encodes); `None` for everything else.
    pub forward_form: Option<String>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ConfigMeta,
    pub params: Vec<ParamEntry>,
    pub matrix_ranks: Vec<MatrixRank>,
    pub lozo_rank: usize,
    pub subzo_rank: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let v = jsonx::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_value(dir.to_path_buf(), &v)
    }

    fn from_value(dir: PathBuf, v: &Value) -> Result<Manifest> {
        let c = v.get("config")?;
        let config = ConfigMeta {
            name: c.get_str("name")?.to_string(),
            d_model: c.get_usize("d_model")?,
            n_layers: c.get_usize("n_layers")?,
            n_heads: c.get_usize("n_heads")?,
            d_ff: c.get_usize("d_ff")?,
            vocab: c.get_usize("vocab")?,
            seq_len: c.get_usize("seq_len")?,
            batch: c.get_usize("batch")?,
            r_max: c.get_usize("r_max")?,
            rank_threshold: c.get_f64("rank_threshold")?,
            use_pallas: c.get("use_pallas")?.as_bool()?,
            n_params: c.get_usize("n_params")?,
            init_seed: c.get("init_seed")?.as_i64()?,
        };
        let mut params = Vec::new();
        for p in v.get("params")?.as_array()? {
            params.push(ParamEntry {
                name: p.get_str("name")?.to_string(),
                shape: shape_of(p.get("shape")?)?,
                bin: p.get_str("bin")?.to_string(),
            });
        }
        let mut matrix_ranks = Vec::new();
        for r in v.get("matrix_ranks")?.as_array()? {
            matrix_ranks.push(MatrixRank {
                name: r.get_str("name")?.to_string(),
                m: r.get_usize("m")?,
                n: r.get_usize("n")?,
                rank: r.get_usize("rank")?,
            });
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.get("artifacts")?.as_object()? {
            artifacts.insert(name.clone(), ArtifactMeta {
                file: a.get_str("file")?.to_string(),
                inputs: io_list(a.get("inputs")?)?,
                outputs: io_list(a.get("outputs")?)?,
                // optional: manifests from before the implicit forward
                // (and non-loss artifacts) carry no tag
                forward_form: a.get("forward_form").ok()
                    .and_then(|v| v.as_str().ok())
                    .map(str::to_string),
            });
        }
        Ok(Manifest {
            dir,
            config,
            params,
            matrix_ranks,
            lozo_rank: v.get_usize("lozo_rank")?,
            subzo_rank: v.get_usize("subzo_rank")?,
            artifacts,
        })
    }

    /// The two-point loss artifact `method` dispatches under `form`.
    ///
    /// Only the low-rank families (TeZO, LOZO) ship an implicit factor-form
    /// artifact; everything else resolves to its materialized loss
    /// regardless of `form`. Requesting `Implicit` against a manifest built
    /// before the implicit artifacts existed falls back to the materialized
    /// name (the knob selects among what the manifest *has*), so old
    /// artifact dirs keep working with the new default.
    pub fn loss_artifact(&self, method: Method, form: ForwardForm) -> &'static str {
        let (materialized, implicit): (&'static str, Option<&'static str>) = match method {
            Method::Tezo | Method::TezoM | Method::TezoAdam => {
                ("tezo_loss_pm", Some("tezo_loss_pm_implicit"))
            }
            Method::Lozo | Method::LozoM => {
                ("lozo_loss_pm", Some("lozo_loss_pm_implicit"))
            }
            Method::Mezo | Method::MezoM | Method::MezoAdam => ("mezo_loss_pm", None),
            Method::Subzo => ("subzo_loss_pm", None),
            Method::ZoAdamu => ("adamu_loss_pm", None),
            Method::FoAdam => ("fo_valgrad", None),
        };
        match (form, implicit) {
            (ForwardForm::Implicit, Some(name)) if self.artifacts.contains_key(name) => name,
            _ => materialized,
        }
    }

    /// The artifacts `method` dispatches during training under `form`, in a
    /// stable order (loss before update, lazy-factor initializers first).
    /// This is the warmup contract: [`Runtime::warmup_method`] precompiles
    /// exactly this set, so first-step latency no longer depends on which
    /// artifact happens to run first. Errors if the manifest is missing any
    /// of them.
    ///
    /// [`Runtime::warmup_method`]: super::client::Runtime::warmup_method
    pub fn method_artifacts(&self, method: Method,
                            form: ForwardForm) -> Result<Vec<&'static str>> {
        let loss = self.loss_artifact(method, form);
        let names: Vec<&'static str> = match method {
            Method::Mezo => vec![loss, "mezo_update_sgd"],
            Method::MezoM => vec![loss, "mezo_update_m"],
            Method::MezoAdam => vec![loss, "mezo_update_adam"],
            Method::Lozo => vec!["lozo_init_u", loss, "lozo_update_sgd"],
            Method::LozoM => vec!["lozo_init_u", loss, "lozo_update_m"],
            Method::Subzo => vec!["subzo_factors", loss, "subzo_update"],
            Method::ZoAdamu => vec![loss, "adamu_update"],
            Method::Tezo | Method::TezoM => vec![loss, "tezo_update_factor"],
            Method::TezoAdam => vec![loss, "tezo_update_adam"],
            Method::FoAdam => vec![loss, "fo_adam_update"],
        };
        for n in &names {
            self.artifact(n)?;
        }
        Ok(names)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest (have: {:?})",
                                           self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Rank of a named matrix parameter.
    pub fn rank_of(&self, name: &str) -> Result<usize> {
        self.matrix_ranks
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.rank)
            .ok_or_else(|| anyhow::anyhow!("no rank entry for {name:?}"))
    }

    /// Matrix parameters in param order (the factor-list convention).
    pub fn matrix_params(&self) -> Vec<&ParamEntry> {
        self.params.iter().filter(|p| p.is_matrix()).collect()
    }

    pub fn vector_params(&self) -> Vec<&ParamEntry> {
        self.params.iter().filter(|p| !p.is_matrix()).collect()
    }
}

fn shape_of(v: &Value) -> Result<Vec<usize>> {
    v.as_array()?.iter().map(|x| x.as_usize()).collect()
}

fn io_list(v: &Value) -> Result<Vec<IoDesc>> {
    let mut out = Vec::new();
    for d in v.as_array()? {
        let dtype = d.get_str("dtype")?;
        if !matches!(dtype, "f32" | "i32" | "u32") {
            bail!("unsupported dtype {dtype:?}");
        }
        out.push(IoDesc {
            role: d.get_str("role")?.to_string(),
            name: d.get_str("name")?.to_string(),
            shape: shape_of(d.get("shape")?)?,
            dtype: dtype.to_string(),
        });
    }
    Ok(out)
}
