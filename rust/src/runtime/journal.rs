//! The step journal: an append-only, fsynced write-ahead log of
//! `(step, sub, perturb_seed, kappa)` records.
//!
//! Because every ZO update is fully described by its perturbation seed
//! plus one scalar (the resampling trick — see docs/fleet.md), this tiny
//! log plus the last checkpoint *is* the complete training state. The
//! single-process trainer and the fleet coordinator both write through it
//! (WAL ordering: a record is durable before its update is applied or
//! broadcast), which is what makes `--resume` and coordinator restart
//! reproduce an uninterrupted run bitwise. See docs/robustness.md for the
//! failure model.
//!
//! ## On-disk format (all little-endian)
//!
//! ```text
//! header:  "TEZOJRNL" (8)  | version u32 (=1) | run seed u64      = 20 B
//! frame:   payload_len u32 | payload (21 B)   | fnv1a64(payload)  = 33 B
//! payload: step u64 | sub u32 | perturb_seed u32 | tag u8 | kappa bits u32
//! ```
//!
//! `tag` is 1 for an applied update (kappa meaningful) and 0 for a
//! lockstep skip (kappa bits are zero). Recovery scans frames from the
//! front and truncates the file at the first short, oversized, or
//! checksum-failing frame — a kill -9 mid-append loses at most the torn
//! tail, never a committed record.

use std::fs::File;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::durable;

const MAGIC: &[u8; 8] = b"TEZOJRNL";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 20;
const PAYLOAD_LEN: usize = 21;
const FRAME_LEN: usize = 4 + PAYLOAD_LEN + 8;

/// One journaled sub-step: the complete description of one ZO update
/// (`kappa = None` records a lockstep-skipped update).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JournalEntry {
    pub step: u64,
    pub sub: u32,
    pub perturb_seed: u32,
    pub kappa: Option<f32>,
}

/// FNV-1a 64-bit (the same digest the checkpoint manifest and the
/// autotuner fingerprint use — one hash for the whole durability layer).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn rd_u32(b: &[u8], off: usize) -> Option<u32> {
    b.get(off..off + 4)
        .and_then(|s| <[u8; 4]>::try_from(s).ok())
        .map(u32::from_le_bytes)
}

fn rd_u64(b: &[u8], off: usize) -> Option<u64> {
    b.get(off..off + 8)
        .and_then(|s| <[u8; 8]>::try_from(s).ok())
        .map(u64::from_le_bytes)
}

fn header_bytes(seed: u64) -> Vec<u8> {
    let mut h = Vec::with_capacity(HEADER_LEN);
    h.extend_from_slice(MAGIC);
    h.extend_from_slice(&VERSION.to_le_bytes());
    h.extend_from_slice(&seed.to_le_bytes());
    h
}

fn encode_frame(e: &JournalEntry) -> Vec<u8> {
    let mut payload = Vec::with_capacity(PAYLOAD_LEN);
    payload.extend_from_slice(&e.step.to_le_bytes());
    payload.extend_from_slice(&e.sub.to_le_bytes());
    payload.extend_from_slice(&e.perturb_seed.to_le_bytes());
    match e.kappa {
        Some(k) => {
            payload.push(1);
            payload.extend_from_slice(&k.to_bits().to_le_bytes());
        }
        None => {
            payload.push(0);
            payload.extend_from_slice(&0u32.to_le_bytes());
        }
    }
    let mut frame = Vec::with_capacity(FRAME_LEN);
    frame.extend_from_slice(&(PAYLOAD_LEN as u32).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    frame
}

fn decode_payload(p: &[u8]) -> Option<JournalEntry> {
    let step = rd_u64(p, 0)?;
    let sub = rd_u32(p, 8)?;
    let perturb_seed = rd_u32(p, 12)?;
    let tag = *p.get(16)?;
    let bits = rd_u32(p, 17)?;
    let kappa = match tag {
        1 => Some(f32::from_bits(bits)),
        0 => None,
        _ => return None, // unknown tag = corrupt frame
    };
    Some(JournalEntry { step, sub, perturb_seed, kappa })
}

/// Result of scanning a journal image: the decoded prefix and the byte
/// offset of the first bad frame (== image length when fully valid).
struct Scan {
    entries: Vec<JournalEntry>,
    valid_len: usize,
}

fn scan_frames(image: &[u8]) -> Scan {
    let mut entries = Vec::new();
    let mut off = HEADER_LEN;
    while off + FRAME_LEN <= image.len() {
        let Some(plen) = rd_u32(image, off) else { break };
        if plen as usize != PAYLOAD_LEN {
            break; // corrupt length word: stop here
        }
        let Some(payload) = image.get(off + 4..off + 4 + PAYLOAD_LEN) else { break };
        let Some(want) = rd_u64(image, off + 4 + PAYLOAD_LEN) else { break };
        if fnv1a64(payload) != want {
            break; // bit flip or torn checksum
        }
        let Some(e) = decode_payload(payload) else { break };
        entries.push(e);
        off += FRAME_LEN;
    }
    Scan { entries, valid_len: off }
}

/// An open journal positioned for appending.
pub struct Journal {
    path: PathBuf,
    seed: u64,
    file: File,
    entries_len: usize,
}

impl Journal {
    /// Open (or create) the journal at `path` for run seed `seed`,
    /// returning the handle plus every committed entry.
    ///
    /// Recovery is torn-tail-tolerant: the file is scanned frame by frame
    /// and physically truncated at the first bad frame, so a crash
    /// mid-append costs exactly the record being written. A journal whose
    /// header names a different run seed is a typed error — replaying
    /// another run's kappas would corrupt silently.
    pub fn open(path: &Path, seed: u64) -> Result<(Journal, Vec<JournalEntry>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        if !path.exists() {
            durable::write_atomic(path, &header_bytes(seed))
                .with_context(|| format!("creating journal {}", path.display()))?;
            if let Some(parent) = path.parent() {
                durable::sync_dir(parent);
            }
        }
        let image = std::fs::read(path)
            .with_context(|| format!("reading journal {}", path.display()))?;
        ensure!(image.len() >= HEADER_LEN && image.get(..8) == Some(MAGIC.as_slice()),
                "{}: not a tezo journal (bad magic or short header)",
                path.display());
        let version = rd_u32(&image, 8)
            .ok_or_else(|| anyhow::anyhow!("{}: short header", path.display()))?;
        ensure!(version == VERSION,
                "{}: journal version {version}, expected {VERSION}", path.display());
        let file_seed = rd_u64(&image, 12)
            .ok_or_else(|| anyhow::anyhow!("{}: short header", path.display()))?;
        ensure!(file_seed == seed,
                "{}: journal belongs to run seed {file_seed}, this run is {seed}",
                path.display());

        let scan = scan_frames(&image);
        if scan.valid_len < image.len() {
            // torn or corrupt tail: truncate it away so appends extend a
            // clean frame boundary
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("opening {} to truncate tail", path.display()))?;
            f.set_len(scan.valid_len as u64)
                .with_context(|| format!("truncating {} to {} bytes",
                                         path.display(), scan.valid_len))?;
            f.sync_all()
                .with_context(|| format!("syncing truncated {}", path.display()))?;
        }
        let file = durable::open_append(path)?;
        let j = Journal {
            path: path.to_path_buf(),
            seed,
            file,
            entries_len: scan.entries.len(),
        };
        Ok((j, scan.entries))
    }

    /// Read-only recovery: committed entries without taking the append
    /// handle (coordinator restart inspects the journal before staffing).
    pub fn read(path: &Path, seed: u64) -> Result<Vec<JournalEntry>> {
        let (_, entries) = Journal::open(path, seed)?;
        Ok(entries)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Committed entries (recovered + appended this process).
    pub fn len(&self) -> usize {
        self.entries_len
    }

    pub fn is_empty(&self) -> bool {
        self.entries_len == 0
    }

    /// Append one entry durably (frame write + fsync). WAL contract: only
    /// apply/broadcast the update after this returns Ok.
    pub fn append(&mut self, e: &JournalEntry) -> Result<()> {
        durable::append_sync(&mut self.file, &encode_frame(e))
            .with_context(|| format!("journaling step {} sub {}", e.step, e.sub))?;
        self.entries_len += 1;
        Ok(())
    }

    /// Rewrite the journal keeping only entries that satisfy `keep`
    /// (atomic temp+rename, then the append handle is reopened). Used for
    /// rollback (`e.step < target`) and checkpoint pruning
    /// (`e.step >= checkpoint_step`).
    fn rewrite(&mut self, keep: impl Fn(&JournalEntry) -> bool) -> Result<()> {
        let entries = Journal::read(&self.path, self.seed)?;
        let mut image = header_bytes(self.seed);
        let mut n = 0usize;
        for e in &entries {
            if keep(e) {
                image.extend_from_slice(&encode_frame(e));
                n += 1;
            }
        }
        durable::write_atomic(&self.path, &image)
            .with_context(|| format!("rewriting journal {}", self.path.display()))?;
        if let Some(parent) = self.path.parent() {
            durable::sync_dir(parent);
        }
        self.file = durable::open_append(&self.path)?;
        self.entries_len = n;
        Ok(())
    }

    /// Drop every entry at `step >= target` — the rollback path: the tail
    /// being undone must not be replayed by a later resume.
    pub fn truncate_from_step(&mut self, target: u64) -> Result<()> {
        self.rewrite(|e| e.step < target)
    }

    /// Drop every entry at `step < checkpoint_step` — the pruning path:
    /// once a checkpoint at `checkpoint_step` is durable, older records
    /// are dead weight (mirrors the fleet's in-memory log pruning).
    pub fn retain_from_step(&mut self, checkpoint_step: u64) -> Result<()> {
        self.rewrite(|e| e.step >= checkpoint_step)
    }
}

/// Analytic size of a journal holding `entries` records (header + frames)
/// — the memmodel residency term.
pub fn journal_bytes(entries: u64) -> u64 {
    HEADER_LEN as u64 + entries * FRAME_LEN as u64
}

/// The recovered journal tail split for resume: the complete steps to
/// re-apply update-only, plus the step a crash left half-journaled (if
/// any) — that one is truncated and re-run live. Shared by the
/// single-process trainer and the fleet coordinator restart path.
pub struct Replay {
    pub steps: Vec<(u64, Vec<JournalEntry>)>,
    pub partial: Option<u64>,
}

/// A step's journal footprint is complete when it ends in a skip record
/// (`kappa = None` aborts the step in lockstep) or holds all `q` applied
/// sub-perturbations.
fn group_complete(group: &[JournalEntry], q: u32) -> bool {
    group.last().map(|e| e.kappa.is_none()).unwrap_or(false)
        || group.len() as u32 == q
}

/// Group recovered entries by step and validate the invariants a
/// write-ahead log guarantees: steps contiguous from the checkpoint, subs
/// in order, skips only terminal, and at most the *last* step incomplete.
pub fn plan_replay(entries: &[JournalEntry], ckpt_step: u64, q: u32)
                   -> Result<Replay> {
    let mut steps: Vec<(u64, Vec<JournalEntry>)> = Vec::new();
    for e in entries {
        // pruning can lag one crash behind the checkpoint — drop the stale
        // prefix, the checkpoint already covers it
        if e.step < ckpt_step {
            continue;
        }
        match steps.last_mut() {
            Some((s, group)) if *s == e.step => group.push(*e),
            _ => steps.push((e.step, vec![*e])),
        }
    }
    let mut expected = ckpt_step;
    let n = steps.len();
    for (i, (s, group)) in steps.iter().enumerate() {
        ensure!(*s == expected,
                "journal gap: expected step {expected}, found step {s}");
        expected += 1;
        for (k, e) in group.iter().enumerate() {
            ensure!(e.sub as usize == k,
                    "journal step {s}: sub {} out of order (position {k})",
                    e.sub);
            ensure!(e.kappa.is_some() || k + 1 == group.len(),
                    "journal step {s}: skip record before sub {}", group.len());
        }
        ensure!(group.len() as u32 <= q,
                "journal step {s} has {} subs, config says {q} — wrong \
                 n_perturb?", group.len());
        ensure!(group_complete(group, q) || i + 1 == n,
                "journal step {s} is incomplete mid-log — wrong n_perturb?");
    }
    let partial = steps
        .last()
        .filter(|(_, g)| !group_complete(g, q))
        .map(|(s, _)| *s);
    if partial.is_some() {
        steps.pop();
    }
    Ok(Replay { steps, partial })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tezo_journal_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d.join("journal.bin")
    }

    fn e(step: u64, sub: u32, kappa: Option<f32>) -> JournalEntry {
        JournalEntry { step, sub, perturb_seed: (step as u32) ^ (sub << 8), kappa }
    }

    #[test]
    fn roundtrip_and_reopen() {
        let p = tmp("roundtrip");
        let want = vec![e(0, 0, Some(0.5)), e(0, 1, Some(-1.25)), e(1, 0, None)];
        {
            let (mut j, prior) = Journal::open(&p, 42).unwrap();
            assert!(prior.is_empty());
            for x in &want {
                j.append(x).unwrap();
            }
            assert_eq!(j.len(), 3);
        }
        let (_, got) = Journal::open(&p, 42).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn kappa_bits_survive_including_nan() {
        let p = tmp("bits");
        let nan = f32::from_bits(0x7FC0_1234);
        let (mut j, _) = Journal::open(&p, 7).unwrap();
        j.append(&e(3, 0, Some(nan))).unwrap();
        let got = Journal::read(&p, 7).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].kappa.unwrap().to_bits(), nan.to_bits());
    }

    #[test]
    fn torn_tail_is_truncated() {
        let p = tmp("torn");
        {
            let (mut j, _) = Journal::open(&p, 1).unwrap();
            j.append(&e(0, 0, Some(1.0))).unwrap();
            j.append(&e(1, 0, Some(2.0))).unwrap();
        }
        // simulate kill -9 mid-append: half a frame of garbage
        let mut img = std::fs::read(&p).unwrap();
        img.extend_from_slice(&[21, 0, 0, 0, 0xde, 0xad]);
        std::fs::write(&p, &img).unwrap();
        let (mut j, got) = Journal::open(&p, 1).unwrap();
        assert_eq!(got.len(), 2);
        // the tail was physically removed: appends extend cleanly
        j.append(&e(2, 0, Some(3.0))).unwrap();
        assert_eq!(Journal::read(&p, 1).unwrap().len(), 3);
    }

    #[test]
    fn bit_flip_truncates_at_the_flipped_frame() {
        let p = tmp("flip");
        {
            let (mut j, _) = Journal::open(&p, 1).unwrap();
            for s in 0..4 {
                j.append(&e(s, 0, Some(s as f32))).unwrap();
            }
        }
        let mut img = std::fs::read(&p).unwrap();
        // flip one payload byte inside frame 2
        let off = HEADER_LEN + 2 * FRAME_LEN + 6;
        img[off] ^= 0x40;
        std::fs::write(&p, &img).unwrap();
        let (_, got) = Journal::open(&p, 1).unwrap();
        assert_eq!(got, vec![e(0, 0, Some(0.0)), e(1, 0, Some(1.0))]);
    }

    #[test]
    fn seed_mismatch_is_a_typed_error() {
        let p = tmp("seed");
        drop(Journal::open(&p, 5).unwrap());
        let err = Journal::open(&p, 6).unwrap_err().to_string();
        assert!(err.contains("seed 5"), "{err}");
    }

    #[test]
    fn truncate_and_retain() {
        let p = tmp("trunc");
        let (mut j, _) = Journal::open(&p, 9).unwrap();
        for s in 0..6 {
            j.append(&e(s, 0, Some(s as f32))).unwrap();
        }
        j.truncate_from_step(4).unwrap();
        assert_eq!(j.len(), 4);
        j.retain_from_step(2).unwrap();
        assert_eq!(j.len(), 2);
        let got = Journal::read(&p, 9).unwrap();
        assert_eq!(got, vec![e(2, 0, Some(2.0)), e(3, 0, Some(3.0))]);
        // appends still extend the rewritten file
        j.append(&e(4, 0, Some(4.0))).unwrap();
        assert_eq!(Journal::read(&p, 9).unwrap().len(), 3);
    }

    #[test]
    fn journal_bytes_matches_frame_math() {
        assert_eq!(journal_bytes(0), 20);
        assert_eq!(journal_bytes(10), 20 + 10 * 33);
    }

    #[test]
    fn plan_replay_splits_complete_and_partial() {
        // steps 4,5 complete (q=2); step 6 interrupted after sub 0
        let entries = vec![
            e2(4, 0, Some(0.1)), e2(4, 1, Some(0.2)),
            e2(5, 0, None),
            e2(6, 0, Some(0.3)),
        ];
        let r = plan_replay(&entries, 4, 2).unwrap();
        assert_eq!(r.steps.len(), 2);
        assert_eq!(r.steps.first().map(|(s, _)| *s), Some(4));
        assert_eq!(r.steps.last().map(|(s, _)| *s), Some(5));
        assert_eq!(r.partial, Some(6));
    }

    #[test]
    fn plan_replay_drops_prefix_below_checkpoint() {
        let entries = vec![
            e2(2, 0, Some(0.1)),
            e2(3, 0, Some(0.2)),
            e2(4, 0, Some(0.3)),
        ];
        let r = plan_replay(&entries, 3, 1).unwrap();
        assert_eq!(r.steps.len(), 2);
        assert_eq!(r.steps.first().map(|(s, _)| *s), Some(3));
        assert_eq!(r.partial, None);
    }

    #[test]
    fn plan_replay_rejects_gaps_and_disorder() {
        let gap = vec![e2(0, 0, Some(0.1)), e2(2, 0, Some(0.2))];
        assert!(plan_replay(&gap, 0, 1).is_err());
        let disorder = vec![e2(0, 1, Some(0.1))];
        assert!(plan_replay(&disorder, 0, 2).is_err());
        // q=2: step 0 has one applied sub of two and is not last → error
        let mid_incomplete = vec![e2(0, 0, Some(0.1)), e2(1, 0, Some(0.2)),
                                  e2(1, 1, Some(0.3))];
        assert!(plan_replay(&mid_incomplete, 0, 2).is_err());
    }

    #[test]
    fn plan_replay_empty_journal_is_fresh_start() {
        let r = plan_replay(&[], 7, 2).unwrap();
        assert!(r.steps.is_empty());
        assert_eq!(r.partial, None);
    }

    /// entry with a fixed seed — `plan_replay` never reads the seed field
    fn e2(step: u64, sub: u32, kappa: Option<f32>) -> JournalEntry {
        JournalEntry { step, sub, perturb_seed: 0, kappa }
    }
}
