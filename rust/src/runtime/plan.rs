//! Prepared calls: the plan-once dispatch layer.
//!
//! A [`CallPlan`] is the per-artifact half of the calling convention,
//! resolved ONCE from the manifest: named slots mapped to positions, dtype
//! strings parsed, element counts precomputed, and every validation rule
//! hoisted out of the training hot loop. Plans are cached by the
//! [`Runtime`] next to the compiled executables, so a steady-state step is
//! two hash lookups plus pure binding — no manifest walking, no string
//! dtype comparisons, no per-slot re-derivation.
//!
//! A [`PreparedCall`] binds values against a plan *by name* — `(role,
//! name)` or `(role, occurrence)` — instead of by hand-ordered position,
//! which is what lets every optimizer driver state its convention
//! declaratively and lets host tensors flow through the
//! [`StepArena`](super::stage::StepArena) so each one is uploaded at most
//! once per step. The plan is backend-neutral: nothing in it references
//! PJRT until `run()`.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, ensure, Result};

use super::client::Runtime;
use super::manifest::ArtifactMeta;
use super::stage::StepArena;

/// The dtypes the AOT pipeline emits (manifest `io_list` enforces the same
/// closed set at load time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::U32 => "u32",
        }
    }

    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }
}

/// One input slot of the plan (the resolved form of a manifest `IoDesc`).
#[derive(Clone, Debug)]
pub struct PlanSlot {
    pub role: String,
    pub name: String,
    pub shape: Vec<usize>,
    /// precomputed element count (1 for scalars)
    pub numel: usize,
    pub dtype: Dtype,
}

/// The resolved calling convention of one artifact.
///
/// Construction is pure over [`ArtifactMeta`] (no runtime, no device), so
/// the validation rules are property-testable offline; the error messages
/// here are THE argument-validation errors of the runtime — the legacy
/// positional [`CallBuilder`](super::exec::CallBuilder) delegates to these
/// same checks.
#[derive(Debug)]
pub struct CallPlan {
    /// artifact name (used in every error message)
    pub name: String,
    slots: Vec<PlanSlot>,
    /// role -> positions in slot order (e.g. all `param` or `tau` slots);
    /// name lookup scans the (small) group, so steady-state binding
    /// allocates nothing
    by_role: HashMap<String, Vec<usize>>,
    n_outputs: usize,
}

impl CallPlan {
    /// Resolve `meta` into a plan. Fails on unknown dtypes or duplicate
    /// `(role, name)` slots — both are manifest bugs worth failing loudly.
    pub fn new(name: &str, meta: &ArtifactMeta) -> Result<CallPlan> {
        let mut slots: Vec<PlanSlot> = Vec::with_capacity(meta.inputs.len());
        let mut by_role: HashMap<String, Vec<usize>> = HashMap::new();
        for (pos, d) in meta.inputs.iter().enumerate() {
            let slot = PlanSlot {
                role: d.role.clone(),
                name: d.name.clone(),
                shape: d.shape.clone(),
                numel: d.shape.iter().product(),
                dtype: Dtype::parse(&d.dtype)?,
            };
            let group = by_role.entry(slot.role.clone()).or_default();
            ensure!(
                group.iter().all(|&p| slots[p].name != slot.name),
                "{name}: duplicate slot {}/{}", slot.role, slot.name
            );
            group.push(pos);
            slots.push(slot);
        }
        Ok(CallPlan {
            name: name.to_string(),
            slots,
            by_role,
            n_outputs: meta.outputs.len(),
        })
    }

    /// Number of input slots.
    pub fn arity(&self) -> usize {
        self.slots.len()
    }

    pub fn slot(&self, pos: usize) -> &PlanSlot {
        debug_assert!(pos < self.slots.len(), "slot {pos} out of range");
        &self.slots[pos]
    }

    /// Slot at `pos`, or the legacy too-many-arguments error.
    pub fn next_slot(&self, pos: usize) -> Result<&PlanSlot> {
        self.slots.get(pos).ok_or_else(|| {
            anyhow::anyhow!("{}: too many arguments (expects {})",
                            self.name, self.slots.len())
        })
    }

    /// Position of the `(role, name)` slot (allocation-free: a hash lookup
    /// on the role plus a scan of that role's group).
    pub fn position(&self, role: &str, name: &str) -> Result<usize> {
        self.by_role
            .get(role)
            .and_then(|ps| ps.iter().copied().find(|&p| self.slots[p].name == name))
            .ok_or_else(|| anyhow::anyhow!("{}: no {role}/{name} slot", self.name))
    }

    /// Positions of every slot with `role`, in convention order (empty when
    /// the artifact has none).
    pub fn role_positions(&self, role: &str) -> &[usize] {
        self.by_role.get(role).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Validate a host tensor against slot `pos` (dtype, then length).
    pub fn check_host(&self, pos: usize, got: Dtype, len: usize) -> Result<()> {
        let desc = &self.slots[pos];
        ensure!(desc.dtype == got, "{}: slot {} ({}) wants {}, got {}",
                self.name, pos, desc.name, desc.dtype.name(), got.name());
        ensure!(len == desc.numel, "{}: slot {} ({}) wants {} elems, got {}",
                self.name, pos, desc.name, desc.numel, len);
        Ok(())
    }

    /// Validate that slot `pos` is a scalar of `got`.
    pub fn check_scalar(&self, pos: usize, got: Dtype) -> Result<()> {
        let desc = &self.slots[pos];
        let article = if got == Dtype::U32 { "a" } else { "an" };
        ensure!(desc.dtype == got && desc.numel == 1,
                "{}: slot {} ({}) is not {article} {} scalar", self.name, pos,
                desc.name, got.name());
        Ok(())
    }

    /// Validate the bound-argument count before execution.
    pub fn check_arity(&self, bound: usize) -> Result<()> {
        ensure!(bound == self.slots.len(),
                "{}: got {} args, artifact expects {}",
                self.name, bound, self.slots.len());
        Ok(())
    }

    /// Validate the executable's output count against the manifest.
    pub fn check_outputs(&self, got: usize) -> Result<()> {
        ensure!(got == self.n_outputs,
                "{}: got {} outputs, manifest says {} (untuple patch missing?)",
                self.name, got, self.n_outputs);
        Ok(())
    }
}

/// One bound argument.
enum BoundSlot<'c> {
    Empty,
    /// a caller-owned device buffer (params, factor panels, moment state)
    Borrowed(&'c xla::PjRtBuffer),
    /// a pooled staged buffer (host data routed through the arena)
    Staged(Rc<xla::PjRtBuffer>),
}

/// A call being bound against a [`CallPlan`].
///
/// Obtained from [`Runtime::prepared`]; slots are addressed by name, may be
/// bound in any order, and each exactly once. `run()` checks completeness
/// with the same arity error the positional builder used.
pub struct PreparedCall<'c> {
    rt: &'c Runtime,
    plan: Rc<CallPlan>,
    bound: Vec<BoundSlot<'c>>,
    n_bound: usize,
}

impl Runtime {
    /// Start a named-slot call to `artifact` (plan + executable both come
    /// from the per-runtime caches; see [`Runtime::warmup`]).
    pub fn prepared(&self, artifact: &str) -> Result<PreparedCall<'_>> {
        let plan = self.plan(artifact)?;
        let mut bound = Vec::with_capacity(plan.arity());
        bound.resize_with(plan.arity(), || BoundSlot::Empty);
        Ok(PreparedCall { rt: self, plan, bound, n_bound: 0 })
    }
}

impl<'c> PreparedCall<'c> {
    pub fn plan(&self) -> &CallPlan {
        &self.plan
    }

    fn set(&mut self, pos: usize, value: BoundSlot<'c>) -> Result<()> {
        ensure!(matches!(self.bound[pos], BoundSlot::Empty),
                "{}: slot {} ({}) bound twice", self.plan.name, pos,
                self.plan.slot(pos).name);
        self.bound[pos] = value;
        self.n_bound += 1;
        Ok(())
    }

    /// Bind a caller-owned device buffer to the `(role, name)` slot.
    pub fn bind_buf(&mut self, role: &str, name: &str,
                    buf: &'c xla::PjRtBuffer) -> Result<&mut Self> {
        let pos = self.plan.position(role, name)?;
        self.set(pos, BoundSlot::Borrowed(buf))?;
        Ok(self)
    }

    /// Bind one device buffer per slot of `role`, in convention order —
    /// e.g. the whole parameter list, or the U factor panels.
    pub fn bind_bufs<'b: 'c, I>(&mut self, role: &str, bufs: I) -> Result<&mut Self>
    where
        I: IntoIterator<Item = &'b xla::PjRtBuffer>,
    {
        // clone the Rc (not the position vector) so the plan outlives the
        // &mut self borrows below without a per-call allocation
        let plan = Rc::clone(&self.plan);
        let positions = plan.role_positions(role);
        let mut n = 0usize;
        for buf in bufs {
            ensure!(n < positions.len(), "{}: role {role:?} has {} slots, got more buffers",
                    plan.name, positions.len());
            self.set(positions[n], BoundSlot::Borrowed(buf))?;
            n += 1;
        }
        ensure!(n == positions.len(), "{}: role {role:?} has {} slots, got {} buffers",
                plan.name, positions.len(), n);
        Ok(self)
    }

    /// Bind an already-staged pooled buffer to the `(role, name)` slot.
    pub fn bind_staged(&mut self, role: &str, name: &str,
                       buf: Rc<xla::PjRtBuffer>) -> Result<&mut Self> {
        let pos = self.plan.position(role, name)?;
        self.set(pos, BoundSlot::Staged(buf))?;
        Ok(self)
    }

    /// Stage + bind a host f32 tensor to the `(role, name)` slot. The arena
    /// dedupes the upload: identical content staged earlier this step (or
    /// persistently) is reused without touching the device.
    pub fn bind_f32(&mut self, role: &str, name: &str, data: &[f32],
                    arena: &StepArena) -> Result<&mut Self> {
        let pos = self.plan.position(role, name)?;
        self.stage_f32_at(pos, data, arena)
    }

    /// Stage + bind a host f32 tensor to the `idx`-th slot of `role` (the
    /// per-matrix factor groups: `tau`, `tau_eff`, `tau_m`, `tau_v`).
    pub fn bind_nth_f32(&mut self, role: &str, idx: usize, data: &[f32],
                        arena: &StepArena) -> Result<&mut Self> {
        let positions = self.plan.role_positions(role);
        ensure!(idx < positions.len(), "{}: role {role:?} has {} slots, index {idx}",
                self.plan.name, positions.len());
        let pos = positions[idx];
        self.stage_f32_at(pos, data, arena)
    }

    fn stage_f32_at(&mut self, pos: usize, data: &[f32],
                    arena: &StepArena) -> Result<&mut Self> {
        self.plan.check_host(pos, Dtype::F32, data.len())?;
        let slot = self.plan.slot(pos);
        let buf = arena.stage_f32(&slot.role, &slot.name, data, &slot.shape)?;
        self.set(pos, BoundSlot::Staged(buf))?;
        Ok(self)
    }

    /// Stage + bind a host i32 tensor to the `(role, name)` slot.
    pub fn bind_i32(&mut self, role: &str, name: &str, data: &[i32],
                    arena: &StepArena) -> Result<&mut Self> {
        let pos = self.plan.position(role, name)?;
        self.plan.check_host(pos, Dtype::I32, data.len())?;
        let slot = self.plan.slot(pos);
        let buf = arena.stage_i32(&slot.role, &slot.name, data, &slot.shape)?;
        self.set(pos, BoundSlot::Staged(buf))?;
        Ok(self)
    }

    /// Stage + bind an f32 scalar (role `scalar`). Run-constant scalars
    /// (rho) stay resident in the pool for the whole run.
    pub fn bind_scalar_f32(&mut self, name: &str, value: f32,
                           arena: &StepArena) -> Result<&mut Self> {
        let pos = self.plan.position("scalar", name)?;
        self.plan.check_scalar(pos, Dtype::F32)?;
        let buf = arena.stage_scalar_f32(name, value)?;
        self.set(pos, BoundSlot::Staged(buf))?;
        Ok(self)
    }

    /// Stage + bind a u32 scalar (the step seeds). The forward and update
    /// halves of a step share one staged seed buffer.
    pub fn bind_scalar_u32(&mut self, name: &str, value: u32,
                           arena: &StepArena) -> Result<&mut Self> {
        let pos = self.plan.position("scalar", name)?;
        self.plan.check_scalar(pos, Dtype::U32)?;
        let buf = arena.stage_scalar_u32(name, value)?;
        self.set(pos, BoundSlot::Staged(buf))?;
        Ok(self)
    }

    /// Execute; returns the output buffers (replica 0). Staged pool buffers
    /// are kept alive by their `Rc` for the duration of the call.
    pub fn run(self) -> Result<Vec<xla::PjRtBuffer>> {
        use anyhow::Context;
        self.plan.check_arity(self.n_bound)?;
        let exe = self.rt.executable(&self.plan.name)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.bound.len());
        for (pos, b) in self.bound.iter().enumerate() {
            match b {
                BoundSlot::Borrowed(x) => args.push(*x),
                BoundSlot::Staged(rc) => args.push(rc.as_ref()),
                // check_arity + bind-once should make this impossible, but a
                // plan bug must fail the call, not abort the run
                BoundSlot::Empty => bail!(
                    "{}: slot {pos} unbound after arity check (plan bug)",
                    self.plan.name
                ),
            }
        }
        let mut out = exe
            .execute_b(&args)
            .with_context(|| format!("executing {}", self.plan.name))?;
        if out.is_empty() {
            bail!("{}: no replica outputs", self.plan.name);
        }
        let row = out.swap_remove(0);
        self.plan.check_outputs(row.len())?;
        Ok(row)
    }
}
