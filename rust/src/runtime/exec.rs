//! Positional argument assembly + validated execution of artifacts.
//!
//! [`CallBuilder`] is the positional convenience API (tests, benches,
//! one-off analysis calls): arguments are appended in manifest order and
//! validated as they go. Since the prepared-call refactor it is a thin
//! layer over [`CallPlan`](super::plan::CallPlan) — every validation rule
//! and error message comes from the plan, so the positional and named
//! dispatch paths cannot drift. The training hot loop uses
//! [`PreparedCall`](super::plan::PreparedCall) instead, which adds
//! named-slot binding and pooled staging.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::client::Runtime;
use super::plan::{CallPlan, Dtype};

/// One argument value supplied by the coordinator.
pub enum ArgValue<'a> {
    /// An existing device buffer (params, factor panels, optimizer state).
    Buf(&'a xla::PjRtBuffer),
    /// Host f32 tensor (uploaded for this call).
    F32(&'a [f32]),
    /// Host i32 tensor.
    I32(&'a [i32]),
    /// f32 scalar.
    ScalarF32(f32),
    /// u32 scalar (seeds).
    ScalarU32(u32),
}

/// Assembles the positional argument list for one artifact call.
pub struct CallBuilder<'rt> {
    rt: &'rt Runtime,
    plan: Rc<CallPlan>,
    /// staged device buffers for host-supplied args (kept alive here)
    staged: Vec<xla::PjRtBuffer>,
    /// (position, Staged(idx) | Borrowed(ptr))
    slots: Vec<Slot<'rt>>,
}

enum Slot<'a> {
    Borrowed(&'a xla::PjRtBuffer),
    Staged(usize),
}

impl Runtime {
    /// Start building a positional call to `artifact`.
    pub fn call(&self, artifact: &str) -> Result<CallBuilder<'_>> {
        Ok(CallBuilder {
            rt: self,
            plan: self.plan(artifact)?,
            staged: Vec::new(),
            slots: Vec::new(),
        })
    }
}

impl<'rt> CallBuilder<'rt> {
    /// Keep an uploaded one-off buffer alive, counting its bytes in the
    /// runtime's staging stats (so legacy and prepared dispatch are
    /// measured on the same scale).
    fn push_staged(&mut self, buf: xla::PjRtBuffer, elems: usize) {
        self.rt.stage().note_upload((elems * 4) as u64);
        self.staged.push(buf);
        self.slots.push(Slot::Staged(self.staged.len() - 1));
    }

    /// Append one argument (must match the next manifest slot).
    pub fn arg(mut self, value: ArgValue<'rt>) -> Result<Self> {
        let pos = self.slots.len();
        self.plan.next_slot(pos)?;
        match value {
            ArgValue::Buf(b) => {
                self.slots.push(Slot::Borrowed(b));
            }
            ArgValue::F32(data) => {
                self.plan.check_host(pos, Dtype::F32, data.len())?;
                let buf = self.rt.client.buffer_from_host_buffer(
                    data, &self.plan.slot(pos).shape, None)?;
                self.push_staged(buf, data.len());
            }
            ArgValue::I32(data) => {
                self.plan.check_host(pos, Dtype::I32, data.len())?;
                let buf = self.rt.client.buffer_from_host_buffer(
                    data, &self.plan.slot(pos).shape, None)?;
                self.push_staged(buf, data.len());
            }
            ArgValue::ScalarF32(x) => {
                self.plan.check_scalar(pos, Dtype::F32)?;
                let buf = self.rt.client.buffer_from_host_buffer(&[x], &[], None)?;
                self.push_staged(buf, 1);
            }
            ArgValue::ScalarU32(x) => {
                self.plan.check_scalar(pos, Dtype::U32)?;
                let buf = self.rt.client.buffer_from_host_buffer(&[x], &[], None)?;
                self.push_staged(buf, 1);
            }
        }
        Ok(self)
    }

    /// Append many buffers (e.g. the whole parameter list).
    pub fn bufs<'b: 'rt>(mut self, bufs: impl IntoIterator<Item = &'b xla::PjRtBuffer>) -> Result<Self> {
        for b in bufs {
            self = self.arg(ArgValue::Buf(b))?;
        }
        Ok(self)
    }

    /// Execute; returns the output buffers (replica 0).
    pub fn run(self) -> Result<Vec<xla::PjRtBuffer>> {
        self.plan.check_arity(self.slots.len())?;
        let exe = self.rt.executable(&self.plan.name)?;
        let args: Vec<&xla::PjRtBuffer> = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Borrowed(b) => *b,
                Slot::Staged(i) => &self.staged[*i],
            })
            .collect();
        let mut out = exe
            .execute_b(&args)
            .with_context(|| format!("executing {}", self.plan.name))?;
        if out.is_empty() {
            bail!("{}: no replica outputs", self.plan.name);
        }
        let row = out.swap_remove(0);
        self.plan.check_outputs(row.len())?;
        Ok(row)
    }
}

/// Read a scalar f32 output buffer.
pub fn scalar_f32(buf: &xla::PjRtBuffer) -> Result<f32> {
    let lit = buf.to_literal_sync()?;
    Ok(lit.get_first_element::<f32>()?)
}

/// Read the first output as a scalar f32, checking it exists.
pub fn scalar_first(out: &[xla::PjRtBuffer]) -> Result<f32> {
    scalar_f32(out.first().ok_or_else(|| anyhow::anyhow!("no output buffers"))?)
}

/// Read the leading `(f+, f-)` two-point loss pair, checking arity.
pub fn scalar_pair(out: &[xla::PjRtBuffer]) -> Result<(f32, f32)> {
    match out {
        [p, m, ..] => Ok((scalar_f32(p)?, scalar_f32(m)?)),
        _ => bail!("expected a (f+, f-) output pair, got {} buffer(s)", out.len()),
    }
}

/// Read an f32 tensor output to host.
pub fn to_vec_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync()?;
    Ok(lit.to_vec::<f32>()?)
}
