//! Argument assembly + validated execution of artifacts.
//!
//! The manifest records every artifact's positional calling convention;
//! [`CallBuilder`] assembles the argument vector in that order, validating
//! role/shape/dtype as it goes, then executes and returns the output
//! buffers (untupled by the patched xla crate — see third_party/xla).

use anyhow::{bail, ensure, Context, Result};

use super::client::Runtime;
use super::manifest::ArtifactMeta;

/// One argument value supplied by the coordinator.
pub enum ArgValue<'a> {
    /// An existing device buffer (params, factor panels, optimizer state).
    Buf(&'a xla::PjRtBuffer),
    /// Host f32 tensor (uploaded for this call).
    F32(&'a [f32]),
    /// Host i32 tensor.
    I32(&'a [i32]),
    /// f32 scalar.
    ScalarF32(f32),
    /// u32 scalar (seeds).
    ScalarU32(u32),
}

/// Assembles the positional argument list for one artifact call.
pub struct CallBuilder<'rt> {
    rt: &'rt Runtime,
    meta: &'rt ArtifactMeta,
    name: String,
    /// staged device buffers for host-supplied args (kept alive here)
    staged: Vec<xla::PjRtBuffer>,
    /// (position, Staged(idx) | Borrowed(ptr))
    slots: Vec<Slot<'rt>>,
}

enum Slot<'a> {
    Borrowed(&'a xla::PjRtBuffer),
    Staged(usize),
}

impl<'rt> Runtime {
    /// Start building a call to `artifact`.
    pub fn call(&'rt self, artifact: &str) -> Result<CallBuilder<'rt>> {
        let meta = self.manifest.artifact(artifact)?;
        Ok(CallBuilder {
            rt: self,
            meta,
            name: artifact.to_string(),
            staged: Vec::new(),
            slots: Vec::new(),
        })
    }
}

impl<'rt> CallBuilder<'rt> {
    fn next_desc(&self) -> Result<&super::manifest::IoDesc> {
        self.meta.inputs.get(self.slots.len()).ok_or_else(|| {
            anyhow::anyhow!("{}: too many arguments (expects {})",
                            self.name, self.meta.inputs.len())
        })
    }

    /// Append one argument (must match the next manifest slot).
    pub fn arg(mut self, value: ArgValue<'rt>) -> Result<Self> {
        let desc = self.next_desc()?;
        let numel: usize = desc.shape.iter().product();
        match value {
            ArgValue::Buf(b) => {
                self.slots.push(Slot::Borrowed(b));
            }
            ArgValue::F32(data) => {
                ensure!(desc.dtype == "f32", "{}: slot {} ({}) wants {}, got f32",
                        self.name, self.slots.len(), desc.name, desc.dtype);
                ensure!(data.len() == numel, "{}: slot {} ({}) wants {} elems, got {}",
                        self.name, self.slots.len(), desc.name, numel, data.len());
                let buf = self.rt.client.buffer_from_host_buffer(data, &desc.shape, None)?;
                self.staged.push(buf);
                self.slots.push(Slot::Staged(self.staged.len() - 1));
            }
            ArgValue::I32(data) => {
                ensure!(desc.dtype == "i32", "{}: slot {} ({}) wants {}, got i32",
                        self.name, self.slots.len(), desc.name, desc.dtype);
                ensure!(data.len() == numel, "{}: slot {} ({}) wants {} elems, got {}",
                        self.name, self.slots.len(), desc.name, numel, data.len());
                let buf = self.rt.client.buffer_from_host_buffer(data, &desc.shape, None)?;
                self.staged.push(buf);
                self.slots.push(Slot::Staged(self.staged.len() - 1));
            }
            ArgValue::ScalarF32(x) => {
                ensure!(desc.dtype == "f32" && numel == 1,
                        "{}: slot {} ({}) is not an f32 scalar", self.name,
                        self.slots.len(), desc.name);
                let buf = self.rt.client.buffer_from_host_buffer(&[x], &[], None)?;
                self.staged.push(buf);
                self.slots.push(Slot::Staged(self.staged.len() - 1));
            }
            ArgValue::ScalarU32(x) => {
                ensure!(desc.dtype == "u32" && numel == 1,
                        "{}: slot {} ({}) is not a u32 scalar", self.name,
                        self.slots.len(), desc.name);
                let buf = self.rt.client.buffer_from_host_buffer(&[x], &[], None)?;
                self.staged.push(buf);
                self.slots.push(Slot::Staged(self.staged.len() - 1));
            }
        }
        Ok(self)
    }

    /// Append many buffers (e.g. the whole parameter list).
    pub fn bufs<'b: 'rt>(mut self, bufs: impl IntoIterator<Item = &'b xla::PjRtBuffer>) -> Result<Self> {
        for b in bufs {
            self = self.arg(ArgValue::Buf(b))?;
        }
        Ok(self)
    }

    /// Execute; returns the output buffers (replica 0).
    pub fn run(self) -> Result<Vec<xla::PjRtBuffer>> {
        ensure!(self.slots.len() == self.meta.inputs.len(),
                "{}: got {} args, artifact expects {}",
                self.name, self.slots.len(), self.meta.inputs.len());
        let exe = self.rt.executable(&self.name)?;
        let args: Vec<&xla::PjRtBuffer> = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Borrowed(b) => *b,
                Slot::Staged(i) => &self.staged[*i],
            })
            .collect();
        let mut out = exe
            .execute_b(&args)
            .with_context(|| format!("executing {}", self.name))?;
        if out.is_empty() {
            bail!("{}: no replica outputs", self.name);
        }
        let row = out.swap_remove(0);
        ensure!(row.len() == self.meta.outputs.len(),
                "{}: got {} outputs, manifest says {} (untuple patch missing?)",
                self.name, row.len(), self.meta.outputs.len());
        Ok(row)
    }
}

/// Read a scalar f32 output buffer.
pub fn scalar_f32(buf: &xla::PjRtBuffer) -> Result<f32> {
    let lit = buf.to_literal_sync()?;
    Ok(lit.get_first_element::<f32>()?)
}

/// Read an f32 tensor output to host.
pub fn to_vec_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync()?;
    Ok(lit.to_vec::<f32>()?)
}
