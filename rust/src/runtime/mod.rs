//! PJRT runtime: loads the AOT HLO-text artifacts and executes them with
//! device-resident parameters.
//!
//! * [`manifest`] — typed view of `artifacts/<config>/manifest.json` (the
//!   calling convention emitted by `python/compile/aot.py`).
//! * [`client`] — PJRT CPU client + lazy executable cache (HLO text →
//!   `HloModuleProto::from_text_file` → compile; text is the interchange
//!   format, see DESIGN.md).
//! * [`params`] — the parameter store: every model weight lives as a
//!   `PjRtBuffer`; updates swap buffers in place, so the training hot loop
//!   never copies parameters through the host.
//! * [`exec`] — argument assembly + typed call wrappers for the artifact
//!   families (loss_pm, update, eval, grads).

pub mod checkpoint;
pub mod client;
pub mod exec;
pub mod hlo_stats;
pub mod manifest;
pub mod params;

pub use client::Runtime;
pub use exec::ArgValue;
pub use manifest::{ArtifactMeta, IoDesc, Manifest, MatrixRank, ParamEntry};
pub use params::ParamStore;
