//! PJRT runtime: loads the AOT HLO-text artifacts and executes them with
//! device-resident parameters.
//!
//! * [`manifest`] — typed view of `artifacts/<config>/manifest.json` (the
//!   calling convention emitted by `python/compile/aot.py`), including the
//!   per-method artifact lists the warmup path precompiles.
//! * [`client`] — PJRT CPU client + lazy executable/plan caches (HLO text →
//!   `HloModuleProto::from_text_file` → compile; text is the interchange
//!   format, see DESIGN.md).
//! * [`params`] — the parameter store: every model weight lives as a
//!   `PjRtBuffer`; updates swap buffers in place, so the training hot loop
//!   never copies parameters through the host.
//! * [`plan`] — prepared calls: per-artifact [`CallPlan`]s resolved once
//!   (named slots, dtypes, validation) and [`PreparedCall`] named-slot
//!   binding — the hot-loop dispatch path. See docs/runtime.md.
//! * [`stage`] — the persistent [`DeviceStage`] pool + step-scoped
//!   [`StepArena`]s: each host tensor is uploaded at most once per step and
//!   shared across the calls that consume it.
//! * [`exec`] — the positional [`CallBuilder`] convenience layer over the
//!   same plans (tests, benches, one-off calls).
//! * [`tune`] — the shape-aware forward-form autotuner: measures both
//!   two-point lowerings at warmup, pins the winner in a persisted
//!   `tuning.json` keyed by manifest fingerprint + shape, and resolves
//!   `--forward-form auto` for every dispatch layer (see docs/runtime.md).
//! * [`durable`] — the durable-IO seam (atomic replace, fsynced append,
//!   injectable failpoints); the one module allowed to create files on the
//!   hot path (lint rule `TZ-IO001`).
//! * [`journal`] — the append-only `(seed, kappa)` write-ahead log behind
//!   `--resume`, guard rollback, and coordinator restart
//!   (see docs/robustness.md).

pub mod checkpoint;
pub mod client;
pub mod durable;
pub mod exec;
pub mod hlo_stats;
pub mod journal;
pub mod manifest;
pub mod params;
pub mod plan;
pub mod stage;
pub mod tune;

pub use client::Runtime;
pub use exec::{ArgValue, CallBuilder};
pub use journal::{Journal, JournalEntry};
pub use manifest::{ArtifactMeta, IoDesc, Manifest, MatrixRank, ParamEntry};
pub use params::ParamStore;
pub use plan::{CallPlan, Dtype, PreparedCall};
pub use stage::{DeviceStage, StageStats, StepArena};
pub use tune::{Resolution, TuneSource, TuningTable};
