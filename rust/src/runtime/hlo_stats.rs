//! Lightweight HLO-text analyzer for the perf pass.
//!
//! Parses the artifact's HLO text (the interchange format) and reports the
//! structural facts the §Perf targets are stated in:
//! * op-kind histogram (how many rng ops per step, dots, fusions, ...);
//! * the largest intermediate tensor (did a full m x n Z materialize more
//!   than necessary?);
//! * total parameter-shaped temporaries.
//!
//! `tezo inspect --hlo <artifact>` prints this; the integration tests use
//! [`HloStats::count`] to assert the single-RNG-per-step and fused-update
//! properties.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed statistics over one HLO module text.
#[derive(Clone, Debug, Default)]
pub struct HloStats {
    /// op name -> occurrences (e.g. "dot", "rng-bit-generator", "fusion")
    pub ops: BTreeMap<String, usize>,
    /// total instruction count
    pub instructions: usize,
    /// largest tensor element count seen in any instruction result shape
    pub largest_tensor: u64,
    /// shape string of that tensor
    pub largest_shape: String,
}

impl HloStats {
    /// Parse HLO text.
    pub fn parse(text: &str) -> HloStats {
        let mut stats = HloStats::default();
        for line in text.lines() {
            let t = line.trim_start();
            // instruction lines look like (xla_extension 0.5.1 text form):
            //   name.N = f32[64,256]{1,0} op-name(...)
            // optionally prefixed by ROOT or % in other dialects
            let Some(eq) = t.find(" = ") else { continue };
            let lhs = t[..eq].trim_start_matches("ROOT ").trim_start_matches('%');
            if lhs.is_empty()
                || !lhs.chars().all(|c| c.is_alphanumeric() || ".-_".contains(c))
            {
                continue;
            }
            let rest = &t[eq + 3..];
            // result type, e.g. f32[64,256]{1,0} or (f32[..], f32[..])
            let (shape_part, after_shape) = match rest.find(' ') {
                Some(sp) => (&rest[..sp], &rest[sp + 1..]),
                None => continue,
            };
            // op name is the token before '('
            let op = after_shape.split('(').next().unwrap_or("").trim();
            if op.is_empty() {
                continue;
            }
            stats.instructions += 1;
            *stats.ops.entry(op.to_string()).or_insert(0) += 1;
            for (elems, shape) in parse_shapes(shape_part) {
                if elems > stats.largest_tensor {
                    stats.largest_tensor = elems;
                    stats.largest_shape = shape;
                }
            }
        }
        stats
    }

    /// Load + parse an artifact file.
    pub fn from_file(path: &Path) -> Result<HloStats> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Self::parse(&text))
    }

    /// Occurrences of ops whose name contains `needle`.
    pub fn count(&self, needle: &str) -> usize {
        self.ops
            .iter()
            .filter(|(k, _)| k.contains(needle))
            .map(|(_, v)| *v)
            .sum()
    }

    /// Top-k ops by count.
    pub fn top_ops(&self, k: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self.ops.iter()
            .map(|(a, b)| (a.clone(), *b))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(k);
        v
    }
}

/// Extract (element_count, shape_string) for every array shape in a result
/// type like `f32[64,256]{1,0}` or `(f32[2], u32[])`.
fn parse_shapes(s: &str) -> Vec<(u64, String)> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'[' {
            // find the matching ']'
            if let Some(end) = s[i + 1..].find(']') {
                let dims = &s[i + 1..i + 1 + end];
                let elems: u64 = if dims.is_empty() {
                    1
                } else {
                    dims.split(',')
                        .filter_map(|d| d.trim().parse::<u64>().ok())
                        .product()
                };
                // recover the dtype prefix
                let start = s[..i].rfind(|c: char| !c.is_alphanumeric())
                    .map(|p| p + 1)
                    .unwrap_or(0);
                out.push((elems, format!("{}[{}]", &s[start..i], dims)));
                i += end + 1;
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY main {
  %p0 = f32[64,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  %dot = f32[64,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %rng = u32[2]{0} rng-bit-generator(%p0), algorithm=rng_default
  ROOT %t = (f32[64,64]{1,0}) tuple(%dot)
}
"#;

    #[test]
    fn parses_ops_and_shapes() {
        let s = HloStats::parse(SAMPLE);
        assert_eq!(s.ops.get("dot"), Some(&1));
        assert_eq!(s.count("rng"), 1);
        assert_eq!(s.ops.get("parameter"), Some(&2));
        assert_eq!(s.largest_tensor, 64 * 256);
    }

    #[test]
    fn scalar_shapes_count_as_one() {
        let shapes = parse_shapes("f32[]");
        assert_eq!(shapes[0].0, 1);
        let shapes = parse_shapes("(f32[2,3], u32[])");
        assert_eq!(shapes[0].0, 6);
        assert_eq!(shapes[1].0, 1);
    }
}
